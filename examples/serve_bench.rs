//! E2E serving driver (EXPERIMENTS.md §E2E): start the HTTP server on the
//! trained small model, replay a synthetic request trace against it over
//! real sockets, and report latency percentiles + throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_bench
//! ```

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

use warp_cortex::cortex::{CortexConfig, WarpCortex};
use warp_cortex::model::Engine;
use warp_cortex::runtime::{DeviceHandle, DeviceOptions};
use warp_cortex::serve::{serve, ServerConfig};
use warp_cortex::text::SamplerConfig;
use warp_cortex::util::vecmath::percentile;
use warp_cortex::util::Json;
use warp_cortex::workload::{generate, Arrivals, WorkloadConfig};

fn post_generate(addr: std::net::SocketAddr, prompt: &str, max_tokens: usize) -> anyhow::Result<(usize, f64)> {
    let body = Json::obj()
        .with("prompt", prompt)
        .with("max_tokens", max_tokens)
        .to_string();
    let mut stream = TcpStream::connect(addr)?;
    let raw = format!(
        "POST /generate HTTP/1.1\r\nHost: bench\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    );
    stream.write_all(raw.as_bytes())?;
    let mut response = String::new();
    stream.read_to_string(&mut response)?;
    let payload = response
        .split("\r\n\r\n")
        .nth(1)
        .ok_or_else(|| anyhow::anyhow!("no body"))?;
    let json = Json::parse(payload).map_err(|e| anyhow::anyhow!("{e}"))?;
    if let Some(err) = json.get("error") {
        anyhow::bail!("server error: {err}");
    }
    let tokens = json.get("tokens").and_then(|v| v.as_usize()).unwrap_or(0);
    let tps = json
        .get("tokens_per_sec")
        .and_then(|v| v.as_f64())
        .unwrap_or(0.0);
    Ok((tokens, tps))
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).cloned().unwrap_or_else(|| "small".into());
    let n_requests: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(24);
    let concurrency: usize = args.get(3).and_then(|s| s.parse().ok()).unwrap_or(4);

    println!("bringing up warp-cortex server (model={model}) ...");
    let device = DeviceHandle::new(DeviceOptions::from_env().with_configs(&[&model]))?;
    let engine = Engine::new(device, &model)?;
    let cortex = Arc::new(WarpCortex::new(
        engine,
        CortexConfig {
            model: model.clone(),
            max_side_agents: 2,
            side_gen_budget: 12,
            sampler: SamplerConfig {
                temperature: 0.7,
                seed: 99,
                ..SamplerConfig::default()
            },
            ..CortexConfig::default()
        },
    )?);
    let handle = serve(
        cortex.clone(),
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers: concurrency,
            max_tokens_cap: 64,
        },
    )?;
    let addr = handle.addr;
    println!("serving on {addr}; replaying {n_requests} requests x{concurrency} workers\n");

    let trace = generate(&WorkloadConfig {
        seed: 31,
        requests: n_requests,
        arrivals: Arrivals::Burst,
        min_tokens: 16,
        max_tokens: 40,
        trigger_prob: 0.4,
    });

    let t0 = Instant::now();
    let latencies = std::sync::Mutex::new(Vec::<f64>::new());
    let total_tokens = std::sync::atomic::AtomicUsize::new(0);
    let errors = std::sync::atomic::AtomicUsize::new(0);
    let next = std::sync::atomic::AtomicUsize::new(0);

    std::thread::scope(|scope| {
        for _ in 0..concurrency {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                if i >= trace.len() {
                    return;
                }
                let req = &trace[i];
                let rt0 = Instant::now();
                match post_generate(addr, &req.prompt, req.max_tokens) {
                    Ok((tokens, _)) => {
                        total_tokens.fetch_add(tokens, std::sync::atomic::Ordering::Relaxed);
                        latencies
                            .lock()
                            .unwrap()
                            .push(rt0.elapsed().as_secs_f64() * 1e3);
                    }
                    Err(e) => {
                        eprintln!("request {i} failed: {e:#}");
                        errors.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                }
            });
        }
    });

    let wall = t0.elapsed().as_secs_f64();
    let lat = latencies.into_inner().unwrap();
    let tokens = total_tokens.load(std::sync::atomic::Ordering::Relaxed);
    let errors = errors.load(std::sync::atomic::Ordering::Relaxed);

    println!("── E2E serving results ──");
    println!("requests:   {} ok, {} errors", lat.len(), errors);
    println!("wall time:  {wall:.2} s");
    println!("throughput: {:.2} req/s, {:.1} tok/s aggregate", lat.len() as f64 / wall, tokens as f64 / wall);
    println!(
        "latency ms: p50 {:.0}  p95 {:.0}  p99 {:.0}  max {:.0}",
        percentile(&lat, 50.0),
        percentile(&lat, 95.0),
        percentile(&lat, 99.0),
        percentile(&lat, 100.0)
    );

    let dev = cortex.engine.device().stats();
    println!(
        "device ops: {} (river {}, stream {}, background {}); mean exec {:.2} ms",
        dev.ops,
        dev.lane_ops[0],
        dev.lane_ops[1],
        dev.lane_ops[2],
        dev.exec_ns as f64 / dev.ops.max(1) as f64 / 1e6
    );
    let gate = cortex.gate.stats();
    let step = cortex.step.stats();
    println!(
        "gate: {} evaluated, {:.0}% accepted; synapse pushes {}; \
         step: {:.2} tokens/op ({:.2} ops/token), {} fused ticks, parked peak {}",
        gate.evaluated,
        gate.accept_rate() * 100.0,
        cortex.synapse.stats().pushes,
        step.batch_occupancy(),
        step.ops_per_token(),
        step.fused_ticks,
        step.parked_peak,
    );
    handle.stop();
    Ok(())
}
