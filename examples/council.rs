//! Council of Agents: the full Warp-Cortex episode of the paper's Figure 1.
//!
//! A main agent (the River) generates while the Cortex Router watches its
//! stream for `[TASK: ...]` / `[RECALL: ...]` / `[VERIFY: ...]` triggers.
//! Each trigger spawns a side agent (a Stream) seeded from the Topological
//! Synapse; finished thoughts pass the Validation Gate and are merged back
//! via Referential Injection.
//!
//! ```bash
//! cargo run --release --example council [-- <model> [max_tokens]]
//! ```

use std::sync::Arc;

use warp_cortex::cortex::{CortexConfig, Event, WarpCortex};
use warp_cortex::cortex::memory::fmt_bytes;
use warp_cortex::model::Engine;
use warp_cortex::runtime::{DeviceHandle, DeviceOptions};
use warp_cortex::text::SamplerConfig;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).cloned().unwrap_or_else(|| "small".into());
    let max_tokens: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(160);
    // Optional θ override (paper default 0.5; lower it to watch Referential
    // Injection fire on this small byte-LM, e.g. `council small 160 0.0`).
    let theta: Option<f32> = args.get(3).and_then(|s| s.parse().ok());

    let device = DeviceHandle::new(DeviceOptions::from_env().with_configs(&[&model]))?;
    let engine = Engine::new(device, &model)?;
    let cortex = Arc::new(WarpCortex::new(
        engine,
        CortexConfig {
            model: model.clone(),
            max_side_agents: 3,
            synapse_refresh_every: 24,
            side_gen_budget: 24,
            gate_theta: theta,
            sampler: SamplerConfig {
                temperature: 0.75,
                seed: 1234,
                ..SamplerConfig::default()
            },
            ..CortexConfig::default()
        },
    )?);

    // The prompt plants two explicit triggers; the trained byte-LM often
    // emits its own `[TASK: ...]` patterns as well (they're in-corpus).
    let prompt = "user: tell me about the synapse and the landmarks. \
                  [TASK: verify the units] [RECALL: the definition]\nriver: ";
    println!("── prompt ──\n{prompt}\n── episode ──");
    let report = cortex.run_episode(prompt, max_tokens)?;

    println!("{}\n", report.text);
    println!("── events ──");
    for e in &report.events {
        match e {
            Event::Spawned { task_id, tag, payload, at_token } => {
                println!("  t+{at_token:<4} SPAWN   #{task_id} [{tag}] {payload:?}")
            }
            Event::Dropped { payload, at_token } => {
                println!("  t+{at_token:<4} DROP    {payload:?}")
            }
            Event::Merged { task_id, score, thought, injected_rows, at_token } => println!(
                "  t+{at_token:<4} MERGE   #{task_id} score={score:.3} rows={injected_rows} {thought:?}"
            ),
            Event::Rejected { task_id, score, thought, at_token } => {
                println!("  t+{at_token:<4} REJECT  #{task_id} score={score:.3} {thought:?}")
            }
            Event::Failed { task_id, error, at_token } => {
                println!("  t+{at_token:<4} FAIL    #{task_id} {error}")
            }
            Event::SynapsePushed { version, source_len, at_token } => println!(
                "  t+{at_token:<4} SYNAPSE v{version} ({source_len} rows compressed to k)"
            ),
        }
    }

    println!("\n── summary ──");
    println!(
        "tokens: {}  ({:.1} tok/s, p50 step {:.2} ms, p95 {:.2} ms)",
        report.tokens_generated,
        report.main_tokens_per_sec,
        report.step_latency_p50_ns / 1e6,
        report.step_latency_p95_ns / 1e6,
    );
    println!(
        "gate: {} evaluated, {:.0}% accepted (θ={})",
        report.gate.evaluated,
        report.gate.accept_rate() * 100.0,
        cortex.gate.theta()
    );
    println!(
        "inject: {} thoughts merged, {} rows total",
        report.inject.injected, report.inject.rows_total
    );
    println!(
        "synapse: {} pushes / {} reads, last source {} rows",
        report.synapse.pushes, report.synapse.reads, report.synapse.last_source_len
    );
    println!(
        "scheduler: {} submitted, {} completed, {} rejected",
        report.scheduler.submitted, report.scheduler.completed, report.scheduler.rejected_capacity
    );
    let mem = &report.memory;
    println!(
        "memory: weights {} + main kv {} + side kv {} + synapse {} = {}",
        fmt_bytes(mem.per_kind[0] as f64),
        fmt_bytes(mem.per_kind[1] as f64),
        fmt_bytes(mem.per_kind[2] as f64),
        fmt_bytes(mem.per_kind[3] as f64),
        fmt_bytes(mem.total() as f64),
    );
    let dev = cortex.engine.device().stats();
    println!(
        "device: {} ops (river {}, stream {}, background {})",
        dev.ops, dev.lane_ops[0], dev.lane_ops[1], dev.lane_ops[2]
    );
    Ok(())
}
