//! Agent scaling demo: the live version of Table 2.
//!
//! Spawns N shared-weight agents (1 main + N-1 synapse-seeded side agents),
//! measures the *actual* tracked bytes at each population step, and prints
//! both the measured table (our config) and the projection onto the paper's
//! testbed (Qwen2.5-0.5B fp16 on a 24 GB RTX 4090).
//!
//! ```bash
//! cargo run --release --example scaling [-- <model> [max_agents]]
//! ```

use warp_cortex::cortex::memory::{fmt_bytes, MemoryModel, MemoryTracker};
use warp_cortex::cortex::{AgentKind, Prism, SeedMode, Synapse};
use warp_cortex::model::Engine;
use warp_cortex::runtime::{DeviceHandle, DeviceOptions, Lane, Manifest};
use warp_cortex::text::Tokenizer;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let model = args.get(1).cloned().unwrap_or_else(|| "tiny".into());
    let max_agents: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(100);

    let device = DeviceHandle::new(DeviceOptions::from_env().with_configs(&[&model]))?;
    let engine = Engine::new(device, &model)?;
    let tracker = MemoryTracker::new();
    let prism = Prism::new(engine.clone(), tracker.clone());
    let synapse = Synapse::new(tracker.clone());
    let tk = Tokenizer::new();

    // Main agent with a real context, synapse extracted once.
    let mut main = prism.register(AgentKind::Main)?;
    let prompt = tk.encode(
        "user: tell me about the kv cache.\nriver: the cache grows one row \
         per token. the synapse selects landmark tokens.\nriver: ",
        true,
    );
    let pre = engine.prefill(&prompt, &mut main.kv, Lane::River)?;
    let s = engine.synapse_extract(&pre.hidden_last, &main.kv, Lane::Background)?;
    synapse.push(s);

    println!("spawning up to {max_agents} shared-weight agents ({model})\n");
    println!("{:>8} {:>14} {:>14} {:>14}", "agents", "total", "delta", "per-agent");

    let baseline = tracker.total_live();
    let mut side_agents = Vec::new();
    let mut checkpoints: Vec<usize> = vec![1, 10, 50, 100, 200, 400, 1000];
    checkpoints.retain(|&n| n <= max_agents);

    for &target in &checkpoints {
        while side_agents.len() + 1 < target {
            let mut ticket = prism.register(AgentKind::Side)?;
            // seed the rented cache in place from the synapse: the agent is
            // *live*, not just allocated, and its landmark rows land in the
            // shared block pool
            synapse.seed_into(&mut ticket.kv, SeedMode::Full)?;
            side_agents.push(ticket);
        }
        let total = tracker.total_live();
        let delta = total - baseline;
        let per = if target > 1 {
            delta as f64 / (target - 1) as f64
        } else {
            0.0
        };
        println!(
            "{:>8} {:>14} {:>14} {:>14}",
            target,
            fmt_bytes(total as f64),
            if target > 1 { fmt_bytes(delta as f64) } else { "—".into() },
            if target > 1 { fmt_bytes(per) } else { "—".into() },
        );
    }

    // Prove the side agents actually work: run one decode step on a sample.
    if let Some(ticket) = side_agents.first_mut() {
        let pos = ticket.kv.len() as i32;
        let out = engine.decode(97, pos, &mut ticket.kv, Lane::Stream)?;
        assert!(out.logits.iter().all(|x| x.is_finite()));
        println!("\nside agent sanity decode: ok ({} logits)", out.logits.len());
    }

    println!(
        "\npopulation: {} agents, weights resident once: {}",
        prism.population().total(),
        fmt_bytes(engine.device().weight_bytes(&model) as f64)
    );
    let p = prism.pool().stats();
    println!(
        "kv pool: {} blocks live (high-water {}), resident {} vs {} eager-equivalent",
        p.blocks_live,
        p.blocks_high_water,
        fmt_bytes(p.resident_bytes() as f64),
        fmt_bytes(prism.registered_kv_bytes() as f64)
    );

    // ── Projection to the paper's testbed ──
    let manifest = Manifest::load(Manifest::default_dir())?;
    if let Some(qwen) = manifest.analytic.get("qwen2_5_0_5b") {
        let m = MemoryModel::qwen05b_on_4090(qwen);
        println!("\nprojected to Qwen2.5-0.5B fp16 on RTX 4090 (paper Table 2):");
        println!("{:>8} {:>14} {:>14} {:>14}", "agents", "total", "delta", "per-agent");
        for n in [1u64, 10, 50, 100, 400, 1000] {
            let total = m.warp_total_bytes(n);
            let delta = total - m.warp_total_bytes(1);
            println!(
                "{:>8} {:>14} {:>14} {:>14}",
                n,
                fmt_bytes(total as f64),
                if n > 1 { fmt_bytes(delta as f64) } else { "—".into() },
                if n > 1 { fmt_bytes(delta as f64 / (n - 1) as f64) } else { "—".into() },
            );
        }
        println!(
            "\nmax agents in 24 GB: standard ≈ {}, warp-cortex ≈ {}",
            m.max_agents_standard(),
            m.max_agents_warp()
        );
    }
    Ok(())
}
