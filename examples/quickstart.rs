//! Quickstart: load the AOT artifacts, prefill a prompt, stream tokens.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! This is the smallest end-to-end use of the public API: a single main
//! agent, no side agents — the baseline everything else builds on.

use warp_cortex::model::Engine;
use warp_cortex::runtime::{DeviceHandle, DeviceOptions, Lane};
use warp_cortex::text::{Sampler, SamplerConfig, Tokenizer, EOS_ID};

fn main() -> anyhow::Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "small".into());
    println!("bringing up device with config `{model}` ...");
    let device = DeviceHandle::new(DeviceOptions::from_env().with_configs(&[&model]))?;
    let engine = Engine::new(device, &model)?;
    println!(
        "model: d={} layers={} heads={}/{} params={} (weights resident once: {} bytes)",
        engine.config().d_model,
        engine.config().n_layers,
        engine.config().n_heads,
        engine.config().n_kv_heads,
        engine.config().param_count,
        engine.device().weight_bytes(&model),
    );

    let tk = Tokenizer::new();
    let prompt = "user: tell me about the kv cache.\nriver: ";
    let ids = tk.encode(prompt, true);

    let mut kv = engine.new_main_cache();
    let t0 = std::time::Instant::now();
    let pre = engine.prefill(&ids, &mut kv, Lane::River)?;
    println!(
        "prefill: {} tokens in {:.1} ms",
        pre.len,
        t0.elapsed().as_secs_f64() * 1e3
    );

    let v = engine.config().vocab_size;
    let mut logits = pre.logits[(pre.len - 1) * v..pre.len * v].to_vec();
    let mut sampler = Sampler::new(SamplerConfig {
        temperature: 0.7,
        seed: 7,
        ..SamplerConfig::default()
    });

    print!("{prompt}");
    let t0 = std::time::Instant::now();
    let mut pos = kv.len() as i32;
    let mut generated = 0;
    for _ in 0..120 {
        let id = sampler.sample(&logits);
        if id == EOS_ID || kv.remaining() == 0 {
            break;
        }
        if let Some(b) = tk.decode_one(id) {
            print!("{}", b as char);
            use std::io::Write;
            std::io::stdout().flush()?;
        }
        let out = engine.decode(id, pos, &mut kv, Lane::River)?;
        logits = out.logits;
        pos += 1;
        generated += 1;
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "\n\n{generated} tokens in {:.2}s = {:.1} tok/s (KV cache: {} rows, {} bytes)",
        dt,
        generated as f64 / dt,
        kv.len(),
        kv.bytes()
    );
    Ok(())
}
