#!/usr/bin/env python3
"""Versioned bench threshold gate.

Replaces the inline Python heredoc that used to live in
.github/workflows/ci.yml: thresholds are declarative data in
ci/thresholds.json (one entry per BENCH_*.json metric), this script is
the single versioned evaluator, and the merged BENCH_summary.json it
emits is uploaded with the bench artifacts so the perf trajectory is one
file per commit.

Usage:
    python3 ci/check_bench.py [--thresholds ci/thresholds.json]
                              [--summary BENCH_summary.json]
                              [--reports-dir .]
    python3 ci/check_bench.py --self-test

thresholds.json shape:
    {
      "BENCH_foo.json": [
        {"key": "warm_bytes", "op": "==", "bound": 0},
        {"key": "ops_at_8", "op": "<=", "bound": "0.6 * ops_at_1"}
      ],
      ...
    }

`bound` is a number, or an arithmetic expression (+ - * / and
parentheses) over numeric keys of the same report — evaluated by a small
AST whitelist, never eval().  Every listed report must exist and every
referenced key must be present: a bench that silently stopped emitting a
metric fails the gate instead of passing by omission.

`--self-test` proves those fail-closed properties against synthetic
reports in a temp dir (missing report -> non-zero, missing key ->
non-zero, violated bound -> non-zero, all-good -> zero) so a regression
in the gate itself cannot silently wave benches through.  CI runs it
before the real evaluation.

Exit status: 0 iff every check passes.
"""

from __future__ import annotations

import argparse
import ast
import json
import operator
import sys
import tempfile
from pathlib import Path

OPS = {
    "<": operator.lt,
    "<=": operator.le,
    ">": operator.gt,
    ">=": operator.ge,
    "==": operator.eq,
    "!=": operator.ne,
}

_BINOPS = {
    ast.Add: operator.add,
    ast.Sub: operator.sub,
    ast.Mult: operator.mul,
    ast.Div: operator.truediv,
}


def eval_bound(bound, report: dict, where: str) -> float:
    """A number, or a whitelisted arithmetic expression over report keys."""
    if isinstance(bound, (int, float)) and not isinstance(bound, bool):
        return float(bound)
    if not isinstance(bound, str):
        raise ValueError(f"{where}: bound must be a number or expression, got {bound!r}")

    def walk(node) -> float:
        if isinstance(node, ast.Expression):
            return walk(node.body)
        if isinstance(node, ast.Constant):
            if isinstance(node.value, (int, float)) and not isinstance(node.value, bool):
                return float(node.value)
            raise ValueError(f"{where}: non-numeric literal {node.value!r}")
        if isinstance(node, ast.Name):
            if node.id not in report:
                raise KeyError(f"{where}: key `{node.id}` missing from report")
            return as_number(report[node.id], f"{where}: `{node.id}`")
        if isinstance(node, ast.BinOp) and type(node.op) in _BINOPS:
            return _BINOPS[type(node.op)](walk(node.left), walk(node.right))
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            return -walk(node.operand)
        raise ValueError(f"{where}: disallowed syntax {ast.dump(node)}")

    return walk(ast.parse(bound, mode="eval"))


def as_number(value, where: str) -> float:
    if isinstance(value, bool) or not isinstance(value, (int, float)):
        raise ValueError(f"{where} is not numeric: {value!r}")
    return float(value)


def evaluate(thresholds: dict, reports_dir: Path, summary_path: Path):
    """Run every threshold check; returns (summary dict, failure list)."""
    summary = {"thresholds_file": None, "reports": {}, "checks": []}
    failures = []

    for report_name in sorted(thresholds):
        path = reports_dir / report_name
        if not path.exists():
            failures.append(f"{report_name}: report missing (bench did not run?)")
            summary["reports"][report_name] = None
            continue
        report = json.loads(path.read_text())
        summary["reports"][report_name] = report
        for check in thresholds[report_name]:
            key, op_name, bound = check["key"], check["op"], check["bound"]
            where = f"{report_name}: {key} {op_name} {bound!r}"
            entry = {"report": report_name, "key": key, "op": op_name, "bound": bound}
            try:
                if key not in report:
                    raise KeyError(f"{where}: key `{key}` missing from report")
                actual = as_number(report[key], f"{where}: `{key}`")
                bound_value = eval_bound(bound, report, where)
                ok = OPS[op_name](actual, bound_value)
                entry.update(actual=actual, bound_value=bound_value, passed=ok)
                if not ok:
                    failures.append(f"FAIL {where}  (actual {actual}, bound {bound_value})")
            except (KeyError, ValueError) as e:
                entry.update(passed=False, error=str(e))
                failures.append(f"FAIL {e}")
            summary["checks"].append(entry)

    # Fold in any extra BENCH_*.json the thresholds don't know yet, so the
    # per-commit summary artifact is complete even before a gate exists.
    for extra in sorted(reports_dir.glob("BENCH_*.json")):
        if extra.name == summary_path.name or extra.name in summary["reports"]:
            continue
        try:
            summary["reports"][extra.name] = json.loads(extra.read_text())
        except json.JSONDecodeError as e:
            failures.append(f"{extra.name}: unparseable report: {e}")

    summary["passed"] = not failures
    return summary, failures


def self_test() -> int:
    """Prove the gate fails closed.  Each case is (thresholds, reports on
    disk, expected-failure-count); any mismatch is a gate bug."""
    cases = [
        (
            "missing report fails",
            {"BENCH_absent.json": [{"key": "x", "op": ">=", "bound": 1}]},
            {},
            1,
        ),
        (
            "missing key fails",
            {"BENCH_a.json": [{"key": "gone", "op": ">=", "bound": 1}]},
            {"BENCH_a.json": {"x": 5}},
            1,
        ),
        (
            "violated bound fails",
            {"BENCH_a.json": [{"key": "x", "op": ">=", "bound": 10}]},
            {"BENCH_a.json": {"x": 5}},
            1,
        ),
        (
            "expression bound over missing key fails",
            {"BENCH_a.json": [{"key": "x", "op": "<=", "bound": "2 * gone"}]},
            {"BENCH_a.json": {"x": 5}},
            1,
        ),
        (
            "boolean metric is rejected, not coerced",
            {"BENCH_a.json": [{"key": "ok", "op": "==", "bound": 1}]},
            {"BENCH_a.json": {"ok": True}},
            1,
        ),
        (
            "all-good passes",
            {"BENCH_a.json": [{"key": "x", "op": ">=", "bound": "x - 1"}]},
            {"BENCH_a.json": {"x": 5}},
            0,
        ),
    ]
    bad = 0
    for name, thresholds, reports, want in cases:
        with tempfile.TemporaryDirectory() as tmp:
            tmp = Path(tmp)
            for fname, content in reports.items():
                (tmp / fname).write_text(json.dumps(content))
            summary, failures = evaluate(thresholds, tmp, tmp / "BENCH_summary.json")
            if len(failures) != want or summary["passed"] != (want == 0):
                bad += 1
                print(f"self-test FAIL: {name}: expected {want} failure(s), "
                      f"got {len(failures)}: {failures}")
            else:
                print(f"self-test ok: {name}")
    if bad:
        print(f"self-test: {bad} case(s) broken — the gate does not fail closed")
        return 1
    print(f"self-test: all {len(cases)} cases passed")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--thresholds", default="ci/thresholds.json")
    ap.add_argument("--summary", default="BENCH_summary.json")
    ap.add_argument("--reports-dir", default=".")
    ap.add_argument("--self-test", action="store_true",
                    help="verify the gate fails closed, then exit")
    args = ap.parse_args()

    if args.self_test:
        return self_test()

    thresholds = json.loads(Path(args.thresholds).read_text())
    summary, failures = evaluate(thresholds, Path(args.reports_dir), Path(args.summary))
    summary["thresholds_file"] = args.thresholds
    Path(args.summary).write_text(json.dumps(summary, indent=2, sort_keys=True) + "\n")

    checked = len(summary["checks"])
    if failures:
        print(f"bench gate: {len(failures)} failure(s) across {checked} checks:")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"bench gate: all {checked} checks passed "
          f"({len(summary['reports'])} reports merged into {args.summary})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
