//! Bench P3 — copy-on-write prefix sharing: one prefill, N agents.
//!
//! The prefix-sharing refactor adds a content-addressed registry to the KV
//! block pool: the first agent of a prompt (or landmark seed) writes and
//! registers its full blocks, every later agent adopts them *by reference*.
//! This bench drives the pool/cache layer directly (host-only — the engine
//! path is covered by the device-gated integration tests) and *asserts* the
//! acceptance criteria — it runs in the CI bench-smoke step:
//!
//! 1. spawning a second agent with an identical prefix attaches the shared
//!    blocks with ZERO host→device bytes and allocates O(1) new blocks
//!    (only the private tail);
//! 2. shared reads are bit-identical across agents, host and device side;
//! 3. divergence after sharing copies-on-write and never perturbs the
//!    other agents or the registry;
//! 4. parked registry entries are LRU-evicted under the pool cap.
//!
//! Emits `BENCH_prefix_share.json` so the perf trajectory is
//! machine-readable (published as a CI artifact and threshold-checked).
//!
//! ```bash
//! cargo bench --bench prefix_share
//! ```

use warp_cortex::cortex::memory::fmt_bytes;
use warp_cortex::model::{KvPool, KvPoolConfig};
use warp_cortex::runtime::ModelConfig;
use warp_cortex::util::timer::bench_median;
use warp_cortex::util::Json;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "tiny".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 4,
        n_kv_heads: 2,
        d_ff: 192,
        vocab_size: 260,
        head_dim: 16,
        rope_theta: 1e4,
        param_count: 116_032,
    }
}

const L: usize = 2; // layers of tiny_cfg
const ROW: usize = 32; // KV * hd of tiny_cfg
const PROMPT: usize = 100; // prompt tokens
const CAPACITY: usize = 256;
const WARM_AGENTS: usize = 8;
const SALT: u64 = 0xBE7C; // bench's registry domain

/// Deterministic prompt token ids.
fn prompt_tokens() -> Vec<i32> {
    (0..PROMPT as i32).map(|i| (i * 37 + 11) % 256).collect()
}

/// Deterministic `[L, n, KV, hd]` rows derived from the tokens — the
/// content-addressing contract (same keys ⇒ same rows) made literal, which
/// is exactly what a real prefill guarantees for a fixed model.
fn canon_rows(tokens: &[i32]) -> (Vec<f32>, Vec<f32>) {
    let n = tokens.len();
    let mut k = Vec::with_capacity(L * n * ROW);
    let mut v = Vec::with_capacity(L * n * ROW);
    for layer in 0..L {
        for (pos, &tok) in tokens.iter().enumerate() {
            for j in 0..ROW {
                let x = (layer * 7919 + pos * 131 + j) as f32 * 1e-3 + tok as f32 * 1e-2;
                k.push(x);
                v.push(-x);
            }
        }
    }
    (k, v)
}

fn bit_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn main() -> anyhow::Result<()> {
    let cfg = tiny_cfg();
    let pool = KvPool::new(
        &cfg,
        KvPoolConfig {
            block_tokens: 16,
            ..KvPoolConfig::default()
        },
    );
    let bt = pool.block_tokens();
    let tokens = prompt_tokens();
    let (k_rows, v_rows) = canon_rows(&tokens);
    let shared_blocks_per_prompt = PROMPT / bt; // full blocks only
    let row_bytes = (L * ROW * 2 * 4) as u64; // one position, K+V, f32

    println!("═══ P3: copy-on-write prefix sharing (one prefill, N agents) ═══\n");

    // ── cold: the first agent writes and registers the prompt ──────────
    let before = pool.stats();
    let mut cold = pool.new_cache(CAPACITY);
    cold.replace_rows_keyed(PROMPT, SALT, &tokens, &k_rows, &v_rows)?;
    let s = pool.stats();
    let cold_blocks = s.blocks_live - before.blocks_live;
    let cold_h2d = s.h2d_bytes - before.h2d_bytes;
    assert_eq!(cold_blocks, pool.blocks_for(PROMPT));
    assert_eq!(cold.shared_blocks(), shared_blocks_per_prompt);
    assert_eq!(s.shared_blocks, shared_blocks_per_prompt);
    println!(
        "cold agent: {} blocks ({} registered), {} uploaded",
        cold_blocks,
        shared_blocks_per_prompt,
        fmt_bytes(cold_h2d as f64)
    );

    // ── a pure attach is free: zero bytes, zero new blocks ─────────────
    let hashes = pool.prefix_hashes(SALT, &tokens);
    let before = pool.stats();
    let mut attached = pool.new_cache(CAPACITY);
    let covered = attached.attach_shared_prefix(&hashes, &tokens)?;
    let s = pool.stats();
    let attach_h2d = s.h2d_bytes - before.h2d_bytes;
    let attach_blocks = s.blocks_live - before.blocks_live;
    assert_eq!(covered, shared_blocks_per_prompt * bt);
    assert_eq!(attach_h2d, 0, "attaching a shared prefix must upload nothing");
    assert_eq!(attach_blocks, 0, "attaching a shared prefix must rent nothing");
    drop(attached);

    // ── warm: N more agents seed the identical prompt ──────────────────
    let before = pool.stats();
    let mut warm = Vec::with_capacity(WARM_AGENTS);
    for _ in 0..WARM_AGENTS {
        let mut c = pool.new_cache(CAPACITY);
        c.replace_rows_keyed(PROMPT, SALT, &tokens, &k_rows, &v_rows)?;
        warm.push(c);
    }
    let s = pool.stats();
    let warm_blocks = s.blocks_live - before.blocks_live;
    let warm_h2d = s.h2d_bytes - before.h2d_bytes;
    let warm_new_blocks_per_agent = warm_blocks / WARM_AGENTS;
    let warm_h2d_per_agent = warm_h2d / WARM_AGENTS as u64;
    let tail_rows = (PROMPT - shared_blocks_per_prompt * bt) as u64;
    let prefix_hits = s.prefix_hits;
    println!(
        "{WARM_AGENTS} warm agents: {warm_new_blocks_per_agent} new block(s) and {} \
         uploaded each (tail only) vs {} blocks / {} for a cold spawn",
        fmt_bytes(warm_h2d_per_agent as f64),
        cold_blocks,
        fmt_bytes(cold_h2d as f64)
    );

    // ── the acceptance criteria ──
    // 1. O(1) fresh memory per warm agent: only the private tail block.
    assert_eq!(
        warm_blocks,
        WARM_AGENTS * (pool.blocks_for(PROMPT) - shared_blocks_per_prompt),
        "warm agents rented more than their tails"
    );
    // 2. zero h2d for the shared prefix: each agent pays its tail rows only.
    assert_eq!(
        warm_h2d,
        WARM_AGENTS as u64 * tail_rows * row_bytes,
        "warm seeding uploaded shared rows"
    );
    // 3. every full block hit the registry (the attach probe added one
    //    extra chain of hits before the warm wave).
    assert!(
        prefix_hits >= (WARM_AGENTS * shared_blocks_per_prompt) as u64,
        "expected ≥{} prefix hits, saw {prefix_hits}",
        WARM_AGENTS * shared_blocks_per_prompt
    );
    // 4. resident bytes for the shared prefix are independent of N.
    assert_eq!(s.shared_blocks, shared_blocks_per_prompt);
    // 5. shared reads are bit-identical, host and device side.
    let (ck, cv) = cold.prefix_upload(CAPACITY);
    for w in &warm {
        let (wk, wv) = w.prefix_upload(CAPACITY);
        assert!(bit_eq(&ck, &wk) && bit_eq(&cv, &wv), "shared K/V diverged");
        let (dk, dv) = w.device_gather(CAPACITY)?;
        assert!(bit_eq(&dk, &wk) && bit_eq(&dv, &wv), "device gather diverged");
    }

    // ── CoW: divergence is private ──────────────────────────────────────
    let (cold_before, _) = cold.prefix_upload(CAPACITY);
    {
        let w = warm.last_mut().expect("warm agents exist");
        w.truncate(90); // back into the shared prefix (block 5 of 16-row blocks)
        let div_k = vec![7.5f32; L * ROW];
        let div_v = vec![-7.5f32; L * ROW];
        w.append_row(&div_k, &div_v)?;
    }
    let s = pool.stats();
    assert!(s.cow_copies >= 1, "write into a shared block must CoW");
    let (cold_after, _) = cold.prefix_upload(CAPACITY);
    assert!(
        bit_eq(&cold_before, &cold_after),
        "CoW divergence leaked into another agent"
    );
    let cow_copies = s.cow_copies;
    println!("divergence: {cow_copies} CoW copies, other agents bit-identical");

    // ── timing: attach vs cold fill ─────────────────────────────────────
    let t_attach = bench_median(3, 50, || {
        let mut c = pool.new_cache(CAPACITY);
        let covered = c.attach_shared_prefix(&hashes, &tokens).expect("attach");
        std::hint::black_box(covered);
    });
    let cold_pool = KvPool::new(
        &cfg,
        KvPoolConfig {
            block_tokens: 16,
            ..KvPoolConfig::default()
        },
    );
    let t_cold = bench_median(3, 50, || {
        let mut c = cold_pool.new_cache(CAPACITY);
        c.replace_rows(PROMPT, &k_rows, &v_rows).expect("fill");
        std::hint::black_box(c.len());
    });
    println!(
        "seed latency: attach {:.1} µs vs cold fill {:.1} µs median ({:.0}x)",
        t_attach.median_ns / 1e3,
        t_cold.median_ns / 1e3,
        t_cold.median_ns / t_attach.median_ns.max(1.0)
    );

    // ── LRU eviction under the cap ──────────────────────────────────────
    drop(warm);
    drop(cold);
    let s = pool.stats();
    assert_eq!(
        s.blocks_live, shared_blocks_per_prompt,
        "only parked registry entries may remain live"
    );
    pool.set_limits(shared_blocks_per_prompt, usize::MAX);
    let mut fresh = pool.new_cache(CAPACITY);
    let one_k = vec![0.25f32; L * ROW];
    fresh.append_row(&one_k, &one_k)?;
    let s = pool.stats();
    assert!(s.prefix_evictions >= 1, "cap pressure must evict parked entries");
    assert_eq!(s.blocks_live, shared_blocks_per_prompt, "eviction reuses in place");
    let prefix_evictions = s.prefix_evictions;
    drop(fresh);
    println!("cap pressure: {prefix_evictions} parked entries LRU-evicted\n");

    // ── machine-readable report ─────────────────────────────────────────
    let report = Json::obj()
        .with("bench", "prefix_share")
        .with("block_tokens", bt)
        .with("prompt_tokens", PROMPT)
        .with("shared_blocks_per_prompt", shared_blocks_per_prompt)
        .with("warm_agents", WARM_AGENTS)
        .with("cold_blocks", cold_blocks)
        .with("cold_h2d_bytes", cold_h2d)
        .with("warm_new_blocks_per_agent", warm_new_blocks_per_agent)
        .with("warm_h2d_bytes_per_agent", warm_h2d_per_agent)
        .with("warm_attach_h2d_bytes", attach_h2d)
        .with("warm_attach_new_blocks", attach_blocks)
        .with("prefix_hits", prefix_hits)
        .with("cow_copies", cow_copies)
        .with("prefix_evictions", prefix_evictions)
        .with("attach_us", t_attach.median_ns / 1e3)
        .with("cold_fill_us", t_cold.median_ns / 1e3);
    std::fs::write("BENCH_prefix_share.json", report.to_string())?;
    println!("wrote BENCH_prefix_share.json");
    println!("shape check: one prefill, N agents — shared prefix is O(1)  ✓");
    Ok(())
}
