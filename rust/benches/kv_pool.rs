//! Bench P1 — the paged-KV claim: resident context bytes track *fill*, not
//! configured capacity, and blocks released by finished agents are reused
//! by new ones (high-water blocks ≪ the sum of per-agent capacities).
//!
//! Pure host-side — runs on any machine, no device artifacts required:
//!
//! ```bash
//! cargo bench --bench kv_pool
//! ```
//!
//! Simulates the serving pattern the cortex produces: a long-lived main
//! agent plus waves of short-lived side agents with short, varied contexts,
//! all renting from one shared pool.

use warp_cortex::cortex::memory::fmt_bytes;
use warp_cortex::model::{KvPool, KvPoolConfig};
use warp_cortex::runtime::ModelConfig;
use warp_cortex::util::rng::XorShift;
use warp_cortex::util::timer::bench_median;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "tiny".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 4,
        n_kv_heads: 2,
        d_ff: 192,
        vocab_size: 260,
        head_dim: 16,
        rope_theta: 1e4,
        param_count: 116_032,
    }
}

const MAIN_CTX: usize = 512;
const SIDE_CTX: usize = 96;
const WAVES: usize = 8;
const AGENTS_PER_WAVE: usize = 25;

fn main() -> anyhow::Result<()> {
    let cfg = tiny_cfg();
    let pool = KvPool::new(
        &cfg,
        KvPoolConfig {
            block_tokens: 16,
            ..KvPoolConfig::default()
        },
    );
    let row_floats = cfg.n_layers * cfg.n_kv_heads * cfg.head_dim;
    let mut rng = XorShift::new(0xB10C);

    println!("═══ P1: shared KV block pool (paged context memory) ═══\n");

    // A main agent that stays resident the whole run.
    let mut main = pool.new_cache(MAIN_CTX);
    let main_fill = 200;
    for _ in 0..main_fill {
        let k: Vec<f32> = (0..row_floats).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        main.append_row(&k, &k)?;
    }

    // Waves of short-lived side agents: each seeds ~64 landmark rows plus a
    // short generated thought, then drops — the pool should absorb every
    // wave into the same block set.
    let mut total_side_agents = 0usize;
    let mut sum_capacity_rows = main.capacity();
    println!(
        "{:>6} {:>14} {:>14} {:>14} {:>12}",
        "wave", "resident", "eager-equiv", "high-water", "reuse rate"
    );
    for wave in 0..WAVES {
        let mut side = Vec::with_capacity(AGENTS_PER_WAVE);
        for _ in 0..AGENTS_PER_WAVE {
            let mut kv = pool.new_cache(SIDE_CTX);
            let fill = 64 + (rng.below(24) as usize); // landmarks + thought
            for _ in 0..fill {
                let k: Vec<f32> =
                    (0..row_floats).map(|_| rng.range_f32(-1.0, 1.0)).collect();
                kv.append_row(&k, &k)?;
            }
            side.push(kv);
            total_side_agents += 1;
            sum_capacity_rows += SIDE_CTX;
        }
        let s = pool.stats();
        let eager = side.iter().map(|c| c.capacity_bytes()).sum::<u64>()
            + main.capacity_bytes();
        let reuse_rate = if s.rents > 0 {
            s.reuses as f64 / s.rents as f64
        } else {
            0.0
        };
        println!(
            "{:>6} {:>14} {:>14} {:>14} {:>11.1}%",
            wave,
            fmt_bytes(s.live_bytes() as f64),
            fmt_bytes(eager as f64),
            fmt_bytes(s.high_water_bytes() as f64),
            reuse_rate * 100.0
        );
        // wave ends: agents finish, blocks return to the pool
        drop(side);
    }

    let s = pool.stats();
    let sum_capacity_blocks =
        (sum_capacity_rows + s.block_tokens - 1) / s.block_tokens;
    println!(
        "\n{total_side_agents} side agents served across {WAVES} waves \
         (+1 main, {main_fill}/{MAIN_CTX} rows filled)"
    );
    println!(
        "blocks: high-water {} vs {} if every agent kept its full capacity \
         ({}x saving); {} reuses / {} rents; fragmentation {:.1}%",
        s.blocks_high_water,
        sum_capacity_blocks,
        sum_capacity_blocks / s.blocks_high_water.max(1),
        s.reuses,
        s.rents,
        s.fragmentation() * 100.0
    );

    // Gather-path throughput: the per-step upload cost of block translation.
    let t = bench_median(3, 50, || {
        let (k, v) = main.prefix_upload(256);
        std::hint::black_box((k, v));
    });
    println!(
        "prefix_upload(256) on a {}-row main cache: {:.1} µs median",
        main.len(),
        t.median_ns / 1e3
    );

    // ── shape checks (the acceptance criteria of the paged-KV refactor) ──
    // 1. block reuse: the pool's peak is far below the sum of capacities.
    assert!(
        s.blocks_high_water < sum_capacity_blocks / 4,
        "high-water {} not < {}/4 — block reuse failed",
        s.blocks_high_water,
        sum_capacity_blocks
    );
    // 2. resident bytes track fill: the live main agent holds exactly
    //    ceil(fill/bt) blocks, not its full capacity.
    assert_eq!(
        main.bytes(),
        pool.blocks_for(main_fill) as u64 * pool.block_bytes()
    );
    assert!(main.bytes() < main.capacity_bytes());
    // 3. released blocks were actually reused across waves.
    assert!(s.reuses > 0, "no block reuse observed");
    println!("\nshape check: reuse + fill-proportional residency  ✓");
    Ok(())
}
