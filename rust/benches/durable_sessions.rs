//! Bench P10 — durable sessions: the single-file checkpoint store as the
//! fourth memory tier and the fourth admission tier.
//!
//! Drives the store + pool layers directly (host-only — runs in the CI
//! bench-smoke step) and *asserts* the durable-session acceptance
//! criteria:
//!
//! 1. checkpoint → drop → resume round-trips a session losslessly: the
//!    registry-shared prompt prefix re-attaches by hash chain with **zero
//!    new blocks and zero h2d bytes** (no re-prefill), the private tail
//!    reloads from the file, and the post-resume gather is bit-identical
//!    to the pre-checkpoint one;
//! 2. at the pool cap, tiering alone (PR 8: no slab headroom left) sheds
//!    the next arrival — but with hibernated residents parked in the
//!    store, preempting the coldest to disk frees its blocks and the
//!    arrival **admits** instead of 503ing, and the preempted session
//!    still resumes bit-identically from its durable record afterwards;
//! 3. the store's record ledger reconciles (`checkpoints == resumes +
//!    superseded + corrupt_records_skipped + retained`) through all of it.
//!
//! Emits `BENCH_durable_sessions.json` (threshold-checked by
//! ci/check_bench.py and folded into the per-commit BENCH_summary.json).
//!
//! ```bash
//! cargo bench --bench durable_sessions
//! ```

use warp_cortex::cortex::{SessionCheckpoint, SessionStore};
use warp_cortex::model::{KvPool, KvPoolConfig};
use warp_cortex::runtime::ModelConfig;
use warp_cortex::util::timer::bench_median;
use warp_cortex::util::Json;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "tiny".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 4,
        n_kv_heads: 2,
        d_ff: 192,
        vocab_size: 260,
        head_dim: 16,
        rope_theta: 1e4,
        param_count: 116_032,
    }
}

const L: usize = 2; // layers of tiny_cfg
const ROW: usize = 32; // KV * hd of tiny_cfg
const BT: usize = 16; // block_tokens
const PROMPT: usize = 32; // registered prompt (2 full blocks)
const TAIL: usize = 16; // private decode rows past the prompt
const TOTAL: usize = PROMPT + TAIL;
const CAPACITY: usize = 256;
const SESSIONS: usize = 4;
const SESSION_ROWS: usize = 32; // per hibernated session (2 full blocks)
const CAP_BLOCKS: usize = (SESSIONS * SESSION_ROWS) / BT; // exactly the residents
const SALT: u64 = 0x0D15; // bench's registry domain

/// Deterministic prompt token ids, distinct per `seed`.
fn prompt_tokens(seed: usize) -> Vec<i32> {
    (0..PROMPT as i32)
        .map(|i| (i * 37 + 11 + seed as i32 * 101) % 256)
        .collect()
}

/// Deterministic `[L, n, ROW]` rows for positions `start..start + n` —
/// the layout `replace_rows` / `append_rows` expect, and the same layout
/// `SessionCheckpoint::k_tail`/`v_tail` carry.
fn span_rows(seed: usize, start: usize, n: usize) -> (Vec<f32>, Vec<f32>) {
    let mut k = Vec::with_capacity(L * n * ROW);
    let mut v = Vec::with_capacity(L * n * ROW);
    for layer in 0..L {
        for pos in start..start + n {
            for j in 0..ROW {
                let x = (layer * 7919 + pos * 131 + j) as f32 * 1e-3 + seed as f32 * 1e-2;
                k.push(x);
                v.push(-x);
            }
        }
    }
    (k, v)
}

fn bit_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn pool_with(max_blocks: usize, slab: usize) -> std::sync::Arc<KvPool> {
    KvPool::new(
        &tiny_cfg(),
        KvPoolConfig {
            block_tokens: BT,
            max_blocks,
            // Bit-identity scenarios: the warm int8 tier is lossy by
            // design, so parked state must stay fp32 here.
            quantize_parked: false,
            host_slab_blocks: slab,
            ..KvPoolConfig::default()
        },
    )
}

/// A synthetic but fully-populated checkpoint: real tail rows, synthetic
/// sampler/logits state (the cortex-level codec tests prove those fields
/// bit-exactly; this bench proves the KV path).
fn checkpoint_for(id: u64, seed: usize, shared_rows: usize, total_rows: usize) -> SessionCheckpoint {
    let (k_tail, v_tail) = span_rows(seed, shared_rows, total_rows - shared_rows);
    SessionCheckpoint {
        id,
        rng_state: 0x9E37_79B9 ^ id,
        synapse_version: 1,
        generated: (total_rows - shared_rows) as u64,
        max_tokens: 64,
        pos: total_rows as i64,
        shared_rows: shared_rows as u32,
        total_rows: total_rows as u32,
        offloaded_blocks: 0,
        prompt: format!("bench prompt {seed}"),
        text: String::new(),
        prompt_ids: prompt_tokens(seed),
        recent: vec![1, 2, 3],
        logits: vec![0.25; 16],
        hidden: vec![-0.5; 8],
        k_tail,
        v_tail,
    }
}

fn store_path() -> std::path::PathBuf {
    std::env::temp_dir().join(format!("warpstore_bench_{}.wst", std::process::id()))
}

fn main() -> anyhow::Result<()> {
    println!("═══ P10: durable sessions (checkpoint store + preempt-to-disk) ═══\n");
    let path = store_path();
    let _ = std::fs::remove_file(&path);

    // ── A: checkpoint → drop → resume, zero re-prefill ──────────────────
    // A session with a registry-shared prompt and a private decode tail
    // checkpoints, fully drops, and resumes: the prompt re-attaches from
    // the prefix registry by hash chain (no new blocks, no h2d), the tail
    // reloads from the file, and the gather is bit-identical.
    let pool = pool_with(0, 16);
    let store = SessionStore::open(&path)?;
    let tokens = prompt_tokens(7);
    let (pk, pv) = span_rows(7, 0, PROMPT);
    let (tk, tv) = span_rows(7, PROMPT, TAIL);
    let mut cache = pool.new_cache(CAPACITY);
    cache.replace_rows_keyed(PROMPT, SALT, &tokens, &pk, &pv)?;
    cache.append_rows(TAIL, &tk, &tv)?;
    let baseline = cache.device_gather(TOTAL)?;
    store.checkpoint(&checkpoint_for(1, 7, PROMPT, TOTAL))?;
    drop(cache); // the session is gone; only the registry + the file remain

    let ticket = store.take(1)?;
    assert!(ticket.resident.is_none(), "nothing was parked resident");
    let cp = ticket.checkpoint;
    let s0 = pool.stats();
    let mut resumed = pool.new_cache(CAPACITY);
    let hashes = pool.prefix_hashes(SALT, &cp.prompt_ids);
    let attached = resumed.attach_shared_prefix(&hashes, &cp.prompt_ids)?;
    let s1 = pool.stats();
    let resume_prefix_new_blocks = s1.blocks_live - s0.blocks_live;
    let resume_prefix_h2d_bytes = s1.h2d_bytes - s0.h2d_bytes;
    assert_eq!(attached, cp.shared_rows as usize, "hash chain must cover the prompt");
    assert_eq!(resume_prefix_new_blocks, 0, "shared prefix resumes by reference");
    assert_eq!(resume_prefix_h2d_bytes, 0, "shared prefix resumes without upload");
    let tail_rows = cp.total_rows as usize - cp.shared_rows as usize;
    resumed.append_rows(tail_rows, &cp.k_tail, &cp.v_tail)?;
    let after = resumed.device_gather(TOTAL)?;
    let resume_bitident = bit_eq(&baseline.0, &after.0) && bit_eq(&baseline.1, &after.1);
    assert!(resume_bitident, "checkpoint→resume must be bit-identical");
    println!(
        "durable resume: {attached} prompt rows re-attached by hash chain \
         ({resume_prefix_new_blocks} new blocks, {resume_prefix_h2d_bytes} h2d bytes), \
         {tail_rows} tail rows from the file — bit-identical gather"
    );
    drop(resumed);

    // ── B: preempt-to-disk as the fourth admission tier ─────────────────
    // SESSIONS hibernated sessions (checkpointed + parked resident) fill
    // a capped, slab-less pool exactly: tiering alone sheds the next
    // arrival (PR 8's terminal state).  Preempting the coldest resident
    // to disk frees its blocks and the arrival admits.
    let capped = pool_with(CAP_BLOCKS, 0);
    let mut baseline0 = None;
    for s in 0..SESSIONS {
        let (k, v) = span_rows(100 + s, 0, SESSION_ROWS);
        let mut c = capped.new_cache(CAPACITY);
        c.replace_rows(SESSION_ROWS, &k, &v)?;
        if s == 0 {
            baseline0 = Some(c.device_gather(SESSION_ROWS)?);
        }
        store.checkpoint(&checkpoint_for(100 + s as u64, 100 + s, 0, SESSION_ROWS))?;
        store.park_resident(100 + s as u64, Box::new(c));
    }
    let need = SESSION_ROWS / BT;
    let tiering_sheds = !capped.can_admit(need);
    assert!(tiering_sheds, "the budget is exactly the hibernated residents — must shed");
    // The admission loop the cortex runs: preempt the coldest resident to
    // disk until the reservation fits.
    let mut preempted = 0usize;
    while !capped.can_admit(need) && store.preempt_coldest() {
        preempted += 1;
    }
    let preempt_admits = capped.can_admit(need);
    assert!(preempt_admits, "preempt-to-disk must open the slot tiering could not");
    assert_eq!(preempted, 1, "one coldest victim frees exactly one session's blocks");
    let (ak, av) = span_rows(50, 0, SESSION_ROWS);
    let mut arrival = capped.new_cache(CAPACITY);
    arrival.replace_rows(SESSION_ROWS, &ak, &av)?;
    println!(
        "admission: tiered pool shed at the {CAP_BLOCKS}-block cap; preempting \
         {preempted} resident to disk admitted the arrival ({} still resident)",
        store.parked_resident()
    );

    // The preempted session (id 100, the coldest) lost its resident
    // ticket but kept its durable record: free a slot and rebuild it from
    // the file — still bit-identical.
    while !capped.can_admit(need) && store.preempt_coldest() {}
    let ticket = store.take(100)?;
    assert!(ticket.resident.is_none(), "the victim's ticket was dropped to disk");
    let cp = ticket.checkpoint;
    let mut revived = capped.new_cache(CAPACITY);
    revived.append_rows(cp.total_rows as usize, &cp.k_tail, &cp.v_tail)?;
    let after0 = revived.device_gather(SESSION_ROWS)?;
    let base0 = baseline0.expect("captured before parking");
    let preempted_resume_bitident =
        bit_eq(&base0.0, &after0.0) && bit_eq(&base0.1, &after0.1);
    assert!(preempted_resume_bitident, "preempt-to-disk must be lossless");
    println!("preempted session rebuilt from its record — bit-identical gather");

    // ── ledger: conservation through every transition ───────────────────
    store.check_invariants().map_err(anyhow::Error::msg)?;
    let ss = store.stats();
    let store_conservation_ok = ss.checkpoints
        == ss.resumes + ss.superseded + ss.corrupt_records_skipped + ss.retained;
    assert!(store_conservation_ok, "store ledger must reconcile: {ss:?}");

    // ── timing: one checkpoint+take cycle on a 2-block tail ─────────────
    let cycle_cp = checkpoint_for(999, 9, 0, SESSION_ROWS);
    let t_cycle = bench_median(3, 50, || {
        store.checkpoint(&cycle_cp).expect("checkpoint");
        let t = store.take(999).expect("take");
        std::hint::black_box(t.checkpoint.total_rows);
    });
    println!(
        "checkpoint+take cycle ({} tail rows): {:.1} µs median",
        SESSION_ROWS,
        t_cycle.median_ns / 1e3
    );
    drop(arrival);
    drop(revived);

    // ── machine-readable report ─────────────────────────────────────────
    let ss = store.stats();
    let report = Json::obj()
        .with("bench", "durable_sessions")
        .with("resume_shared_rows", attached)
        .with("resume_prefix_new_blocks", resume_prefix_new_blocks)
        .with("resume_prefix_h2d_bytes", resume_prefix_h2d_bytes)
        // 0/1 gauges (not JSON booleans — the threshold gate compares
        // numbers only)
        .with("resume_bitident", u64::from(resume_bitident))
        .with("tiering_sheds", u64::from(tiering_sheds))
        .with("preempt_admits", u64::from(preempt_admits))
        .with("preempted_resume_bitident", u64::from(preempted_resume_bitident))
        .with("store_conservation_ok", u64::from(store_conservation_ok))
        .with("checkpoints", ss.checkpoints)
        .with("resumes", ss.resumes)
        .with("preempt_to_disk", ss.preempt_to_disk)
        .with("retained", ss.retained)
        .with("superseded", ss.superseded)
        .with("corrupt_records_skipped", ss.corrupt_records_skipped)
        .with("parked_resident", ss.parked_resident)
        .with("store_bytes", ss.store_bytes)
        .with("checkpoint_take_cycle_us", t_cycle.median_ns / 1e3);
    std::fs::write("BENCH_durable_sessions.json", report.to_string())?;
    println!("wrote BENCH_durable_sessions.json");
    let _ = std::fs::remove_file(&path);
    println!("\nshape check: zero-re-prefill resume + preempt-to-disk admission  ✓");
    Ok(())
}
