//! Perf baseline — per-op latency of every compiled program through the full
//! runtime path (marshal → lane queue → PJRT execute → readback).  This is
//! the §Perf L3 measurement harness: EXPERIMENTS.md records before/after of
//! the optimization passes from these numbers.
//!
//! ```bash
//! cargo bench --bench engine_hotpath
//! ```

use warp_cortex::model::Engine;
use warp_cortex::runtime::{DeviceHandle, DeviceOptions, Lane};
use warp_cortex::text::Tokenizer;
use warp_cortex::util::timer::bench_median;

fn main() -> anyhow::Result<()> {
    let model = std::env::var("WARP_BENCH_MODEL").unwrap_or_else(|_| "tiny".into());
    let device = DeviceHandle::new(DeviceOptions::from_env().with_configs(&[&model]))?;
    let engine = Engine::new(device.clone(), &model)?;
    let tk = Tokenizer::new();
    let manifest = device.manifest().config(&model)?.clone();

    let prompt = tk.encode(
        "user: tell me about the kv cache.\nriver: the cache grows one row \
         per token. the synapse selects landmark tokens.\nriver: ",
        true,
    );
    let mut kv = engine.new_main_cache();
    let pre = engine.prefill(&prompt, &mut kv, Lane::River)?;
    // grow context so decode pays a realistic upload
    {
        let v = engine.config().vocab_size;
        let mut logits = pre.logits[(pre.len - 1) * v..pre.len * v].to_vec();
        while kv.len() < 256 {
            let id = warp_cortex::util::vecmath::argmax(&logits) as i32;
            let id = if id >= 256 { 32 } else { id };
            logits = engine.decode(id, kv.len() as i32, &mut kv, Lane::River)?.logits;
        }
    }
    let hidden = pre.hidden_last.clone();

    // side cache for side/batch paths
    let s = engine.synapse_extract(&hidden, &kv, Lane::Background)?;
    let mut side_kv = engine.new_side_cache();
    side_kv.append_rows(s.indices.len(), &s.lm_k, &s.lm_v)?;
    let side_pos = s.source_len as i32;

    println!("═══ engine hot-path op latency ({model}) ═══\n");
    println!(
        "{:<26} {:>12} {:>12} {:>12} {:>14}",
        "op", "p50", "p10", "p90", "derived"
    );
    let flops_of = |prefix: &str| {
        manifest
            .artifacts
            .iter()
            .find(|a| a.program.starts_with(prefix))
            .map(|a| a.flops)
            .unwrap_or(0)
    };
    let print_row = |name: &str, stats: warp_cortex::util::timer::BenchStats, derived: String| {
        println!(
            "{:<26} {:>12} {:>12} {:>12} {:>14}",
            name,
            warp_cortex::util::timer::format_ns(stats.median_ns),
            warp_cortex::util::timer::format_ns(stats.p10_ns),
            warp_cortex::util::timer::format_ns(stats.p90_ns),
            derived
        );
        stats.median_ns
    };

    // decode (main cache) across context lengths — exercises the capacity-
    // tier dispatcher (§Perf opt A): short contexts route to small tiers.
    let mut decode_ns = 0.0;
    for target_len in [64usize, 120, 250, 400] {
        let mut base = kv.clone();
        // shrink/grow the working cache to the target length
        while base.len() > target_len {
            base = {
                let mut fresh = engine.new_main_cache();
                let (k, v) = kv.gather_rows(&(0..target_len).collect::<Vec<_>>());
                fresh.append_rows(target_len, &k, &v).unwrap();
                fresh
            };
        }
        while base.len() < target_len {
            engine.decode(32, base.len() as i32, &mut base, Lane::River)?;
        }
        let st = bench_median(5, 60, || {
            let mut c = base.clone();
            let out = engine.decode(32, c.len() as i32, &mut c, Lane::River).unwrap();
            std::hint::black_box(out);
        });
        decode_ns = st.median_ns;
        print_row(
            &format!("decode (main, len={target_len})"),
            st.clone(),
            format!("{:.0} tok/s", 1e9 / st.median_ns),
        );
    }

    // decode (side ctx)
    let st = bench_median(5, 40, || {
        let mut c = side_kv.clone();
        let out = engine.decode(32, side_pos, &mut c, Lane::Stream).unwrap();
        std::hint::black_box(out);
    });
    print_row(
        "decode (side, C=96)",
        st.clone(),
        format!("{:.0} tok/s", 1e9 / st.median_ns),
    );

    // batched side decode
    let b = engine.caps().decode_batch;
    let st = bench_median(3, 25, || {
        let mut caches: Vec<_> = (0..b).map(|_| side_kv.clone()).collect();
        let mut slots: Vec<(i32, i32, &mut warp_cortex::model::KvCache)> = caches
            .iter_mut()
            .map(|c| (32, side_pos, c))
            .collect();
        let out = engine.decode_batch(&mut slots, Lane::Stream).unwrap();
        std::hint::black_box(out);
    });
    print_row(
        &format!("decode_batch (B={b})"),
        st.clone(),
        format!("{:.0} tok/s", b as f64 * 1e9 / st.median_ns),
    );

    // prefill
    let st = bench_median(2, 15, || {
        let mut c = engine.new_main_cache();
        let out = engine.prefill(&prompt, &mut c, Lane::River).unwrap();
        std::hint::black_box(out);
    });
    let prefill_flops = flops_of("prefill") as f64;
    print_row(
        "prefill (S=128)",
        st.clone(),
        format!("{:.2} GFLOP/s", prefill_flops / st.median_ns),
    );

    // synapse extract
    let st = bench_median(3, 25, || {
        let out = engine.synapse_extract(&hidden, &kv, Lane::Background).unwrap();
        std::hint::black_box(out);
    });
    print_row(
        "synapse_extract (C=512)",
        st.clone(),
        format!("{:.2} GFLOP/s", flops_of("synapse") as f64 / st.median_ns),
    );

    // inject encode
    let thought = tk.encode("fact: a kilobyte", false);
    let st = bench_median(3, 25, || {
        let out = engine.inject_encode(&thought, 300, Lane::Stream).unwrap();
        std::hint::black_box(out);
    });
    print_row("inject_encode (T=16)", st.clone(), String::new());

    // dispatch overhead estimate: decode minus pure exec time
    let stats = device.stats();
    let mean_exec = stats.exec_ns as f64 / stats.ops.max(1) as f64;
    println!(
        "\ndispatch anatomy: decode p50 {} vs device-thread exec mean {} \
         (marshal + queue + wakeup ≈ {})",
        warp_cortex::util::timer::format_ns(decode_ns),
        warp_cortex::util::timer::format_ns(mean_exec),
        warp_cortex::util::timer::format_ns((decode_ns - mean_exec).max(0.0)),
    );
    println!(
        "device totals: {} ops, {:.1}% of wall in exec",
        stats.ops,
        100.0 * stats.exec_ns as f64 / stats.exec_ns.max(1) as f64
    );
    Ok(())
}
