//! Bench A3 — Referential Injection (§3.6) vs the traditional alternative
//! ("pasting text into the context, which disrupts the Main Agent's
//! generation flow").
//!
//! Both mechanisms deliver the same thought to the main agent; we measure
//! what each costs:
//!
//! * visible-stream disruption (tokens inserted into the text stream),
//! * wall latency on the main agent's critical path,
//! * KV growth,
//! * influence (max |Δlogit| on the next decode step) — both must influence
//!   generation, only text-paste may disrupt the stream.
//!
//! ```bash
//! cargo bench --bench ablation_injection
//! ```

use warp_cortex::cortex::Injector;
use warp_cortex::model::Engine;
use warp_cortex::runtime::{DeviceHandle, DeviceOptions, Lane};
use warp_cortex::text::Tokenizer;
use warp_cortex::util::timer::{bench_median, format_ns};

fn main() -> anyhow::Result<()> {
    let model = std::env::var("WARP_BENCH_MODEL").unwrap_or_else(|_| "tiny".into());
    let device = DeviceHandle::new(DeviceOptions::from_env().with_configs(&[&model]))?;
    let engine = Engine::new(device, &model)?;
    let tk = Tokenizer::new();
    let injector = Injector::new(16);

    // main agent mid-generation
    let prompt = tk.encode("user: what is a kilobyte?\nriver: a kilobyte is ", true);
    let mut kv = engine.new_main_cache();
    let pre = engine.prefill(&prompt, &mut kv, Lane::River)?;
    let pos = kv.len() as i32;
    let next_token = 32i32; // the token the main agent is about to decode

    let thought = tk.encode("fact: a kilobyte is 1024 bytes", false);
    let thought_len = thought.len().min(engine.caps().inject_len);

    // baseline next-step logits (no thought delivered)
    let baseline = {
        let mut c = kv.clone();
        engine.decode(next_token, pos, &mut c, Lane::River)?.logits
    };
    let influence = |logits: &[f32]| {
        logits
            .iter()
            .zip(&baseline)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    };

    println!("═══ A3: Referential Injection vs text-paste ═══\n");
    println!(
        "{:<22} {:>12} {:>14} {:>10} {:>12}",
        "mechanism", "disruption", "latency p50", "KV rows", "influence"
    );

    // ── Referential Injection ──
    let inj_lat = bench_median(2, 12, || {
        let mut c = kv.clone();
        injector
            .inject(&engine, &mut c, &thought, pos, Lane::Stream)
            .expect("inject");
        std::hint::black_box(&c);
    });
    let (inj_rows, inj_influence) = {
        let mut c = kv.clone();
        let report = injector.inject(&engine, &mut c, &thought, pos, Lane::Stream)?;
        let out = engine.decode(next_token, pos, &mut c, Lane::River)?;
        (report.rows, influence(&out.logits))
    };
    println!(
        "{:<22} {:>12} {:>14} {:>10} {:>12.4}",
        "referential inject",
        "0 tokens",
        inj_lat.format_time(),
        inj_rows,
        inj_influence
    );

    // ── Text paste: decode the thought tokens through the visible stream ──
    let paste_lat = bench_median(2, 12, || {
        let mut c = kv.clone();
        let mut p = pos;
        for &id in &thought[..thought_len] {
            engine.decode(id, p, &mut c, Lane::River).expect("decode");
            p += 1;
        }
        std::hint::black_box(&c);
    });
    let paste_influence = {
        let mut c = kv.clone();
        let mut p = pos;
        for &id in &thought[..thought_len] {
            engine.decode(id, p, &mut c, Lane::River)?;
            p += 1;
        }
        // the next "real" token now sits after the pasted text
        let out = engine.decode(next_token, p, &mut c, Lane::River)?;
        influence(&out.logits)
    };
    println!(
        "{:<22} {:>12} {:>14} {:>10} {:>12.4}",
        "text paste",
        format!("{thought_len} tokens"),
        paste_lat.format_time(),
        thought_len,
        paste_influence
    );

    println!(
        "\nper-token paste cost: {} — injection amortises the whole thought into \
         one reference pass off the River lane",
        format_ns(paste_lat.median_ns / thought_len as f64)
    );

    // the paper's positional-integrity claim: injected keys carry virtual
    // RoPE positions, so the main agent's own position bookkeeping (and its
    // visible stream) is unchanged — 0 disruption by construction, while
    // both mechanisms demonstrably influence the next-token distribution.
    assert!(inj_influence > 1e-4, "injection must influence generation");
    assert!(paste_influence > 1e-4);
    println!("\nshape check: 0-token disruption with non-zero influence  ✓");

    let _ = pre;
    Ok(())
}
