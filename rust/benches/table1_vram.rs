//! Bench T1 — reproduces **Table 1**: Theoretical VRAM Usage Comparison
//! (0.5B model on a 24 GB card), Standard Architecture vs Warp-Cortex.
//!
//! ```bash
//! cargo bench --bench table1_vram
//! ```
//!
//! Prints the paper's reported rows next to our analytic model's rows
//! (DESIGN.md §4: same arithmetic, run on the real Qwen2.5-0.5B config),
//! and flags the paper's internal max-agents inconsistency.

use warp_cortex::cortex::memory::{fmt_bytes, MemoryModel, GIB};
use warp_cortex::runtime::Manifest;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(Manifest::default_dir())?;
    let qwen = manifest
        .analytic
        .get("qwen2_5_0_5b")
        .expect("analytic qwen config in manifest");
    let m = MemoryModel::qwen05b_on_4090(qwen);

    println!("═══ Table 1: Theoretical VRAM Usage Comparison (0.5B model) ═══\n");
    println!(
        "{:<26} {:>16} {:>16} {:>14} {:>14} {:>14}",
        "Component", "Standard(paper)", "Warp(paper)", "Standard(ours)", "Warp(ours)", "Warp-q8(ours)"
    );
    let row = |name: &str, sp: &str, wp: &str, so: u64, wo: u64, wq: u64| {
        println!(
            "{:<26} {:>16} {:>16} {:>14} {:>14} {:>14}",
            name,
            sp,
            wp,
            fmt_bytes(so as f64),
            fmt_bytes(wo as f64),
            fmt_bytes(wq as f64)
        );
    };
    row(
        "Main model weights",
        "1.2 GB",
        "1.2 GB",
        m.weight_bytes,
        m.weight_bytes,
        m.weight_bytes,
    );
    row("Side agent weights", "1.2 GB", "0.0 GB (shared)", m.weight_bytes, 0, 0);
    row(
        "Side agent context",
        "~0.5 GB (full)",
        "0.01 GB (synapse)",
        m.full_ctx_bytes(),
        m.warp_agent_bytes(),
        m.warp_agent_bytes_q8(),
    );
    println!();
    println!(
        "{:<26} {:>16} {:>16} {:>14} {:>14} {:>14}",
        "Max agents (24 GB)",
        "≈ 12",
        "≈ 400",
        m.max_agents_standard(),
        m.max_agents_warp(),
        m.max_agents_warp_q8()
    );

    println!("\nnotes:");
    println!(
        "  • our per-side-agent context = synapse k={} rows + {} generation rows \
         + {} overhead = {}",
        m.synapse_k,
        m.side_gen,
        fmt_bytes(m.per_agent_overhead as f64),
        fmt_bytes(m.warp_agent_bytes() as f64)
    );
    println!(
        "  • synapse-only row (paper's 0.01 GB): {}",
        fmt_bytes(m.synapse_bytes() as f64)
    );
    println!(
        "  • compression vs full {}-token context: {:.2}% (paper claims 98%)",
        m.full_ctx,
        m.compression() * 100.0
    );
    println!(
        "  • Warp-q8 column: the tiered pool's warm tier (parked blocks as int8 \
         values + one f32 scale per row) shrinks per-agent KV to {} and lifts the \
         24 GB ceiling to {} agents",
        fmt_bytes(m.warp_agent_bytes_q8() as f64),
        m.max_agents_warp_q8()
    );
    println!(
        "  • PAPER INCONSISTENCY: with its own 0.01 GB/agent figure, (24 GB − 1.2 GB)/0.01 GB \
         ≈ {} agents, not 400; our model includes the ~12 MiB/agent runtime overhead the \
         paper's Table 2 measures but Table 1 omits, landing at {}.",
        ((24 * GIB - m.weight_bytes) / (10 * 1024 * 1024)) as u64,
        m.max_agents_warp()
    );

    // Shape assertions (who wins, by what order): fail loudly if broken.
    assert!(m.max_agents_standard() >= 10 && m.max_agents_standard() <= 16);
    assert!(m.max_agents_warp() > 20 * m.max_agents_standard());
    assert!(m.compression() > 0.98);
    // The warm int8 tier strictly extends the ceiling: smaller per-agent
    // KV, more agents, and the KV portion shrinks by > 1.5x (the per-row
    // scales keep it just under the raw 2x fp16→int8 halving).
    assert!(m.warp_agent_bytes_q8() < m.warp_agent_bytes());
    assert!(m.max_agents_warp_q8() > m.max_agents_warp());
    let kv32 = m.warp_agent_bytes() - m.per_agent_overhead;
    let kv8 = m.warp_agent_bytes_q8() - m.per_agent_overhead;
    assert!(kv8 * 3 < kv32 * 2, "q8 KV rows should be < 2/3 of fp32 rows");
    println!(
        "\nshape check: standard ≈ 12, warp ≫ standard, compression > 98%, \
         q8 ceiling > fp32 ceiling  ✓"
    );
    Ok(())
}
