//! Bench P5 — multi-session continuous batching: device ops per generated
//! token with S concurrent serving sessions fall toward 1/S of the
//! single-session fused baseline, because every admitted session's main
//! step rides the same per-tick fused op (the PR-5 tentpole) instead of
//! serializing one episode per blocked worker.
//!
//! Drives the real [`StepScheduler`] — session admission, FIFO permits,
//! the cross-session gather window, per-tick multi-main collection and
//! fan-back — over the deterministic host-only stub executor from
//! `cortex/step.rs::testing` (ONE home for the op-accounting rules, so
//! this bench can never drift from the semantics the scheduling-
//! equivalence proptests pin).  Each session runs on its own thread and
//! blocks on its per-step reply, exactly like a serving worker.
//!
//! CI asserts (via `ci/check_bench.py` over the emitted
//! `BENCH_multi_session.json`):
//!
//! * ops/token at 8 concurrent sessions ≤ 0.6× the 1-session fused
//!   baseline,
//! * and strictly below sequential-episode serving (the S-episodes-in-a-
//!   row reference, which pays one op per token),
//! * no main step ever deferred behind side work (`main_deferred == 0`),
//! * all 8 sessions admitted and completed (gauges reconcile).
//!
//! ```bash
//! cargo bench --bench multi_session
//! ```

use std::sync::Arc;
use std::time::Duration;

use warp_cortex::cortex::step::testing::stub_exec;
use warp_cortex::cortex::{StepConfig, StepScheduler, StepSeams};
use warp_cortex::model::{KvPool, KvPoolConfig};
use warp_cortex::runtime::ModelConfig;
use warp_cortex::util::Json;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "tiny".into(),
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 2,
        d_ff: 32,
        vocab_size: 260,
        head_dim: 8,
        rope_theta: 1e4,
        param_count: 0,
    }
}

const SIDE_CTX: usize = 96;
const BATCH_WIDTH: usize = 8;
const SESSIONS: usize = 8;
const TOKENS_PER_SESSION: usize = 64;

fn scheduler(pool: &Arc<KvPool>) -> Arc<StepScheduler> {
    StepScheduler::new(
        StepConfig {
            batch_width: BATCH_WIDTH,
            side_ctx: SIDE_CTX,
            max_active: 4,
            max_parked: 64,
            max_sessions: SESSIONS,
            max_parked_sessions: SESSIONS,
            // Generous gather window so the bench is deterministic on slow
            // CI machines: with instant stub ops, ticks would otherwise
            // race the session threads' resubmissions.
            main_gather: Duration::from_millis(2),
            ..StepConfig::default()
        },
        StepSeams::new(
            stub_exec(tiny_cfg(), SIDE_CTX, BATCH_WIDTH),
            // No side tasks in this bench; the spawner is never called.
            {
                let pool = pool.clone();
                Arc::new(move |t| {
                    warp_cortex::cortex::SideAgent::from_parts(
                        t,
                        warp_cortex::cortex::AgentCache::Bare(pool.new_cache(SIDE_CTX)),
                        0,
                        1,
                        vec![],
                        0,
                        warp_cortex::text::SamplerConfig::greedy(),
                    )
                })
            },
        ),
    )
}

/// Run `sessions` concurrent sessions of `tokens` main steps each and
/// return (ops_per_token, occupancy, admitted, completed, main_deferred).
fn run_concurrent(pool: &Arc<KvPool>, sessions: usize, tokens: usize) -> (f64, f64, u64, u64, u64) {
    let sched = scheduler(pool);
    std::thread::scope(|scope| {
        for s in 0..sessions {
            let sched = sched.clone();
            let pool = pool.clone();
            scope.spawn(move || {
                let _permit = sched.open_session().expect("session under the cap admits");
                let mut kv = pool.new_cache(256);
                for step in 0..tokens {
                    let tok = ((s * 37 + step) % 200) as i32;
                    sched
                        .main_step(tok, kv.len() as i32, &mut kv)
                        .expect("main step");
                }
            });
        }
    });
    let st = sched.stats();
    let ss = sched.session_stats();
    assert_eq!(st.main_steps, (sessions * tokens) as u64, "lost main steps");
    let out = (
        st.ops_per_token(),
        ss.occupancy,
        ss.admitted,
        ss.completed,
        st.main_deferred,
    );
    sched.shutdown();
    out
}

fn main() -> anyhow::Result<()> {
    let cfg = tiny_cfg();
    let pool = KvPool::new(
        &cfg,
        KvPoolConfig {
            block_tokens: 16,
            ..KvPoolConfig::default()
        },
    );

    println!("═══ P5: multi-session continuous batching (ops per token vs concurrent sessions) ═══\n");

    // ── sequential-episode serving: S episodes one after another, each
    //    paying one op per token (the pre-session serving path) ──
    let mut seq_ops_per_token_acc = 0.0;
    for _ in 0..SESSIONS {
        let (opt, _, _, _, _) = run_concurrent(&pool, 1, TOKENS_PER_SESSION);
        seq_ops_per_token_acc += opt;
    }
    let sequential_ops_per_token = seq_ops_per_token_acc / SESSIONS as f64;
    println!("sequential-episode serving: {sequential_ops_per_token:.3} ops/token");
    assert!(
        (sequential_ops_per_token - 1.0).abs() < 1e-9,
        "a lone session pays exactly one op per token"
    );

    // ── fused path: ops/token vs concurrent session count ──
    println!(
        "\n{:>10} {:>12} {:>12} {:>10}",
        "sessions", "ops/token", "occupancy", "deferred"
    );
    let mut curve = Vec::new();
    let mut measured_admitted = 0u64;
    let mut measured_deferred = 0u64;
    for &s in &[1usize, 2, 4, 8] {
        let (opt, occ, admitted, completed, deferred) =
            run_concurrent(&pool, s, TOKENS_PER_SESSION);
        println!("{s:>10} {opt:>12.3} {occ:>12.2} {deferred:>10}");
        assert_eq!(admitted, s as u64, "all sessions must admit");
        assert_eq!(completed, s as u64, "all sessions must complete");
        assert_eq!(deferred, 0, "mains must never defer behind side work");
        curve.push((s, opt, occ));
        measured_admitted = admitted;
        measured_deferred = deferred;
    }
    let at_1 = curve[0].1;
    let (_, at_8, occ_8) = *curve.last().expect("curve has the 8-session point");

    // ── acceptance criteria (mirrored in ci/thresholds.json) ──
    assert!(
        (at_1 - 1.0).abs() < 1e-9,
        "1-session fused baseline must be 1.0 ops/token, got {at_1}"
    );
    assert!(
        at_8 <= 0.6 * at_1,
        "ops/token at 8 sessions is {at_8:.3}, expected ≤ 0.6× the 1-session baseline {at_1:.3}"
    );
    assert!(
        at_8 < sequential_ops_per_token,
        "fused multi-session serving must beat sequential episodes"
    );
    assert!(
        occ_8 > 1.0,
        "session occupancy {occ_8:.2} must exceed one stream per tick"
    );

    // Machine-readable report, gated by ci/check_bench.py (declarative
    // thresholds in ci/thresholds.json — no inline CI heredoc).
    let mut report = Json::obj()
        .with("bench", "multi_session")
        .with("batch_width", BATCH_WIDTH)
        .with("sessions", SESSIONS)
        .with("tokens_per_session", TOKENS_PER_SESSION)
        .with("sequential_ops_per_token", sequential_ops_per_token)
        .with("ops_per_token_at_1", at_1)
        .with("ops_per_token_at_8", at_8)
        .with("occupancy_at_8", occ_8)
        .with("sessions_admitted", measured_admitted)
        .with("main_deferred", measured_deferred);
    for (s, opt, _) in &curve {
        if *s != 1 && *s != 8 {
            report = report.with(format!("ops_per_token_at_{s}").as_str(), *opt);
        }
    }
    std::fs::write("BENCH_multi_session.json", report.to_string())?;
    println!("\nwrote BENCH_multi_session.json");

    println!(
        "\nshape check: 1.0 ops/token sequential → {at_8:.3} at {SESSIONS} concurrent sessions \
         (occupancy {occ_8:.1})  ✓"
    );
    Ok(())
}
