//! Bench P6 — chunked prefill interleaved with the fused decode tick:
//! admitting a max-length prompt while four sessions decode must never
//! add more than ONE device op to any tick (bounded TPOT), while the
//! prompt still finishes in `ceil(prompt/budget)` ticks (bounded TTFT)
//! instead of stalling the whole batch for one monolithic prefill.
//!
//! Drives the real [`StepScheduler`] — budgeted prefill lanes, the
//! fair decode/prefill interleave, per-tick fan-back — over the
//! deterministic host-only stub executor from `cortex/step.rs::testing`,
//! wrapped in a counting executor that logs every tick's `device_ops`.
//! The decode population replays a `workload::generate` Poisson trace
//! (the trace fixes the session count, admission order and generation
//! lengths; arrivals are replayed closed-loop, not in real time).  Two
//! IDENTICAL long prompts prefill in interleaved chunks from one driver
//! thread, so the second must adopt blocks the first registers
//! *mid-prefill* — the copy-on-write registry working inside the
//! prefill window, not just at episode start.
//!
//! CI asserts (via `ci/check_bench.py` over the emitted
//! `BENCH_prefill_interleave.json`):
//!
//! * p99 (and max) device ops per tick ≤ 2 — one fused op plus at most
//!   the single budgeted prefill chunk that has outgrown a batch lane,
//! * mid-prefill registry hits > 0 — the interleaved twin prompt
//!   attached blocks registered while its sibling was still prefilling,
//! * no prefill chunk and no decode main ever deferred at this load.
//!
//! ```bash
//! cargo bench --bench prefill_interleave
//! ```

use std::sync::{Arc, Mutex};
use std::time::Duration;

use warp_cortex::cortex::step::testing::{stub_exec, stub_raw};
use warp_cortex::cortex::{FusedExec, StepConfig, StepScheduler, StepSeams};
use warp_cortex::model::{ChunkedPrefill, FusedReq, KvPool, KvPoolConfig, MainLane};
use warp_cortex::runtime::ModelConfig;
use warp_cortex::util::Json;
use warp_cortex::workload::{generate, Arrivals, WorkloadConfig};

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "tiny".into(),
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 2,
        d_ff: 32,
        vocab_size: 260,
        head_dim: 8,
        rope_theta: 1e4,
        param_count: 0,
    }
}

const SIDE_CTX: usize = 96;
const BATCH_WIDTH: usize = 8;
const BLOCK_TOKENS: usize = 16;
const DECODERS: usize = 4;
/// Longer than `SIDE_CTX`, so the prompt's tail chunks outgrow a batch
/// lane and must run as their own (budget-bounded) op.
const PROMPT_LEN: usize = 120;

fn main() -> anyhow::Result<()> {
    let cfg = tiny_cfg();
    let pool = KvPool::new(
        &cfg,
        KvPoolConfig {
            block_tokens: BLOCK_TOKENS,
            ..KvPoolConfig::default()
        },
    );

    // Per-tick device-op log: the inter-token latency proxy this bench
    // gates on (every tick is one inter-token interval for all decoders).
    let per_tick: Arc<Mutex<Vec<u64>>> = Arc::new(Mutex::new(Vec::new()));
    let exec: FusedExec = {
        let inner = stub_exec(cfg.clone(), SIDE_CTX, BATCH_WIDTH);
        let per_tick = per_tick.clone();
        Arc::new(move |mains: &[MainLane], sides: &[FusedReq], fuse: bool| {
            let out = inner(mains, sides, fuse)?;
            per_tick.lock().expect("tick log").push(out.device_ops);
            Ok(out)
        })
    };
    let sched = StepScheduler::new(
        StepConfig {
            batch_width: BATCH_WIDTH,
            side_ctx: SIDE_CTX,
            max_active: 4,
            max_parked: 64,
            max_sessions: DECODERS + 2,
            max_parked_sessions: DECODERS + 2,
            // One chunk per tick: the tightest TPOT bound (and the
            // slowest TTFT) the knob allows — the worst case to gate.
            prefill_budget: 1,
            // Generous gather window so the bench is deterministic on
            // slow CI machines (same reasoning as multi_session).
            main_gather: Duration::from_millis(2),
            ..StepConfig::default()
        },
        StepSeams::new(exec, {
            let pool = pool.clone();
            // No side tasks in this bench; the spawner is never called.
            Arc::new(move |t| {
                warp_cortex::cortex::SideAgent::from_parts(
                    t,
                    warp_cortex::cortex::AgentCache::Bare(pool.new_cache(SIDE_CTX)),
                    0,
                    1,
                    vec![],
                    0,
                    warp_cortex::text::SamplerConfig::greedy(),
                )
            })
        }),
    );

    println!("═══ P6: chunked prefill interleaved with the fused decode tick ═══\n");

    // ── decode population: replay a Poisson trace, closed-loop ──────────
    let trace = generate(&WorkloadConfig {
        seed: 17,
        requests: DECODERS,
        arrivals: Arrivals::Poisson(64.0),
        min_tokens: 24,
        max_tokens: 56,
        trigger_prob: 0.3,
    });
    // The two identical long prompts that prefill mid-flight.
    let prompt: Vec<i32> = (0..PROMPT_LEN).map(|i| ((i * 7 + 3) % 200) as i32).collect();

    let prefill_result: Mutex<Option<(usize, usize)>> = Mutex::new(None);
    std::thread::scope(|scope| {
        // Decode sessions: one serving worker per trace request, admission
        // in arrival order, generation length from the trace.
        for req in &trace {
            let sched = sched.clone();
            let pool = pool.clone();
            scope.spawn(move || {
                let _permit = sched.open_session().expect("session under the cap admits");
                let toks: Vec<i32> = req.prompt.bytes().map(|b| i32::from(b % 200)).collect();
                let mut kv = pool.new_cache(256);
                for step in 0..req.max_tokens {
                    let tok = toks[step % toks.len()];
                    sched
                        .main_step(tok, kv.len() as i32, &mut kv)
                        .expect("main step");
                }
            });
        }
        // Prefill driver: prompt A starts cold; once A has two full blocks
        // registered, its twin B begins and the two interleave chunk by
        // chunk — B's block-boundary probes must then adopt blocks A
        // registered mid-prefill.
        let sched = sched.clone();
        let pool = pool.clone();
        let prefill_result = &prefill_result;
        let prompt = &prompt;
        scope.spawn(move || {
            let _pa = sched.open_session().expect("prefill session A admits");
            let _pb = sched.open_session().expect("prefill session B admits");
            let mut kv_a = pool.new_cache(PROMPT_LEN + 8);
            let mut kv_b = pool.new_cache(PROMPT_LEN + 8);
            let mut cp_a = ChunkedPrefill::begin(prompt, &mut kv_a).expect("A begins");
            assert_eq!(cp_a.adopted_rows(), 0, "A starts cold");
            while kv_a.len() < 2 * BLOCK_TOKENS {
                let (tok, pos) = cp_a.next_lane(&mut kv_a).expect("A has rows left");
                sched.prefill_step(tok, pos, &mut kv_a).expect("A chunk");
                cp_a.advance(&mut kv_a);
            }
            let mut cp_b = ChunkedPrefill::begin(prompt, &mut kv_b).expect("B begins");
            let (mut last_a, mut last_b) = (None, None);
            while !(cp_a.is_done() && cp_b.is_done()) {
                if let Some((tok, pos)) = cp_a.next_lane(&mut kv_a) {
                    last_a = Some(sched.prefill_step(tok, pos, &mut kv_a).expect("A chunk"));
                    cp_a.advance(&mut kv_a);
                }
                if let Some((tok, pos)) = cp_b.next_lane(&mut kv_b) {
                    last_b = Some(sched.prefill_step(tok, pos, &mut kv_b).expect("B chunk"));
                    cp_b.advance(&mut kv_b);
                }
            }
            // Chunked ≡ monolithic: both streams end on the reference
            // final-token decode, regardless of how many blocks B adopted.
            let want = stub_raw(
                &tiny_cfg(),
                prompt[PROMPT_LEN - 1],
                (PROMPT_LEN - 1) as i32,
                PROMPT_LEN - 1,
            );
            assert_eq!(last_a.expect("A decoded its tail").logits, want.logits);
            assert_eq!(last_b.expect("B decoded its tail").logits, want.logits);
            *prefill_result.lock().expect("prefill result") =
                Some((cp_a.tail_steps(), cp_b.adopted_rows()));
        });
    });

    let st = sched.stats();
    let ss = sched.session_stats();
    let ps = pool.stats();
    let (a_steps, b_adopted) = prefill_result
        .lock()
        .expect("prefill result")
        .take()
        .expect("prefill driver finished");
    sched.shutdown();

    let mut ops = per_tick.lock().expect("tick log").clone();
    assert!(!ops.is_empty(), "the run must have ticked");
    ops.sort_unstable();
    let p99_idx = ((ops.len() as f64 * 0.99).ceil() as usize).saturating_sub(1);
    let p99_ops_per_tick = ops[p99_idx] as f64;
    let max_ops_per_tick = *ops.last().expect("non-empty") as f64;

    let decode_steps: usize = trace.iter().map(|r| r.max_tokens).sum();
    println!("{:>22} {}", "ticks", st.ticks);
    println!("{:>22} {}", "device ops", st.device_ops);
    println!("{:>22} {:.3}", "ops/token", st.ops_per_token());
    println!("{:>22} {p99_ops_per_tick}", "p99 ops/tick");
    println!("{:>22} {max_ops_per_tick}", "max ops/tick");
    println!("{:>22} {}", "prefill chunks", st.prefill_steps);
    println!("{:>22} {}", "mid-prefill hits", ps.prefix_mid_hits);
    println!("{:>22} {b_adopted}", "rows B adopted");

    // ── acceptance criteria (mirrored in ci/thresholds.json) ────────────
    assert_eq!(st.main_steps, decode_steps as u64, "lost decode steps");
    assert_eq!(
        ss.completed,
        (DECODERS + 2) as u64,
        "all sessions must complete"
    );
    assert!(
        p99_ops_per_tick <= 2.0 && max_ops_per_tick <= 2.0,
        "a prefilling prompt may add at most one op to a tick \
         (p99 {p99_ops_per_tick}, max {max_ops_per_tick})"
    );
    assert!(
        ps.prefix_mid_hits > 0,
        "the twin prompt must hit blocks registered mid-prefill"
    );
    assert!(
        b_adopted > 0 && a_steps + b_adopted > PROMPT_LEN,
        "B must skip rows A already filled (adopted {b_adopted})"
    );
    assert_eq!(st.prefill_deferred, 0, "budget 1 never defers a lone driver");
    assert_eq!(st.main_deferred, 0, "decode never waits behind prefill lanes");

    // Machine-readable report, gated by ci/check_bench.py (declarative
    // thresholds in ci/thresholds.json — no inline CI heredoc).
    let report = Json::obj()
        .with("bench", "prefill_interleave")
        .with("batch_width", BATCH_WIDTH)
        .with("decoders", DECODERS)
        .with("prompt_len", PROMPT_LEN)
        .with("prefill_budget", 1u64)
        .with("ticks", st.ticks)
        .with("device_ops", st.device_ops)
        .with("ops_per_token", st.ops_per_token())
        .with("p99_ops_per_tick", p99_ops_per_tick)
        .with("max_ops_per_tick", max_ops_per_tick)
        .with("main_steps", st.main_steps)
        .with("prefill_steps", st.prefill_steps)
        .with("prefill_deferred", st.prefill_deferred)
        .with("main_deferred", st.main_deferred)
        .with("mid_prefill_hits", ps.prefix_mid_hits)
        .with("rows_adopted_by_twin", b_adopted as u64);
    std::fs::write("BENCH_prefill_interleave.json", report.to_string())?;
    println!("\nwrote BENCH_prefill_interleave.json");

    println!(
        "\nshape check: {PROMPT_LEN}-token prompt prefilled under budget 1 while {DECODERS} \
         sessions decoded — p99 {p99_ops_per_tick} ops/tick, twin adopted {b_adopted} rows  ✓"
    );
    Ok(())
}
