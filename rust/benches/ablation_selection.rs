//! Bench A1 — ablation of the §3.3 landmark-selection policy.
//!
//! The paper claims hybrid density-coverage landmarking preserves the
//! semantics of the full context ("98% compression without semantic loss").
//! We measure that downstream, not rhetorically: a side agent seeded with
//! k landmark rows teacher-forces the SAME continuation the full-context
//! main agent produced, and we compare its per-step logits to the main
//! agent's.  Policies:
//!
//! * `hybrid`    — the paper's sampler (α = 0.5)          [Pallas kernel]
//! * `attn-only` — attention-mass top-k (α = 1)           [Pallas kernel]
//! * `coverage`  — density/coverage only (α = 0)          [Pallas kernel]
//! * `recency`   — last k rows (sliding window baseline)
//! * `stride`    — every ⌈L/k⌉-th row (uniform skeleton)
//! * `random`    — k uniformly random rows (seeded)
//!
//! ```bash
//! cargo bench --bench ablation_selection
//! ```

use warp_cortex::model::{Engine, KvCache};
use warp_cortex::runtime::{DeviceHandle, DeviceOptions, Lane};
use warp_cortex::text::Tokenizer;
use warp_cortex::util::rng::XorShift;
use warp_cortex::util::vecmath::argmax;

const CONTINUATION: usize = 24;

struct Eval {
    agree_at_1: f64,
    mean_abs: f64,
    kl: f64,
}

fn softmax(v: &[f32]) -> Vec<f64> {
    let m = v.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let exps: Vec<f64> = v.iter().map(|x| ((*x as f64) - m).exp()).collect();
    let z: f64 = exps.iter().sum();
    exps.into_iter().map(|e| e / z).collect()
}

fn evaluate(
    engine: &Engine,
    mut side_kv: KvCache,
    start_pos: i32,
    tokens: &[i32],
    reference: &[Vec<f32>],
) -> anyhow::Result<Eval> {
    let mut agree = 0usize;
    let mut abs = 0.0f64;
    let mut kl = 0.0f64;
    let mut pos = start_pos;
    for (t, (&tok, ref_logits)) in tokens.iter().zip(reference).enumerate() {
        let out = engine.decode(tok, pos, &mut side_kv, Lane::Stream)?;
        if argmax(&out.logits) == argmax(ref_logits) {
            agree += 1;
        }
        abs += out
            .logits
            .iter()
            .zip(ref_logits)
            .map(|(a, b)| (a - b).abs() as f64)
            .sum::<f64>()
            / out.logits.len() as f64;
        let p = softmax(ref_logits);
        let q = softmax(&out.logits);
        kl += p
            .iter()
            .zip(&q)
            .map(|(pi, qi)| if *pi > 0.0 { pi * (pi / qi.max(1e-12)).ln() } else { 0.0 })
            .sum::<f64>();
        pos += 1;
        let _ = t;
    }
    let n = tokens.len() as f64;
    Ok(Eval {
        agree_at_1: agree as f64 / n,
        mean_abs: abs / n,
        kl: kl / n,
    })
}

fn main() -> anyhow::Result<()> {
    let model = std::env::var("WARP_BENCH_MODEL").unwrap_or_else(|_| "tiny".into());
    let device = DeviceHandle::new(DeviceOptions::from_env().with_configs(&[&model]))?;
    let engine = Engine::new(device, &model)?;
    let tk = Tokenizer::new();
    let k = engine.caps().synapse_k;

    // ── full-context reference run ──
    let prompt = tk.encode(
        "user: tell me about the kv cache.\nriver: the cache grows one row \
         per token. the synapse selects landmark tokens.\nriver: ",
        true,
    );
    let mut kv = engine.new_main_cache();
    let pre = engine.prefill(&prompt, &mut kv, Lane::River)?;
    let v = engine.config().vocab_size;
    let mut logits = pre.logits[(pre.len - 1) * v..pre.len * v].to_vec();
    let mut hidden = pre.hidden_last.clone();

    // grow the context to ~4.5x the landmark budget
    while kv.len() < (engine.caps().side_ctx - CONTINUATION).max(4 * k + 40) {
        let id = argmax(&logits) as i32;
        let id = if id >= 256 { 32 } else { id };
        let out = engine.decode(id, kv.len() as i32, &mut kv, Lane::River)?;
        logits = out.logits;
        hidden = out.hidden;
    }
    let source_len = kv.len();

    // the main agent's own continuation + its logits = the reference
    let mut tokens = Vec::new();
    let mut reference = Vec::new();
    {
        let mut main_kv = kv.clone();
        let mut lg = logits.clone();
        let mut pos = source_len as i32;
        for _ in 0..CONTINUATION {
            let id = argmax(&lg) as i32;
            let id = if id >= 256 { 32 } else { id };
            let out = engine.decode(id, pos, &mut main_kv, Lane::River)?;
            tokens.push(id);
            reference.push(out.logits.clone());
            lg = out.logits;
            pos += 1;
        }
    }

    println!("═══ A1: landmark-selection policy ablation ═══");
    println!(
        "\ncontext {} rows → k = {} landmarks ({:.1}% compression); \
         teacher-forced {}-token continuation vs full-context logits\n",
        source_len,
        k,
        (1.0 - k as f64 / source_len as f64) * 100.0,
        CONTINUATION
    );
    println!(
        "{:<12} {:>10} {:>14} {:>12}",
        "policy", "agree@1", "mean|Δlogit|", "KL(full‖side)"
    );

    let seed_from_extract = |alpha: f32| -> anyhow::Result<KvCache> {
        let s = engine.synapse_extract_with(&hidden, &kv, alpha, engine.inv2sig2, Lane::Stream)?;
        let mut side = engine.new_side_cache();
        side.append_rows(s.indices.len(), &s.lm_k, &s.lm_v)?;
        Ok(side)
    };
    let seed_from_indices = |idx: &[usize]| -> anyhow::Result<KvCache> {
        let (kr, vr) = kv.gather_rows(idx);
        let mut side = engine.new_side_cache();
        side.append_rows(idx.len(), &kr, &vr)?;
        Ok(side)
    };

    let mut results: Vec<(String, Eval)> = Vec::new();
    for (name, cache) in [
        ("hybrid", seed_from_extract(0.5)?),
        ("attn-only", seed_from_extract(1.0)?),
        ("coverage", seed_from_extract(0.0)?),
        ("recency", seed_from_indices(&((source_len - k)..source_len).collect::<Vec<_>>())?),
        (
            "stride",
            seed_from_indices(
                &(0..k).map(|i| i * source_len / k).collect::<Vec<_>>(),
            )?,
        ),
        ("random", {
            let mut rng = XorShift::new(404);
            let mut idx: Vec<usize> = Vec::new();
            while idx.len() < k {
                let c = rng.below(source_len as u64) as usize;
                if !idx.contains(&c) {
                    idx.push(c);
                }
            }
            idx.sort_unstable();
            seed_from_indices(&idx)?
        }),
    ] {
        let eval = evaluate(&engine, cache, source_len as i32, &tokens, &reference)?;
        println!(
            "{:<12} {:>9.1}% {:>14.4} {:>12.4}",
            name,
            eval.agree_at_1 * 100.0,
            eval.mean_abs,
            eval.kl
        );
        results.push((name.to_string(), eval));
    }

    let get = |n: &str| results.iter().find(|(name, _)| name == n).unwrap().1.kl;
    println!(
        "\nshape check: hybrid (KL {:.4}) ≤ random (KL {:.4}) — informed selection \
         beats uninformed at equal budget",
        get("hybrid"),
        get("random")
    );
    assert!(
        get("hybrid") <= get("random") * 1.05,
        "hybrid should not lose to random"
    );
    Ok(())
}
