//! Bench P2 — device-resident paged decode: per-step host→device traffic
//! is O(new row + block table), not O(capacity).
//!
//! The seed's decode hot path re-uploaded the full gathered cache every
//! token (`prefix_upload(capacity)` per step per agent); since the
//! device-resident refactor a step ships the freshly produced row
//! (write-through at append) plus the block table (gather), and the K/V
//! itself is read from the pool's resident block copies.  This bench
//! measures the pool's `h2d_bytes` gauge around simulated decode steps and
//! *asserts* the O(k) claim — it runs in the CI bench-smoke step.
//!
//! Pure host-side — the device slab stands in for PJRT buffers with
//! identical layout and write-through/gather semantics:
//!
//! ```bash
//! cargo bench --bench decode_upload
//! ```

use warp_cortex::cortex::memory::fmt_bytes;
use warp_cortex::model::{KvPool, KvPoolConfig};
use warp_cortex::runtime::ModelConfig;
use warp_cortex::util::rng::XorShift;
use warp_cortex::util::timer::bench_median;
use warp_cortex::util::Json;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "tiny".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 4,
        n_kv_heads: 2,
        d_ff: 192,
        vocab_size: 260,
        head_dim: 16,
        rope_theta: 1e4,
        param_count: 116_032,
    }
}

const FILL: usize = 100;
const STEPS: usize = 40;

fn main() -> anyhow::Result<()> {
    let cfg = tiny_cfg();
    let pool = KvPool::new(
        &cfg,
        KvPoolConfig {
            block_tokens: 16,
            ..KvPoolConfig::default()
        },
    );
    let row_floats = cfg.n_layers * cfg.n_kv_heads * cfg.head_dim;
    let row_bytes = (row_floats * 2 * 4) as u64; // K+V, f32
    let mut rng = XorShift::new(0xDEC0DE);

    println!("═══ P2: device-resident paged decode (upload bytes per step) ═══\n");
    println!(
        "{:>10} {:>6} {:>14} {:>14} {:>9}",
        "capacity", "fill", "per-step h2d", "flat re-upload", "saving"
    );

    // Two caches with very different configured capacities, same fill: the
    // per-step upload must not see the capacity at all.  (Both leave room
    // for FILL + STEPS rows.)
    let capacities = [160usize, 2048];
    let mut per_step = Vec::new();
    for &capacity in &capacities {
        let mut kv = pool.new_cache(capacity);
        for _ in 0..FILL {
            let r: Vec<f32> = (0..row_floats).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            kv.append_row(&r, &r)?;
        }
        let before = pool.stats().h2d_bytes;
        let mut expected = 0u64;
        for _ in 0..STEPS {
            // one decode step: paged gather (ships the block table) + the
            // write-through of the newly produced row
            expected += kv.paged().upload_bytes() + row_bytes;
            let (k_up, v_up) = kv.device_gather(capacity)?;
            std::hint::black_box((&k_up, &v_up));
            let r: Vec<f32> = (0..row_floats).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            kv.append_row(&r, &r)?;
        }
        let delta = pool.stats().h2d_bytes - before;
        // exact composition: every step paid table + len scalar + one row
        assert_eq!(
            delta, expected,
            "per-step upload accounting drifted from table+row"
        );
        let step = delta / STEPS as u64;
        // the flat path re-uploaded the full [L, C, KV, hd] K and V
        let flat = capacity as u64 * row_bytes;
        println!(
            "{:>10} {:>6} {:>14} {:>14} {:>8.0}x",
            capacity,
            kv.len(),
            fmt_bytes(step as f64),
            fmt_bytes(flat as f64),
            flat as f64 / step as f64
        );
        per_step.push(step);

        // ── the acceptance criteria ──
        // 1. O(k), not O(capacity): orders of magnitude under the flat
        //    re-upload even at the SMALL capacity.
        assert!(
            step * 50 < flat,
            "per-step upload {step} B is not ≪ flat {flat} B (capacity {capacity})"
        );
        // 2. bounded by row + table, with no hidden capacity term.
        assert!(
            step <= row_bytes + kv.paged().upload_bytes(),
            "per-step upload {step} B exceeds row + block table"
        );
    }
    // 3. capacity-independent: a 16x larger cache pays identical bytes.
    assert_eq!(
        per_step[0], per_step[1],
        "per-step upload must not depend on configured capacity"
    );

    // The batcher-channel payload shrink (Request carries a PagedKv now).
    let kv = {
        let mut kv = pool.new_cache(2048);
        for _ in 0..FILL {
            let r: Vec<f32> = (0..row_floats).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            kv.append_row(&r, &r)?;
        }
        kv
    };
    let paged = kv.paged();
    let flat_req = 2048 * row_bytes; // the k + v vectors a request used to carry
    println!(
        "\nbatcher request payload: {} (block table) vs {} (flat K/V) — {:.0}x smaller",
        fmt_bytes(paged.upload_bytes() as f64),
        fmt_bytes(flat_req as f64),
        flat_req as f64 / paged.upload_bytes() as f64
    );
    assert!(paged.upload_bytes() * 100 < flat_req);

    // Gather throughput: device-side paged gather vs the host flat gather.
    let t_dev = bench_median(3, 50, || {
        let (k, v) = kv.device_gather(2048).expect("gather");
        std::hint::black_box((k, v));
    });
    let t_host = bench_median(3, 50, || {
        let (k, v) = kv.prefix_upload(2048);
        std::hint::black_box((k, v));
    });
    println!(
        "gather at c=2048, {} rows: device-resident {:.1} µs vs host flat {:.1} µs median",
        kv.len(),
        t_dev.median_ns / 1e3,
        t_host.median_ns / 1e3
    );

    // Machine-readable report (published as a CI artifact and
    // threshold-checked alongside BENCH_prefix_share.json).
    let flat_large = capacities[1] as u64 * row_bytes;
    let report = Json::obj()
        .with("bench", "decode_upload")
        .with("fill_rows", FILL)
        .with("steps", STEPS)
        .with("per_step_h2d_bytes", per_step[0])
        .with("flat_reupload_bytes", flat_large)
        .with("saving_x", flat_large as f64 / per_step[0].max(1) as f64)
        .with("request_payload_bytes", paged.upload_bytes())
        .with("flat_request_bytes", flat_req)
        .with("dev_gather_us", t_dev.median_ns / 1e3)
        .with("host_gather_us", t_host.median_ns / 1e3);
    std::fs::write("BENCH_decode_upload.json", report.to_string())?;
    println!("wrote BENCH_decode_upload.json");

    println!("\nshape check: per-step upload is O(new row + block table)  ✓");
    Ok(())
}
