//! Bench M1 — the title/abstract claim: "million-agent cognitive scaling",
//! "theoretical capacity exceeding 1,000 agents before compute latency
//! becomes the bottleneck".
//!
//! Feeds MEASURED per-op costs from this machine into the two-resource
//! capacity model (`cortex::capacity`) and prints the scaling curve for
//! (a) this CPU substrate and (b) the paper's RTX-4090/0.5B testbed with
//! compute costs scaled by the FLOP ratio — reporting, at every population,
//! which resource binds.
//!
//! ```bash
//! cargo bench --bench million_scale
//! ```

use warp_cortex::cortex::capacity::{Bottleneck, CapacityModel, ComputeCosts};
use warp_cortex::cortex::memory::{fmt_bytes, MemoryModel};
use warp_cortex::model::Engine;
use warp_cortex::runtime::{DeviceHandle, DeviceOptions, Lane, Manifest};
use warp_cortex::text::Tokenizer;
use warp_cortex::util::timer::bench_median;

fn print_curve(tag: &str, model: &CapacityModel) {
    println!("\n{tag}:");
    println!(
        "{:>10} {:>14} {:>12} {:>12}",
        "agents", "memory", "device util", "state"
    );
    for p in model.curve(1_000_000).expect("valid capacity model") {
        println!(
            "{:>10} {:>14} {:>11.1}% {:>12}",
            p.agents,
            fmt_bytes(p.mem_bytes as f64),
            p.utilization * 100.0,
            match p.bottleneck {
                Bottleneck::Feasible => "ok",
                Bottleneck::Memory => "OOM",
                Bottleneck::Compute => "saturated",
            }
        );
    }
    let (n, why) = model.limit().expect("valid capacity model");
    println!(
        "limit: {n} agents, bound by {}",
        match why {
            Bottleneck::Memory => "memory",
            Bottleneck::Compute => "compute",
            Bottleneck::Feasible => "nothing",
        }
    );
    // The step scheduler's fused ticks remove the per-token main op: the
    // compute ceiling moves out accordingly (∞ when sides are free).
    let serial = model.max_agents_compute().expect("valid capacity model");
    let fused = model.max_agents_compute_fused().expect("valid capacity model");
    println!(
        "compute ceiling: serial op stream {serial}, fused step-scheduler ticks {fused}"
    );
}

fn main() -> anyhow::Result<()> {
    let model_name = std::env::var("WARP_BENCH_MODEL").unwrap_or_else(|_| "tiny".into());
    let device = DeviceHandle::new(DeviceOptions::from_env().with_configs(&[&model_name]))?;
    let engine = Engine::new(device, &model_name)?;
    let tk = Tokenizer::new();

    // ── measure per-op costs on this substrate ──
    let mut kv = engine.new_main_cache();
    let pre = engine.prefill(
        &tk.encode(
            "user: tell me about the kv cache.\nriver: the cache grows one \
             row per token. the synapse selects landmark tokens.\nriver: ",
            true,
        ),
        &mut kv,
        Lane::River,
    )?;
    let s = engine.synapse_extract(&pre.hidden_last, &kv, Lane::Background)?;
    let mut side_kv = engine.new_side_cache();
    side_kv.append_rows(s.indices.len(), &s.lm_k, &s.lm_v)?;
    let side_pos = s.source_len as i32;

    let t_main = bench_median(3, 30, || {
        let mut c = kv.clone();
        let out = engine.decode(32, c.len() as i32, &mut c, Lane::River).unwrap();
        std::hint::black_box(out);
    })
    .median_ns
        / 1e9;
    let b = engine.caps().decode_batch;
    let t_batch = bench_median(3, 20, || {
        let mut caches: Vec<_> = (0..b).map(|_| side_kv.clone()).collect();
        let mut slots: Vec<(i32, i32, &mut warp_cortex::model::KvCache)> =
            caches.iter_mut().map(|c| (32, side_pos, c)).collect();
        let out = engine.decode_batch(&mut slots, Lane::Stream).unwrap();
        std::hint::black_box(out);
    })
    .median_ns
        / 1e9;

    println!("═══ M1: million-agent scaling (title/abstract claim) ═══");
    println!(
        "\nmeasured on this substrate: t_main_decode = {:.2} ms, \
         t_side_batch(B={b}) = {:.2} ms",
        t_main * 1e3,
        t_batch * 1e3
    );

    // (a) this substrate, measured costs, projected qwen memory arithmetic
    let manifest = Manifest::load(Manifest::default_dir())?;
    let qwen = manifest.analytic.get("qwen2_5_0_5b").expect("qwen config");
    let mem = MemoryModel::qwen05b_on_4090(qwen);
    let ours = CapacityModel {
        mem: mem.clone(),
        compute: ComputeCosts {
            t_main_decode: t_main,
            t_side_batch: t_batch,
            batch_width: b,
        },
        main_rate: 30.0, // a conversational main agent (30 tok/s)
        side_duty: 0.25, // one 24-token thought per ~100 main tokens
    };
    print_curve("(a) this CPU substrate (measured op costs)", &ours);

    // (b) the paper's testbed: scale decode cost by the FLOP ratio between
    // our tiny config and Qwen-0.5B, then by a 4090-vs-CPU factor measured
    // from the paper's own throughput ballpark (0.5B fp16 decode ≈ 1.5 ms
    // on a 4090 at batch 1 — memory-bound regime).
    let paper = CapacityModel {
        mem,
        compute: ComputeCosts {
            t_main_decode: 1.5e-3,
            t_side_batch: 2.2e-3, // batched side step amortised
            batch_width: 4,
        },
        main_rate: 30.0,
        side_duty: 0.25,
    };
    print_curve("(b) projected RTX-4090 / Qwen2.5-0.5B", &paper);

    // The paper's "1,000+ agents before compute becomes the bottleneck":
    // sweep the side-agent duty cycle to find where that claim holds.
    println!("\nside-agent duty sweep (4090 projection): where does 1,000+ hold?");
    println!("{:>12} {:>12} {:>12}", "side duty", "limit", "bound by");
    let mut duty_for_1000 = None;
    for duty in [0.5, 0.25, 0.1, 0.05, 0.02, 0.01, 0.005] {
        let mut m = paper.clone();
        m.side_duty = duty;
        let (n, why) = m.limit().expect("valid capacity model");
        println!(
            "{:>12} {:>12} {:>12}",
            duty,
            n,
            match why {
                Bottleneck::Memory => "memory",
                Bottleneck::Compute => "compute",
                Bottleneck::Feasible => "—",
            }
        );
        if n >= 1000 && duty_for_1000.is_none() {
            duty_for_1000 = Some(duty);
        }
    }

    let duty = duty_for_1000.expect("1,000+ agents must hold at some duty");
    println!(
        "\nfindings: with conversational side agents (duty 0.25) the device \
         saturates at {} agents — 'compute latency becomes the bottleneck', \
         as the paper predicts, but well before 1,000.  The paper's 1,000+ \
         figure requires mostly-idle side agents (duty ≤ {duty}), i.e. it \
         is a *capacity* (memory) claim, which does hold: memory alone \
         carries {} agents/card, and the 'million-agent' title needs \
         ~{} cards at synapse-only footprints.",
        paper.limit().expect("valid capacity model").0,
        paper.max_agents_memory(),
        1_000_000 / paper.max_agents_memory().max(1)
    );

    // The measurement loop above churned real pool-backed caches (clones
    // rent and release blocks every iteration): show that the shared pool
    // absorbed the churn instead of growing.
    let p = engine.pool().stats();
    println!(
        "\nkv pool after measurement churn: high-water {} blocks \
         ({}), {} reuses / {} rents",
        p.blocks_high_water,
        warp_cortex::cortex::memory::fmt_bytes(p.high_water_bytes() as f64),
        p.reuses,
        p.rents
    );
    assert!(
        p.reuses > 0,
        "bench churn should exercise block reuse (rents {}, reuses {})",
        p.rents,
        p.reuses
    );

    // Shape checks: compute binds under active duty; the claim's memory
    // half holds; limits are monotone in duty.
    assert_eq!(paper.limit().expect("valid capacity model").1, Bottleneck::Compute);
    assert!(paper.max_agents_memory() > 1000);
    println!("\nshape check: compute-bottleneck prediction + 1,000+ memory capacity  ✓");
    Ok(())
}
