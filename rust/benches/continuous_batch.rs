//! Bench P4 — continuous batching: device ops per generated token fall
//! from ~1.0 (the pre-PR-4 serial op stream) toward 1/B as the agent
//! population grows, and main-agent steps are never queued behind side
//! batches.
//!
//! Drives the real [`StepScheduler`] — admission, parking, per-tick
//! collection, fan-back, continuous slot refill — over a deterministic
//! host-only fused executor whose per-item results depend ONLY on
//! `(token, pos, view len)`, mirroring the engine's op-count rules
//! (1 op per fused tick, 2 when an unfusable main runs ahead of the side
//! batch).  The engine-level numeric equivalence of fused vs single
//! decode is covered by the device-gated integration tests; the
//! *scheduling* equivalence is proven by the proptest in
//! `cortex/step.rs`.  This bench runs in the CI bench-smoke step and
//! asserts the acceptance criteria:
//!
//! * ops/token ≤ 0.5 at 16 concurrent agents (vs exactly 1.0 sequential),
//! * ops/token is non-increasing as the population grows,
//! * a concurrent main agent is included in every tick it is pending for
//!   (`main_deferred == 0`) and fuses into the side batch.
//!
//! ```bash
//! cargo bench --bench continuous_batch
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use warp_cortex::cortex::router::AgentRole;
use warp_cortex::cortex::step::testing::{stub_exec, stub_raw};
use warp_cortex::cortex::{
    AgentCache, AgentSpawner, SideAgent, SideTask, StepConfig, StepScheduler, StepSeams,
};
use warp_cortex::model::{KvPool, KvPoolConfig};
use warp_cortex::runtime::ModelConfig;
use warp_cortex::text::{SamplerConfig, Tokenizer};
use warp_cortex::util::Json;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "tiny".into(),
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 2,
        d_ff: 32,
        vocab_size: 260,
        head_dim: 8,
        rope_theta: 1e4,
        param_count: 0,
    }
}

const SIDE_CTX: usize = 96;
const BATCH_WIDTH: usize = 8;
const GEN_BUDGET: usize = 32;

fn task(id: u64) -> SideTask {
    SideTask {
        id,
        session: 0,
        role: AgentRole::Verify,
        payload: format!("agent {id}: inspect the shared block pool"),
        main_pos: 0,
        spawned_at: Instant::now(),
    }
}

fn spawner(pool: Arc<KvPool>) -> AgentSpawner {
    Arc::new(move |t: SideTask| {
        let prompt_ids = Tokenizer::new().encode(&t.payload, false);
        SideAgent::from_parts(
            t,
            AgentCache::Bare(pool.new_cache(SIDE_CTX)),
            0,
            1,
            prompt_ids,
            GEN_BUDGET,
            SamplerConfig::greedy(),
        )
    })
}

fn scheduler(pool: &Arc<KvPool>, max_active: usize) -> Arc<StepScheduler> {
    StepScheduler::new(
        StepConfig {
            batch_width: BATCH_WIDTH,
            side_ctx: SIDE_CTX,
            max_active,
            max_parked: 64,
            ..StepConfig::default()
        },
        StepSeams::new(stub_exec(tiny_cfg(), SIDE_CTX, BATCH_WIDTH), spawner(pool.clone())),
    )
}

fn main() -> anyhow::Result<()> {
    let cfg = tiny_cfg();
    let pool = KvPool::new(
        &cfg,
        KvPoolConfig {
            block_tokens: 16,
            ..KvPoolConfig::default()
        },
    );

    println!("═══ P4: continuous batching (device ops per generated token) ═══\n");

    // ── sequential baseline: one device op per step, by construction ──
    let mut seq_ops = 0u64;
    let mut seq_tokens = 0u64;
    for i in 0..16u64 {
        let mut agent = spawner(pool.clone())(task(1000 + i));
        while let Some((token, pos)) = agent.next_request() {
            let len = agent.paged().len;
            agent.feed(stub_raw(&cfg, token, pos, len));
            seq_ops += 1;
            seq_tokens += 1;
        }
    }
    let seq_ops_per_token = seq_ops as f64 / seq_tokens as f64;
    println!(
        "sequential baseline: {seq_ops} ops / {seq_tokens} tokens = {seq_ops_per_token:.3} ops/token"
    );
    assert!(
        (seq_ops_per_token - 1.0).abs() < 1e-9,
        "sequential decode must cost exactly one op per token"
    );

    // ── fused path: ops/token vs population ──
    println!(
        "\n{:>10} {:>8} {:>8} {:>12} {:>12}",
        "agents", "ops", "tokens", "ops/token", "occupancy"
    );
    let populations = [1usize, 2, 4, 8, 16];
    let mut curve = Vec::new();
    for &n in &populations {
        let sched = scheduler(&pool, n);
        for i in 0..n as u64 {
            assert!(sched.submit(task(i + 1)), "submit under the bound rejected");
        }
        assert!(
            sched.drain(Duration::from_secs(30)),
            "population {n} never drained"
        );
        let outcomes = sched.poll_results();
        assert_eq!(outcomes.len(), n, "lost outcomes at population {n}");
        for o in &outcomes {
            assert!(o.error.is_none(), "agent failed: {:?}", o.error);
            assert!(o.steps > 0, "agent did no work");
        }
        let st = sched.stats();
        println!(
            "{:>10} {:>8} {:>8} {:>12.3} {:>12.2}",
            n,
            st.device_ops,
            st.side_steps,
            st.ops_per_token(),
            st.batch_occupancy()
        );
        curve.push((n, st.ops_per_token()));
        sched.shutdown();
    }

    // ── acceptance criteria ──
    // 1. toward 1/B: non-increasing in the population (small tolerance for
    //    tail ticks, where a draining cohort under-fills the batch).
    for w in curve.windows(2) {
        assert!(
            w[1].1 <= w[0].1 + 0.05,
            "ops/token must not grow with population: {curve:?}"
        );
    }
    assert!(
        (curve[0].1 - 1.0).abs() < 1e-9,
        "a lone agent pays exactly one op per token: {curve:?}"
    );
    // 2. ≤ 0.5 at 16 concurrent agents (the fused claim; the serial path
    //    is pinned at 1.0 above).
    let at_16 = curve.last().unwrap().1;
    assert!(
        at_16 <= 0.5,
        "ops/token at 16 agents is {at_16:.3}, expected ≤ 0.5"
    );

    // ── main-lane priority: a live main agent fuses into every tick and
    //    is never deferred behind side work ──
    let sched = scheduler(&pool, 8);
    for i in 0..8u64 {
        assert!(sched.submit(task(100 + i)));
    }
    let mut main_kv = pool.new_cache(256);
    let mut main_tokens = 0u64;
    for step in 0..64 {
        let token = (step % 190) as i32;
        let pos = main_kv.len() as i32;
        sched.main_step(token, pos, &mut main_kv)?;
        main_tokens += 1;
    }
    assert!(sched.drain(Duration::from_secs(30)), "mixed run never drained");
    let mixed = sched.stats();
    let outcomes = sched.poll_results();
    assert_eq!(outcomes.len(), 8);
    println!(
        "\nmixed run: {} main + {} side steps in {} ops ({} fused ticks) — \
         {:.3} ops/token, main_deferred = {}",
        mixed.main_steps,
        mixed.side_steps,
        mixed.device_ops,
        mixed.fused_ticks,
        mixed.ops_per_token(),
        mixed.main_deferred
    );
    assert_eq!(mixed.main_steps, main_tokens);
    assert_eq!(
        mixed.main_deferred, 0,
        "a main step waited behind side work"
    );
    assert!(
        mixed.fused_ticks > 0,
        "the main agent never rode the fused batch"
    );
    sched.shutdown();

    // Machine-readable report (published as a CI artifact and
    // threshold-checked alongside the other BENCH_*.json files).
    let mut report = Json::obj()
        .with("bench", "continuous_batch")
        .with("batch_width", BATCH_WIDTH)
        .with("gen_budget", GEN_BUDGET)
        .with("sequential_ops_per_token", seq_ops_per_token)
        .with("ops_per_token_at_1", curve[0].1)
        .with("ops_per_token_at_16", at_16)
        .with("mixed_ops_per_token", mixed.ops_per_token())
        .with("mixed_fused_ticks", mixed.fused_ticks)
        .with("main_deferred", mixed.main_deferred);
    for (n, opt) in &curve {
        if *n != 1 && *n != 16 {
            report = report.with(format!("ops_per_token_at_{n}").as_str(), *opt);
        }
    }
    std::fs::write("BENCH_continuous_batch.json", report.to_string())?;
    println!("wrote BENCH_continuous_batch.json");

    println!(
        "\nshape check: ops/token 1.0 (serial) → {:.3} at 16 agents, main never deferred  ✓",
        at_16
    );
    Ok(())
}
