//! Bench F1 — the Topological Synapse data-flows of **Figure 1**:
//! extraction latency vs context length, compression ratio, push/read/seed
//! costs, and landmark-set statistics.
//!
//! ```bash
//! cargo bench --bench synapse
//! ```

use warp_cortex::cortex::memory::{fmt_bytes, MemoryTracker};
use warp_cortex::cortex::Synapse;
use warp_cortex::model::Engine;
use warp_cortex::runtime::{DeviceHandle, DeviceOptions, Lane};
use warp_cortex::text::Tokenizer;
use warp_cortex::util::timer::{bench_median, format_ns};

fn main() -> anyhow::Result<()> {
    let model = std::env::var("WARP_BENCH_MODEL").unwrap_or_else(|_| "tiny".into());
    let device = DeviceHandle::new(DeviceOptions::from_env().with_configs(&[&model]))?;
    let engine = Engine::new(device, &model)?;
    let tk = Tokenizer::new();
    let tracker = MemoryTracker::new();
    let synapse = Synapse::new(tracker.clone());

    // Build a main context, then extend it by decoding to each target len.
    let prompt = tk.encode(
        "user: tell me about the kv cache.\nriver: the cache grows one row \
         per token. the synapse selects landmark tokens.\nriver: ",
        true,
    );
    let mut kv = engine.new_main_cache();
    let pre = engine.prefill(&prompt, &mut kv, Lane::River)?;
    let mut hidden = pre.hidden_last.clone();
    let v = engine.config().vocab_size;
    let mut logits = pre.logits[(pre.len - 1) * v..pre.len * v].to_vec();

    println!("═══ Figure 1 flows: Topological Synapse ═══\n");
    println!(
        "{:>10} {:>14} {:>14} {:>12} {:>12}",
        "ctx rows", "extract p50", "per-row", "compression", "synapse B"
    );

    let k = engine.caps().synapse_k;
    for target in [128usize, 256, 384, 500] {
        while kv.len() < target && kv.remaining() > 0 {
            let id = warp_cortex::util::vecmath::argmax(&logits) as i32;
            let id = if id >= 256 { 32 } else { id };
            let out = engine.decode(id, kv.len() as i32, &mut kv, Lane::River)?;
            logits = out.logits;
            hidden = out.hidden;
        }
        let stats = bench_median(2, 10, || {
            let s = engine
                .synapse_extract(&hidden, &kv, Lane::Background)
                .expect("extract");
            std::hint::black_box(&s);
        });
        let s = engine.synapse_extract(&hidden, &kv, Lane::Background)?;
        let bytes = (s.lm_k.len() + s.lm_v.len()) * 4;
        let compression = 1.0 - k as f64 / kv.len() as f64;
        println!(
            "{:>10} {:>14} {:>14} {:>11.1}% {:>12}",
            kv.len(),
            stats.format_time(),
            format_ns(stats.median_ns / kv.len() as f64),
            compression * 100.0,
            fmt_bytes(bytes as f64),
        );
        synapse.push(s);
    }

    // Landmark statistics from the last extraction.
    let snap = synapse.read().unwrap();
    let idx = &snap.landmarks.indices;
    let spread = idx.last().unwrap() - idx.first().unwrap();
    let mut gaps: Vec<i32> = idx.windows(2).map(|w| w[1] - w[0]).collect();
    gaps.sort_unstable();
    println!(
        "\nlandmarks: k={} covering [{}..{}] (span {} of {} rows), \
         median gap {}, max gap {}",
        idx.len(),
        idx.first().unwrap(),
        idx.last().unwrap(),
        spread,
        snap.landmarks.source_len,
        gaps[gaps.len() / 2],
        gaps.last().unwrap(),
    );

    // push / read / seed costs.
    let s = engine.synapse_extract(&hidden, &kv, Lane::Background)?;
    let push = bench_median(5, 50, || {
        synapse.push(s.clone());
    });
    let read = bench_median(5, 200, || {
        std::hint::black_box(synapse.read());
    });
    let seed = bench_median(2, 20, || {
        std::hint::black_box(synapse.seed_side_cache(&engine).unwrap());
    });
    println!(
        "\ncosts: push {}, read (zero-copy Arc) {}, seed side cache {}",
        push.format_time(),
        read.format_time(),
        seed.format_time()
    );
    println!(
        "memory: synapse buffer {} (shared by all readers)",
        fmt_bytes(tracker.live_bytes(warp_cortex::cortex::MemKind::Synapse) as f64)
    );

    // Shape checks.
    assert!(idx.windows(2).all(|w| w[0] < w[1]));
    assert!(read.median_ns < 50_000.0, "read should be ~free");
    println!("\nshape check: landmarks causal+unique, reads zero-copy  ✓");
    Ok(())
}
