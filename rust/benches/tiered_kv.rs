//! Bench P7 — tiered KV memory: block-granular int8 quantization for warm
//! (parked / registry) blocks + host-RAM offload for cold (parked-session)
//! state.
//!
//! Drives the pool/cache layer directly (host-only — runs in the CI
//! bench-smoke step) and *asserts* the tiered-store acceptance criteria:
//!
//! 1. with `quantize_parked` on, parked registry blocks cost
//!    [`KvPool::q8_block_bytes`] instead of [`KvPool::block_bytes`] —
//!    resident blocks per GB ≥ 3× the fp32 baseline;
//! 2. at the `max_blocks` cap, a single-tier pool sacrifices its warm
//!    prefix registry to LRU eviction and STILL sheds the next session,
//!    while the tiered pool spills parked state to the host slab, keeps
//!    the registry intact, and admits;
//! 3. park→offload→resume round-trips a session's fp32 state losslessly:
//!    the post-resume gather is bit-identical to the pre-park one, and the
//!    swap gauges reconcile (`swap_out == swap_in + host_slab_bytes`).
//!
//! Emits `BENCH_tiered_kv.json` (threshold-checked by ci/check_bench.py
//! and folded into the per-commit BENCH_summary.json).
//!
//! ```bash
//! cargo bench --bench tiered_kv
//! ```

use warp_cortex::cortex::memory::fmt_bytes;
use warp_cortex::model::{KvCache, KvPool, KvPoolConfig};
use warp_cortex::runtime::ModelConfig;
use warp_cortex::util::timer::bench_median;
use warp_cortex::util::Json;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "tiny".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 4,
        n_kv_heads: 2,
        d_ff: 192,
        vocab_size: 260,
        head_dim: 16,
        rope_theta: 1e4,
        param_count: 116_032,
    }
}

const L: usize = 2; // layers of tiny_cfg
const ROW: usize = 32; // KV * hd of tiny_cfg
const BT: usize = 16; // block_tokens
const PROMPT: usize = 32; // registered prompt (2 full blocks)
const SESSION_ROWS: usize = 32; // per parked session (2 full blocks)
const CAPACITY: usize = 256;
const PARKED_PROMPTS: usize = 6;
const SESSIONS: usize = 4;
const CAP_BLOCKS: usize = (SESSIONS * SESSION_ROWS) / BT; // budget = exactly the sessions
const SALT: u64 = 0x71E2; // bench's registry domain

/// Deterministic prompt token ids, distinct per `seed`.
fn prompt_tokens(seed: usize) -> Vec<i32> {
    (0..PROMPT as i32)
        .map(|i| (i * 37 + 11 + seed as i32 * 101) % 256)
        .collect()
}

/// Deterministic `[L, n, KV, hd]` rows derived from the tokens (the
/// content-addressing contract made literal).
fn canon_rows(tokens: &[i32]) -> (Vec<f32>, Vec<f32>) {
    let n = tokens.len();
    let mut k = Vec::with_capacity(L * n * ROW);
    let mut v = Vec::with_capacity(L * n * ROW);
    for layer in 0..L {
        for (pos, &tok) in tokens.iter().enumerate() {
            for j in 0..ROW {
                let x = (layer * 7919 + pos * 131 + j) as f32 * 1e-3 + tok as f32 * 1e-2;
                k.push(x);
                v.push(-x);
            }
        }
    }
    (k, v)
}

fn bit_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

fn pool_with(quantize: bool, max_blocks: usize, slab: usize) -> std::sync::Arc<KvPool> {
    KvPool::new(
        &tiny_cfg(),
        KvPoolConfig {
            block_tokens: BT,
            max_blocks,
            quantize_parked: quantize,
            host_slab_blocks: slab,
            ..KvPoolConfig::default()
        },
    )
}

fn main() -> anyhow::Result<()> {
    println!("═══ P7: tiered KV memory (warm int8 + cold host slab) ═══\n");

    // ── A: blocks per GB — quantized parked registry vs fp32 ───────────
    // Register PARKED_PROMPTS distinct prompts and park them (drop the
    // writing caches): with quantization on, each parked block's resident
    // cost drops from block_bytes to q8_block_bytes.
    let quant = pool_with(true, 0, 0);
    let fp32 = pool_with(false, 0, 0);
    for p in [&quant, &fp32] {
        for seed in 0..PARKED_PROMPTS {
            let tokens = prompt_tokens(seed);
            let (k, v) = canon_rows(&tokens);
            let mut c = p.new_cache(CAPACITY);
            c.replace_rows_keyed(PROMPT, SALT, &tokens, &k, &v)?;
            drop(c); // park: refs → 0, entry stays registered
        }
    }
    let (qs, fs) = (quant.stats(), fp32.stats());
    let parked_blocks = PARKED_PROMPTS * (PROMPT / BT);
    assert_eq!(qs.blocks_live, parked_blocks);
    assert_eq!(fs.blocks_live, parked_blocks);
    assert_eq!(qs.quantized_blocks, parked_blocks, "every parked block demotes");
    // Same parked population, fewer resident bytes ⇒ more blocks per GB.
    let ratio = fs.live_bytes() as f64 / qs.live_bytes() as f64;
    println!(
        "warm tier: {parked_blocks} parked blocks resident at {} (int8) vs {} (fp32) \
         — {ratio:.2}x blocks/GB, {} saved",
        fmt_bytes(qs.live_bytes() as f64),
        fmt_bytes(fs.live_bytes() as f64),
        fmt_bytes(qs.quant_saved_bytes as f64)
    );
    assert!(ratio >= 3.0, "quantized tier must fit ≥3x blocks/GB, got {ratio:.2}");
    assert_eq!(
        qs.quant_saved_bytes,
        parked_blocks as u64 * (quant.block_bytes() - quant.q8_block_bytes())
    );
    // Parked reads stay correct: a later agent adopts the quantized prefix
    // and reconstructs each row within the per-row quantization bound.
    let tokens = prompt_tokens(0);
    let (k_src, _) = canon_rows(&tokens);
    let hashes = quant.prefix_hashes(SALT, &tokens);
    let mut reader = quant.new_cache(CAPACITY);
    assert_eq!(reader.attach_shared_prefix(&hashes, &tokens)?, PROMPT);
    let (k_got, _) = reader.prefix_upload(PROMPT);
    for (pos, (orig, got)) in k_src.chunks(ROW).zip(k_got.chunks(ROW)).enumerate() {
        let bound = orig.iter().fold(0f32, |m, x| m.max(x.abs())) / 127.0 + 1e-6;
        for (o, g) in orig.iter().zip(got) {
            assert!((o - g).abs() <= bound, "row {pos}: |{o} - {g}| > {bound}");
        }
    }
    drop(reader);

    // ── B: admission at the max_blocks cap ──────────────────────────────
    // Workload: one registered prompt (the warm registry), then SESSIONS
    // sessions each filling SESSION_ROWS private rows — exactly the byte
    // budget.  The single-tier pool can only evict the registry to make
    // room, and still sheds the next session; the tiered pool spills
    // parked state to host RAM, keeps the registry, and admits.
    let reg_tokens = prompt_tokens(99);
    let (reg_k, reg_v) = canon_rows(&reg_tokens);
    let fill_sessions = |p: &std::sync::Arc<KvPool>| -> anyhow::Result<Vec<KvCache>> {
        let mut reg = p.new_cache(CAPACITY);
        reg.replace_rows_keyed(PROMPT, SALT, &reg_tokens, &reg_k, &reg_v)?;
        drop(reg); // park the registry entry
        let mut sessions = Vec::with_capacity(SESSIONS);
        for s in 0..SESSIONS {
            let tokens = prompt_tokens(10 + s);
            let (k, v) = canon_rows(&tokens);
            let mut c = p.new_cache(CAPACITY);
            c.replace_rows(SESSION_ROWS, &k, &v)?; // private, unregistered
            sessions.push(c);
        }
        Ok(sessions)
    };

    // Single tier: sessions fit only by evicting the parked registry.
    let single = pool_with(false, CAP_BLOCKS, 0);
    let mut single_sessions = fill_sessions(&single)?;
    let ss = single.stats();
    assert!(ss.prefix_evictions > 0, "single tier must sacrifice the registry");
    let single_sheds = !single.can_admit(1);
    assert!(single_sheds, "budget is exactly the held sessions — must shed");
    assert!(
        single.new_cache(CAPACITY).append_row(&[0.5; L * ROW], &[0.5; L * ROW]).is_err(),
        "single-tier growth past the cap must fail"
    );
    let reg_hashes = single.prefix_hashes(SALT, &reg_tokens);
    let mut probe = single.new_cache(CAPACITY);
    assert_eq!(
        probe.attach_shared_prefix(&reg_hashes, &reg_tokens)?,
        0,
        "the evicted registry covers nothing"
    );
    drop(probe);

    // Tiered: same workload + quantized parking + a host slab.
    let tiered = pool_with(true, CAP_BLOCKS, 16);
    let mut sessions = fill_sessions(&tiered)?;
    let ts = tiered.stats();
    assert_eq!(ts.prefix_evictions, 0, "pressure offloads, never evicts, here");
    assert!(ts.offloaded_blocks > 0, "the parked registry spilled to the slab");
    // Park every session (a quiet client): private fp32 blocks move to the
    // host slab verbatim and their budget cost drops to zero.
    let baseline = sessions[0].device_gather(SESSION_ROWS)?;
    let mut parked_blocks_cold = 0usize;
    for s in sessions.iter_mut() {
        parked_blocks_cold += s.park_to_host()?;
    }
    assert_eq!(parked_blocks_cold, SESSIONS * SESSION_ROWS / BT);
    let admits = tiered.can_admit(SESSION_ROWS / BT);
    assert!(admits, "tiered pool must admit after parking");
    let adm_tokens = prompt_tokens(50);
    let (adm_k, adm_v) = canon_rows(&adm_tokens);
    let mut admitted = tiered.new_cache(CAPACITY);
    admitted.replace_rows(SESSION_ROWS, &adm_k, &adm_v)?;
    // Resume the first parked session: page-in is lossless, so the gather
    // is bit-identical to the pre-park baseline.
    let resumed = sessions[0].resume_from_host()?;
    assert_eq!(resumed, SESSION_ROWS / BT);
    let after = sessions[0].device_gather(SESSION_ROWS)?;
    let roundtrip_ok = bit_eq(&baseline.0, &after.0) && bit_eq(&baseline.1, &after.1);
    assert!(roundtrip_ok, "park→offload→resume must be bit-identical");
    // And the warm registry survived the pressure (paged back on hit).
    let mut probe = tiered.new_cache(CAPACITY);
    assert_eq!(
        probe.attach_shared_prefix(&tiered.prefix_hashes(SALT, &reg_tokens), &reg_tokens)?,
        PROMPT,
        "tiered pool keeps the registry through cap pressure"
    );
    drop(probe);
    let ts = tiered.stats();
    assert_eq!(
        ts.swap_out_bytes,
        ts.swap_in_bytes + ts.swap_dropped_bytes + ts.host_slab_bytes,
        "swap conservation"
    );
    tiered.check_invariants().map_err(anyhow::Error::msg)?;
    println!(
        "cold tier: single-tier pool shed at the {CAP_BLOCKS}-block cap (registry \
         evicted); tiered pool parked {parked_blocks_cold} blocks to host \
         ({} out / {} in), admitted a new session, resumed bit-identical",
        fmt_bytes(ts.swap_out_bytes as f64),
        fmt_bytes(ts.swap_in_bytes as f64)
    );

    // ── timing: one park→resume cycle on a 2-block session ─────────────
    let t_cycle = bench_median(3, 50, || {
        let n = sessions[1].park_to_host().expect("park");
        std::hint::black_box(n);
        let n = sessions[1].resume_from_host().expect("resume");
        std::hint::black_box(n);
    });
    println!(
        "park+resume cycle ({} blocks): {:.1} µs median",
        SESSION_ROWS / BT,
        t_cycle.median_ns / 1e3
    );
    drop(admitted);
    drop(single_sessions.drain(..));
    drop(sessions.drain(..));

    // ── machine-readable report ─────────────────────────────────────────
    let ts = tiered.stats();
    let report = Json::obj()
        .with("bench", "tiered_kv")
        .with("block_tokens", BT)
        .with("block_bytes", quant.block_bytes())
        .with("q8_block_bytes", quant.q8_block_bytes())
        .with("parked_blocks", parked_blocks)
        .with("blocks_per_gb_ratio", ratio)
        .with("quant_saved_bytes", qs.quant_saved_bytes)
        // 0/1 gauges (not JSON booleans — the threshold gate compares
        // numbers only)
        .with("single_tier_sheds", u64::from(single_sheds))
        .with("admission_after_offload", u64::from(admits))
        .with("roundtrip_bitident", u64::from(roundtrip_ok))
        .with("swap_out_bytes", ts.swap_out_bytes)
        .with("swap_in_bytes", ts.swap_in_bytes)
        .with("resume_page_ins", ts.resume_page_ins)
        .with("park_resume_cycle_us", t_cycle.median_ns / 1e3);
    std::fs::write("BENCH_tiered_kv.json", report.to_string())?;
    println!("wrote BENCH_tiered_kv.json");
    println!("\nshape check: 3x warm density + lossless cold parking + admission  ✓");
    Ok(())
}
