//! Bench P1 — reproduces **§5.2 "Performance Characteristics"**: the Main
//! Agent maintains near-baseline generation speed while side agents execute
//! asynchronously (graceful degradation, not collapse).
//!
//! ```bash
//! cargo bench --bench throughput
//! ```
//!
//! Method: decode a fixed number of main-agent tokens on the River lane
//! while N side agents run continuous decode loops through the dynamic
//! batcher on the Stream lane.  Reports main tok/s, side aggregate tok/s,
//! and the degradation ratio at each N.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use warp_cortex::cortex::{Batcher, MemoryTracker, Synapse};
use warp_cortex::model::Engine;
use warp_cortex::runtime::{DeviceHandle, DeviceOptions, Lane};
use warp_cortex::text::Tokenizer;

const MAIN_TOKENS: usize = 150;
const SIDE_COUNTS: [usize; 5] = [0, 1, 2, 4, 8];

fn main() -> anyhow::Result<()> {
    let model = std::env::var("WARP_BENCH_MODEL").unwrap_or_else(|_| "tiny".into());
    let device = DeviceHandle::new(DeviceOptions::from_env().with_configs(&[&model]))?;
    let engine = Engine::new(device, &model)?;
    let tk = Tokenizer::new();
    let tracker = MemoryTracker::new();
    let synapse = Synapse::new(tracker);
    let batcher = Batcher::new(engine.clone(), std::time::Duration::from_micros(400));

    // Main context + synapse for side seeding.
    let prompt = tk.encode(
        "user: tell me about the kv cache.\nriver: the cache grows one row \
         per token. the synapse selects landmark tokens.\nriver: ",
        true,
    );

    println!("═══ §5.2 Performance Characteristics: main-agent throughput vs side load ═══\n");
    println!(
        "{:>12} {:>14} {:>16} {:>14} {:>12}",
        "side agents", "main tok/s", "side tok/s (agg)", "degradation", "p50 step"
    );

    let mut baseline_tps = 0.0;
    for &n_side in &SIDE_COUNTS {
        // fresh main agent per row
        let mut kv = engine.new_main_cache();
        let pre = engine.prefill(&prompt, &mut kv, Lane::River)?;
        let s = engine.synapse_extract(&pre.hidden_last, &kv, Lane::Background)?;
        synapse.push(s);

        let stop = Arc::new(AtomicBool::new(false));
        let side_tokens = Arc::new(AtomicU64::new(0));

        let mut workers = Vec::new();
        for w in 0..n_side {
            let engine = engine.clone();
            let synapse = synapse.clone();
            let batcher = batcher.clone();
            let stop = stop.clone();
            let side_tokens = side_tokens.clone();
            workers.push(std::thread::spawn(move || {
                // continuous side agent: reseed when its budget is spent
                let mut seed = 65 + w as i32;
                'outer: while !stop.load(Ordering::Relaxed) {
                    let Ok((mut kv, mut pos, _)) = synapse.seed_side_cache(&engine) else {
                        break;
                    };
                    while kv.remaining() > 0 {
                        if stop.load(Ordering::Relaxed) {
                            break 'outer;
                        }
                        if batcher.decode(seed, pos, &mut kv).is_err() {
                            break 'outer;
                        }
                        side_tokens.fetch_add(1, Ordering::Relaxed);
                        pos += 1;
                        seed = (seed + 7) % 256;
                    }
                }
            }));
        }

        // main decode loop (greedy over its own argmax, River lane)
        let mut lat = Vec::with_capacity(MAIN_TOKENS);
        let v = engine.config().vocab_size;
        let mut logits = pre.logits[(pre.len - 1) * v..pre.len * v].to_vec();
        let mut pos = kv.len() as i32;
        let t0 = Instant::now();
        for _ in 0..MAIN_TOKENS {
            let id = warp_cortex::util::vecmath::argmax(&logits) as i32;
            let id = if id >= 256 { 32 } else { id }; // keep to visible bytes
            let st = Instant::now();
            let out = engine.decode(id, pos, &mut kv, Lane::River)?;
            lat.push(st.elapsed().as_nanos() as f64);
            logits = out.logits;
            pos += 1;
            if kv.remaining() == 0 {
                break;
            }
        }
        let main_dt = t0.elapsed().as_secs_f64();
        stop.store(true, Ordering::Relaxed);
        for w in workers {
            let _ = w.join();
        }

        let main_tps = MAIN_TOKENS as f64 / main_dt;
        let side_tps = side_tokens.load(Ordering::Relaxed) as f64 / main_dt;
        if n_side == 0 {
            baseline_tps = main_tps;
        }
        let degradation = baseline_tps / main_tps;
        lat.sort_by(|a, b| a.total_cmp(b));
        let p50 = lat[lat.len() / 2] / 1e6;
        println!(
            "{:>12} {:>14.1} {:>16.1} {:>13.2}x {:>10.2}ms",
            n_side, main_tps, side_tps, degradation, p50
        );
    }

    let dev = engine.device().stats();
    println!(
        "\ndevice: {} ops, river queue mean {:.1} µs vs stream queue mean {:.1} µs \
         (priority lanes at work)",
        dev.ops,
        dev.lane_queue_ns[0] as f64 / dev.lane_ops[0].max(1) as f64 / 1e3,
        dev.lane_queue_ns[1] as f64 / dev.lane_ops[1].max(1) as f64 / 1e3,
    );
    println!(
        "\nshape check: degradation grows smoothly with side load (the paper's \
         'graceful degradation'), and the River lane waits less than Stream."
    );
    batcher.shutdown();
    Ok(())
}
