//! Bench T2 — reproduces **Table 2**: Measured VRAM Usage vs. Agent Count.
//!
//! ```bash
//! cargo bench --bench table2_vram
//! ```
//!
//! Spawns real shared-weight agent populations (1 main, prefilled from a
//! real prompt + N−1 side agents seeded from the live Topological Synapse),
//! measures the tracked bytes of every allocated buffer at each checkpoint,
//! and prints (a) the measured table on this config, (b) the projection to
//! the paper's Qwen2.5-0.5B/RTX-4090 testbed next to the paper's numbers,
//! and (c) the Standard-Architecture comparison the paper's Table 1 implies.

use warp_cortex::cortex::memory::{fmt_bytes, MemoryModel, MemoryTracker, GIB};
use warp_cortex::cortex::{AgentKind, Prism, SeedMode, StandardArchitecture, Synapse};
use warp_cortex::model::Engine;
use warp_cortex::runtime::{DeviceHandle, DeviceOptions, Lane, Manifest};
use warp_cortex::text::Tokenizer;

const CHECKPOINTS: [usize; 4] = [1, 10, 50, 100];
// Paper Table 2 (GB): total VRAM at each agent count.
const PAPER_GB: [f64; 4] = [0.93, 1.05, 1.44, 2.22];

fn main() -> anyhow::Result<()> {
    let model = std::env::var("WARP_BENCH_MODEL").unwrap_or_else(|_| "tiny".into());
    let device = DeviceHandle::new(DeviceOptions::from_env().with_configs(&[&model]))?;
    let engine = Engine::new(device, &model)?;
    let tracker = MemoryTracker::new();
    let prism = Prism::new(engine.clone(), tracker.clone());
    let synapse = Synapse::new(tracker.clone());
    let tk = Tokenizer::new();

    // Live main agent + synapse.
    let mut main = prism.register(AgentKind::Main)?;
    let prompt = tk.encode(
        "user: tell me about the kv cache.\nriver: the cache grows one row \
         per token. the synapse selects landmark tokens.\nriver: ",
        true,
    );
    let pre = engine.prefill(&prompt, &mut main.kv, Lane::River)?;
    let s = engine.synapse_extract(&pre.hidden_last, &main.kv, Lane::Background)?;
    synapse.push(s);

    println!("═══ Table 2: Measured VRAM vs Agent Count ═══\n");
    println!(
        "measured on `{model}` (f32; resident-block bytes — the tracker \
         charges rented pool blocks, not configured capacity):"
    );
    println!(
        "{:>8} {:>14} {:>14} {:>14} {:>14}",
        "agents", "total", "delta", "per-agent", "eager-equiv"
    );
    let mut side = Vec::new();
    let baseline = tracker.total_live();
    let mut measured = Vec::new();
    for &target in &CHECKPOINTS {
        while side.len() + 1 < target {
            let mut t = prism.register(AgentKind::Side)?;
            // Seed the rented cache in place: landmark rows land directly
            // in the shared pool's blocks.
            synapse.seed_into(&mut t.kv, SeedMode::Full)?;
            side.push(t);
        }
        let total = tracker.total_live();
        measured.push(total);
        let delta = total - baseline;
        println!(
            "{:>8} {:>14} {:>14} {:>14} {:>14}",
            target,
            fmt_bytes(total as f64),
            if target > 1 { fmt_bytes(delta as f64) } else { "—".into() },
            if target > 1 {
                fmt_bytes(delta as f64 / (target - 1) as f64)
            } else {
                "—".into()
            },
            fmt_bytes(prism.registered_kv_bytes() as f64),
        );
    }

    // Pool gauges: the resident-vs-reserved story in block units.
    {
        let p = prism.pool().stats();
        println!(
            "\npool: {} blocks live ({} free, high-water {}), block = {} rows / {}, \
             resident {}, fragmentation {:.1}%",
            p.blocks_live,
            p.blocks_free,
            p.blocks_high_water,
            p.block_tokens,
            fmt_bytes(p.block_bytes as f64),
            fmt_bytes(p.resident_bytes() as f64),
            p.fragmentation() * 100.0
        );
        // Acceptance: with short side contexts, per-agent resident bytes are
        // proportional to actual fill, not the configured side capacity.
        let seeded_rows = side.first().map(|t| t.kv.len()).unwrap_or(0);
        let expect_blocks = prism.pool().blocks_for(seeded_rows);
        for t in &side {
            assert_eq!(
                t.kv.bytes(),
                expect_blocks as u64 * prism.pool().block_bytes(),
                "side agent resident bytes must equal ceil(fill/bt) blocks"
            );
            assert!(
                t.kv.bytes() <= t.kv.used_bytes() + prism.pool().block_bytes(),
                "resident exceeds fill by more than one block"
            );
        }
        assert!(
            (tracker.total_live() as u64) < prism.registered_kv_bytes(),
            "resident tracking should undercut eager reservation"
        );
    }

    // Projection to the paper's testbed, side by side with the paper.
    let manifest = Manifest::load(Manifest::default_dir())?;
    let qwen = manifest.analytic.get("qwen2_5_0_5b").expect("qwen config");
    let m = MemoryModel::qwen05b_on_4090(qwen);
    println!("\nprojected to Qwen2.5-0.5B fp16 / RTX 4090 vs the paper:");
    println!(
        "{:>8} {:>14} {:>14} {:>14} {:>15} {:>15}",
        "agents", "paper total", "ours total", "ours q8", "paper per-agent", "ours per-agent"
    );
    for (i, &n) in CHECKPOINTS.iter().enumerate() {
        let ours = m.warp_total_bytes(n as u64);
        // The tiered pool's warm column: side-agent KV parked as int8
        // blocks (one f32 scale per row) instead of fp16-width rows.
        let ours_q8 = m.warp_total_bytes_q8(n as u64);
        let paper_per = if n > 1 {
            (PAPER_GB[i] - PAPER_GB[0]) * 1e9 / (n - 1) as f64
        } else {
            0.0
        };
        let ours_per = if n > 1 {
            (ours - m.warp_total_bytes(1)) as f64 / (n - 1) as f64
        } else {
            0.0
        };
        println!(
            "{:>8} {:>13.2}GB {:>14} {:>14} {:>15} {:>15}",
            n,
            PAPER_GB[i],
            fmt_bytes(ours as f64),
            fmt_bytes(ours_q8 as f64),
            if n > 1 { fmt_bytes(paper_per) } else { "—".into() },
            if n > 1 { fmt_bytes(ours_per) } else { "—".into() },
        );
    }

    // Standard architecture on the same checkpoints (weights per agent).
    println!("\nstandard architecture (per-agent weight copies), measured on `{model}`:");
    let std_tracker = MemoryTracker::new();
    let mut std_arch = StandardArchitecture::new(engine.clone(), std_tracker.clone());
    println!("{:>8} {:>14} {:>16}", "agents", "total", "@0.5B projected");
    for &target in &CHECKPOINTS {
        while std_arch.len() < target {
            std_arch.spawn()?;
        }
        println!(
            "{:>8} {:>14} {:>16}",
            target,
            fmt_bytes(std_tracker.total_live() as f64),
            fmt_bytes(m.standard_total_bytes(target as u64) as f64),
        );
    }

    // Shape checks: linear scaling, per-agent in the paper's 10–16 MB band,
    // 100 warp agents fit a 24 GB card with room while standard OOMs at ~15.
    let per_agent =
        (m.warp_total_bytes(100) - m.warp_total_bytes(1)) as f64 / 99.0 / 1e6;
    assert!(
        (8.0..=18.0).contains(&per_agent),
        "projected per-agent {per_agent} MB outside the paper band"
    );
    assert!(m.warp_total_bytes(100) < 6 * GIB);
    assert!(m.standard_total_bytes(100) > 24 * GIB);
    // Quantized tier: strictly cheaper at every checkpoint past n=1 (the
    // main agent's hot fp32 context is tier-exempt, so n=1 is equal).
    assert_eq!(m.warp_total_bytes_q8(1), m.warp_total_bytes(1));
    for &n in &CHECKPOINTS[1..] {
        assert!(m.warp_total_bytes_q8(n as u64) < m.warp_total_bytes(n as u64));
    }
    let q8_per =
        (m.warp_total_bytes_q8(100) - m.warp_total_bytes_q8(1)) as f64 / 99.0 / 1e6;
    assert!(
        q8_per < per_agent,
        "q8 per-agent {q8_per} MB should undercut fp16 {per_agent} MB"
    );
    let meas_per_10 = (measured[1] - measured[0]) as f64 / 9.0;
    let meas_per_100 = (measured[3] - measured[0]) as f64 / 99.0;
    assert!(
        (meas_per_10 - meas_per_100).abs() / meas_per_100 < 0.05,
        "measured scaling is not linear: {meas_per_10} vs {meas_per_100}"
    );
    println!(
        "\nshape check: linear (~{} measured/agent), projected {:.1} MB/agent \
         within paper's 10–13 MB band ({:.1} MB/agent quantized), \
         100 agents ≪ 24 GB  ✓",
        fmt_bytes(meas_per_100),
        per_agent,
        q8_per
    );
    Ok(())
}
