//! Bench A2 — Validation Gate threshold sweep (paper §3.5: θ "tuned for
//! precision-recall trade-offs, empirically set to 0.5").
//!
//! Runs identical council episodes at each θ and reports the gate's
//! behaviour: evaluated / accepted / merged / mean score.  The shape to
//! reproduce: accept-rate decreases monotonically in θ, with θ=0
//! accepting everything and high θ rejecting everything.
//!
//! ```bash
//! cargo bench --bench ablation_gate
//! ```

use std::sync::Arc;

use warp_cortex::cortex::{CortexConfig, Event, WarpCortex};
use warp_cortex::model::Engine;
use warp_cortex::runtime::{DeviceHandle, DeviceOptions};
use warp_cortex::text::SamplerConfig;

const THETAS: [f32; 6] = [-1.0, 0.0, 0.1, 0.3, 0.5, 0.9];
const EPISODES: usize = 3;

fn main() -> anyhow::Result<()> {
    let model = std::env::var("WARP_BENCH_MODEL").unwrap_or_else(|_| "tiny".into());
    let device = DeviceHandle::new(DeviceOptions::from_env().with_configs(&[&model]))?;
    let engine = Engine::new(device, &model)?;

    let prompt = "user: tell me about the kv cache.\nriver: the cache grows \
                  one row per token. the synapse selects landmark tokens. \
                  [TASK: verify the math] [RECALL: the definition]\nriver: ";

    println!("═══ A2: Validation Gate θ sweep ═══\n");
    println!(
        "{:>7} {:>10} {:>10} {:>8} {:>12} {:>12}",
        "θ", "evaluated", "accepted", "merged", "accept rate", "mean score"
    );

    let mut rates = Vec::new();
    for &theta in &THETAS {
        let mut evaluated = 0u64;
        let mut accepted = 0u64;
        let mut merged = 0usize;
        let mut score_sum = 0.0f64;
        for ep in 0..EPISODES {
            let cortex = WarpCortex::new(
                engine.clone(),
                CortexConfig {
                    model: model.clone(),
                    max_side_agents: 2,
                    side_gen_budget: 10,
                    synapse_refresh_every: 16,
                    gate_theta: Some(theta),
                    sampler: SamplerConfig {
                        temperature: 0.7,
                        seed: 1000 + ep as u64,
                        ..SamplerConfig::default()
                    },
                    ..CortexConfig::default()
                },
            )?;
            let cortex = Arc::new(cortex);
            let report = cortex.run_episode(prompt, 48)?;
            evaluated += report.gate.evaluated;
            accepted += report.gate.accepted;
            score_sum += report.gate.mean_score() * report.gate.evaluated as f64;
            merged += report
                .events
                .iter()
                .filter(|e| matches!(e, Event::Merged { .. }))
                .count();
        }
        let rate = if evaluated > 0 {
            accepted as f64 / evaluated as f64
        } else {
            0.0
        };
        rates.push((theta, rate));
        println!(
            "{:>7.2} {:>10} {:>10} {:>8} {:>11.0}% {:>12.4}",
            theta,
            evaluated,
            accepted,
            merged,
            rate * 100.0,
            if evaluated > 0 { score_sum / evaluated as f64 } else { 0.0 },
        );
    }

    // Shape: monotone non-increasing accept rate; θ=-1 accepts all.
    for w in rates.windows(2) {
        assert!(
            w[1].1 <= w[0].1 + 1e-9,
            "accept rate not monotone: {:?}",
            rates
        );
    }
    assert!((rates[0].1 - 1.0).abs() < 1e-9, "θ=-1 must accept everything");
    println!("\nshape check: accept rate monotone in θ, θ=-1 accepts all  ✓");
    Ok(())
}
