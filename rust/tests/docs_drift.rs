//! Docs-drift gate: `docs/ARCHITECTURE.md`'s gauge-reference table vs
//! the live `/stats` serializer in `serve/server.rs`.
//!
//! The handbook promises operators one row per wire key.  This test
//! reconciles the two *bidirectionally* at compile-snapshot level (both
//! files arrive via `include_str!`, so the gate can never test a stale
//! copy):
//!
//! * every `"key"` the serializer region writes must appear in the
//!   fenced gauge-reference table (an undocumented gauge fails CI), and
//! * every `block.key` row in the table must name identifiers the
//!   serializer actually writes (a documented phantom gauge fails CI).
//!
//! The serializer region is everything from `pub fn sessions_json` (the
//! first gauge-block helper) to `#[cfg(test)]` — it contains
//! `sessions_json`, `store_json`, and `stats_json`, and no non-gauge
//! `.with("...")` calls (the streaming protocol keys live in
//! `stream_session`, above the region).

const SERVER_SRC: &str = include_str!("../src/serve/server.rs");
const HANDBOOK: &str = include_str!("../../docs/ARCHITECTURE.md");

/// Every string literal passed as the first argument of a `.with(`
/// inside the serializer region — exactly the `/stats` wire keys (block
/// names and leaves alike).
fn server_keys() -> std::collections::BTreeSet<String> {
    let start = SERVER_SRC
        .find("pub fn sessions_json")
        .expect("serializer region anchor `pub fn sessions_json` moved — update docs_drift.rs");
    let end = SERVER_SRC[start..]
        .find("#[cfg(test)]")
        .map(|i| start + i)
        .unwrap_or(SERVER_SRC.len());
    let region = &SERVER_SRC[start..end];
    let mut keys = std::collections::BTreeSet::new();
    let mut rest = region;
    while let Some(i) = rest.find(".with(") {
        rest = &rest[i + ".with(".len()..];
        let arg = rest.trim_start();
        if let Some(lit) = arg.strip_prefix('"') {
            if let Some(close) = lit.find('"') {
                keys.insert(lit[..close].to_string());
            }
        }
    }
    assert!(
        keys.len() > 40,
        "suspiciously few serializer keys extracted ({}): parser drifted from the source",
        keys.len()
    );
    keys
}

/// Every identifier part of every `block.key` row inside the
/// gauge-reference markers: `pool.prefix_hits` contributes both `pool`
/// and `prefix_hits`, the top-level leaf `population` contributes
/// itself.
fn doc_parts() -> std::collections::BTreeSet<String> {
    let begin = HANDBOOK
        .find("<!-- gauge-reference:begin -->")
        .expect("gauge-reference:begin marker missing from docs/ARCHITECTURE.md");
    let end = HANDBOOK
        .find("<!-- gauge-reference:end -->")
        .expect("gauge-reference:end marker missing from docs/ARCHITECTURE.md");
    assert!(begin < end, "gauge-reference markers are out of order");
    let mut parts = std::collections::BTreeSet::new();
    let mut rows = 0usize;
    for line in HANDBOOK[begin..end].lines() {
        let line = line.trim();
        if !line.starts_with('|') {
            continue;
        }
        // First backtick-fenced token of the row is the gauge name.
        let Some(tick) = line.find('`') else { continue };
        let rest = &line[tick + 1..];
        let Some(close) = rest.find('`') else { continue };
        let token = &rest[..close];
        let well_formed = !token.is_empty()
            && token
                .chars()
                .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_' || c == '.');
        if !well_formed {
            continue; // header row / prose cell
        }
        rows += 1;
        for part in token.split('.') {
            assert!(!part.is_empty(), "malformed gauge row `{token}`");
            parts.insert(part.to_string());
        }
    }
    assert!(
        rows > 40,
        "suspiciously few gauge rows parsed ({rows}): table format drifted"
    );
    parts
}

#[test]
fn every_stats_wire_key_is_documented() {
    let keys = server_keys();
    let parts = doc_parts();
    let missing: Vec<&String> = keys.iter().filter(|k| !parts.contains(*k)).collect();
    assert!(
        missing.is_empty(),
        "serve/server.rs serializes gauge keys the handbook never documents \
         (add rows to the gauge-reference table in docs/ARCHITECTURE.md): {missing:?}"
    );
}

#[test]
fn every_documented_gauge_exists_on_the_wire() {
    let keys = server_keys();
    let parts = doc_parts();
    let phantom: Vec<&String> = parts.iter().filter(|p| !keys.contains(*p)).collect();
    assert!(
        phantom.is_empty(),
        "docs/ARCHITECTURE.md documents gauges serve/server.rs never serializes \
         (stale rows in the gauge-reference table): {phantom:?}"
    );
}

#[test]
fn store_block_documents_the_full_conservation_ledger() {
    // The durable-store ledger is the newest block and the one the
    // conservation law reads from — pin its rows explicitly so a partial
    // rename can't slip through the set reconciliation.
    for key in [
        "store.checkpoints",
        "store.resumes",
        "store.preempt_to_disk",
        "store.store_bytes",
        "store.corrupt_records_skipped",
        "store.retained",
        "store.superseded",
        "store.parked_resident",
    ] {
        assert!(
            HANDBOOK.contains(&format!("`{key}`")),
            "docs/ARCHITECTURE.md lost the `{key}` gauge row"
        );
    }
}
