//! Integration: rust runtime vs the Python build path's golden vectors.
//!
//! Loads the real `artifacts/` (run `make artifacts` first), executes the
//! compiled programs through the full DeviceHandle → Engine path, and checks
//! the numerics against `golden_tiny.json` — proving the AOT interchange
//! (weights npz + HLO text) round-trips exactly.  Skips cleanly when the
//! artifacts or the PJRT backend are unavailable.

use std::sync::{Arc, OnceLock};

use warp_cortex::model::Engine;
use warp_cortex::runtime::{DeviceHandle, DeviceOptions, Lane};
use warp_cortex::util::json::Json;

const TOL: f32 = 2e-4;

fn engine() -> Option<&'static Arc<Engine>> {
    static ENGINE: OnceLock<Result<Arc<Engine>, String>> = OnceLock::new();
    match ENGINE.get_or_init(|| {
        let opts = DeviceOptions::from_env().with_configs(&["tiny"]);
        let device = DeviceHandle::new(opts).map_err(|e| format!("{e:#}"))?;
        Engine::new(device, "tiny").map_err(|e| format!("{e:#}"))
    }) {
        Ok(e) => Some(e),
        // Surface the REAL bring-up error so stub/missing-artifacts skips
        // are distinguishable from genuine device-layer regressions.
        Err(why) => {
            eprintln!("skipping device-dependent test — engine bring-up failed: {why}");
            None
        }
    }
}

macro_rules! require_engine {
    () => {
        match engine() {
            Some(e) => e,
            None => return,
        }
    };
}

fn golden() -> Json {
    let dir = warp_cortex::runtime::Manifest::default_dir();
    let text = std::fs::read_to_string(dir.join("golden_tiny.json")).expect("golden file");
    Json::parse(&text).expect("golden json")
}

fn close(a: &[f32], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - *y as f32).abs() < TOL,
            "{what}[{i}]: rust={x} python={y}"
        );
    }
}

fn prompt_tokens(g: &Json) -> Vec<i32> {
    g.get("prompt_tokens")
        .unwrap()
        .num_vec()
        .unwrap()
        .into_iter()
        .map(|v| v as i32)
        .collect()
}

#[test]
fn prefill_matches_golden() {
    let eng = require_engine!();
    let g = golden();
    let tokens = prompt_tokens(&g);
    let mut kv = eng.new_main_cache();
    let out = eng.prefill(&tokens, &mut kv, Lane::River).unwrap();
    assert_eq!(kv.len(), tokens.len());

    let gp = g.get("prefill").unwrap();
    let v = eng.config().vocab_size;
    let last = &out.logits[(tokens.len() - 1) * v..tokens.len() * v];
    let expect_argmax = gp.get("argmax_last").unwrap().as_i64().unwrap() as usize;
    assert_eq!(
        warp_cortex::util::vecmath::argmax(last),
        expect_argmax,
        "prefill argmax"
    );
    close(
        &last[..8],
        &gp.get("logits8_last").unwrap().num_vec().unwrap(),
        "prefill logits8",
    );
    close(
        &out.hidden_last[..8],
        &gp.get("hidden8").unwrap().num_vec().unwrap(),
        "prefill hidden8",
    );
}

#[test]
fn decode_steps_match_golden() {
    let eng = require_engine!();
    let g = golden();
    let tokens = prompt_tokens(&g);
    let mut kv = eng.new_main_cache();
    eng.prefill(&tokens, &mut kv, Lane::River).unwrap();

    for (i, step) in g.get("decode_steps").unwrap().as_arr().unwrap().iter().enumerate() {
        let tok = step.get("token_in").unwrap().as_i64().unwrap() as i32;
        let pos = step.get("pos").unwrap().as_i64().unwrap() as i32;
        assert_eq!(pos as usize, kv.len(), "step {i} position bookkeeping");
        let out = eng.decode(tok, pos, &mut kv, Lane::River).unwrap();
        let expect_argmax = step.get("argmax").unwrap().as_i64().unwrap() as usize;
        assert_eq!(
            warp_cortex::util::vecmath::argmax(&out.logits),
            expect_argmax,
            "step {i} argmax"
        );
        close(
            &out.logits[..8],
            &step.get("logits8").unwrap().num_vec().unwrap(),
            &format!("step {i} logits8"),
        );
        close(
            &out.hidden[..4],
            &step.get("hidden4").unwrap().num_vec().unwrap(),
            &format!("step {i} hidden4"),
        );
    }
}

#[test]
fn synapse_extract_matches_golden() {
    let eng = require_engine!();
    let g = golden();
    let tokens = prompt_tokens(&g);
    let mut kv = eng.new_main_cache();
    let pre = eng.prefill(&tokens, &mut kv, Lane::River).unwrap();

    let gs = g.get("synapse").unwrap();
    let alpha = gs.get("alpha").unwrap().as_f64().unwrap() as f32;
    let sig = gs.get("inv2sig2").unwrap().as_f64().unwrap() as f32;
    let out = eng
        .synapse_extract_with(&pre.hidden_last, &kv, alpha, sig, Lane::Stream)
        .unwrap();

    let expect_idx: Vec<i32> = gs
        .get("indices")
        .unwrap()
        .num_vec()
        .unwrap()
        .into_iter()
        .map(|v| v as i32)
        .collect();
    assert_eq!(out.indices, expect_idx, "landmark indices");
    close(
        &out.scores[..8],
        &gs.get("scores8").unwrap().num_vec().unwrap(),
        "landmark scores",
    );
    close(
        &out.lm_k[..4],
        &gs.get("lm_k_slice").unwrap().num_vec().unwrap(),
        "lm_k slice",
    );
}

#[test]
fn inject_encode_matches_golden() {
    let eng = require_engine!();
    let g = golden();
    let gi = g.get("inject").unwrap();
    let len = gi.get("length").unwrap().as_usize().unwrap();
    let tokens: Vec<i32> = gi
        .get("tokens")
        .unwrap()
        .num_vec()
        .unwrap()
        .into_iter()
        .map(|v| v as i32)
        .take(len)
        .collect();
    let pos_base = gi.get("pos_base").unwrap().as_i64().unwrap() as i32;
    let out = eng.inject_encode(&tokens, pos_base, Lane::Stream).unwrap();
    assert_eq!(out.len, len);
    close(
        &out.k[..4],
        &gi.get("k_slice").unwrap().num_vec().unwrap(),
        "inject k slice",
    );
    close(
        &out.hidden_last[..4],
        &gi.get("hidden4").unwrap().num_vec().unwrap(),
        "inject hidden4",
    );
}

#[test]
fn batched_decode_agrees_with_single() {
    // Batched side decode must equal per-slot single decode (vmap soundness
    // through the whole AOT pipeline).
    let eng = require_engine!();
    let tk = warp_cortex::text::Tokenizer::new();

    // Build two distinct side caches via referential-style seeding: encode a
    // short text each and append.
    let mk = |text: &str, seed_pos: i32| {
        let toks = tk.encode(text, true);
        let enc = eng.inject_encode(&toks, seed_pos, Lane::Stream).unwrap();
        let (k, v) = eng.slice_inject_rows(&enc, enc.len);
        let mut kv = eng.new_side_cache();
        kv.append_rows(enc.len, &k, &v).unwrap();
        kv
    };
    let mut a = mk("the river flows", 0);
    let mut b = mk("check the fact", 0);

    let mut a2 = a.clone();
    let mut b2 = b.clone();

    let pos_a = a.len() as i32;
    let pos_b = b.len() as i32;
    let single_a = eng.decode(65, pos_a, &mut a, Lane::Stream).unwrap();
    let single_b = eng.decode(66, pos_b, &mut b, Lane::Stream).unwrap();

    let mut slots = [(65, pos_a, &mut a2), (66, pos_b, &mut b2)];
    let batched = eng.decode_batch(&mut slots, Lane::Stream).unwrap();

    for (s, bt) in [(&single_a, &batched[0]), (&single_b, &batched[1])] {
        for (x, y) in s.logits.iter().zip(&bt.logits) {
            assert!((x - y).abs() < 1e-3, "batched logits diverge: {x} vs {y}");
        }
    }
    assert_eq!(a.len(), a2.len());
    assert_eq!(a.k_slice(0, 0, a.len()), a2.k_slice(0, 0, a2.len()));
}

#[test]
fn river_lane_reports_lower_queue_time_under_load() {
    // Submit a burst of Stream ops then a River op: the River op must not
    // wait behind the whole burst (strict priority pop order).
    let eng = require_engine!();
    let dev = eng.device().clone();
    let id = dev.program_id("tiny_inject_encode_t16").unwrap();
    let t = eng.caps().inject_len;

    let inputs = || {
        vec![
            warp_cortex::runtime::HostTensor::i32(vec![65; t], vec![t]),
            warp_cortex::runtime::HostTensor::scalar_i32(t as i32),
            warp_cortex::runtime::HostTensor::scalar_i32(0),
        ]
    };
    let mut stream_rx = Vec::new();
    for _ in 0..8 {
        stream_rx.push(dev.submit(id, inputs(), Lane::Stream));
    }
    let river = dev.call(id, inputs(), Lane::River).unwrap();
    let mut stream_q = Vec::new();
    for rx in stream_rx {
        stream_q.push(rx.recv().unwrap().unwrap().queue_ns);
    }
    let max_stream = *stream_q.iter().max().unwrap();
    assert!(
        river.queue_ns < max_stream,
        "river queued {} ns >= slowest stream {} ns",
        river.queue_ns,
        max_stream
    );
}
