//! Integration: the full Warp-Cortex coordinator against real artifacts.
//!
//! Covers the paper's mechanisms end-to-end: Prism registration accounting
//! (resident-block bytes), synapse extraction→seeding, side agents through
//! the dynamic batcher, validation gating, referential injection into a
//! live main cache, and a complete council episode.
//!
//! Device-dependent tests skip cleanly when the artifacts or the PJRT
//! backend are unavailable (run `make artifacts` with a real `xla` binding
//! to exercise them); pool/cache behaviour itself is covered device-free by
//! the unit tests in `model/pool.rs` and `model/kv.rs`.

use std::sync::{Arc, OnceLock};
use std::time::Duration;

use warp_cortex::cortex::{
    AgentKind, CortexConfig, Event, Injector, MemKind, MemoryTracker, Prism,
    StandardArchitecture, Synapse, WarpCortex,
};
use warp_cortex::model::Engine;
use warp_cortex::runtime::{DeviceHandle, DeviceOptions, Lane};
use warp_cortex::text::{SamplerConfig, Tokenizer};

fn engine() -> Option<&'static Arc<Engine>> {
    static ENGINE: OnceLock<Result<Arc<Engine>, String>> = OnceLock::new();
    match ENGINE.get_or_init(|| {
        let device = DeviceHandle::new(DeviceOptions::from_env().with_configs(&["tiny"]))
            .map_err(|e| format!("{e:#}"))?;
        Engine::new(device, "tiny").map_err(|e| format!("{e:#}"))
    }) {
        Ok(e) => Some(e),
        // Surface the REAL bring-up error: "stub backend" and "artifacts
        // missing" read very differently from a genuine device regression.
        Err(why) => {
            eprintln!("skipping device-dependent test — engine bring-up failed: {why}");
            None
        }
    }
}

/// Resolve the shared engine or skip the test (artifacts/backend absent).
macro_rules! require_engine {
    () => {
        match engine() {
            Some(e) => e,
            None => return,
        }
    };
}

// > synapse_k (64) tokens but < prefill_len (128) with BOS.
fn long_prompt() -> String {
    "user: tell me about the kv cache.\n\
     river: the cache grows one row per token. the synapse selects \
     landmark tokens.\nriver: "
        .to_string()
}

#[test]
fn prism_accounting_tracks_resident_blocks() {
    let eng = require_engine!();
    let tracker = MemoryTracker::new();
    // Private pool so concurrent tests sharing the engine's default pool
    // cannot perturb the block-count assertions.
    let pool = warp_cortex::model::KvPool::new(
        eng.config(),
        warp_cortex::model::KvPoolConfig::default(),
    );
    let prism = Prism::with_pool(eng.clone(), tracker.clone(), pool);
    let w = tracker.live_bytes(MemKind::Weights);
    assert!(w > 0, "weights accounted once");

    let mut t1 = prism.register(AgentKind::Main).unwrap();
    let t2 = prism.register(AgentKind::Side).unwrap();
    let t3 = prism.register(AgentKind::Side).unwrap();
    assert_eq!(prism.population().main, 1);
    assert_eq!(prism.population().side, 2);
    // weights did NOT grow with agents — the singleton claim
    assert_eq!(tracker.live_bytes(MemKind::Weights), w);
    // fresh caches hold no blocks: registration is free until rows land
    assert_eq!(tracker.live_bytes(MemKind::MainKv), 0);
    assert_eq!(tracker.live_bytes(MemKind::SideKv), 0);
    assert_eq!(t1.kv.bytes(), 0);
    // side capacity is much smaller than main capacity (O(k) vs O(L))
    assert!(t2.kv.capacity_bytes() * 4 < t1.kv.capacity_bytes());

    // filling the main cache charges resident-block bytes as it grows
    let tk = Tokenizer::new();
    eng.prefill(&tk.encode(&long_prompt(), true), &mut t1.kv, Lane::River)
        .unwrap();
    let main_live = tracker.live_bytes(MemKind::MainKv);
    assert_eq!(main_live as u64, t1.kv.bytes());
    assert!(t1.kv.bytes() > 0);
    // resident tracks fill, not the configured capacity
    assert!(t1.kv.bytes() < t1.kv.capacity_bytes());
    assert_eq!(
        t1.kv.bytes(),
        prism.pool().blocks_for(t1.kv.len()) as u64 * prism.pool().block_bytes()
    );

    drop(t2);
    assert_eq!(prism.population().side, 1);
    assert_eq!(tracker.live_bytes(MemKind::SideKv) as u64, t3.kv.bytes());
    drop(t1);
    drop(t3);
    assert_eq!(prism.population().total(), 0);
    assert_eq!(tracker.live_bytes(MemKind::MainKv), 0);
    // every block went back to the pool
    assert_eq!(prism.pool().stats().blocks_live, 0);
}

#[test]
fn synapse_extraction_seeds_side_agents() {
    let eng = require_engine!();
    let tk = Tokenizer::new();
    let tracker = MemoryTracker::new();
    let synapse = Synapse::new(tracker.clone());

    let mut kv = eng.new_main_cache();
    let prompt = tk.encode(&long_prompt(), true);
    let pre = eng.prefill(&prompt, &mut kv, Lane::River).unwrap();

    let out = eng
        .synapse_extract(&pre.hidden_last, &kv, Lane::Background)
        .unwrap();
    let k = eng.caps().synapse_k;
    assert_eq!(out.indices.len(), k);
    assert!(out.indices.windows(2).all(|w| w[0] < w[1]));
    assert!(out.indices.iter().all(|&i| (i as usize) < kv.len()));

    synapse.push(out);
    let (side_kv, pos, version) = synapse.seed_side_cache(eng).unwrap();
    assert_eq!(side_kv.len(), k);
    assert_eq!(pos as usize, kv.len());
    assert_eq!(version, 1);
    // compression: k rows vs full context
    let snap = synapse.read().unwrap();
    assert!(snap.compression() > 0.4, "{}", snap.compression());

    // seeding in place reuses an existing cache (the pool path)
    let mut reseeded = eng.new_side_cache();
    let (pos2, v2) = synapse
        .seed_into(&mut reseeded, warp_cortex::cortex::SeedMode::Full)
        .unwrap();
    assert_eq!(pos2, pos);
    assert_eq!(v2, version);
    assert_eq!(reseeded.len(), k);
    assert_eq!(
        reseeded.k_slice(0, 0, k),
        side_kv.k_slice(0, 0, k),
        "seed_into and seed_side_cache must agree"
    );

    // the seeded side cache can decode immediately
    let mut side_kv = side_kv;
    let out = eng.decode(97, pos, &mut side_kv, Lane::Stream).unwrap();
    assert_eq!(out.logits.len(), eng.config().vocab_size);
    assert!(out.logits.iter().all(|x| x.is_finite()));
}

#[test]
fn referential_injection_changes_predictions_not_positions() {
    let eng = require_engine!();
    let tk = Tokenizer::new();
    let injector = Injector::new(8);

    let mut kv = eng.new_main_cache();
    let prompt = tk.encode("user: what is a kilobyte?\nriver: a kilobyte is ", true);
    eng.prefill(&prompt, &mut kv, Lane::River).unwrap();
    let pos = kv.len() as i32;

    // Clone the cache; inject into one copy only.
    let mut kv_injected = kv.clone();
    let thought = tk.encode("fact: a kilobyte is 1024 bytes.", false);
    let report = injector
        .inject(eng, &mut kv_injected, &thought, pos, Lane::Stream)
        .unwrap();
    assert!(report.rows > 0);
    assert_eq!(report.len_after, report.len_before + report.rows);

    // Decode the SAME next token id at the SAME text position in both.
    let plain = eng.decode(32, pos, &mut kv, Lane::River).unwrap();
    let inj = eng.decode(32, pos, &mut kv_injected, Lane::River).unwrap();
    // The injected memory must influence the distribution...
    let diff: f32 = plain
        .logits
        .iter()
        .zip(&inj.logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f32::max);
    assert!(diff > 1e-4, "injection had no effect (max diff {diff})");
    // ...while the visible stream/position bookkeeping is unchanged.
    assert_eq!(kv_injected.len(), kv.len() + report.rows);

    let stats = injector.stats();
    assert_eq!(stats.injected, 1);
}

#[test]
fn injection_headroom_refusal() {
    let eng = require_engine!();
    let injector = Injector::new(eng.caps().main_ctx); // absurd reserve
    let mut kv = eng.new_main_cache();
    let tk = Tokenizer::new();
    eng.prefill(&tk.encode("hi", true), &mut kv, Lane::River).unwrap();
    let err = injector
        .inject(eng, &mut kv, &[65, 66], 2, Lane::Stream)
        .unwrap_err();
    assert!(format!("{err:#}").contains("headroom"));
    assert_eq!(injector.stats().refused_headroom, 1);
}

#[test]
fn full_council_episode_produces_events_and_text() {
    let eng = require_engine!();
    let cfg = CortexConfig {
        model: "tiny".into(),
        max_side_agents: 2,
        synapse_refresh_every: 8,
        side_gen_budget: 8,
        sampler: SamplerConfig {
            temperature: 0.7,
            seed: 42,
            ..SamplerConfig::default()
        },
        ..CortexConfig::default()
    };
    let cortex = WarpCortex::new(eng.clone(), cfg).unwrap();

    // Prompt carries explicit triggers so routing fires deterministically.
    let prompt = format!(
        "{} [TASK: verify the math] [RECALL: the definition] ",
        long_prompt()
    );
    let report = cortex.run_episode(&prompt, 48).unwrap();

    assert!(report.tokens_generated > 0);
    assert!(!report.text.is_empty());
    assert!(report.main_tokens_per_sec > 0.0);

    let spawned = report
        .events
        .iter()
        .filter(|e| matches!(e, Event::Spawned { .. }))
        .count();
    let synapse_pushes = report
        .events
        .iter()
        .filter(|e| matches!(e, Event::SynapsePushed { .. }))
        .count();
    assert!(synapse_pushes >= 1, "synapse never refreshed");
    // Prompt triggers fire on the first generated tokens (router saw the
    // prompt) — at least the two explicit tasks must spawn or drop.
    let routed = spawned
        + report
            .events
            .iter()
            .filter(|e| matches!(e, Event::Dropped { .. }))
            .count();
    assert!(routed >= 2, "prompt triggers not routed: {:?}", report.events);
    // every spawned task reaches a terminal event
    let terminal = report
        .events
        .iter()
        .filter(|e| {
            matches!(
                e,
                Event::Merged { .. } | Event::Rejected { .. } | Event::Failed { .. }
            )
        })
        .count();
    assert!(terminal >= 1, "no side agent completed: {:?}", report.events);
    // memory snapshot is alive and categorised
    assert!(report.memory.get(MemKind::Weights) > 0);
    assert!(report.memory.total() > 0);
    // the pool served the episode and finished agents returned their blocks:
    // only the main agent's blocks remain live at episode end
    assert!(report.pool.blocks_high_water > 0);
    assert!(report.pool.blocks_live <= report.pool.blocks_high_water);
}

#[test]
fn batcher_concurrent_decodes_are_correct_and_batched() {
    use warp_cortex::cortex::Batcher;
    let eng = require_engine!();
    let tk = Tokenizer::new();
    let batcher = Batcher::new(eng.clone(), Duration::from_millis(3));

    // Reference: single-threaded engine decode.
    let seed_cache = |text: &str| {
        let toks = tk.encode(text, true);
        let enc = eng.inject_encode(&toks, 0, Lane::Stream).unwrap();
        let (k, v) = eng.slice_inject_rows(&enc, enc.len);
        let mut kv = eng.new_side_cache();
        kv.append_rows(enc.len, &k, &v).unwrap();
        kv
    };

    let texts = ["alpha", "beta", "gamma", "delta"];
    let mut expected = Vec::new();
    for t in texts {
        let mut kv = seed_cache(t);
        let pos = kv.len() as i32;
        let out = eng.decode(65, pos, &mut kv, Lane::Stream).unwrap();
        expected.push(out.logits);
    }

    // Concurrent: four threads through the batcher.
    let handles: Vec<_> = texts
        .iter()
        .map(|t| {
            let batcher = batcher.clone();
            let eng = eng.clone();
            let t = t.to_string();
            std::thread::spawn(move || {
                let tk = Tokenizer::new();
                let toks = tk.encode(&t, true);
                let enc = eng.inject_encode(&toks, 0, Lane::Stream).unwrap();
                let (k, v) = eng.slice_inject_rows(&enc, enc.len);
                let mut kv = eng.new_side_cache();
                kv.append_rows(enc.len, &k, &v).unwrap();
                let pos = kv.len() as i32;
                batcher.decode(65, pos, &mut kv).unwrap().logits
            })
        })
        .collect();
    let results: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    for (got, want) in results.iter().zip(&expected) {
        for (a, b) in got.iter().zip(want) {
            assert!((a - b).abs() < 1e-3, "batched decode diverged: {a} vs {b}");
        }
    }
    let stats = batcher.stats();
    assert_eq!(stats.requests, 4);
}

#[test]
fn batcher_shutdown_is_clean_and_idempotent() {
    use warp_cortex::cortex::Batcher;
    let eng = require_engine!();
    let batcher = Batcher::new(eng.clone(), Duration::from_micros(200));

    // A decode completed before shutdown proves the channel worked.
    let tk = Tokenizer::new();
    let toks = tk.encode("ok", true);
    let enc = eng.inject_encode(&toks, 0, Lane::Stream).unwrap();
    let (k, v) = eng.slice_inject_rows(&enc, enc.len);
    let mut kv = eng.new_side_cache();
    kv.append_rows(enc.len, &k, &v).unwrap();
    let pos = kv.len() as i32;
    batcher.decode(65, pos, &mut kv).unwrap();

    batcher.shutdown();
    // Post-shutdown decodes error immediately instead of hanging on a dead
    // channel (the orchestrator-teardown fix).
    let err = batcher.decode(65, pos + 1, &mut kv).unwrap_err();
    assert!(
        format!("{err:#}").contains("shut down"),
        "unexpected error: {err:#}"
    );
    // Idempotent.
    batcher.shutdown();
    assert!(batcher.decode(65, pos + 1, &mut kv).is_err());
}

#[test]
fn hierarchical_and_adaptive_seeding_work_end_to_end() {
    use warp_cortex::cortex::SeedMode;
    let eng = require_engine!();
    let tk = Tokenizer::new();
    let tracker = MemoryTracker::new();
    let synapse = Synapse::new(tracker);

    let mut kv = eng.new_main_cache();
    let pre = eng
        .prefill(&tk.encode(&long_prompt(), true), &mut kv, Lane::River)
        .unwrap();
    let s = eng
        .synapse_extract(&pre.hidden_last, &kv, Lane::Background)
        .unwrap();
    let k_full = s.indices.len();
    synapse.push(s);

    // Hierarchical Synapse (§6.2 #2): coarse seeding yields a smaller but
    // decodable cache whose landmarks are a causal subset of the fine set.
    let (coarse_kv, pos, _) = synapse
        .seed_side_cache_with(eng, SeedMode::Coarse(8))
        .unwrap();
    assert_eq!(coarse_kv.len(), 8);
    let fine = synapse.read().unwrap();
    let coarse = fine.coarsen(8);
    assert!(coarse
        .indices
        .iter()
        .all(|i| fine.landmarks.indices.contains(i)));
    let mut coarse_kv = coarse_kv;
    let out = eng.decode(97, pos, &mut coarse_kv, Lane::Stream).unwrap();
    assert!(out.logits.iter().all(|x| x.is_finite()));

    // Adaptive Landmark Selection (§6.2 #1): mass-driven k in [min_k, K].
    let (small_kv, _, _) = synapse
        .seed_side_cache_with(
            eng,
            SeedMode::Adaptive { target_mass: 0.3, min_k: 4 },
        )
        .unwrap();
    let (big_kv, _, _) = synapse
        .seed_side_cache_with(
            eng,
            SeedMode::Adaptive { target_mass: 0.999, min_k: 4 },
        )
        .unwrap();
    assert!(small_kv.len() >= 4);
    assert!(small_kv.len() <= big_kv.len());
    assert!(big_kv.len() <= k_full);
}

#[test]
fn decode_tiers_agree_across_capacities() {
    // The capacity-tier dispatcher (§Perf opt A) must be numerically
    // transparent: decoding the same state through the small tier and
    // through the full-capacity program gives the same result.  Since the
    // paged refactor both uploads come from the same block-translation
    // gather, so this also pins the zero-fill-past-len convention.
    let eng = require_engine!();
    let tk = Tokenizer::new();
    let mut kv = eng.new_main_cache();
    eng.prefill(&tk.encode("user: hi\nriver: ", true), &mut kv, Lane::River)
        .unwrap();
    // len ≈ 18 → dispatcher picks the 96 or 128 tier
    let small = {
        let mut c = kv.clone();
        eng.decode(65, c.len() as i32, &mut c, Lane::River).unwrap()
    };
    // force the full-capacity program directly through decode_at_tier
    let full = {
        let mut c = kv.clone();
        eng.decode_at_tier(65, c.len() as i32, &mut c, eng.caps().main_ctx, Lane::River)
            .unwrap()
    };
    for (a, b) in small.logits.iter().zip(&full.logits) {
        assert!((a - b).abs() < 1e-4, "tier mismatch: {a} vs {b}");
    }
    for (a, b) in small.hidden.iter().zip(&full.hidden) {
        assert!((a - b).abs() < 1e-4);
    }
}

#[test]
fn failure_injection_bad_inputs_error_cleanly() {
    // Wrong shapes / empty inputs must produce errors, never poison the
    // device thread: a good op afterwards still succeeds.
    let eng = require_engine!();
    let dev = eng.device().clone();
    let tk = Tokenizer::new();

    // empty prompt
    assert!(eng
        .prefill(&[], &mut eng.new_main_cache(), Lane::River)
        .is_err());
    // oversized prompt
    let long = vec![65i32; eng.caps().prefill_len + 1];
    assert!(eng
        .prefill(&long, &mut eng.new_main_cache(), Lane::River)
        .is_err());
    // wrong-shaped raw op through the device layer
    let id = dev.program_id("tiny_inject_encode_t16").unwrap();
    let bad = dev.call(
        id,
        vec![warp_cortex::runtime::HostTensor::scalar_i32(1)],
        Lane::Stream,
    );
    assert!(bad.is_err());
    // empty thought
    assert!(eng.inject_encode(&[], 0, Lane::Stream).is_err());
    // device still healthy afterwards
    let mut kv = eng.new_main_cache();
    assert!(eng
        .prefill(&tk.encode("ok", true), &mut kv, Lane::River)
        .is_ok());
}

#[test]
fn scheduler_backpressure_rejects_over_capacity() {
    use std::time::Duration;
    use warp_cortex::cortex::AgentRole;
    use warp_cortex::cortex::{Batcher, SideContext, SideTask, StreamScheduler};
    let eng = require_engine!();
    let tracker = MemoryTracker::new();
    let synapse = Synapse::new(tracker.clone());
    // deliberately EMPTY synapse: tasks fail fast inside workers, but the
    // queue-capacity check happens before any of that.
    let ctx = std::sync::Arc::new(SideContext {
        engine: eng.clone(),
        synapse,
        batcher: Batcher::new(eng.clone(), Duration::from_micros(100)),
        prism: Prism::new(eng.clone(), tracker),
        seed_mode: warp_cortex::cortex::SeedMode::Full,
        gen_budget: 4,
        sampler: warp_cortex::text::SamplerConfig::greedy(),
    });
    let sched = StreamScheduler::new(ctx, 1, 2);
    let mk = |i| SideTask {
        id: i,
        session: 0,
        role: AgentRole::Task,
        payload: format!("task {i}"),
        main_pos: 0,
        spawned_at: std::time::Instant::now(),
    };
    let mut accepted = 0;
    let mut rejected = 0;
    for i in 0..50 {
        if sched.submit(mk(i)) {
            accepted += 1;
        } else {
            rejected += 1;
        }
    }
    assert!(rejected > 0, "queue never filled");
    assert!(accepted >= 2);
    // all accepted tasks eventually produce (failed) outcomes
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    let mut done = 0;
    while done < accepted && std::time::Instant::now() < deadline {
        done += sched.poll_results().len();
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(done, accepted, "tasks lost in the scheduler");
}

#[test]
fn memory_conservation_under_agent_churn() {
    use warp_cortex::util::proptest::check;
    let eng = require_engine!();
    let tracker = MemoryTracker::new();
    // Private pool: block-leak assertions must not see other tests' caches.
    let pool = warp_cortex::model::KvPool::new(
        eng.config(),
        warp_cortex::model::KvPoolConfig::default(),
    );
    let prism = Prism::with_pool(eng.clone(), tracker.clone(), pool);
    // Per-agent host-KV charge only: the device slab (DeviceKv) legitimately
    // retains copies for free-listed blocks across agent drops, so it is
    // not conserved per-churn-round the way the per-agent guards are.
    let host_kv = |t: &MemoryTracker| t.live_bytes(MemKind::MainKv) + t.live_bytes(MemKind::SideKv);
    let base = host_kv(&tracker);
    let row = eng.config().n_layers * eng.config().n_kv_heads * eng.config().head_dim;
    check("register/fill/drop conserves bytes", 30, |g| {
        let n = g.usize_in(1..6);
        let mut tickets = Vec::new();
        for _ in 0..n {
            let kind = if g.bool() { AgentKind::Main } else { AgentKind::Side };
            let mut t = prism.register(kind).unwrap();
            // fill a random number of rows so resident bytes are non-trivial
            let rows = g.usize_in(0..t.kv.capacity().min(40));
            for _ in 0..rows {
                let k = vec![0.5f32; row];
                t.kv.append_row(&k, &k).map_err(|e| e.to_string())?;
            }
            tickets.push(t);
        }
        let live = host_kv(&tracker);
        // tracker charge equals the sum of resident-block bytes
        let expected: u64 = tickets.iter().map(|t| t.kv.bytes()).sum();
        warp_cortex::prop_assert!(
            live == base + expected as i64,
            "live {live} != base {base} + {expected}"
        );
        drop(tickets);
        warp_cortex::prop_assert!(
            host_kv(&tracker) == base,
            "leak after drop: {} != {base}",
            host_kv(&tracker)
        );
        warp_cortex::prop_assert!(
            prism.pool().stats().blocks_live == 0,
            "blocks leaked: {}",
            prism.pool().stats().blocks_live
        );
        Ok(())
    });
}

#[test]
fn prefix_sharing_runs_one_cold_prefill_for_n_agents() {
    use warp_cortex::model::{KvPool, KvPoolConfig};
    let eng = require_engine!();
    let tk = Tokenizer::new();
    let prompt = tk.encode(&long_prompt(), true);
    // Private pool so other tests' registrations cannot perturb the gauges.
    let pool = KvPool::new(eng.config(), KvPoolConfig::default());
    let bt = pool.block_tokens();

    // cold: the first agent runs the monolithic prefill and registers
    let mut a = pool.new_cache(eng.caps().main_ctx);
    let cold = eng.prefill_shared(&prompt, &mut a, Lane::River).unwrap();
    assert!(cold.cold_prefill);
    assert_eq!(cold.cached_rows, 0);
    assert_eq!(a.len(), prompt.len());
    assert_eq!(a.shared_blocks(), prompt.len() / bt, "full blocks published");

    // warm: identical prompts skip the prefill program entirely
    let blocks_before = pool.stats().blocks_live;
    let mut warm_caches = Vec::new();
    for _ in 0..3 {
        let mut b = pool.new_cache(eng.caps().main_ctx);
        let warm = eng.prefill_shared(&prompt, &mut b, Lane::River).unwrap();
        assert!(!warm.cold_prefill, "second identical prompt must not prefill");
        assert_eq!(warm.cached_rows, ((prompt.len() - 1) / bt) * bt);
        assert_eq!(warm.tail_steps, prompt.len() - warm.cached_rows);
        assert_eq!(b.len(), prompt.len());
        // the warm logits/hidden must agree with the cold path (decode and
        // prefill are the same transformer)
        for (x, y) in cold.last_logits.iter().zip(&warm.last_logits) {
            assert!((x - y).abs() < 1e-3, "warm logits diverged: {x} vs {y}");
        }
        for (x, y) in cold.hidden_last.iter().zip(&warm.hidden_last) {
            assert!((x - y).abs() < 1e-3, "warm hidden diverged: {x} vs {y}");
        }
        warm_caches.push(b);
    }
    // O(1) fresh blocks per warm agent: only the uncovered tail
    let per_agent = (pool.stats().blocks_live - blocks_before) / 3;
    let tail_blocks =
        pool.blocks_for(prompt.len()) - (prompt.len() - 1) / bt;
    assert!(
        per_agent <= tail_blocks,
        "warm spawn rented {per_agent} blocks, tail needs {tail_blocks}"
    );
    // shared-prefix residency is independent of N
    assert_eq!(pool.stats().shared_blocks, prompt.len() / bt);
    assert!(pool.stats().prefix_hits >= 3 * ((prompt.len() - 1) / bt) as u64);

    // a warm agent generates like any other: decode continues from the tail
    let mut b = warm_caches.pop().unwrap();
    let pos = b.len() as i32;
    let out = eng.decode(97, pos, &mut b, Lane::River).unwrap();
    assert!(out.logits.iter().all(|x| x.is_finite()));
}

#[test]
fn standard_architecture_scales_linearly_in_weights() {
    let eng = require_engine!();
    let tracker = MemoryTracker::new();
    let mut std_arch = StandardArchitecture::new(eng.clone(), tracker.clone());
    std_arch.spawn().unwrap();
    let w1 = tracker.live_bytes(MemKind::Weights);
    std_arch.spawn().unwrap();
    std_arch.spawn().unwrap();
    assert_eq!(tracker.live_bytes(MemKind::Weights), 3 * w1);
    // the baseline charges eager full-capacity context per agent
    let eager = eng.new_main_cache().capacity_bytes();
    assert_eq!(tracker.live_bytes(MemKind::MainKv) as u64, 3 * eager);
    // functional equivalence: a baseline agent can still run prompts
    let tk = Tokenizer::new();
    let hidden = std_arch.prefill(0, &tk.encode("hello", true)).unwrap();
    assert_eq!(hidden.len(), eng.config().d_model);
}
