//! Host-only serve-layer integration tests: a stub [`SessionSource`] over
//! the REAL step scheduler drives the real HTTP server — session
//! admission, FIFO parking, 503 shedding, chunked streaming, disconnect
//! cancellation and deterministic shutdown — with no artifacts or device.
//!
//! What the stub replaces is only the model: each session's
//! `next_delta` runs one genuine `StepScheduler::main_step` (so session
//! gauges, fusion and admission are the production code paths), paced by
//! a configurable per-token delay so sessions stay in flight long enough
//! to overlap.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use warp_cortex::cortex::step::testing::stub_exec;
use warp_cortex::cortex::{
    AgentCache, SessionPermit, SideAgent, StepConfig, StepScheduler, StepSeams,
};
use warp_cortex::model::{KvCache, KvPool, KvPoolConfig};
use warp_cortex::runtime::ModelConfig;
use warp_cortex::serve::{
    serve, sessions_json, OpenDenied, ServerConfig, ServerHandle, SessionSource, TokenStream,
};
use warp_cortex::text::SamplerConfig;
use warp_cortex::util::Json;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "tiny".into(),
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 2,
        d_ff: 32,
        vocab_size: 260,
        head_dim: 8,
        rope_theta: 1e4,
        param_count: 0,
    }
}

struct StubSource {
    sched: Arc<StepScheduler>,
    pool: Arc<KvPool>,
    delay: Duration,
}

struct StubStream<'a> {
    src: &'a StubSource,
    // Held for its Drop: closing the session is what frees the slot.
    _permit: SessionPermit,
    kv: KvCache,
    produced: usize,
    max_tokens: usize,
    prompt_len: usize,
}

impl SessionSource for StubSource {
    type Stream<'a> = StubStream<'a>
    where
        Self: 'a;

    fn open_session(
        &self,
        prompt: &str,
        max_tokens: usize,
    ) -> Result<StubStream<'_>, OpenDenied> {
        let permit = self
            .sched
            .open_session()
            .map_err(|d| OpenDenied::Busy(d.to_string()))?;
        Ok(StubStream {
            src: self,
            _permit: permit,
            kv: self.pool.new_cache(256),
            produced: 0,
            max_tokens,
            prompt_len: prompt.len(),
        })
    }

    fn stats(&self) -> Json {
        Json::obj().with("sessions", sessions_json(&self.sched.session_stats()))
    }
}

impl<'a> TokenStream for StubStream<'a> {
    fn next_delta(&mut self) -> anyhow::Result<Option<String>> {
        if self.produced >= self.max_tokens {
            return Ok(None);
        }
        std::thread::sleep(self.src.delay);
        let tok = ((self.prompt_len + self.produced) % 200) as i32;
        self.src
            .sched
            .main_step(tok, self.kv.len() as i32, &mut self.kv)?;
        self.produced += 1;
        Ok(Some(format!("t{}", self.produced)))
    }

    fn finish(self) -> anyhow::Result<Json> {
        Ok(Json::obj().with("text", "stub").with("tokens", self.produced))
    }
}

fn stub_source(max_sessions: usize, max_parked: usize, delay_ms: u64) -> Arc<StubSource> {
    let cfg = tiny_cfg();
    let pool = KvPool::new(
        &cfg,
        KvPoolConfig {
            block_tokens: 16,
            ..KvPoolConfig::default()
        },
    );
    let sched = StepScheduler::new(
        StepConfig {
            batch_width: 8,
            side_ctx: 96,
            max_sessions,
            max_parked_sessions: max_parked,
            main_gather: Duration::from_micros(500),
            ..StepConfig::default()
        },
        StepSeams::new(stub_exec(cfg, 96, 8), {
            let pool = pool.clone();
            Arc::new(move |t| {
                // No side tasks in these tests; never called.
                SideAgent::from_parts(
                    t,
                    AgentCache::Bare(pool.new_cache(96)),
                    0,
                    1,
                    vec![],
                    0,
                    SamplerConfig::greedy(),
                )
            })
        }),
    );
    Arc::new(StubSource {
        sched,
        pool,
        delay: Duration::from_millis(delay_ms),
    })
}

fn start(src: Arc<StubSource>, workers: usize) -> ServerHandle {
    serve(
        src,
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers,
            max_tokens_cap: 256,
        },
    )
    .expect("serve binds")
}

// ── HTTP client helpers ─────────────────────────────────────────────────

fn request(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, Json) {
    let mut stream = TcpStream::connect(addr).unwrap();
    let body = body.unwrap_or("");
    let raw = format!(
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(raw.as_bytes()).unwrap();
    let mut response = String::new();
    stream.read_to_string(&mut response).unwrap();
    let status: u16 = response
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let json_body = response
        .split("\r\n\r\n")
        .nth(1)
        .map(|b| Json::parse(b).unwrap_or(Json::Null))
        .unwrap_or(Json::Null);
    (status, json_body)
}

/// A streaming `/generate` client: sends the request, consumes the
/// response headers, then yields de-chunked NDJSON lines one at a time.
/// Dropping it mid-stream is the disconnect the server must survive.
struct StreamingClient {
    reader: BufReader<TcpStream>,
}

impl StreamingClient {
    fn open(addr: SocketAddr, prompt: &str, max_tokens: usize) -> StreamingClient {
        let mut stream = TcpStream::connect(addr).unwrap();
        let body =
            format!(r#"{{"prompt": "{prompt}", "max_tokens": {max_tokens}, "stream": true}}"#);
        let raw = format!(
            "POST /generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        stream.write_all(raw.as_bytes()).unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("200"), "streaming request refused: {line}");
        let mut saw_chunked = false;
        loop {
            let mut h = String::new();
            reader.read_line(&mut h).unwrap();
            if h.to_ascii_lowercase().contains("transfer-encoding: chunked") {
                saw_chunked = true;
            }
            if h == "\r\n" {
                break;
            }
        }
        assert!(saw_chunked, "streaming responses must use chunked encoding");
        StreamingClient { reader }
    }

    /// Next de-chunked payload, or `None` at the terminating zero chunk.
    fn next_chunk(&mut self) -> Option<String> {
        let mut size_line = String::new();
        if self.reader.read_line(&mut size_line).ok()? == 0 {
            return None;
        }
        let size = usize::from_str_radix(size_line.trim(), 16).ok()?;
        if size == 0 {
            let mut tail = String::new();
            let _ = self.reader.read_line(&mut tail);
            return None;
        }
        let mut buf = vec![0u8; size + 2]; // payload + CRLF
        self.reader.read_exact(&mut buf).ok()?;
        Some(String::from_utf8_lossy(&buf[..size]).into_owned())
    }
}

fn sessions_block(addr: SocketAddr) -> Json {
    let (status, body) = request(addr, "GET", "/stats", None);
    assert_eq!(status, 200);
    body.get("sessions").cloned().unwrap_or(Json::Null)
}

fn gauge(j: &Json, key: &str) -> i64 {
    j.get(key).and_then(|v| v.as_i64()).unwrap_or(-1)
}

// ── Tests ───────────────────────────────────────────────────────────────

#[test]
fn health_and_request_validation_run_host_only() {
    let handle = start(stub_source(4, 8, 1), 2);
    let addr = handle.addr;
    let (status, body) = request(addr, "GET", "/health", None);
    assert_eq!(status, 200);
    assert_eq!(body.get("ok").and_then(|v| v.as_bool()), Some(true));
    let (status, _) = request(addr, "POST", "/generate", Some("{not json"));
    assert_eq!(status, 400);
    let (status, _) = request(addr, "POST", "/generate", Some(r#"{"nope": 1}"#));
    assert_eq!(status, 400);
    let (status, body) = request(
        addr,
        "POST",
        "/generate",
        Some(r#"{"prompt": "x", "stream": "yes"}"#),
    );
    assert_eq!(status, 400, "non-boolean stream must 400: {body}");
    let (status, body) = request(
        addr,
        "POST",
        "/generate",
        Some(r#"{"prompt": "hi", "max_tokens": 5}"#),
    );
    assert_eq!(status, 200, "{body}");
    assert_eq!(body.get("tokens").and_then(|v| v.as_usize()), Some(5));
    handle.stop();
}

/// The streaming acceptance criterion: a NEW session delivers its first
/// chunk while another session is mid-generation — no head-of-line
/// blocking across sessions.
#[test]
fn streaming_first_chunk_arrives_while_another_session_is_mid_generation() {
    let handle = start(stub_source(8, 8, 15), 4);
    let addr = handle.addr;
    // Session A: long-running stream.
    let mut a = StreamingClient::open(addr, "alpha", 60);
    let first = a.next_chunk().expect("A's first chunk");
    assert!(first.contains("delta"), "{first}");
    // Session B arrives while A is mid-generation and must complete first.
    let t0 = Instant::now();
    let mut b = StreamingClient::open(addr, "beta", 3);
    let mut b_chunks = 0;
    while b.next_chunk().is_some() {
        b_chunks += 1;
    }
    let b_elapsed = t0.elapsed();
    assert_eq!(b_chunks, 4, "3 token lines + the done line");
    assert!(
        b_elapsed < Duration::from_millis(450),
        "B took {b_elapsed:?}: it queued behind A's 900ms stream (head-of-line blocking)"
    );
    // A was untouched: the rest of its stream still arrives in full.
    let mut a_rest = 0;
    while a.next_chunk().is_some() {
        a_rest += 1;
    }
    assert_eq!(a_rest, 60, "A's remaining 59 token lines + the done line");
    handle.stop();
}

/// The Prometheus scrape endpoint: `/metrics` renders the same snapshot
/// that answers `/stats`, flattened to `warp_<path> <value>` text
/// exposition — one sample per numeric leaf, each preceded by its
/// `# TYPE warp_<path> gauge` metadata line, nothing else.
#[test]
fn metrics_endpoint_exports_prometheus_text() {
    let handle = start(stub_source(2, 4, 1), 2);
    let addr = handle.addr;
    // One completed episode makes the gauges non-trivial.
    let (status, _) = request(
        addr,
        "POST",
        "/generate",
        Some(r#"{"prompt": "m", "max_tokens": 2}"#),
    );
    assert_eq!(status, 200);
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .write_all(b"GET /metrics HTTP/1.1\r\nHost: t\r\nContent-Length: 0\r\n\r\n")
        .unwrap();
    let mut response = String::new();
    stream.read_to_string(&mut response).unwrap();
    let status: u16 = response
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    assert_eq!(status, 200, "{response}");
    let (head, body) = response.split_once("\r\n\r\n").expect("header/body split");
    assert!(
        head.to_ascii_lowercase()
            .contains("content-type: text/plain; version=0.0.4"),
        "scrapers need the exposition-format content type: {head}"
    );
    // The stub's /stats `sessions` block surfaces leaf-by-leaf, each
    // sample announced by its TYPE metadata line.
    assert!(body.contains("warp_sessions_requested 1\n"), "{body}");
    assert!(body.contains("warp_sessions_completed 1\n"), "{body}");
    assert!(body.contains("warp_sessions_active 0\n"), "{body}");
    assert!(
        body.contains("# TYPE warp_sessions_requested gauge\n"),
        "{body}"
    );
    // Every line is either `# TYPE warp_<name> gauge` metadata or a bare
    // `name value` sample.
    for line in body.trim().lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut parts = rest.split(' ');
            assert!(parts.next().unwrap().starts_with("warp_"), "{line}");
            assert_eq!(parts.next(), Some("gauge"), "{line}");
            assert!(parts.next().is_none(), "{line}");
            continue;
        }
        let mut parts = line.split(' ');
        assert!(parts.next().unwrap().starts_with("warp_"), "{line}");
        assert!(parts.next().unwrap().parse::<f64>().is_ok(), "{line}");
        assert!(parts.next().is_none(), "{line}");
    }
    handle.stop();
}

/// Load shedding: with one session slot and no parking, a second
/// concurrent request answers 503 — and the slot recovers once the first
/// session ends.
#[test]
fn saturated_sessions_shed_with_503() {
    let handle = start(stub_source(1, 0, 20), 4);
    let addr = handle.addr;
    let mut a = StreamingClient::open(addr, "hog", 50);
    let _ = a.next_chunk().expect("A is live");
    let (status, body) = request(addr, "POST", "/generate", Some(r#"{"prompt": "b"}"#));
    assert_eq!(status, 503, "{body}");
    assert!(gauge(&sessions_block(addr), "rejected") >= 1);
    // Disconnect A: its slot frees and new sessions admit again.
    drop(a);
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let s = sessions_block(addr);
        if gauge(&s, "active") == 0 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "disconnected session never released its slot: {s}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    let (status, _) = request(
        addr,
        "POST",
        "/generate",
        Some(r#"{"prompt": "c", "max_tokens": 2}"#),
    );
    assert_eq!(status, 200);
    handle.stop();
}

/// The concurrent-client hammer: N parallel `/generate` clients — mixed
/// streaming and non-streaming, some disconnecting mid-stream — all
/// complete, disconnects cancel only their own session, and the `/stats`
/// session gauges reconcile exactly.
#[test]
fn concurrent_client_hammer_reconciles_session_gauges() {
    const CLIENTS: usize = 12;
    let handle = start(stub_source(4, 16, 2), 8);
    let addr = handle.addr;
    std::thread::scope(|scope| {
        for i in 0..CLIENTS {
            scope.spawn(move || match i % 3 {
                // Non-streaming: full episode, well-formed summary.
                0 => {
                    let (status, body) = request(
                        addr,
                        "POST",
                        "/generate",
                        Some(r#"{"prompt": "plain", "max_tokens": 6}"#),
                    );
                    assert_eq!(status, 200, "client {i}: {body}");
                    assert_eq!(
                        body.get("tokens").and_then(|v| v.as_usize()),
                        Some(6),
                        "client {i}"
                    );
                }
                // Streaming, read to completion.
                1 => {
                    let mut c = StreamingClient::open(addr, "streamy", 6);
                    let mut chunks = 0;
                    let mut saw_done = false;
                    while let Some(line) = c.next_chunk() {
                        if line.contains("\"done\"") {
                            saw_done = true;
                        }
                        chunks += 1;
                    }
                    assert_eq!(chunks, 7, "client {i}: 6 token lines + done");
                    assert!(saw_done, "client {i} never saw the summary line");
                }
                // Streaming, disconnect after two chunks.
                _ => {
                    let mut c = StreamingClient::open(addr, "quitter", 40);
                    let _ = c.next_chunk().expect("first chunk");
                    let _ = c.next_chunk().expect("second chunk");
                    drop(c); // mid-stream disconnect
                }
            });
        }
    });
    // Every session reaches a terminal state; the gauges reconcile:
    //   requested == admitted + rejected + parked
    //   admitted  == completed + active
    let deadline = Instant::now() + Duration::from_secs(15);
    loop {
        let s = sessions_block(addr);
        let (req, adm, rej, comp, act, park) = (
            gauge(&s, "requested"),
            gauge(&s, "admitted"),
            gauge(&s, "rejected"),
            gauge(&s, "completed"),
            gauge(&s, "active"),
            gauge(&s, "parked"),
        );
        assert_eq!(req, adm + rej + park, "requested must reconcile: {s}");
        assert_eq!(adm, comp + act, "admitted must reconcile: {s}");
        if act == 0 && park == 0 && comp == CLIENTS as i64 {
            assert_eq!(req, CLIENTS as i64, "{s}");
            assert_eq!(rej, 0, "queue was sized to fit every client: {s}");
            break;
        }
        assert!(
            Instant::now() < deadline,
            "sessions never settled: {s} (disconnects must cancel only their own session)"
        );
        std::thread::sleep(Duration::from_millis(25));
    }
    handle.stop();
}

/// Regression for the `ServerHandle::stop` wake race: the old
/// implementation poked the acceptor with one `TcpStream::connect`, which
/// could be satisfied by the OS backlog (or swallowed ahead of a queued
/// real client) and leave `stop()` hanging.  The nonblocking accept loop
/// makes shutdown deterministic — including with a streaming session in
/// flight.
#[test]
fn stop_is_deterministic_with_inflight_streaming_sessions() {
    // With an in-flight streaming session: stop() must return as soon as
    // the worker finishes that one session, never hang on the acceptor.
    let handle = start(stub_source(4, 8, 10), 2);
    let addr = handle.addr;
    let reader = std::thread::spawn(move || {
        let mut c = StreamingClient::open(addr, "inflight", 30);
        let mut chunks = 0;
        while c.next_chunk().is_some() {
            chunks += 1;
        }
        chunks
    });
    // Wait until the session is actually live before stopping.
    let deadline = Instant::now() + Duration::from_secs(5);
    while gauge(&sessions_block(addr), "active") == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    let t0 = Instant::now();
    handle.stop();
    let stop_elapsed = t0.elapsed();
    assert!(
        stop_elapsed < Duration::from_secs(5),
        "stop() hung for {stop_elapsed:?} with an in-flight stream"
    );
    // The in-flight client was served to completion, not aborted.
    assert_eq!(reader.join().unwrap(), 31, "30 token lines + done");

    // Idle churn: repeated start/stop cycles never hang on the wake race.
    for round in 0..10 {
        let h = start(stub_source(2, 4, 1), 2);
        let t0 = Instant::now();
        h.stop();
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "idle stop round {round} hung"
        );
    }
}
