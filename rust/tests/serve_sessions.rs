//! Host-only serve-layer integration tests: a stub [`SessionSource`] over
//! the REAL step scheduler drives the real HTTP server — session
//! admission, FIFO parking, 503 shedding, chunked streaming, disconnect
//! cancellation and deterministic shutdown — with no artifacts or device.
//!
//! What the stub replaces is only the model: each session's
//! `next_delta` runs one genuine `StepScheduler::main_step` (so session
//! gauges, fusion and admission are the production code paths), paced by
//! a configurable per-token delay so sessions stay in flight long enough
//! to overlap.
//!
//! The durable suite at the bottom swaps in a second stub backed by a
//! REAL [`SessionStore`]: a mid-stream disconnect hibernates instead of
//! cancelling, and `POST /sessions/{id}/resume` continues the stream
//! with exactly the deltas the unbroken stream would have carried.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use warp_cortex::cortex::step::testing::stub_exec;
use warp_cortex::cortex::{
    AgentCache, SessionCheckpoint, SessionPermit, SessionStore, SideAgent, StepConfig,
    StepScheduler, StepSeams, StoreError,
};
use warp_cortex::model::{KvCache, KvPool, KvPoolConfig};
use warp_cortex::runtime::ModelConfig;
use warp_cortex::serve::{
    serve, sessions_json, store_json, OpenDenied, ResumeDenied, ServerConfig, ServerHandle,
    SessionSource, TokenStream,
};
use warp_cortex::text::SamplerConfig;
use warp_cortex::util::Json;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "tiny".into(),
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 2,
        d_ff: 32,
        vocab_size: 260,
        head_dim: 8,
        rope_theta: 1e4,
        param_count: 0,
    }
}

struct StubSource {
    sched: Arc<StepScheduler>,
    pool: Arc<KvPool>,
    delay: Duration,
}

struct StubStream<'a> {
    src: &'a StubSource,
    // Held for its Drop: closing the session is what frees the slot.
    _permit: SessionPermit,
    kv: KvCache,
    produced: usize,
    max_tokens: usize,
    prompt_len: usize,
}

impl SessionSource for StubSource {
    type Stream<'a> = StubStream<'a>
    where
        Self: 'a;

    fn open_session(
        &self,
        prompt: &str,
        max_tokens: usize,
    ) -> Result<StubStream<'_>, OpenDenied> {
        let permit = self
            .sched
            .open_session()
            .map_err(|d| OpenDenied::Busy(d.to_string()))?;
        Ok(StubStream {
            src: self,
            _permit: permit,
            kv: self.pool.new_cache(256),
            produced: 0,
            max_tokens,
            prompt_len: prompt.len(),
        })
    }

    fn stats(&self) -> Json {
        Json::obj().with("sessions", sessions_json(&self.sched.session_stats()))
    }
}

impl<'a> TokenStream for StubStream<'a> {
    fn next_delta(&mut self) -> anyhow::Result<Option<String>> {
        if self.produced >= self.max_tokens {
            return Ok(None);
        }
        std::thread::sleep(self.src.delay);
        let tok = ((self.prompt_len + self.produced) % 200) as i32;
        self.src
            .sched
            .main_step(tok, self.kv.len() as i32, &mut self.kv)?;
        self.produced += 1;
        Ok(Some(format!("t{}", self.produced)))
    }

    fn finish(self) -> anyhow::Result<Json> {
        Ok(Json::obj().with("text", "stub").with("tokens", self.produced))
    }
}

fn stub_source(max_sessions: usize, max_parked: usize, delay_ms: u64) -> Arc<StubSource> {
    let cfg = tiny_cfg();
    let pool = KvPool::new(
        &cfg,
        KvPoolConfig {
            block_tokens: 16,
            ..KvPoolConfig::default()
        },
    );
    let sched = StepScheduler::new(
        StepConfig {
            batch_width: 8,
            side_ctx: 96,
            max_sessions,
            max_parked_sessions: max_parked,
            main_gather: Duration::from_micros(500),
            ..StepConfig::default()
        },
        StepSeams::new(stub_exec(cfg, 96, 8), {
            let pool = pool.clone();
            Arc::new(move |t| {
                // No side tasks in these tests; never called.
                SideAgent::from_parts(
                    t,
                    AgentCache::Bare(pool.new_cache(96)),
                    0,
                    1,
                    vec![],
                    0,
                    SamplerConfig::greedy(),
                )
            })
        }),
    );
    Arc::new(StubSource {
        sched,
        pool,
        delay: Duration::from_millis(delay_ms),
    })
}

fn start(src: Arc<StubSource>, workers: usize) -> ServerHandle {
    serve(
        src,
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers,
            max_tokens_cap: 256,
        },
    )
    .expect("serve binds")
}

// ── HTTP client helpers ─────────────────────────────────────────────────

fn request(addr: SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, Json) {
    let mut stream = TcpStream::connect(addr).unwrap();
    let body = body.unwrap_or("");
    let raw = format!(
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(raw.as_bytes()).unwrap();
    let mut response = String::new();
    stream.read_to_string(&mut response).unwrap();
    let status: u16 = response
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let json_body = response
        .split("\r\n\r\n")
        .nth(1)
        .map(|b| Json::parse(b).unwrap_or(Json::Null))
        .unwrap_or(Json::Null);
    (status, json_body)
}

/// A streaming `/generate` client: sends the request, consumes the
/// response headers, then yields de-chunked NDJSON lines one at a time.
/// Dropping it mid-stream is the disconnect the server must survive.
struct StreamingClient {
    reader: BufReader<TcpStream>,
}

impl StreamingClient {
    fn open(addr: SocketAddr, prompt: &str, max_tokens: usize) -> StreamingClient {
        let body =
            format!(r#"{{"prompt": "{prompt}", "max_tokens": {max_tokens}, "stream": true}}"#);
        StreamingClient::open_raw(addr, "/generate", &body)
    }

    /// A streaming POST to an arbitrary path — `/generate` or
    /// `/sessions/{id}/resume` — asserting the 200 + chunked head.
    fn open_raw(addr: SocketAddr, path: &str, body: &str) -> StreamingClient {
        let mut stream = TcpStream::connect(addr).unwrap();
        let raw = format!(
            "POST {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        stream.write_all(raw.as_bytes()).unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("200"), "streaming request refused: {line}");
        let mut saw_chunked = false;
        loop {
            let mut h = String::new();
            reader.read_line(&mut h).unwrap();
            if h.to_ascii_lowercase().contains("transfer-encoding: chunked") {
                saw_chunked = true;
            }
            if h == "\r\n" {
                break;
            }
        }
        assert!(saw_chunked, "streaming responses must use chunked encoding");
        StreamingClient { reader }
    }

    /// Next de-chunked payload, or `None` at the terminating zero chunk.
    fn next_chunk(&mut self) -> Option<String> {
        let mut size_line = String::new();
        if self.reader.read_line(&mut size_line).ok()? == 0 {
            return None;
        }
        let size = usize::from_str_radix(size_line.trim(), 16).ok()?;
        if size == 0 {
            let mut tail = String::new();
            let _ = self.reader.read_line(&mut tail);
            return None;
        }
        let mut buf = vec![0u8; size + 2]; // payload + CRLF
        self.reader.read_exact(&mut buf).ok()?;
        Some(String::from_utf8_lossy(&buf[..size]).into_owned())
    }
}

fn sessions_block(addr: SocketAddr) -> Json {
    let (status, body) = request(addr, "GET", "/stats", None);
    assert_eq!(status, 200);
    body.get("sessions").cloned().unwrap_or(Json::Null)
}

fn gauge(j: &Json, key: &str) -> i64 {
    j.get(key).and_then(|v| v.as_i64()).unwrap_or(-1)
}

// ── Tests ───────────────────────────────────────────────────────────────

#[test]
fn health_and_request_validation_run_host_only() {
    let handle = start(stub_source(4, 8, 1), 2);
    let addr = handle.addr;
    let (status, body) = request(addr, "GET", "/health", None);
    assert_eq!(status, 200);
    assert_eq!(body.get("ok").and_then(|v| v.as_bool()), Some(true));
    let (status, _) = request(addr, "POST", "/generate", Some("{not json"));
    assert_eq!(status, 400);
    let (status, _) = request(addr, "POST", "/generate", Some(r#"{"nope": 1}"#));
    assert_eq!(status, 400);
    let (status, body) = request(
        addr,
        "POST",
        "/generate",
        Some(r#"{"prompt": "x", "stream": "yes"}"#),
    );
    assert_eq!(status, 400, "non-boolean stream must 400: {body}");
    let (status, body) = request(
        addr,
        "POST",
        "/generate",
        Some(r#"{"prompt": "hi", "max_tokens": 5}"#),
    );
    assert_eq!(status, 200, "{body}");
    assert_eq!(body.get("tokens").and_then(|v| v.as_usize()), Some(5));
    handle.stop();
}

/// The streaming acceptance criterion: a NEW session delivers its first
/// chunk while another session is mid-generation — no head-of-line
/// blocking across sessions.
#[test]
fn streaming_first_chunk_arrives_while_another_session_is_mid_generation() {
    let handle = start(stub_source(8, 8, 15), 4);
    let addr = handle.addr;
    // Session A: long-running stream.
    let mut a = StreamingClient::open(addr, "alpha", 60);
    let first = a.next_chunk().expect("A's first chunk");
    assert!(first.contains("delta"), "{first}");
    // Session B arrives while A is mid-generation and must complete first.
    let t0 = Instant::now();
    let mut b = StreamingClient::open(addr, "beta", 3);
    let mut b_chunks = 0;
    while b.next_chunk().is_some() {
        b_chunks += 1;
    }
    let b_elapsed = t0.elapsed();
    assert_eq!(b_chunks, 4, "3 token lines + the done line");
    assert!(
        b_elapsed < Duration::from_millis(450),
        "B took {b_elapsed:?}: it queued behind A's 900ms stream (head-of-line blocking)"
    );
    // A was untouched: the rest of its stream still arrives in full.
    let mut a_rest = 0;
    while a.next_chunk().is_some() {
        a_rest += 1;
    }
    assert_eq!(a_rest, 60, "A's remaining 59 token lines + the done line");
    handle.stop();
}

/// The Prometheus scrape endpoint: `/metrics` renders the same snapshot
/// that answers `/stats`, flattened to `warp_<path> <value>` text
/// exposition — one sample per numeric leaf, each preceded by its
/// `# TYPE warp_<path> gauge` metadata line, nothing else.
#[test]
fn metrics_endpoint_exports_prometheus_text() {
    let handle = start(stub_source(2, 4, 1), 2);
    let addr = handle.addr;
    // One completed episode makes the gauges non-trivial.
    let (status, _) = request(
        addr,
        "POST",
        "/generate",
        Some(r#"{"prompt": "m", "max_tokens": 2}"#),
    );
    assert_eq!(status, 200);
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .write_all(b"GET /metrics HTTP/1.1\r\nHost: t\r\nContent-Length: 0\r\n\r\n")
        .unwrap();
    let mut response = String::new();
    stream.read_to_string(&mut response).unwrap();
    let status: u16 = response
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    assert_eq!(status, 200, "{response}");
    let (head, body) = response.split_once("\r\n\r\n").expect("header/body split");
    assert!(
        head.to_ascii_lowercase()
            .contains("content-type: text/plain; version=0.0.4"),
        "scrapers need the exposition-format content type: {head}"
    );
    // The stub's /stats `sessions` block surfaces leaf-by-leaf, each
    // sample announced by its TYPE metadata line.
    assert!(body.contains("warp_sessions_requested 1\n"), "{body}");
    assert!(body.contains("warp_sessions_completed 1\n"), "{body}");
    assert!(body.contains("warp_sessions_active 0\n"), "{body}");
    assert!(
        body.contains("# TYPE warp_sessions_requested gauge\n"),
        "{body}"
    );
    // Every line is either `# TYPE warp_<name> gauge` metadata or a bare
    // `name value` sample.
    for line in body.trim().lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut parts = rest.split(' ');
            assert!(parts.next().unwrap().starts_with("warp_"), "{line}");
            assert_eq!(parts.next(), Some("gauge"), "{line}");
            assert!(parts.next().is_none(), "{line}");
            continue;
        }
        let mut parts = line.split(' ');
        assert!(parts.next().unwrap().starts_with("warp_"), "{line}");
        assert!(parts.next().unwrap().parse::<f64>().is_ok(), "{line}");
        assert!(parts.next().is_none(), "{line}");
    }
    handle.stop();
}

/// Load shedding: with one session slot and no parking, a second
/// concurrent request answers 503 — and the slot recovers once the first
/// session ends.
#[test]
fn saturated_sessions_shed_with_503() {
    let handle = start(stub_source(1, 0, 20), 4);
    let addr = handle.addr;
    let mut a = StreamingClient::open(addr, "hog", 50);
    let _ = a.next_chunk().expect("A is live");
    let (status, body) = request(addr, "POST", "/generate", Some(r#"{"prompt": "b"}"#));
    assert_eq!(status, 503, "{body}");
    assert!(gauge(&sessions_block(addr), "rejected") >= 1);
    // Disconnect A: its slot frees and new sessions admit again.
    drop(a);
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let s = sessions_block(addr);
        if gauge(&s, "active") == 0 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "disconnected session never released its slot: {s}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    let (status, _) = request(
        addr,
        "POST",
        "/generate",
        Some(r#"{"prompt": "c", "max_tokens": 2}"#),
    );
    assert_eq!(status, 200);
    handle.stop();
}

/// The concurrent-client hammer: N parallel `/generate` clients — mixed
/// streaming and non-streaming, some disconnecting mid-stream — all
/// complete, disconnects cancel only their own session, and the `/stats`
/// session gauges reconcile exactly.
#[test]
fn concurrent_client_hammer_reconciles_session_gauges() {
    const CLIENTS: usize = 12;
    let handle = start(stub_source(4, 16, 2), 8);
    let addr = handle.addr;
    std::thread::scope(|scope| {
        for i in 0..CLIENTS {
            scope.spawn(move || match i % 3 {
                // Non-streaming: full episode, well-formed summary.
                0 => {
                    let (status, body) = request(
                        addr,
                        "POST",
                        "/generate",
                        Some(r#"{"prompt": "plain", "max_tokens": 6}"#),
                    );
                    assert_eq!(status, 200, "client {i}: {body}");
                    assert_eq!(
                        body.get("tokens").and_then(|v| v.as_usize()),
                        Some(6),
                        "client {i}"
                    );
                }
                // Streaming, read to completion.
                1 => {
                    let mut c = StreamingClient::open(addr, "streamy", 6);
                    let mut chunks = 0;
                    let mut saw_done = false;
                    while let Some(line) = c.next_chunk() {
                        if line.contains("\"done\"") {
                            saw_done = true;
                        }
                        chunks += 1;
                    }
                    assert_eq!(chunks, 7, "client {i}: 6 token lines + done");
                    assert!(saw_done, "client {i} never saw the summary line");
                }
                // Streaming, disconnect after two chunks.
                _ => {
                    let mut c = StreamingClient::open(addr, "quitter", 40);
                    let _ = c.next_chunk().expect("first chunk");
                    let _ = c.next_chunk().expect("second chunk");
                    drop(c); // mid-stream disconnect
                }
            });
        }
    });
    // Every session reaches a terminal state; the gauges reconcile:
    //   requested == admitted + rejected + parked
    //   admitted  == completed + active
    let deadline = Instant::now() + Duration::from_secs(15);
    loop {
        let s = sessions_block(addr);
        let (req, adm, rej, comp, act, park) = (
            gauge(&s, "requested"),
            gauge(&s, "admitted"),
            gauge(&s, "rejected"),
            gauge(&s, "completed"),
            gauge(&s, "active"),
            gauge(&s, "parked"),
        );
        assert_eq!(req, adm + rej + park, "requested must reconcile: {s}");
        assert_eq!(adm, comp + act, "admitted must reconcile: {s}");
        if act == 0 && park == 0 && comp == CLIENTS as i64 {
            assert_eq!(req, CLIENTS as i64, "{s}");
            assert_eq!(rej, 0, "queue was sized to fit every client: {s}");
            break;
        }
        assert!(
            Instant::now() < deadline,
            "sessions never settled: {s} (disconnects must cancel only their own session)"
        );
        std::thread::sleep(Duration::from_millis(25));
    }
    handle.stop();
}

/// Regression for the `ServerHandle::stop` wake race: the old
/// implementation poked the acceptor with one `TcpStream::connect`, which
/// could be satisfied by the OS backlog (or swallowed ahead of a queued
/// real client) and leave `stop()` hanging.  The nonblocking accept loop
/// makes shutdown deterministic — including with a streaming session in
/// flight.
#[test]
fn stop_is_deterministic_with_inflight_streaming_sessions() {
    // With an in-flight streaming session: stop() must return as soon as
    // the worker finishes that one session, never hang on the acceptor.
    let handle = start(stub_source(4, 8, 10), 2);
    let addr = handle.addr;
    let reader = std::thread::spawn(move || {
        let mut c = StreamingClient::open(addr, "inflight", 30);
        let mut chunks = 0;
        while c.next_chunk().is_some() {
            chunks += 1;
        }
        chunks
    });
    // Wait until the session is actually live before stopping.
    let deadline = Instant::now() + Duration::from_secs(5);
    while gauge(&sessions_block(addr), "active") == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    let t0 = Instant::now();
    handle.stop();
    let stop_elapsed = t0.elapsed();
    assert!(
        stop_elapsed < Duration::from_secs(5),
        "stop() hung for {stop_elapsed:?} with an in-flight stream"
    );
    // The in-flight client was served to completion, not aborted.
    assert_eq!(reader.join().unwrap(), 31, "30 token lines + done");

    // Idle churn: repeated start/stop cycles never hang on the wake race.
    for round in 0..10 {
        let h = start(stub_source(2, 4, 1), 2);
        let t0 = Instant::now();
        h.stop();
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "idle stop round {round} hung"
        );
    }
}

// ── Durable sessions over HTTP ──────────────────────────────────────────
//
// A second stub source with a REAL `SessionStore`: streams announce their
// durable id, a mid-stream disconnect hibernates (checkpoint + resident
// park) instead of dropping, and `POST /sessions/{id}/resume` continues
// the stream.  The token sequence is a deterministic function of the
// generation cursor, so "the resumed stream continues identically" is
// directly assertable at the HTTP layer.

struct DurableSource {
    sched: Arc<StepScheduler>,
    pool: Arc<KvPool>,
    store: Arc<SessionStore>,
    delay: Duration,
    next_id: AtomicU64,
}

struct DurableStream<'a> {
    src: &'a DurableSource,
    _permit: SessionPermit,
    kv: KvCache,
    id: u64,
    produced: usize,
    max_tokens: usize,
}

impl DurableSource {
    /// The minimal durable record the stub needs: the generation cursor
    /// and budget (the cortex-level codec tests cover the full payload;
    /// this layer tests the HTTP choreography around it).
    fn checkpoint_of(&self, id: u64, produced: usize, max_tokens: usize) -> SessionCheckpoint {
        SessionCheckpoint {
            id,
            rng_state: 0,
            synapse_version: 0,
            generated: produced as u64,
            max_tokens: max_tokens as u64,
            pos: 0,
            shared_rows: 0,
            total_rows: 0,
            offloaded_blocks: 0,
            prompt: String::new(),
            text: String::new(),
            prompt_ids: Vec::new(),
            recent: Vec::new(),
            logits: Vec::new(),
            hidden: Vec::new(),
            k_tail: Vec::new(),
            v_tail: Vec::new(),
        }
    }
}

impl SessionSource for DurableSource {
    type Stream<'a> = DurableStream<'a>
    where
        Self: 'a;

    fn open_session(
        &self,
        _prompt: &str,
        max_tokens: usize,
    ) -> Result<DurableStream<'_>, OpenDenied> {
        let permit = self
            .sched
            .open_session()
            .map_err(|d| OpenDenied::Busy(d.to_string()))?;
        Ok(DurableStream {
            src: self,
            _permit: permit,
            kv: self.pool.new_cache(256),
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            produced: 0,
            max_tokens,
        })
    }

    fn resume(&self, id: u64) -> Result<DurableStream<'_>, ResumeDenied> {
        // Admit first: a Busy must not consume the single-use record —
        // the same ordering the production cortex uses.
        let permit = self
            .sched
            .open_session()
            .map_err(|d| ResumeDenied::Busy(d.to_string()))?;
        let ticket = match self.store.take(id) {
            Ok(t) => t,
            Err(e) => {
                permit.shed();
                return Err(match e {
                    StoreError::Unknown(_) => ResumeDenied::Unknown,
                    other => ResumeDenied::Internal(other.to_string()),
                });
            }
        };
        let kv = ticket
            .resident
            .and_then(|b| b.downcast::<KvCache>().ok().map(|b| *b))
            .unwrap_or_else(|| self.pool.new_cache(256));
        Ok(DurableStream {
            src: self,
            _permit: permit,
            kv,
            id,
            produced: ticket.checkpoint.generated as usize,
            max_tokens: ticket.checkpoint.max_tokens as usize,
        })
    }

    fn stats(&self) -> Json {
        Json::obj()
            .with("sessions", sessions_json(&self.sched.session_stats()))
            .with("store", store_json(&self.store.stats()))
    }
}

impl<'a> TokenStream for DurableStream<'a> {
    fn next_delta(&mut self) -> anyhow::Result<Option<String>> {
        if self.produced >= self.max_tokens {
            return Ok(None);
        }
        std::thread::sleep(self.src.delay);
        let tok = (self.produced % 200) as i32;
        self.src
            .sched
            .main_step(tok, self.kv.len() as i32, &mut self.kv)?;
        self.produced += 1;
        // Deterministic in the cursor alone: a resumed stream's deltas
        // are bitwise the ones the unbroken stream would have produced.
        Ok(Some(format!("t{}", self.produced)))
    }

    fn finish(self) -> anyhow::Result<Json> {
        Ok(Json::obj().with("text", "stub").with("tokens", self.produced))
    }

    fn session_id(&self) -> Option<u64> {
        Some(self.id)
    }

    fn hibernate(self) -> Option<u64> {
        let DurableStream { src, kv, id, produced, max_tokens, .. } = self;
        src.store.checkpoint(&src.checkpoint_of(id, produced, max_tokens)).ok()?;
        src.store.park_resident(id, Box::new(kv));
        Some(id) // _permit dropped: the admission slot frees here
    }
}

fn durable_source(max_sessions: usize, delay_ms: u64, tag: &str) -> Arc<DurableSource> {
    let cfg = tiny_cfg();
    let pool = KvPool::new(
        &cfg,
        KvPoolConfig {
            block_tokens: 16,
            ..KvPoolConfig::default()
        },
    );
    let sched = StepScheduler::new(
        StepConfig {
            batch_width: 8,
            side_ctx: 96,
            max_sessions,
            max_parked_sessions: 0,
            main_gather: Duration::from_micros(500),
            ..StepConfig::default()
        },
        StepSeams::new(stub_exec(cfg, 96, 8), {
            let pool = pool.clone();
            Arc::new(move |t| {
                SideAgent::from_parts(
                    t,
                    AgentCache::Bare(pool.new_cache(96)),
                    0,
                    1,
                    vec![],
                    0,
                    SamplerConfig::greedy(),
                )
            })
        }),
    );
    let name = format!("warpstore_serve_{}_{tag}.wst", std::process::id());
    let path = std::env::temp_dir().join(name);
    let _ = std::fs::remove_file(&path);
    let store = Arc::new(SessionStore::open(&path).expect("store opens"));
    Arc::new(DurableSource {
        sched,
        pool,
        store,
        delay: Duration::from_millis(delay_ms),
        next_id: AtomicU64::new(1),
    })
}

fn store_block(addr: SocketAddr) -> Json {
    let (status, body) = request(addr, "GET", "/stats", None);
    assert_eq!(status, 200);
    body.get("store").cloned().unwrap_or(Json::Null)
}

/// The `"delta"` payload of one NDJSON stream line.
fn delta_of(line: &str) -> String {
    Json::parse(line.trim())
        .expect("stream line is json")
        .get("delta")
        .and_then(|v| v.as_str().map(String::from))
        .unwrap_or_else(|| panic!("line carries no delta: {line}"))
}

/// The durable-session acceptance criterion at the HTTP layer: a
/// streaming client disconnects mid-generation, the session hibernates
/// (not cancels), and `POST /sessions/{id}/resume` picks the stream up
/// with exactly the deltas the unbroken stream would have carried.  The
/// hibernation point `k` is timing-dependent (the server notices the
/// disconnect on its next failed chunk write), so the assertion is on
/// the delta *payloads*: the resumed stream is the contiguous tail
/// t{k+1}..tN — no token repeated, none skipped — ending in the same
/// summary line.  The record is single-use: a second resume is a 404.
#[test]
fn disconnected_stream_resumes_over_http_with_identical_deltas() {
    const N: usize = 30;
    let src = durable_source(4, 10, "resume");
    let store = src.store.clone();
    let handle = start_durable(src, 4);
    let addr = handle.addr;

    // The unbroken reference: deltas are t1..tN then the done line.
    let mut c = StreamingClient::open(addr, "ref", N);
    let id_line = c.next_chunk().expect("id line");
    assert!(id_line.contains("\"session\""), "first chunk announces the id: {id_line}");
    let mut reference = Vec::new();
    while let Some(line) = c.next_chunk() {
        reference.push(line);
    }
    assert_eq!(reference.len(), N + 1, "{N} deltas + done: {reference:?}");
    let reference_done = reference.pop().expect("done line");
    assert!(reference_done.contains("\"done\""), "{reference_done}");

    // The broken stream: read the id + two deltas, then disconnect.
    let mut c = StreamingClient::open(addr, "broken", N);
    let id_line = c.next_chunk().expect("id line");
    let id = Json::parse(id_line.trim())
        .expect("id line is json")
        .get("session")
        .and_then(|v| v.as_i64())
        .expect("session id") as u64;
    let first = c.next_chunk().expect("delta 1");
    let second = c.next_chunk().expect("delta 2");
    assert_eq!(first, reference[0]);
    assert_eq!(second, reference[1]);
    drop(c); // mid-stream disconnect → the server hibernates the session

    // Hibernation is observable: the record lands in the store and the
    // resident cache parks.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let s = store.stats();
        if s.retained >= 1 && s.parked_resident >= 1 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "disconnect never hibernated: {s:?}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    let sb = store_block(addr);
    assert!(gauge(&sb, "checkpoints") >= 1, "store gauges on /stats: {sb}");
    assert!(gauge(&sb, "retained") >= 1, "{sb}");

    // Resume: a new chunked stream carrying the contiguous tail.
    let mut r = StreamingClient::open_raw(addr, &format!("/sessions/{id}/resume"), "");
    let id_line = r.next_chunk().expect("resumed id line");
    assert!(id_line.contains("\"session\""), "{id_line}");
    let mut resumed = Vec::new();
    while let Some(line) = r.next_chunk() {
        resumed.push(line);
    }
    let resumed_done = resumed.pop().expect("resumed done line");
    assert_eq!(
        resumed_done, reference_done,
        "the resumed episode must end in the reference's summary"
    );
    // First resumed delta pins the hibernation cursor k: the client read
    // 2 deltas, so k ≥ 2; the stream hadn't finished, so k < N.
    let k: usize = delta_of(&resumed[0])
        .strip_prefix('t')
        .and_then(|s| s.parse().ok())
        .expect("deltas are t<cursor>");
    assert!((3..=N).contains(&k), "resume point t{k} out of range");
    assert_eq!(
        resumed.len(),
        N - k + 1,
        "the tail must run t{k}..t{N} with nothing repeated or skipped"
    );
    for (i, line) in resumed.iter().enumerate() {
        assert_eq!(
            delta_of(line),
            delta_of(&reference[k - 1 + i]),
            "resumed delta {i} diverged from the unbroken stream"
        );
    }

    // Single-use: the consumed record cannot resume twice.
    let (status, body) = request(addr, "POST", &format!("/sessions/{id}/resume"), None);
    assert_eq!(status, 404, "consumed record must 404: {body}");
    let sb = store_block(addr);
    assert!(gauge(&sb, "resumes") >= 1, "{sb}");
    assert_eq!(gauge(&sb, "retained"), 0, "{sb}");
    assert_eq!(gauge(&sb, "parked_resident"), 0, "{sb}");
    handle.stop();
}

/// Typed route errors: malformed ids 400 with a JSON error body,
/// lookalike paths and unknown ids 404, and a source without durable
/// support (the plain stub) 404s every resume.
#[test]
fn resume_route_distinguishes_malformed_unknown_and_unsupported() {
    let src = durable_source(2, 1, "routes");
    let handle = start_durable(src, 2);
    let addr = handle.addr;
    // Malformed ids: the route matched, the id did not parse → 400.
    for path in ["/sessions/abc/resume", "/sessions/-7/resume", "/sessions//resume"] {
        let (status, body) = request(addr, "POST", path, None);
        assert_eq!(status, 400, "{path} must 400: {body}");
        assert!(
            body.get("error").is_some(),
            "400s carry a JSON error body: {body}"
        );
    }
    // Lookalikes that must NOT prefix-match the route → 404.
    for path in [
        "/sessions/7/resume/extra",
        "/session/7/resume",
        "/sessions/7/resumed",
        "/sessions/7",
        "/xsessions/7/resume",
    ] {
        let (status, _) = request(addr, "POST", path, None);
        assert_eq!(status, 404, "{path} must 404, not match the resume route");
    }
    // Well-formed but unknown id → 404 (nothing was ever checkpointed).
    let (status, body) = request(addr, "POST", "/sessions/31337/resume", None);
    assert_eq!(status, 404, "{body}");
    // Wrong method on a session path → 404 via the GET fallthrough.
    let (status, _) = request(addr, "GET", "/sessions/1/resume", None);
    assert_eq!(status, 404);
    handle.stop();

    // A source with no durable support answers 404, not 500.
    let handle = start(stub_source(2, 4, 1), 2);
    let (status, body) = request(handle.addr, "POST", "/sessions/1/resume", None);
    assert_eq!(status, 404, "unsupported resume must 404: {body}");
    handle.stop();
}

fn start_durable(src: Arc<DurableSource>, workers: usize) -> ServerHandle {
    serve(
        src,
        ServerConfig {
            addr: "127.0.0.1:0".into(),
            workers,
            max_tokens_cap: 256,
        },
    )
    .expect("serve binds")
}
