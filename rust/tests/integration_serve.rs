//! Integration: the HTTP serving layer end-to-end over a real socket.
//! Skips cleanly when the artifacts or the PJRT backend are unavailable.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, OnceLock};

use warp_cortex::cortex::{CortexConfig, WarpCortex};
use warp_cortex::model::Engine;
use warp_cortex::runtime::{DeviceHandle, DeviceOptions};
use warp_cortex::serve::{serve, ServerConfig};
use warp_cortex::util::Json;

fn server() -> Option<std::net::SocketAddr> {
    static SERVER: OnceLock<Result<std::net::SocketAddr, String>> = OnceLock::new();
    match SERVER.get_or_init(|| {
        let device = DeviceHandle::new(DeviceOptions::from_env().with_configs(&["tiny"]))
            .map_err(|e| format!("{e:#}"))?;
        let engine = Engine::new(device, "tiny").map_err(|e| format!("{e:#}"))?;
        let cortex = Arc::new(
            WarpCortex::new(
                engine,
                CortexConfig {
                    model: "tiny".into(),
                    max_side_agents: 2,
                    side_gen_budget: 6,
                    ..CortexConfig::default()
                },
            )
            .map_err(|e| format!("{e:#}"))?,
        );
        let handle = serve(
            cortex,
            ServerConfig {
                addr: "127.0.0.1:0".into(),
                workers: 2,
                max_tokens_cap: 64,
            },
        )
        .map_err(|e| format!("{e:#}"))?;
        let addr = handle.addr;
        std::mem::forget(handle); // keep serving for the whole test binary
        Ok(addr)
    }) {
        Ok(addr) => Some(*addr),
        // Surface the REAL bring-up error so stub/missing-artifacts skips
        // are distinguishable from genuine serving regressions.
        Err(why) => {
            eprintln!("skipping device-dependent test — server bring-up failed: {why}");
            None
        }
    }
}

macro_rules! require_server {
    () => {
        match server() {
            Some(addr) => addr,
            None => return,
        }
    };
}

fn request(addr: std::net::SocketAddr, method: &str, path: &str, body: Option<&str>) -> (u16, Json) {
    let mut stream = TcpStream::connect(addr).unwrap();
    let body = body.unwrap_or("");
    let raw = format!(
        "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(raw.as_bytes()).unwrap();
    let mut response = String::new();
    stream.read_to_string(&mut response).unwrap();
    let status: u16 = response
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    let json_body = response
        .split("\r\n\r\n")
        .nth(1)
        .map(|b| Json::parse(b).unwrap_or(Json::Null))
        .unwrap_or(Json::Null);
    (status, json_body)
}

#[test]
fn health_endpoint() {
    let addr = require_server!();
    let (status, body) = request(addr, "GET", "/health", None);
    assert_eq!(status, 200);
    assert_eq!(body.get("ok").and_then(|v| v.as_bool()), Some(true));
}

#[test]
fn generate_endpoint_roundtrip() {
    let addr = require_server!();
    let (status, body) = request(
        addr,
        "POST",
        "/generate",
        Some(r#"{"prompt": "user: tell me about the kv cache.\nriver: ", "max_tokens": 12}"#),
    );
    assert_eq!(status, 200, "{body}");
    let text = body.get("text").and_then(|v| v.as_str()).unwrap();
    assert!(!text.is_empty());
    let tokens = body.get("tokens").and_then(|v| v.as_usize()).unwrap();
    assert!(tokens > 0 && tokens <= 12);
    assert!(body.get("tokens_per_sec").and_then(|v| v.as_f64()).unwrap() > 0.0);
}

#[test]
fn generate_rejects_bad_requests() {
    let addr = require_server!();
    let (status, body) = request(addr, "POST", "/generate", Some("{not json"));
    assert_eq!(status, 400);
    assert!(body.get("error").is_some());

    let (status, _) = request(addr, "POST", "/generate", Some(r#"{"nope": 1}"#));
    assert_eq!(status, 400);
}

#[test]
fn stats_endpoint_reports_categories() {
    let addr = require_server!();
    // generate once so stats are non-trivial
    let _ = request(
        addr,
        "POST",
        "/generate",
        Some(r#"{"prompt": "hello there", "max_tokens": 4}"#),
    );
    let (status, body) = request(addr, "GET", "/stats", None);
    assert_eq!(status, 200);
    let mem = body.get("memory").unwrap();
    assert!(mem.get("weights").and_then(|v| v.as_i64()).unwrap() > 0);
    assert!(body.get("device").unwrap().get("ops").and_then(|v| v.as_i64()).unwrap() > 0);
    assert!(body.get("device").unwrap().get("river_ops").and_then(|v| v.as_i64()).unwrap() > 0);
    // pool occupancy gauges are live after an episode
    let pool = body.get("pool").unwrap();
    assert!(pool.get("block_tokens").and_then(|v| v.as_i64()).unwrap() > 0);
    assert!(pool.get("blocks_high_water").and_then(|v| v.as_i64()).unwrap() > 0);
    assert!(pool.get("resident_bytes").is_some());
    assert!(pool.get("fragmentation").is_some());
}

#[test]
fn unknown_path_404() {
    let addr = require_server!();
    let (status, _) = request(addr, "GET", "/nope", None);
    assert_eq!(status, 404);
}
