//! Round-trip and property coverage for the crate-graph analyzer
//! (`warp_cortex::audit`), pinning three contracts:
//!
//! 1. **Legacy parity** — the `legacy` module below is a pristine copy of
//!    the original token scanner's scanning core (the five rules as they
//!    shipped before the crate-graph rewrite).  The new pipeline must
//!    reproduce its findings *exactly* — same file, line, rule and
//!    message — both on the real `rust/src` tree and on seeded-violation
//!    fixtures that make every rule and the `audit-allow:` suppression
//!    path fire.  Do not "improve" the legacy copy: its whole value is
//!    not moving.
//! 2. **Lexer robustness** — `audit::lexer::strip` never panics on
//!    arbitrary quote/comment/escape soup and always returns the three
//!    channels line-aligned with the input.
//! 3. **Rank-table agreement** — the static lock-order table parsed from
//!    `util/sync.rs` equals the runtime `LockRank` hierarchy debug
//!    builds enforce (the cross-check `LockRank::name` exists for).

use std::path::{Path, PathBuf};

use warp_cortex::audit::{self, AuditInput, SourceFile};
use warp_cortex::util::sync::LockRank;

/// The original warp-audit token scanner, verbatim (sans CLI).  Kept as
/// the reference implementation the crate-graph pipeline is compared
/// against; intentionally self-contained and frozen.
mod legacy {
    use std::path::Path;

    /// Modules on the fused-tick decode path: every mutex here must be
    /// ranked (see `util::sync::LockRank`) so the deadlock detector
    /// covers it.
    const DECODE_PATH_MODULES: [&str; 8] = [
        "model/pool.rs",
        "cortex/step.rs",
        "cortex/scheduler.rs",
        "cortex/batcher.rs",
        "cortex/prism.rs",
        "cortex/synapse.rs",
        "runtime/device.rs",
        "metrics/mod.rs",
    ];

    /// Comparator-position sinks for the `nan-sort` rule: `partial_cmp`
    /// appearing near one of these is a NaN-unsafe ordering.
    const SORTERS: [&str; 5] = [
        "sort_by(",
        "sort_unstable_by(",
        "min_by(",
        "max_by(",
        "binary_search_by(",
    ];

    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum Rule {
        PoisonCascade,
        NanSort,
        RawMutex,
        PanicInServe,
        FloatEq,
    }

    impl Rule {
        pub fn name(self) -> &'static str {
            match self {
                Rule::PoisonCascade => "poison-cascade",
                Rule::NanSort => "nan-sort",
                Rule::RawMutex => "raw-mutex",
                Rule::PanicInServe => "panic-in-serve",
                Rule::FloatEq => "float-eq",
            }
        }

        fn from_name(name: &str) -> Option<Rule> {
            match name {
                "poison-cascade" => Some(Rule::PoisonCascade),
                "nan-sort" => Some(Rule::NanSort),
                "raw-mutex" => Some(Rule::RawMutex),
                "panic-in-serve" => Some(Rule::PanicInServe),
                "float-eq" => Some(Rule::FloatEq),
                _ => None,
            }
        }
    }

    #[derive(Debug)]
    pub struct Finding {
        pub line: usize,
        pub rule: Rule,
        pub message: &'static str,
    }

    /// Source split into lines with comments, string contents and char
    /// literals blanked (`code`), plus the comment text per line
    /// (`comments`, for `audit-allow:` detection).  Line numbers are
    /// preserved exactly.
    struct Stripped {
        code: Vec<String>,
        comments: Vec<String>,
    }

    fn newline(out: &mut Stripped) {
        out.code.push(String::new());
        out.comments.push(String::new());
    }

    fn prev_is_ident(chars: &[char], i: usize) -> bool {
        i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_')
    }

    /// If a raw (byte) string literal starts at `i` (`r"`, `r#"`,
    /// `br##"`, ...), return the index one past its closing quote.
    fn raw_string_end(chars: &[char], i: usize) -> Option<usize> {
        let mut j = i;
        if chars[j] == 'b' {
            j += 1;
        }
        if chars.get(j) != Some(&'r') {
            return None;
        }
        j += 1;
        let mut hashes = 0;
        while chars.get(j) == Some(&'#') {
            hashes += 1;
            j += 1;
        }
        if chars.get(j) != Some(&'"') {
            return None;
        }
        j += 1;
        while j < chars.len() {
            if chars[j] == '"'
                && chars
                    .get(j + 1..j + 1 + hashes)
                    .is_some_and(|t| t.iter().all(|&c| c == '#'))
            {
                return Some(j + 1 + hashes);
            }
            j += 1;
        }
        Some(chars.len())
    }

    fn strip(src: &str) -> Stripped {
        let chars: Vec<char> = src.chars().collect();
        let n = chars.len();
        let mut out = Stripped {
            code: vec![String::new()],
            comments: vec![String::new()],
        };
        let mut i = 0;
        while i < n {
            let c = chars[i];
            if c == '\n' {
                newline(&mut out);
                i += 1;
                continue;
            }
            // Line comment (covers `///` and `//!` doc comments too).
            if c == '/' && chars.get(i + 1) == Some(&'/') {
                while i < n && chars[i] != '\n' {
                    out.comments.last_mut().expect("line present").push(chars[i]);
                    i += 1;
                }
                continue;
            }
            // Block comment, nested.
            if c == '/' && chars.get(i + 1) == Some(&'*') {
                let mut depth = 1;
                i += 2;
                while i < n && depth > 0 {
                    if chars[i] == '\n' {
                        newline(&mut out);
                        i += 1;
                    } else if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                        depth += 1;
                        i += 2;
                    } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                        depth -= 1;
                        i += 2;
                    } else {
                        out.comments.last_mut().expect("line present").push(chars[i]);
                        i += 1;
                    }
                }
                continue;
            }
            // Raw / byte-string prefixes.
            if (c == 'r' || c == 'b') && !prev_is_ident(&chars, i) {
                if let Some(end) = raw_string_end(&chars, i) {
                    for &ch in &chars[i..end] {
                        if ch == '\n' {
                            newline(&mut out);
                        }
                    }
                    i = end;
                    continue;
                }
                // `b"..."` / `b'x'`: step past the prefix; the quote
                // handlers below take over on the next iteration.
                if chars.get(i + 1) == Some(&'"') || chars.get(i + 1) == Some(&'\'') {
                    i += 1;
                    continue;
                }
            }
            // Plain string.
            if c == '"' {
                i += 1;
                while i < n {
                    if chars[i] == '\\' {
                        i += 2;
                    } else if chars[i] == '"' {
                        i += 1;
                        break;
                    } else {
                        if chars[i] == '\n' {
                            newline(&mut out);
                        }
                        i += 1;
                    }
                }
                continue;
            }
            // Char literal vs lifetime.
            if c == '\'' {
                if chars.get(i + 1) == Some(&'\\') {
                    // Escaped char: skip past `'\x`, then scan to the
                    // close.
                    i += 3;
                    while i < n && chars[i] != '\'' {
                        i += 1;
                    }
                    i = (i + 1).min(n);
                    continue;
                }
                if chars.get(i + 2) == Some(&'\'') && chars.get(i + 1) != Some(&'\'') {
                    i += 3; // 'x'
                    continue;
                }
                // Lifetime: drop the quote, keep scanning.
                i += 1;
                continue;
            }
            out.code.last_mut().expect("line present").push(c);
            i += 1;
        }
        out
    }

    /// Rules suppressed by an `audit-allow:` marker in this comment.
    fn allowed_rules(comment: &str) -> Vec<Rule> {
        let Some(pos) = comment.find("audit-allow:") else {
            return Vec::new();
        };
        comment[pos + "audit-allow:".len()..]
            .split([',', ' '].as_slice())
            .filter_map(|name| Rule::from_name(name.trim()))
            .collect()
    }

    /// Brace-tracking skip state for `#[cfg(test)]` / `#[test]` items.
    #[derive(Default)]
    struct TestSkip {
        /// Saw the attribute; waiting for the item body to open.
        pending: bool,
        /// Inside the item body at this brace depth.
        depth: usize,
        active: bool,
    }

    impl TestSkip {
        /// Feed one stripped line; true when it belongs to a test item
        /// (including the attribute lines themselves).
        fn observe(&mut self, line: &str) -> bool {
            let trimmed = line.trim();
            if self.active {
                for c in trimmed.chars() {
                    match c {
                        '{' => self.depth += 1,
                        '}' if self.depth > 0 => {
                            self.depth -= 1;
                            if self.depth == 0 {
                                self.active = false;
                            }
                        }
                        _ => {}
                    }
                }
                return true;
            }
            if self.pending {
                let mut saw_open = false;
                for c in trimmed.chars() {
                    match c {
                        '{' => {
                            saw_open = true;
                            self.depth += 1;
                        }
                        '}' if self.depth > 0 => self.depth -= 1,
                        ';' if self.depth == 0 && !saw_open => {
                            // Bodyless item (`mod tests;`, `use ...;`).
                            self.pending = false;
                            return true;
                        }
                        _ => {}
                    }
                }
                if saw_open {
                    self.pending = false;
                    if self.depth > 0 {
                        self.active = true;
                    }
                }
                return true;
            }
            if trimmed.starts_with("#[cfg(test)")
                || trimmed.starts_with("#[test]")
                || trimmed.starts_with("#[cfg(all(test")
            {
                self.pending = true;
                return true;
            }
            false
        }
    }

    /// True when `s` contains a float-typed expression shape: a float
    /// literal (`1.0`, `2.5e-3`, `1f32`) or an `as f32` / `as f64` cast.
    /// Operates on stripped code, so strings and comments never match.
    fn has_float_expr(s: &str) -> bool {
        if s.contains("as f32") || s.contains("as f64") {
            return true;
        }
        let c: Vec<char> = s.chars().collect();
        for i in 0..c.len() {
            if !c[i].is_ascii_digit() {
                continue;
            }
            // Must start a numeric token (not `x2`, `0x1E`, tuple index
            // `.0`).
            if i > 0 && (c[i - 1].is_alphanumeric() || c[i - 1] == '_' || c[i - 1] == '.') {
                continue;
            }
            let mut j = i;
            while j < c.len() && (c[j].is_ascii_digit() || c[j] == '_') {
                j += 1;
            }
            match c.get(j) {
                Some('.') if c.get(j + 1).is_some_and(|d| d.is_ascii_digit()) => return true,
                Some('e') | Some('E') => {
                    let mut k = j + 1;
                    if matches!(c.get(k), Some('+') | Some('-')) {
                        k += 1;
                    }
                    if c.get(k).is_some_and(|d| d.is_ascii_digit()) {
                        return true;
                    }
                }
                Some('f') => {
                    let suffix = c.get(j + 1..j + 3);
                    if (suffix == Some(&['3', '2']) || suffix == Some(&['6', '4']))
                        && c.get(j + 3).map_or(true, |ch| !(ch.is_alphanumeric() || *ch == '_'))
                    {
                        return true;
                    }
                }
                _ => {}
            }
        }
        false
    }

    /// Does the `==`/`!=` at byte `p` compare a float expression?
    /// Operands are bounded by the nearest expression delimiter on each
    /// side, so a float literal elsewhere on the line cannot condemn an
    /// integer compare.
    fn float_eq_at(line: &str, p: usize) -> bool {
        let left_all = &line[..p];
        let right_all = &line[p + 2..];
        let lb = ["(", "{", "[", ",", ";", "&&", "||"]
            .iter()
            .filter_map(|d| left_all.rfind(d).map(|q| q + d.len()))
            .max()
            .unwrap_or(0);
        let rb = [")", "}", "]", ",", ";", "&&", "||", "{"]
            .iter()
            .filter_map(|d| right_all.find(d))
            .min()
            .unwrap_or(right_all.len());
        has_float_expr(&left_all[lb..]) || has_float_expr(&right_all[..rb])
    }

    /// Run every rule over one file's source.  `module` is the path
    /// relative to `src/` (e.g. `util/sync.rs`), which scopes the
    /// per-module rules.
    pub fn scan_source(module: &str, src: &str) -> Vec<Finding> {
        let stripped = strip(src);
        let mut findings: Vec<Finding> = Vec::new();
        let mut skip = TestSkip::default();
        let decode_path = DECODE_PATH_MODULES.contains(&module);
        let in_serve = module.starts_with("serve/");
        let in_sync = module == "util/sync.rs";
        let float_scope = module.starts_with("model/") || module.starts_with("cortex/");
        for (idx, line) in stripped.code.iter().enumerate() {
            if skip.observe(line) {
                continue;
            }
            let mut report = |rule: Rule, message: &'static str| {
                let allowed = allowed_rules(&stripped.comments[idx]).contains(&rule)
                    || (idx > 0 && allowed_rules(&stripped.comments[idx - 1]).contains(&rule));
                if !allowed {
                    findings.push(Finding {
                        line: idx + 1,
                        rule,
                        message,
                    });
                }
            };
            if !in_sync {
                // Merge with the next line so a formatter-split
                // `.lock()\n.unwrap()` chain is still caught; only
                // matches that *start* on this line are reported here.
                let here = line.trim_end();
                let next = stripped.code.get(idx + 1).map_or("", |l| l.trim());
                let merged = format!("{here}{next}");
                for pat in [".lock().unwrap()", ".lock().expect("] {
                    if let Some(p) = merged.find(pat) {
                        if p < here.len() {
                            report(
                                Rule::PoisonCascade,
                                "poison-intolerant lock: use util::sync::lock_unpoisoned \
                                 or a RankedMutex",
                            );
                            break;
                        }
                    }
                }
            }
            if line.contains(".partial_cmp(") {
                let window = idx.saturating_sub(2);
                let in_comparator = stripped.code[window..=idx]
                    .iter()
                    .any(|l| SORTERS.iter().any(|s| l.contains(s)));
                if in_comparator {
                    report(Rule::NanSort, "NaN-unsafe comparator: use total_cmp");
                }
            }
            if decode_path {
                let mut start = 0;
                while let Some(p) = line[start..].find("Mutex::new(") {
                    let abs = start + p;
                    if line[..abs].ends_with("Ranked") {
                        start = abs + "Mutex::new(".len();
                        continue;
                    }
                    report(
                        Rule::RawMutex,
                        "bare std::sync::Mutex in a decode-path module: \
                         use util::sync::RankedMutex",
                    );
                    break;
                }
            }
            if in_serve {
                for pat in [".unwrap()", ".expect(", "panic!"] {
                    if line.contains(pat) {
                        report(
                            Rule::PanicInServe,
                            "panic path in request handling: return an error \
                             response instead",
                        );
                        break;
                    }
                }
            }
            if float_scope {
                for op in ["==", "!="] {
                    let mut start = 0;
                    let mut fired = false;
                    while let Some(rel) = line[start..].find(op) {
                        let abs = start + rel;
                        // Not part of `<=`, `>=`, `=>`, compound
                        // assignment…
                        let before = line[..abs].chars().next_back();
                        let after = line[abs + 2..].chars().next();
                        let neighbor = matches!(
                            before,
                            Some(
                                '<' | '>' | '=' | '!' | '+' | '-' | '*' | '/' | '%' | '&' | '|'
                                    | '^'
                            )
                        ) || after == Some('=');
                        if !neighbor && float_eq_at(line, abs) {
                            report(
                                Rule::FloatEq,
                                "exact float equality: compare within a bound, \
                                 or on to_bits() where bit-identity is the contract",
                            );
                            fired = true;
                            break;
                        }
                        start = abs + 2;
                    }
                    if fired {
                        break;
                    }
                }
            }
        }
        findings
    }

    /// Module path relative to the last `/src/` component (the scope key
    /// the per-module rules match on); the raw path when there is none.
    pub fn normalize_module(path: &Path) -> String {
        let s = path.to_string_lossy().replace('\\', "/");
        match s.rfind("/src/") {
            Some(p) => s[p + "/src/".len()..].to_string(),
            None => s,
        }
    }
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// One finding in comparable form: (path, 1-based line, rule id,
/// message).
type Key = (String, usize, String, String);

fn legacy_keys(path: &str, src: &str) -> Vec<Key> {
    let module = legacy::normalize_module(Path::new(path));
    legacy::scan_source(&module, src)
        .into_iter()
        .map(|f| (path.to_string(), f.line, f.rule.name().to_string(), f.message.to_string()))
        .collect()
}

const LEGACY_RULES: [&str; 5] = [
    "poison-cascade",
    "nan-sort",
    "raw-mutex",
    "panic-in-serve",
    "float-eq",
];

fn new_pipeline_keys(sources: &[(String, String)]) -> Vec<Key> {
    let mut input = AuditInput::default();
    for (path, src) in sources {
        input.files.push(SourceFile::parse(path, src));
    }
    audit::run(&input)
        .findings
        .into_iter()
        .filter(|f| LEGACY_RULES.contains(&f.rule.name()))
        .map(|f| (f.path, f.line, f.rule.name().to_string(), f.message))
        .collect()
}

/// The new crate-graph pipeline reproduces the frozen reference scanner
/// exactly, rule for rule and message for message, over the real source
/// tree (which is audit-clean, so both sides must agree on *emptiness*
/// too — a new false positive shows up here before it shows up in CI).
#[test]
fn new_pipeline_matches_legacy_scanner_on_real_tree() {
    let mut paths = Vec::new();
    walk(Path::new("rust/src"), &mut paths).expect("rust/src readable");
    paths.sort();
    assert!(paths.len() > 20, "tree walk looks wrong: {} files", paths.len());
    let sources: Vec<(String, String)> = paths
        .iter()
        .map(|p| {
            let src = std::fs::read_to_string(p).expect("source readable");
            (p.display().to_string(), src)
        })
        .collect();

    let mut expected: Vec<Key> = sources
        .iter()
        .flat_map(|(path, src)| legacy_keys(path, src))
        .collect();
    let mut got = new_pipeline_keys(&sources);
    expected.sort();
    got.sort();
    assert_eq!(got, expected, "legacy-rule findings diverged from the reference scanner");
}

/// Same parity on sources seeded with one violation per legacy rule plus
/// a suppressed site — proves agreement on *firing* behaviour, not just
/// on the clean tree, and that both sides honour `audit-allow:`
/// identically.
#[test]
fn new_pipeline_matches_legacy_scanner_on_seeded_violations() {
    let step = r#"
fn tick(m: &std::sync::Mutex<u32>) {
    let v = m.lock().unwrap();
    let q = Mutex::new(0);
    let _ = (v, q);
}

fn order(xs: &mut Vec<f32>) {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
}

fn compare(x: f32) -> bool {
    // audit-allow: float-eq
    x == 1.0
}

fn drift(x: f32) -> bool {
    x == 0.25
}

#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let m = std::sync::Mutex::new(1);
        let _ = m.lock().unwrap();
    }
}
"#;
    let serve = r#"
fn handle(body: Option<String>) -> String {
    body.expect("body present")
}
"#;
    let sources = vec![
        ("rust/src/cortex/step.rs".to_string(), step.to_string()),
        ("rust/src/serve/server.rs".to_string(), serve.to_string()),
    ];
    let mut expected: Vec<Key> = sources
        .iter()
        .flat_map(|(path, src)| legacy_keys(path, src))
        .collect();
    let mut got = new_pipeline_keys(&sources);
    expected.sort();
    got.sort();
    assert_eq!(got, expected);

    // The seeds genuinely fire: one poison-cascade, one raw-mutex, one
    // nan-sort, one float-eq (the second one — the first is waived), one
    // panic-in-serve; the test-mod violations are skipped.
    let rules: Vec<&str> = got.iter().map(|k| k.2.as_str()).collect();
    assert_eq!(
        rules.iter().filter(|r| **r == "poison-cascade").count(),
        1,
        "{got:?}"
    );
    assert_eq!(rules.iter().filter(|r| **r == "raw-mutex").count(), 1, "{got:?}");
    assert_eq!(rules.iter().filter(|r| **r == "nan-sort").count(), 1, "{got:?}");
    assert_eq!(rules.iter().filter(|r| **r == "float-eq").count(), 1, "{got:?}");
    assert_eq!(
        rules.iter().filter(|r| **r == "panic-in-serve").count(),
        1,
        "{got:?}"
    );
}

/// The lexer is total: arbitrary quote/comment/escape soup never panics,
/// the three channels stay line-aligned with the input, and the full
/// file parse built on top is total too.
#[test]
fn lexer_never_panics_and_stays_line_aligned() {
    use warp_cortex::prop_assert;
    use warp_cortex::util::proptest::check;
    // Deliberately hostile alphabet: every byte that changes lexer state.
    let alphabet: &[u8] = b"\"'\\/*#rb{}()[]!.,;:=<>xyzXYZ_09 \n\t";
    check("audit lexer is total and line-aligned", 400, |g| {
        let src = g.string_from(0..160, alphabet);
        let s = warp_cortex::audit::lexer::strip(&src);
        let lines = src.split('\n').count();
        prop_assert!(
            s.code.len() == lines && s.comments.len() == lines && s.strings.len() == lines,
            "channel misalignment on {src:?}: code {} comments {} strings {} vs {lines} lines",
            s.code.len(),
            s.comments.len(),
            s.strings.len()
        );
        for line in &s.code {
            for (off, word) in warp_cortex::audit::lexer::idents(line) {
                prop_assert!(
                    line[off..].starts_with(word),
                    "ident offset out of register on {line:?}"
                );
            }
        }
        // The whole item/fn extraction pipeline must be total as well.
        let file = SourceFile::parse("rust/src/fuzz.rs", &src);
        prop_assert!(
            file.line_fn.len() == lines,
            "line→fn map misaligned: {} vs {lines}",
            file.line_fn.len()
        );
        Ok(())
    });
}

/// The lock-order pass checks the same hierarchy debug builds enforce:
/// the table parsed statically from `util/sync.rs` equals `LockRank`
/// variant for variant, discriminant for discriminant.
#[test]
fn static_rank_table_matches_runtime_hierarchy() {
    let src = std::fs::read_to_string("rust/src/util/sync.rs").expect("sync source");
    let files = vec![SourceFile::parse("rust/src/util/sync.rs", &src)];
    let parsed = audit::passes::parse_rank_enum(&files);
    let runtime: Vec<(String, u8)> = LockRank::ALL
        .iter()
        .map(|r| (r.name().to_string(), *r as u8))
        .collect();
    assert_eq!(parsed, runtime, "static lock-order table drifted from the runtime enum");
}
