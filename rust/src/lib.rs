//! # Warp-Cortex
//!
//! A from-scratch reproduction of *Warp-Cortex: An Asynchronous,
//! Memory-Efficient Architecture for Million-Agent Cognitive Scaling on
//! Consumer Hardware* (Ruiz Williams, 2026) as a three-layer
//! Rust + JAX + Pallas serving stack:
//!
//! * **Layer 1** (`python/compile/kernels/`): Pallas kernels — decode
//!   attention and the Topological Synapse's hybrid density-coverage
//!   landmark sampler.
//! * **Layer 2** (`python/compile/model.py`): JAX transformer, AOT-lowered
//!   to HLO-text artifacts at build time.
//! * **Layer 3** (this crate): the serving coordinator — singleton weight
//!   sharing ([`cortex::prism`]), the shared demand-paged KV block pool
//!   ([`model::pool`]: agent caches are block tables, resident bytes track
//!   fill rather than configured capacity), the Topological Synapse buffer
//!   ([`cortex::synapse`]), the Cortex Router ([`cortex::router`]), the
//!   Validation Gate ([`cortex::gate`]), Referential Injection
//!   ([`cortex::inject`]) and the River & Stream scheduler
//!   ([`runtime::device`] lanes + [`cortex::scheduler`]).
//!
//! Python never runs on the request path: `make artifacts` exports
//! everything once, and this crate serves from the compiled artifacts.

pub mod cortex;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod serve;
pub mod text;
pub mod util;
pub mod workload;
