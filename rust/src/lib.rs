//! # Warp-Cortex
//!
//! A from-scratch reproduction of *Warp-Cortex: An Asynchronous,
//! Memory-Efficient Architecture for Million-Agent Cognitive Scaling on
//! Consumer Hardware* (Ruiz Williams, 2026) as a three-layer
//! Rust + JAX + Pallas serving stack:
//!
//! * **Layer 1** (`python/compile/kernels/`): Pallas kernels — decode
//!   attention and the Topological Synapse's hybrid density-coverage
//!   landmark sampler.
//! * **Layer 2** (`python/compile/model.py`): JAX transformer, AOT-lowered
//!   to HLO-text artifacts at build time.
//! * **Layer 3** (this crate): the serving coordinator — singleton weight
//!   sharing ([`cortex::prism`]), the shared demand-paged KV block pool
//!   ([`model::pool`]: agent caches are block tables, resident bytes track
//!   fill rather than configured capacity; blocks are refcounted and
//!   copy-on-write, with a content-addressed prefix registry so N agents
//!   spawned from one prompt or landmark seed share the prefix blocks
//!   physically — one cold prefill, O(1) shared-prefix memory, LRU
//!   eviction of parked entries under the pool cap), the Topological
//!   Synapse buffer ([`cortex::synapse`]), the Cortex Router
//!   ([`cortex::router`]), the Validation Gate ([`cortex::gate`]),
//!   Referential Injection ([`cortex::inject`]) and the step scheduler
//!   ([`cortex::step`]): iteration-level continuous batching that fuses
//!   every session's main step and every side agent's next decode step
//!   into one device op per tick over paged block tables
//!   ([`runtime::device`] lanes survive as priorities *inside* the tick —
//!   main steps ride the leading lanes at River priority or run ahead of
//!   the side batch, never behind it), with capacity-aware FIFO admission
//!   that parks side tasks when the batch width or pool occupancy
//!   saturates and refills freed slots on the very next tick.  Prompt
//!   prefill is **chunked** ([`model::ChunkedPrefill`]): a long prompt
//!   rides the same fused tick in budgeted block-sized chunks
//!   (`StepConfig::prefill_budget`) instead of stalling every in-flight
//!   session behind one monolithic prefill — TTFT becomes a scheduler
//!   knob while decode pays at most one extra op per tick
//!   (`benches/prefill_interleave.rs` asserts p99 ops/tick ≤ 2), and
//!   completed chunks register in the prefix registry immediately, so a
//!   concurrent identical prompt adopts blocks *mid-prefill*.
//!
//! Serving is **session-based** ([`serve`]): each `/generate` request is
//! admitted as a [`cortex::CortexSession`] — a schedulable unit over the
//! shared weights and KV pool, not a blocked worker thread.  S concurrent
//! sessions' main steps fuse into the same per-tick device op (no
//! cross-request head-of-line blocking; `benches/multi_session.rs`
//! asserts ops/token at 8 sessions ≤ 0.6× one session), admission parks
//! FIFO under `max_sessions`/pool headroom and sheds with 503 beyond the
//! park queue, and `"stream": true` delivers tokens over chunked transfer
//! encoding as ticks produce them.  [`cortex::capacity`] models the
//! multi-session compute ceiling (`max_sessions_compute`).
//!
//! Device ops per generated token fall from ~1.0 (the old serial op
//! stream) toward 1/B as the agent population grows —
//! `benches/continuous_batch.rs` asserts this and the `/stats` endpoint
//! exposes the tick/batch-occupancy/park/session/prefill gauges live
//! (`GET /metrics` renders the same snapshot as Prometheus text
//! exposition via [`serve::metrics_text`]).
//!
//! KV memory is **tiered** ([`model::pool`]): hot blocks are fp32 and
//! device-resident; parked registry entries (refcount 0) demote to a
//! *warm* int8 tier — block-granular quantization with one fp32 scale per
//! (layer, position) row, ~3.5× blocks per GB, dequantized transparently
//! on gather and promoted back to fp32 by copy-on-write on divergence —
//! and parked sessions plus cap-pressured registry entries spill to a
//! *cold* host-RAM slab ([`cortex::CortexSession::park_to_host`] /
//! `resume_from_host`; lossless, zero device-budget bytes until paged
//! back in).  Admission ([`model::KvPool::can_admit`]) counts offloadable
//! headroom across both parking tiers, so a session is shed only when
//! the hot tier AND the slab are exhausted — `benches/tiered_kv.rs`
//! asserts the density, the admission win, and that park→offload→resume
//! decode is bit-identical.
//!
//! Sessions are **durable** ([`cortex::store`]): a crash-safe
//! single-file checkpoint store (append-only CRC-framed records behind
//! an atomic double-slot header flip — no external database) persists
//! each session's identity, sampler/RNG state, and block-table chain,
//! with the registry-shared prompt prefix stored as a hash chain rather
//! than bytes.  `POST /sessions/{id}/resume` rebuilds a checkpointed
//! session with bit-identical next-token logits, a mid-stream client
//! disconnect hibernates instead of cancelling, and under a full pool an
//! arrival preempts the coldest hibernated resident to disk instead of
//! being shed — the fourth admission tier and the fourth memory tier
//! (`benches/durable_sessions.rs` asserts both).  The operator-facing
//! map of all of this — lifecycle, tiers, and every `/stats` gauge — is
//! the handbook at [`architecture`], reconciled against the live
//! serializer by a CI test.
//!
//! Memory accounting follows block ownership: each agent's `MainKv`/
//! `SideKv` charge counts only its *private* blocks, registry-shared
//! blocks are charged exactly once under `SharedKv`, the device slab
//! under `DeviceKv`, and host-slab payloads under `HostKv` — every
//! physical byte exactly once, in the tier it occupies — so Table 2
//! never multiply-counts a shared prefix.  The pool's `/stats` gauges
//! expose the sharing and tiering machinery live: `shared_blocks`/
//! `shared_bytes`, `prefix_hits`/`prefix_misses`/`prefix_evictions`,
//! `cow_copies`, `quantized_blocks`/`quant_saved_bytes`,
//! `offloaded_blocks`/`host_slab_bytes`, and the swap counters
//! `swap_out_bytes`/`swap_in_bytes`/`resume_page_ins`.
//!
//! Concurrency correctness is enforced by construction and by tooling
//! (see the *Correctness tooling* section of [`cortex`]): every
//! production mutex is a [`util::sync::RankedMutex`] acquired in strictly
//! descending [`util::sync::LockRank`] order (debug builds panic on an
//! out-of-order acquisition, naming both ranks), locks are
//! poison-tolerant so one panicking session can never cascade a poisoned
//! `unwrap` into every other session, and debug builds re-prove the pool
//! and session-gauge conservation laws at every tick boundary
//! ([`model::KvPool::check_invariants`],
//! [`cortex::StepScheduler::check_invariants`]).  The project-native
//! linter `warp-audit` (`cargo run --bin warp-audit -- rust/src`, a
//! required CI job) is a crate-graph static analyzer ([`audit`]): the
//! five token rules — `.lock().unwrap()` chains, NaN-unsound
//! `partial_cmp` comparators, bare `std::sync::Mutex` on the decode
//! path, panicking calls in [`serve`], exact float equality in
//! `model/`/`cortex/` production code — plus three whole-crate passes:
//! `lock-order` proves every reachable `RankedMutex` acquisition path
//! strictly rank-descending even where no test executes it,
//! `gauge-lineage` proves every pool/step gauge reaches the `/stats`
//! serialization and some consistency check, and `hot-tick` proves
//! nothing reachable from the fused decode tick does IO, sleeps,
//! prints, or takes a lock ranked above `SchedulerQueue`.  Individual
//! sites opt out with `// audit-allow: <rule>`, and the `stale-allow`
//! pass flags any marker that no longer suppresses a real finding.
//!
//! Python never runs on the request path: `make artifacts` exports
//! everything once, and this crate serves from the compiled artifacts.

/// The operator's handbook — `docs/ARCHITECTURE.md` rendered into the
/// crate docs: the request lifecycle from accept to resume, the
/// four-tier memory hierarchy, and the gauge reference for every
/// `/stats` block.  The gauge table is fenced by markers that
/// `rust/tests/docs_drift.rs` reconciles against the live `/stats`
/// serializer in CI, so the handbook cannot drift from the wire.
#[doc = include_str!("../../docs/ARCHITECTURE.md")]
pub mod architecture {}

pub mod audit;
pub mod cortex;
pub mod metrics;
pub mod model;
pub mod runtime;
pub mod serve;
pub mod text;
pub mod util;
pub mod workload;
