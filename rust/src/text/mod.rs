//! Text substrate: byte-level tokenizer and sampling strategies.

pub mod sampler;
pub mod tokenizer;

pub use sampler::{Sampler, SamplerConfig};
pub use tokenizer::{Tokenizer, BOS_ID, EOS_ID, PAD_ID, REF_ID, VOCAB_SIZE};
