//! Token sampling: greedy, temperature, top-k and nucleus (top-p), with a
//! seeded xorshift RNG and a repetition penalty — everything the serving
//! layer needs, no `rand` crate.
//!
//! NaN robustness (mirrors the synapse score-sort fix): a NaN logit can
//! neither win greedy argmax (the old `vecmath::argmax` returned index 0
//! when `logits[0]` was NaN) nor poison the top-k sort
//! (`partial_cmp().unwrap()` panicked on NaN) or the top-p
//! renormalization.  Greedy skips NaN but keeps ±inf ordered — a
//! +inf logit IS the maximum (fp16-saturated head) and must be selected.
//! The stochastic path short-circuits to a +inf logit for the same reason
//! (softmaxing against an infinite max would NaN every weight; dropping it
//! would emit a ~0-probability token), sorts the rest with `total_cmp`,
//! and drops NaN/-inf mass before the softmax; when nothing finite
//! survives it falls back to the NaN-skipping argmax, so an all-NaN
//! distribution yields id 0 instead of a panic.

use crate::util::rng::XorShift;

/// Sampling hyper-parameters.
#[derive(Debug, Clone)]
pub struct SamplerConfig {
    /// 0.0 = greedy.
    pub temperature: f32,
    /// 0 = disabled.
    pub top_k: usize,
    /// 1.0 = disabled.
    pub top_p: f32,
    /// 1.0 = disabled; >1 penalises recently generated ids.
    pub repetition_penalty: f32,
    /// Window for the repetition penalty.
    pub repetition_window: usize,
    pub seed: u64,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        SamplerConfig {
            temperature: 0.8,
            top_k: 40,
            top_p: 0.95,
            repetition_penalty: 1.1,
            repetition_window: 64,
            seed: 0,
        }
    }
}

impl SamplerConfig {
    pub fn greedy() -> SamplerConfig {
        SamplerConfig {
            temperature: 0.0,
            top_k: 0,
            top_p: 1.0,
            repetition_penalty: 1.0,
            repetition_window: 0,
            seed: 0,
        }
    }
}

/// Stateful sampler (tracks recent ids for the repetition penalty).
#[derive(Debug, Clone)]
pub struct Sampler {
    cfg: SamplerConfig,
    rng: XorShift,
    recent: Vec<i32>,
}

impl Sampler {
    pub fn new(cfg: SamplerConfig) -> Sampler {
        let seed = cfg.seed;
        Sampler {
            cfg,
            rng: XorShift::new(seed ^ 0xA5A5_5A5A_DEAD_BEEF),
            recent: Vec::new(),
        }
    }

    /// Capture the mutable sampler state for checkpointing
    /// (`cortex::store`): the RNG position and the repetition window.
    /// Restoring via [`Sampler::restore`] with the same config reproduces
    /// the exact token stream the interrupted sampler would have drawn.
    pub fn save_state(&self) -> (u64, Vec<i32>) {
        (self.rng.state(), self.recent.clone())
    }

    /// Rebuild a sampler mid-stream from a [`Sampler::save_state`]
    /// capture.  `cfg` must be the config the state was captured under —
    /// the RNG state is post-seed-mapping and is adopted verbatim.
    pub fn restore(cfg: SamplerConfig, rng_state: u64, recent: Vec<i32>) -> Sampler {
        Sampler {
            cfg,
            rng: XorShift::from_state(rng_state),
            recent,
        }
    }

    /// Sample the next id from raw logits (mutates a working copy).
    pub fn sample(&mut self, logits: &[f32]) -> i32 {
        let id = self.sample_inner(logits);
        if self.cfg.repetition_window > 0 {
            self.recent.push(id);
            if self.recent.len() > self.cfg.repetition_window {
                self.recent.remove(0);
            }
        }
        id
    }

    fn sample_inner(&mut self, logits: &[f32]) -> i32 {
        if self.cfg.temperature <= 0.0 {
            return nan_safe_argmax(logits) as i32;
        }
        let mut work: Vec<(usize, f32)> = logits.iter().cloned().enumerate().collect();

        if self.cfg.repetition_penalty > 1.0 {
            for &id in &self.recent {
                let l = &mut work[id as usize].1;
                *l = if *l > 0.0 {
                    *l / self.cfg.repetition_penalty
                } else {
                    *l * self.cfg.repetition_penalty
                };
            }
        }

        // temperature
        let inv_t = 1.0 / self.cfg.temperature;
        for (_, l) in work.iter_mut() {
            *l *= inv_t;
        }

        // A +inf logit (post-penalty/temperature — both preserve the sign
        // of an infinity) is a probability-~1 token: select it outright,
        // matching greedy.  Softmaxing against an infinite max would NaN
        // every weight, and dropping it would emit a ~0-probability token.
        if let Some((i, _)) = work.iter().find(|(_, l)| *l == f32::INFINITY) {
            return *i as i32;
        }
        // Drop the remaining non-finite mass BEFORE ranking: a NaN must not
        // win the sort and -inf carries no weight.  If nothing finite
        // survives (all NaN/-inf), fall back to the greedy argmax.
        work.retain(|(_, l)| l.is_finite());
        if work.is_empty() {
            return nan_safe_argmax(logits) as i32;
        }

        // top-k cut (total order: well-defined for every float)
        work.sort_by(|a, b| b.1.total_cmp(&a.1));
        if self.cfg.top_k > 0 && self.cfg.top_k < work.len() {
            work.truncate(self.cfg.top_k);
        }

        // softmax over the surviving set
        let m = work[0].1;
        let mut total = 0.0f64;
        let mut probs: Vec<f64> = work
            .iter()
            .map(|(_, l)| {
                let p = ((l - m) as f64).exp();
                total += p;
                p
            })
            .collect();
        for p in probs.iter_mut() {
            *p /= total;
        }

        // nucleus cut
        if self.cfg.top_p < 1.0 {
            let mut cum = 0.0;
            let mut keep = probs.len();
            for (i, p) in probs.iter().enumerate() {
                cum += p;
                if cum >= self.cfg.top_p as f64 {
                    keep = i + 1;
                    break;
                }
            }
            probs.truncate(keep);
            let z: f64 = probs.iter().sum();
            for p in probs.iter_mut() {
                *p /= z;
            }
        }

        // inverse-CDF draw
        let u = self.rng.unit();
        let mut cum = 0.0;
        for (i, p) in probs.iter().enumerate() {
            cum += p;
            if u < cum {
                return work[i].0 as i32;
            }
        }
        work[probs.len() - 1].0 as i32
    }
}

/// Argmax that skips NaN — a NaN can never win OR capture the incumbent
/// slot (the old `vecmath::argmax` returned index 0 whenever `logits[0]`
/// was NaN, because every comparison against a NaN incumbent is false).
/// ±inf are ordinary ordered values here: a +inf logit IS the maximum
/// (e.g. an fp16-saturated head) and greedy must select it.  0 when
/// everything is NaN.
fn nan_safe_argmax(logits: &[f32]) -> usize {
    let mut best: Option<usize> = None;
    for (i, x) in logits.iter().enumerate() {
        if x.is_nan() {
            continue;
        }
        match best {
            None => best = Some(i),
            Some(b) => {
                if *x > logits[b] {
                    best = Some(i);
                }
            }
        }
    }
    best.unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniformish_logits(n: usize) -> Vec<f32> {
        (0..n).map(|i| ((i * 37) % 11) as f32 * 0.01).collect()
    }

    #[test]
    fn greedy_picks_argmax() {
        let mut s = Sampler::new(SamplerConfig::greedy());
        let mut logits = vec![0.0f32; 100];
        logits[42] = 5.0;
        assert_eq!(s.sample(&logits), 42);
        assert_eq!(s.sample(&logits), 42); // deterministic
    }

    #[test]
    fn temperature_sampling_is_seeded_deterministic() {
        let cfg = SamplerConfig {
            seed: 9,
            ..Default::default()
        };
        let logits = uniformish_logits(260);
        let a: Vec<i32> = {
            let mut s = Sampler::new(cfg.clone());
            (0..20).map(|_| s.sample(&logits)).collect()
        };
        let b: Vec<i32> = {
            let mut s = Sampler::new(cfg);
            (0..20).map(|_| s.sample(&logits)).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn top_k_restricts_support() {
        let mut logits = vec![0.0f32; 50];
        logits[7] = 10.0;
        logits[13] = 9.5;
        let mut s = Sampler::new(SamplerConfig {
            temperature: 1.0,
            top_k: 2,
            top_p: 1.0,
            repetition_penalty: 1.0,
            repetition_window: 0,
            seed: 3,
        });
        for _ in 0..200 {
            let id = s.sample(&logits);
            assert!(id == 7 || id == 13, "sampled {id} outside top-2");
        }
    }

    #[test]
    fn top_p_restricts_support() {
        let mut logits = vec![-10.0f32; 50];
        logits[1] = 8.0; // overwhelming mass
        logits[2] = 1.0;
        let mut s = Sampler::new(SamplerConfig {
            temperature: 1.0,
            top_k: 0,
            top_p: 0.9,
            repetition_penalty: 1.0,
            repetition_window: 0,
            seed: 4,
        });
        for _ in 0..100 {
            assert_eq!(s.sample(&logits), 1);
        }
    }

    #[test]
    fn nan_logits_never_win_greedy_argmax() {
        let mut s = Sampler::new(SamplerConfig::greedy());
        // NaN in slot 0 used to capture vecmath::argmax (NaN comparisons
        // are all false, so the incumbent never lost).
        let mut logits = vec![f32::NAN; 20];
        logits[7] = 1.5;
        logits[12] = 0.5;
        assert_eq!(s.sample(&logits), 7);
        // trailing NaN must not win either
        let mut logits = vec![0.0f32; 20];
        logits[3] = 2.0;
        logits[19] = f32::NAN;
        assert_eq!(s.sample(&logits), 3);
    }

    #[test]
    fn nan_does_not_corrupt_topk_topp() {
        // The old sort used partial_cmp().unwrap(): a single NaN panicked
        // the decode thread.  Now NaN/-inf carry zero mass: every draw
        // lands on a finite id, and renormalization stays exact.
        let mut logits = vec![0.0f32; 50];
        logits[5] = 4.0;
        logits[9] = 3.5;
        logits[11] = f32::NAN;
        logits[17] = f32::NEG_INFINITY;
        let mut s = Sampler::new(SamplerConfig {
            temperature: 1.0,
            top_k: 2,
            top_p: 0.9,
            repetition_penalty: 1.0,
            repetition_window: 0,
            seed: 11,
        });
        for _ in 0..200 {
            let id = s.sample(&logits);
            assert!(id == 5 || id == 9, "non-finite logit leaked into the draw: {id}");
        }
    }

    #[test]
    fn positive_infinity_wins_greedy_and_stochastic() {
        // +inf is a well-defined probability-~1 token (fp16-saturated
        // logit): both paths must select it — only NaN and -inf are
        // massless.
        let mut logits = vec![0.0f32; 10];
        logits[4] = f32::INFINITY;
        logits[8] = 7.0;
        let mut greedy = Sampler::new(SamplerConfig::greedy());
        assert_eq!(greedy.sample(&logits), 4);
        let mut stochastic = Sampler::new(SamplerConfig {
            temperature: 1.0,
            repetition_penalty: 1.0,
            repetition_window: 0,
            ..SamplerConfig::default()
        });
        for _ in 0..50 {
            assert_eq!(stochastic.sample(&logits), 4);
        }
    }

    #[test]
    fn all_non_finite_logits_fall_back_instead_of_panicking() {
        // Nothing finite: no panic; the NaN-skipping argmax picks the
        // +inf entry (the only meaningful maximum).
        let logits = vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY, f32::NAN];
        let mut greedy = Sampler::new(SamplerConfig::greedy());
        assert_eq!(greedy.sample(&logits), 1);
        let mut stochastic = Sampler::new(SamplerConfig {
            temperature: 1.0,
            ..SamplerConfig::default()
        });
        assert_eq!(stochastic.sample(&logits), 1);
        // all-NaN: deterministic id 0, no panic
        let nans = vec![f32::NAN; 5];
        assert_eq!(greedy.sample(&nans), 0);
        assert_eq!(stochastic.sample(&nans), 0);
    }

    #[test]
    fn repetition_penalty_discourages_loops() {
        // two equal peaks: with penalty, after sampling one it should switch
        let mut logits = vec![-5.0f32; 20];
        logits[3] = 4.0;
        logits[5] = 4.0;
        let mut s = Sampler::new(SamplerConfig {
            temperature: 0.01, // near-greedy
            top_k: 0,
            top_p: 1.0,
            repetition_penalty: 2.0,
            repetition_window: 8,
            seed: 5,
        });
        let first = s.sample(&logits);
        let second = s.sample(&logits);
        assert_ne!(first, second, "penalty should break the tie loop");
    }
}
