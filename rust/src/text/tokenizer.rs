//! Byte-level tokenizer (mirrors `python/compile/configs.py`).
//!
//! ids 0..255 are raw bytes; 256..259 are PAD/BOS/EOS/REF specials.  Decoding
//! is streaming-friendly: specials render as empty strings so the router can
//! scan the visible byte stream directly.

pub const PAD_ID: i32 = 256;
pub const BOS_ID: i32 = 257;
pub const EOS_ID: i32 = 258;
/// Marks Referential-Injection reference segments (§3.6).
pub const REF_ID: i32 = 259;
pub const VOCAB_SIZE: usize = 260;

/// Stateless byte tokenizer.
#[derive(Debug, Clone, Default)]
pub struct Tokenizer;

impl Tokenizer {
    pub fn new() -> Tokenizer {
        Tokenizer
    }

    /// Encode text to ids, optionally prefixing BOS.
    pub fn encode(&self, text: &str, add_bos: bool) -> Vec<i32> {
        let mut out = Vec::with_capacity(text.len() + 1);
        if add_bos {
            out.push(BOS_ID);
        }
        out.extend(text.bytes().map(|b| b as i32));
        out
    }

    /// Decode ids to text (specials skipped; non-UTF8 replaced).
    pub fn decode(&self, ids: &[i32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter(|&&id| (0..256).contains(&id))
            .map(|&id| id as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Decode a single id for streaming output (None for specials).
    pub fn decode_one(&self, id: i32) -> Option<u8> {
        if (0..256).contains(&id) {
            Some(id as u8)
        } else {
            None
        }
    }

    pub fn is_special(&self, id: i32) -> bool {
        !(0..256).contains(&id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let tk = Tokenizer::new();
        let ids = tk.encode("hello [TASK: x]", false);
        assert_eq!(ids.len(), 15);
        assert_eq!(tk.decode(&ids), "hello [TASK: x]");
    }

    #[test]
    fn bos_and_specials() {
        let tk = Tokenizer::new();
        let ids = tk.encode("ab", true);
        assert_eq!(ids, vec![BOS_ID, 97, 98]);
        assert_eq!(tk.decode(&ids), "ab");
        assert!(tk.is_special(BOS_ID));
        assert!(tk.is_special(EOS_ID));
        assert!(!tk.is_special(65));
        assert_eq!(tk.decode_one(EOS_ID), None);
        assert_eq!(tk.decode_one(65), Some(b'A'));
    }

    #[test]
    fn non_ascii_bytes() {
        let tk = Tokenizer::new();
        let ids = tk.encode("é", false); // two UTF-8 bytes
        assert_eq!(ids.len(), 2);
        assert_eq!(tk.decode(&ids), "é");
    }
}
