//! The Warp-Cortex HTTP API.
//!
//! Endpoints:
//! * `POST /generate` — `{"prompt": "...", "max_tokens": 64}` → episode
//!   report (text, events, timing).
//! * `GET  /stats`    — live system statistics (memory, gate, synapse,
//!   scheduler, device).
//! * `GET  /health`   — readiness probe.
//!
//! Connections are handled by a small accept-loop thread pool; every episode
//! runs through the shared [`WarpCortex`] orchestrator, so all requests
//! share the singleton weights and the device priority lanes.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use super::http::{respond, respond_json, BadRequest, HttpRequest};
use crate::cortex::WarpCortex;
use crate::util::Json;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub addr: String,
    pub workers: usize,
    /// Cap on tokens per request.
    pub max_tokens_cap: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:8787".into(),
            workers: 2,
            max_tokens_cap: 128,
        }
    }
}

/// Handle to a running server (for tests and the CLI).
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // poke the acceptor
        let _ = TcpStream::connect(self.addr);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Start serving; returns immediately with a handle.
pub fn serve(cortex: Arc<WarpCortex>, cfg: ServerConfig) -> Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr).with_context(|| format!("bind {}", cfg.addr))?;
    let addr = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let requests = Arc::new(AtomicU64::new(0));

    // Accept loop distributes connections to handler threads via a channel.
    let (tx, rx) = std::sync::mpsc::channel::<TcpStream>();
    let rx = Arc::new(std::sync::Mutex::new(rx));
    let mut threads = Vec::new();

    for i in 0..cfg.workers.max(1) {
        let rx = rx.clone();
        let cortex = cortex.clone();
        let cfg = cfg.clone();
        let requests = requests.clone();
        threads.push(
            std::thread::Builder::new()
                .name(format!("warp-http-{i}"))
                .spawn(move || loop {
                    let conn = rx.lock().unwrap().recv();
                    match conn {
                        Ok(mut stream) => {
                            requests.fetch_add(1, Ordering::Relaxed);
                            if let Err(e) = handle_connection(&mut stream, &cortex, &cfg) {
                                log::debug!("connection error: {e:#}");
                            }
                        }
                        Err(_) => return,
                    }
                })?,
        );
    }

    {
        let stop = stop.clone();
        threads.push(
            std::thread::Builder::new()
                .name("warp-accept".into())
                .spawn(move || {
                    for conn in listener.incoming() {
                        if stop.load(Ordering::SeqCst) {
                            return;
                        }
                        if let Ok(stream) = conn {
                            if tx.send(stream).is_err() {
                                return;
                            }
                        }
                    }
                })?,
        );
    }

    Ok(ServerHandle { addr, stop, threads })
}

fn handle_connection(
    stream: &mut TcpStream,
    cortex: &WarpCortex,
    cfg: &ServerConfig,
) -> Result<()> {
    // Malformed requests (bad/missing/oversized Content-Length, broken
    // request line) get a clean 400; only transport errors drop the
    // connection without a response.
    let req = match HttpRequest::read_from(stream) {
        Ok(Some(req)) => req,
        Ok(None) => return Ok(()),
        Err(e) => {
            if let Some(bad) = e.downcast_ref::<BadRequest>() {
                return respond_json(stream, 400, &Json::obj().with("error", bad.0.as_str()));
            }
            return Err(e);
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => respond_json(stream, 200, &Json::obj().with("ok", true)),
        ("GET", "/stats") => respond_json(stream, 200, &stats_json(cortex)),
        ("POST", "/generate") => match handle_generate(&req, cortex, cfg) {
            Ok(body) => respond_json(stream, 200, &body),
            Err(e) => respond_json(
                stream,
                400,
                &Json::obj().with("error", format!("{e:#}")),
            ),
        },
        ("POST", _) | ("GET", _) => respond(stream, 404, "text/plain", "not found"),
        _ => respond(stream, 405, "text/plain", "method not allowed"),
    }
}

fn handle_generate(req: &HttpRequest, cortex: &WarpCortex, cfg: &ServerConfig) -> Result<Json> {
    let body = Json::parse(req.body_str()?).map_err(|e| anyhow::anyhow!("bad json: {e}"))?;
    let prompt = body
        .req("prompt")?
        .as_str()
        .context("`prompt` must be a string")?
        .to_string();
    // Clamp against what the main cache can actually hold once the
    // (possibly truncated) prompt is prefilled — the truncation invariant
    // lives on WarpCortex::prompt_rows, not here.
    let remaining = cortex
        .engine
        .caps()
        .main_ctx
        .saturating_sub(cortex.prompt_rows(&prompt));
    let max_tokens = resolve_max_tokens(body.get("max_tokens"), 48, cfg.max_tokens_cap, remaining)?;

    let report = cortex.run_episode(&prompt, max_tokens)?;
    let events: Vec<Json> = report
        .events
        .iter()
        .map(|e| match e {
            crate::cortex::Event::Spawned { task_id, tag, payload, at_token } => Json::obj()
                .with("type", "spawned")
                .with("task", *task_id as i64)
                .with("tag", tag.as_str())
                .with("payload", payload.as_str())
                .with("at_token", *at_token),
            crate::cortex::Event::Dropped { payload, at_token } => Json::obj()
                .with("type", "dropped")
                .with("payload", payload.as_str())
                .with("at_token", *at_token),
            crate::cortex::Event::Merged { task_id, score, thought, injected_rows, at_token } => {
                Json::obj()
                    .with("type", "merged")
                    .with("task", *task_id as i64)
                    .with("score", *score as f64)
                    .with("thought", thought.as_str())
                    .with("injected_rows", *injected_rows)
                    .with("at_token", *at_token)
            }
            crate::cortex::Event::Rejected { task_id, score, thought, at_token } => Json::obj()
                .with("type", "rejected")
                .with("task", *task_id as i64)
                .with("score", *score as f64)
                .with("thought", thought.as_str())
                .with("at_token", *at_token),
            crate::cortex::Event::Failed { task_id, error, at_token } => Json::obj()
                .with("type", "failed")
                .with("task", *task_id as i64)
                .with("error", error.as_str())
                .with("at_token", *at_token),
            crate::cortex::Event::SynapsePushed { version, source_len, at_token } => Json::obj()
                .with("type", "synapse")
                .with("version", *version)
                .with("source_len", *source_len)
                .with("at_token", *at_token),
        })
        .collect();

    Ok(Json::obj()
        .with("text", report.text.as_str())
        .with("tokens", report.tokens_generated)
        .with("elapsed_ms", report.elapsed.as_secs_f64() * 1e3)
        .with("tokens_per_sec", report.main_tokens_per_sec)
        .with("events", Json::Arr(events)))
}

/// Resolve the requested `max_tokens`: absent → `default`; non-numeric or
/// non-positive → a clean 400 (the old behaviour let an oversized request
/// fail mid-episode with a confusing cache-append error); otherwise clamped
/// to the server cap and to the rows the main cache can still hold after
/// the prompt.  A full cache still yields a well-formed 1-token request —
/// the episode loop then terminates cleanly on `remaining() == 0`.
fn resolve_max_tokens(
    requested: Option<&Json>,
    default: usize,
    cap: usize,
    remaining: usize,
) -> Result<usize> {
    let n = match requested {
        None => default,
        Some(v) => {
            let x = v.as_f64().context("`max_tokens` must be a number")?;
            if x < 1.0 || x.fract() != 0.0 {
                anyhow::bail!("`max_tokens` must be a positive integer (got {x})");
            }
            x as usize
        }
    };
    Ok(n.min(cap).min(remaining.max(1)))
}

fn stats_json(cortex: &WarpCortex) -> Json {
    let mem = cortex.tracker.snapshot();
    let gate = cortex.gate.stats();
    let syn = cortex.synapse.stats();
    let step = cortex.step.stats();
    let dev = cortex.engine.device().stats();
    let pool = cortex.pool.stats();
    Json::obj()
        .with(
            "memory",
            Json::obj()
                .with("total_bytes", mem.total())
                .with("weights", mem.per_kind[0])
                .with("main_kv", mem.per_kind[1])
                .with("side_kv", mem.per_kind[2])
                .with("synapse", mem.per_kind[3])
                .with("device_kv", mem.per_kind[5])
                .with("shared_kv", mem.per_kind[6]),
        )
        .with(
            "pool",
            Json::obj()
                .with("block_tokens", pool.block_tokens)
                .with("block_bytes", pool.block_bytes)
                .with("blocks_live", pool.blocks_live)
                .with("blocks_free", pool.blocks_free)
                .with("blocks_high_water", pool.blocks_high_water)
                .with("resident_bytes", pool.resident_bytes())
                .with("live_bytes", pool.live_bytes())
                .with("reuses", pool.reuses)
                .with("fragmentation", pool.fragmentation())
                .with("dev_blocks", pool.dev_blocks)
                .with("dev_bytes", pool.dev_bytes)
                .with("h2d_bytes", pool.h2d_bytes)
                .with("dev_gathers", pool.dev_gathers)
                // prefix-sharing gauges: registry occupancy (charged once
                // globally), hit/miss/eviction counters and CoW copies
                .with("shared_blocks", pool.shared_blocks)
                .with("shared_bytes", pool.shared_bytes())
                .with("prefix_hits", pool.prefix_hits)
                .with("prefix_misses", pool.prefix_misses)
                .with("prefix_evictions", pool.prefix_evictions)
                .with("cow_copies", pool.cow_copies),
        )
        .with(
            "gate",
            Json::obj()
                .with("evaluated", gate.evaluated)
                .with("accepted", gate.accepted)
                .with("accept_rate", gate.accept_rate()),
        )
        .with(
            "synapse",
            Json::obj()
                .with("pushes", syn.pushes)
                .with("reads", syn.reads)
                .with("last_source_len", syn.last_source_len),
        )
        .with(
            "scheduler",
            Json::obj()
                .with("submitted", step.submitted)
                .with("completed", step.completed)
                .with("rejected_capacity", step.rejected_capacity)
                .with("active", step.active)
                .with("queued", step.parked),
        )
        // Step-scheduler gauges: continuous-batching health.  The figure
        // of merit is ops_per_token (→ 1/B as the population grows);
        // parked/parked_peak expose capacity-gated admission, and
        // main_deferred counts main steps that waited behind *another
        // main* (never behind side work — >0 only with concurrent
        // episodes).
        .with(
            "step",
            Json::obj()
                .with("ticks", step.ticks)
                .with("device_ops", step.device_ops)
                .with("main_steps", step.main_steps)
                .with("side_steps", step.side_steps)
                .with("fused_ticks", step.fused_ticks)
                .with("batch_occupancy", step.batch_occupancy())
                .with("ops_per_token", step.ops_per_token())
                .with("admitted", step.admitted)
                .with("parked", step.parked)
                .with("parked_peak", step.parked_peak)
                .with("main_deferred", step.main_deferred),
        )
        .with(
            "device",
            Json::obj()
                .with("ops", dev.ops)
                .with("exec_ns", dev.exec_ns)
                .with("river_ops", dev.lane_ops[0])
                .with("stream_ops", dev.lane_ops[1])
                .with("background_ops", dev.lane_ops[2]),
        )
        .with("population", cortex.prism.population().total())
}

// End-to-end server tests live in rust/tests/integration_serve.rs.

#[cfg(test)]
mod tests {
    use super::resolve_max_tokens;
    use crate::util::Json;

    #[test]
    fn max_tokens_clamping() {
        // absent → default
        assert_eq!(resolve_max_tokens(None, 48, 128, 1000).unwrap(), 48);
        // explicit, clamped by the server cap
        let big = Json::Num(1e6);
        assert_eq!(resolve_max_tokens(Some(&big), 48, 128, 1000).unwrap(), 128);
        // clamped to the rows the main cache can still hold (the old code
        // let this run into a mid-episode append error)
        let req = Json::Num(500.0);
        assert_eq!(resolve_max_tokens(Some(&req), 48, 1024, 70).unwrap(), 70);
        // non-positive and non-numeric → clean 400-shaped errors
        assert!(resolve_max_tokens(Some(&Json::Num(0.0)), 48, 128, 10).is_err());
        assert!(resolve_max_tokens(Some(&Json::Num(-3.0)), 48, 128, 10).is_err());
        assert!(resolve_max_tokens(Some(&Json::Str("x".into())), 48, 128, 10).is_err());
        assert!(resolve_max_tokens(Some(&Json::Num(0.4)), 48, 128, 10).is_err());
        assert!(
            resolve_max_tokens(Some(&Json::Num(2.7)), 48, 128, 10).is_err(),
            "fractional values must 400, not silently floor"
        );
        // a full cache still yields a well-formed 1-token request
        assert_eq!(resolve_max_tokens(None, 48, 128, 0).unwrap(), 1);
    }
}
