//! The Warp-Cortex HTTP API: a *session layer* over the multi-session
//! step scheduler.
//!
//! Endpoints:
//! * `POST /generate` — `{"prompt": "...", "max_tokens": 64}` → episode
//!   report (text, events, timing).  With `"stream": true` the response
//!   switches to chunked transfer encoding: one NDJSON line per token
//!   delta as the fused ticks produce them, then a final `"done": true`
//!   summary line.
//! * `POST /sessions/{id}/resume` — reattach to a hibernated session by
//!   its durable id (announced as the first chunk of every streaming
//!   response).  Always streams: the client is reconnecting to a
//!   generation in progress, so the body mirrors `"stream": true` —
//!   the id line, one NDJSON delta per token, then the `"done"` summary.
//!   `404` unknown/corrupt-consumed id, `400` malformed id, `503` no
//!   admission slot (the record is retained; retry).
//! * `GET  /stats`    — live system statistics (memory, pool, gate,
//!   synapse, scheduler, **sessions**, **store**, **prefill**, device).
//! * `GET  /metrics`  — the same gauges in Prometheus text exposition
//!   (version 0.0.4): every numeric leaf of the `/stats` tree flattened
//!   to one `warp_<path>` sample, so scrapers need no JSON shim and the
//!   two endpoints can never drift.
//! * `GET  /health`   — readiness probe.
//!
//! Every `/generate` request is admitted as a **session**
//! ([`SessionSource::open_session`]): a schedulable unit over the shared
//! weights and KV pool, not a blocked thread.  N in-flight requests'
//! main steps fuse into the same per-tick device op (see
//! [`crate::cortex::StepScheduler`]), so a new session streams its first
//! token while others are mid-generation — admission control (FIFO
//! parking, 503 shedding) replaces head-of-line blocking.  A client that
//! disconnects mid-stream cancels only its own session: the failed chunk
//! write **hibernates** the session when the backend supports it
//! (checkpoint to the durable store + ticket parked as a preempt-to-disk
//! candidate, resumable via `POST /sessions/{id}/resume`) and otherwise
//! drops it — either way its slot and cache blocks free and every other
//! session is untouched.
//!
//! The handler pool is still thread-per-connection (it is the *device
//! scheduling* that multiplexes, not the sockets), behind a nonblocking
//! accept loop so [`ServerHandle::stop`] is deterministic: no wake-up
//! poke that a worker could swallow, no hanging on a full OS backlog.
//!
//! The serving substrate is generic over [`SessionSource`] — production
//! uses [`WarpCortex`]; host-only tests drive the identical HTTP paths
//! with a stub source over the real step scheduler.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::http::{
    finish_chunked, parse_session_route, respond, respond_chunked_head, respond_json,
    write_chunk, BadRequest, HttpRequest, SessionRoute,
};
use crate::cortex::{
    CortexSession, ResumeError, SessionError, SessionStats, StoreStats, WarpCortex,
};
use crate::util::sync::{LockRank, RankedMutex};
use crate::util::Json;

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub addr: String,
    pub workers: usize,
    /// Cap on tokens per request.
    pub max_tokens_cap: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:8787".into(),
            // A streaming session occupies its worker for the whole
            // generation, so the pool should exceed the session table
            // (CortexConfig::max_sessions, default 8) with headroom for
            // /stats probes — otherwise HTTP queuing hides the session
            // layer's own FIFO parking and 503 shedding.
            workers: 10,
            max_tokens_cap: 128,
        }
    }
}

/// Per-socket read/write timeout: bounds how long a stalled client (no
/// request bytes, or a streaming reader that stopped draining its TCP
/// window) can pin a worker thread and — on the streaming path — a
/// session slot.  The timed-out write/read errs, the handler drops the
/// session (cancelling only it), and the worker moves on; `stop()` is
/// therefore bounded by one generation + this timeout, never infinite.
const IO_TIMEOUT: Duration = Duration::from_secs(30);

/// Why a session could not be opened, as the HTTP layer needs it.
#[derive(Debug)]
pub enum OpenDenied {
    /// Admission refused (session queue full / shutting down) → 503.
    Busy(String),
    /// Bring-up failed (prefill, registration) → 500.
    Internal(String),
}

/// Why a session could not be resumed, as the HTTP layer needs it.
#[derive(Debug)]
pub enum ResumeDenied {
    /// No durable record under that id — never checkpointed, already
    /// consumed by an earlier resume, or no store configured → 404.
    Unknown,
    /// Admission refused (the record is retained; retry later) → 503.
    Busy(String),
    /// The record was corrupt or the rebuild failed → 500.
    Internal(String),
    /// This source has no durable-session support at all → 404 (a
    /// stub's ids are as unknown as a misremembered one).
    Unsupported,
}

/// One live generation session from the server's perspective: a pull
/// iterator of visible text deltas plus a finalizer producing the
/// summary JSON.
pub trait TokenStream {
    /// Next visible text delta (may be empty for unprintable tokens);
    /// `None` once generation finished.
    fn next_delta(&mut self) -> Result<Option<String>>;
    /// Finalize: the episode summary (the non-streaming response body /
    /// the trailing streaming chunk, before `"done"` is added).
    fn finish(self) -> Result<Json>
    where
        Self: Sized;
    /// The durable id this stream can later be resumed under, when the
    /// backend checkpoints it — announced as the first streaming chunk
    /// so the client knows what to `POST /sessions/{id}/resume` after a
    /// disconnect.  Default `None`: no id line is emitted.
    fn session_id(&self) -> Option<u64> {
        None
    }
    /// The client disconnected mid-stream: checkpoint and park instead
    /// of dropping, where the backend supports it and policy allows.
    /// Returns the durable resume id, or `None` if the session was
    /// simply dropped (the default).
    fn hibernate(self) -> Option<u64>
    where
        Self: Sized,
    {
        None
    }
}

/// What the server serves: a source of generation sessions plus the
/// `/stats` snapshot.  Implemented by [`WarpCortex`] in production and by
/// host-only stubs in the serve-layer tests.
pub trait SessionSource: Send + Sync + 'static {
    type Stream<'a>: TokenStream
    where
        Self: 'a;
    /// Open a session for up to `max_tokens` tokens.  The backend owns the
    /// context clamp: a session whose cache fills simply ends early (the
    /// serve layer deliberately does NOT pre-compute a context budget —
    /// that cost a second prompt tokenization per request).
    fn open_session(
        &self,
        prompt: &str,
        max_tokens: usize,
    ) -> std::result::Result<Self::Stream<'_>, OpenDenied>;
    /// Resume a hibernated session by its durable id.  Default:
    /// unsupported — sources without a checkpoint store answer 404.
    fn resume(&self, id: u64) -> std::result::Result<Self::Stream<'_>, ResumeDenied> {
        let _ = id;
        Err(ResumeDenied::Unsupported)
    }
    fn stats(&self) -> Json;
}

impl SessionSource for WarpCortex {
    type Stream<'a> = CortexSession<'a>
    where
        Self: 'a;

    fn open_session(
        &self,
        prompt: &str,
        max_tokens: usize,
    ) -> std::result::Result<CortexSession<'_>, OpenDenied> {
        WarpCortex::open_session(self, prompt, max_tokens).map_err(|e| match e {
            SessionError::Busy(m) => OpenDenied::Busy(m),
            SessionError::Failed(err) => OpenDenied::Internal(format!("{err:#}")),
        })
    }

    fn resume(&self, id: u64) -> std::result::Result<CortexSession<'_>, ResumeDenied> {
        WarpCortex::resume_session(self, id).map_err(|e| match e {
            ResumeError::Unknown(_) => ResumeDenied::Unknown,
            ResumeError::Corrupt(m) => ResumeDenied::Internal(m),
            ResumeError::Session(SessionError::Busy(m)) => ResumeDenied::Busy(m),
            ResumeError::Session(SessionError::Failed(err)) => {
                ResumeDenied::Internal(format!("{err:#}"))
            }
        })
    }

    fn stats(&self) -> Json {
        stats_json(self)
    }
}

impl<'a> TokenStream for CortexSession<'a> {
    fn next_delta(&mut self) -> Result<Option<String>> {
        self.next_token()
    }

    fn finish(self) -> Result<Json> {
        Ok(CortexSession::finish(self)?.to_json())
    }

    fn session_id(&self) -> Option<u64> {
        Some(self.durable_id())
    }

    fn hibernate(self) -> Option<u64> {
        if !self.hibernate_on_disconnect() {
            return None; // policy off or no store: plain drop, as before
        }
        match CortexSession::hibernate(self) {
            Ok(id) => Some(id),
            Err(e) => {
                // Hibernation is best-effort on this path — a failed
                // checkpoint degrades to the pre-durability behaviour
                // (drop the session, free its slot), never to a stall.
                log::debug!("hibernate on disconnect failed: {e:#}");
                None
            }
        }
    }
}

/// Handle to a running server (for tests and the CLI).
pub struct ServerHandle {
    pub addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    threads: Vec<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Stop accepting and join every thread.  Deterministic: the acceptor
    /// polls a nonblocking listener, so no connect-poke is needed — the
    /// old poke could be swallowed by the OS backlog (or satisfied by a
    /// queued real client) and leave `stop()` hanging until the backlog
    /// drained.  Workers finish their in-flight connections (including
    /// active streaming sessions) and exit when the accept channel
    /// closes.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Start serving; returns immediately with a handle.
pub fn serve<S: SessionSource>(src: Arc<S>, cfg: ServerConfig) -> Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr).with_context(|| format!("bind {}", cfg.addr))?;
    let addr = listener.local_addr()?;
    // Nonblocking acceptor: the accept loop re-checks the stop flag every
    // few ms instead of blocking in accept() forever (the ServerHandle
    // wake race fix).
    listener.set_nonblocking(true)?;
    let stop = Arc::new(AtomicBool::new(false));
    let requests = Arc::new(AtomicU64::new(0));

    // Accept loop distributes connections to handler threads via a BOUNDED
    // channel: connections beyond the worker pool plus this small queue are
    // shed with an immediate 503 instead of piling up invisibly in an
    // unbounded buffer where neither the session layer's parking nor its
    // load shedding can see them.
    let workers = cfg.workers.max(1);
    let (tx, rx) = std::sync::mpsc::sync_channel::<TcpStream>(workers);
    // Ranked `Registry`: a worker holds it only for the recv handoff,
    // never while a request handler runs (the guard is a statement
    // temporary), so it can never invert against the session/pool locks.
    let rx = Arc::new(RankedMutex::new(LockRank::Registry, rx));
    let mut threads = Vec::new();

    for i in 0..workers {
        let rx = rx.clone();
        let src = src.clone();
        let cfg = cfg.clone();
        let requests = requests.clone();
        threads.push(
            std::thread::Builder::new()
                .name(format!("warp-http-{i}"))
                .spawn(move || loop {
                    let conn = rx.lock().recv();
                    match conn {
                        Ok(mut stream) => {
                            requests.fetch_add(1, Ordering::Relaxed);
                            if let Err(e) = handle_connection(&mut stream, src.as_ref(), &cfg) {
                                log::debug!("connection error: {e:#}");
                            }
                        }
                        Err(_) => return,
                    }
                })?,
        );
    }

    {
        let stop = stop.clone();
        threads.push(
            std::thread::Builder::new()
                .name("warp-accept".into())
                .spawn(move || loop {
                    if stop.load(Ordering::SeqCst) {
                        return; // drops tx: workers drain and exit
                    }
                    match listener.accept() {
                        Ok((stream, _)) => {
                            // Accepted sockets must be blocking regardless
                            // of the listener's mode — but never *unboundedly*
                            // blocking: a client that stops sending (or stops
                            // reading its stream) errors out after IO_TIMEOUT
                            // instead of pinning a worker and its session slot
                            // forever, and `stop()` stays bounded.
                            if stream.set_nonblocking(false).is_err() {
                                continue;
                            }
                            let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
                            let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
                            match tx.try_send(stream) {
                                Ok(()) => {}
                                Err(std::sync::mpsc::TrySendError::Full(mut s)) => {
                                    // Every worker busy and the queue full:
                                    // shed NOW with a 503 (never block the
                                    // acceptor — stop() must stay
                                    // deterministic).
                                    let _ = respond_json(
                                        &mut s,
                                        503,
                                        &Json::obj()
                                            .with("error", "server at capacity, retry"),
                                    );
                                }
                                Err(std::sync::mpsc::TrySendError::Disconnected(_)) => return,
                            }
                        }
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => std::thread::sleep(Duration::from_millis(2)),
                    }
                })?,
        );
    }

    Ok(ServerHandle { addr, stop, threads })
}

fn handle_connection<S: SessionSource>(
    stream: &mut TcpStream,
    src: &S,
    cfg: &ServerConfig,
) -> Result<()> {
    // Malformed requests (bad/missing/oversized Content-Length, broken
    // request line) get a clean 400; only transport errors drop the
    // connection without a response.
    let req = match HttpRequest::read_from(stream) {
        Ok(Some(req)) => req,
        Ok(None) => return Ok(()),
        Err(e) => {
            if let Some(bad) = e.downcast_ref::<BadRequest>() {
                return respond_json(stream, 400, &Json::obj().with("error", bad.0.as_str()));
            }
            return Err(e);
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => respond_json(stream, 200, &Json::obj().with("ok", true)),
        ("GET", "/stats") => respond_json(stream, 200, &src.stats()),
        ("GET", "/metrics") => respond(
            stream,
            200,
            "text/plain; version=0.0.4",
            &metrics_text(&src.stats()),
        ),
        ("POST", "/generate") => handle_generate(stream, &req, src, cfg),
        // Parameterized routes resolve by exact path *segments*, never by
        // prefix: `/sessions/7/resume/x` is a 404, `/sessions/abc/resume`
        // a typed 400 (the route matched; the id didn't parse).
        ("POST", path) => match parse_session_route(path) {
            SessionRoute::Resume(id) => handle_resume(stream, src, id),
            SessionRoute::Malformed(seg) => respond_json(
                stream,
                400,
                &error_json(format!("`{seg}` is not a valid session id (expect u64)")),
            ),
            SessionRoute::NotSession => respond(stream, 404, "text/plain", "not found"),
        },
        ("GET", _) => respond(stream, 404, "text/plain", "not found"),
        _ => respond(stream, 405, "text/plain", "method not allowed"),
    }
}

/// `POST /sessions/{id}/resume`: re-admit a hibernated session and
/// reattach to its stream.  Resume always streams — the client is
/// reconnecting to a generation in progress, so the response mirrors the
/// `"stream": true` shape of `/generate`.  The durable record is
/// single-use: a successful resume consumes it (the announced id on the
/// new stream covers the *next* disconnect), while a `503` retains it
/// for retry.
fn handle_resume<S: SessionSource>(stream: &mut TcpStream, src: &S, id: u64) -> Result<()> {
    let session = match src.resume(id) {
        Ok(s) => s,
        Err(ResumeDenied::Unknown) | Err(ResumeDenied::Unsupported) => {
            return respond_json(stream, 404, &error_json(format!("unknown session {id}")))
        }
        Err(ResumeDenied::Busy(m)) => return respond_json(stream, 503, &error_json(m)),
        Err(ResumeDenied::Internal(m)) => return respond_json(stream, 500, &error_json(m)),
    };
    stream_session(stream, session)
}

fn error_json(msg: impl std::fmt::Display) -> Json {
    Json::obj().with("error", format!("{msg}"))
}

fn handle_generate<S: SessionSource>(
    stream: &mut TcpStream,
    req: &HttpRequest,
    src: &S,
    cfg: &ServerConfig,
) -> Result<()> {
    let body = match req
        .body_str()
        .and_then(|s| Json::parse(s).map_err(|e| anyhow::anyhow!("bad json: {e}")))
    {
        Ok(b) => b,
        Err(e) => return respond_json(stream, 400, &error_json(format!("{e:#}"))),
    };
    let prompt = match body
        .req("prompt")
        .and_then(|v| v.as_str().context("`prompt` must be a string"))
    {
        Ok(p) => p.to_string(),
        Err(e) => return respond_json(stream, 400, &error_json(format!("{e:#}"))),
    };
    let stream_mode = match body.get("stream") {
        None => false,
        Some(v) => match v.as_bool() {
            Some(b) => b,
            None => {
                return respond_json(stream, 400, &error_json("`stream` must be a boolean"))
            }
        },
    };
    let max_tokens = match resolve_max_tokens(body.get("max_tokens"), 48, cfg.max_tokens_cap) {
        Ok(n) => n,
        Err(e) => return respond_json(stream, 400, &error_json(format!("{e:#}"))),
    };
    // Admission: Busy (slots + park queue saturated) sheds with 503 so the
    // client retries, instead of queueing unboundedly behind a blocked
    // thread.
    let session = match src.open_session(&prompt, max_tokens) {
        Ok(s) => s,
        Err(OpenDenied::Busy(m)) => return respond_json(stream, 503, &error_json(m)),
        Err(OpenDenied::Internal(m)) => return respond_json(stream, 500, &error_json(m)),
    };
    if stream_mode {
        stream_session(stream, session)
    } else {
        collect_session(stream, session)
    }
}

/// Non-streaming `/generate`: run the session to completion, answer with
/// the episode summary.
fn collect_session<T: TokenStream>(stream: &mut TcpStream, mut session: T) -> Result<()> {
    loop {
        match session.next_delta() {
            Ok(Some(_)) => {}
            Ok(None) => break,
            Err(e) => return respond_json(stream, 500, &error_json(format!("{e:#}"))),
        }
    }
    match session.finish() {
        Ok(j) => respond_json(stream, 200, &j),
        Err(e) => respond_json(stream, 500, &error_json(format!("{e:#}"))),
    }
}

/// Streaming `/generate` and `/sessions/{id}/resume`: chunked transfer
/// encoding — an id line when the backend is durable, one NDJSON line
/// per token as the fused ticks produce them, then a `"done": true`
/// summary line.  A failed chunk write is the disconnect signal: the
/// session hibernates if the backend and policy support it (resumable
/// later under the announced id), else drops — only itself, either way.
fn stream_session<T: TokenStream>(stream: &mut TcpStream, mut session: T) -> Result<()> {
    respond_chunked_head(stream, 200, "application/x-ndjson")?;
    // Durable backends announce the resume id before the first delta, so
    // a client that loses the connection knows what to POST.
    if let Some(id) = session.session_id() {
        let line = Json::obj().with("session", id).to_string() + "\n";
        if write_chunk(stream, &line).is_err() {
            let _ = session.hibernate();
            return Ok(());
        }
    }
    let mut n = 0usize;
    loop {
        match session.next_delta() {
            Ok(Some(delta)) => {
                n += 1;
                let line =
                    Json::obj().with("n", n).with("delta", delta.as_str()).to_string() + "\n";
                if write_chunk(stream, &line).is_err() {
                    // Client went away mid-stream: hibernate (checkpoint
                    // + park, resumable by id) when supported, else drop.
                    // Both cancel ONLY this session — the admission slot
                    // and cache blocks free; every other session is
                    // untouched.
                    let _ = session.hibernate();
                    return Ok(());
                }
            }
            Ok(None) => break,
            Err(e) => {
                // Even the failure line carries the protocol's terminal
                // marker: clients read until `"done": true` and must be
                // able to tell a server-side error from a truncated
                // stream.
                let line = Json::obj()
                    .with("done", true)
                    .with("error", format!("{e:#}"))
                    .to_string()
                    + "\n";
                let _ = write_chunk(stream, &line);
                let _ = finish_chunked(stream);
                return Ok(());
            }
        }
    }
    let tail = match session.finish() {
        Ok(mut j) => {
            j.set("done", true);
            j
        }
        Err(e) => Json::obj().with("done", true).with("error", format!("{e:#}")),
    };
    let _ = write_chunk(stream, &(tail.to_string() + "\n"));
    let _ = finish_chunked(stream);
    Ok(())
}

/// Resolve the requested `max_tokens`: absent → `default`; non-numeric or
/// non-positive → a clean 400; otherwise clamped to the server cap.  The
/// *context* clamp lives in the session itself — `next_token` ends the
/// stream cleanly at `remaining() == 0` — so an oversized request just
/// stops early (and the serve layer avoids the prompt re-tokenization a
/// pre-computed budget used to cost).
fn resolve_max_tokens(requested: Option<&Json>, default: usize, cap: usize) -> Result<usize> {
    let n = match requested {
        None => default,
        Some(v) => {
            let x = v.as_f64().context("`max_tokens` must be a number")?;
            if x < 1.0 || x.fract() != 0.0 {
                anyhow::bail!("`max_tokens` must be a positive integer (got {x})");
            }
            x as usize
        }
    };
    Ok(n.min(cap))
}

/// Render a stats snapshot as Prometheus text exposition (version 0.0.4):
/// every numeric leaf of the JSON tree becomes one `warp_<path>` sample
/// (booleans as 0/1) preceded by its `# TYPE warp_<path> gauge` metadata
/// line, so the same snapshot that answers `/stats` answers the scrape
/// endpoint and the two can never drift.  Everything is declared `gauge`:
/// the snapshot has no reset semantics a scraper could rely on, and a
/// monotone counter read as a gauge is still `rate()`-able.  Strings and
/// arrays have no Prometheus scalar type and are skipped.
pub fn metrics_text(stats: &Json) -> String {
    let mut out = String::new();
    flatten_metrics(stats, "warp", &mut out);
    out
}

fn flatten_metrics(node: &Json, prefix: &str, out: &mut String) {
    match node {
        Json::Obj(pairs) => {
            for (k, v) in pairs {
                let name = format!("{prefix}_{}", sanitize_metric(k));
                flatten_metrics(v, &name, out);
            }
        }
        Json::Num(x) if x.is_finite() => {
            out.push_str(&format!("# TYPE {prefix} gauge\n"));
            // Integral values print without a trailing `.0`, matching the
            // /stats wire shape (counters stay counters to the scraper).
            if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{prefix} {}\n", *x as i64));
            } else {
                out.push_str(&format!("{prefix} {x}\n"));
            }
        }
        Json::Bool(b) => {
            out.push_str(&format!("# TYPE {prefix} gauge\n"));
            out.push_str(&format!("{prefix} {}\n", u8::from(*b)));
        }
        _ => {}
    }
}

/// Prometheus metric names allow `[a-zA-Z0-9_:]`; JSON keys may not.
fn sanitize_metric(key: &str) -> String {
    key.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

/// The `/stats` `sessions` gauge block — one shape shared by the cortex
/// backend and the host-only test stubs, so gauge-reconciliation tests
/// pin the wire format the dashboards read.
pub fn sessions_json(s: &SessionStats) -> Json {
    Json::obj()
        .with("requested", s.requested)
        .with("admitted", s.admitted)
        .with("rejected", s.rejected)
        .with("completed", s.completed)
        .with("active", s.active)
        .with("parked", s.parked)
        .with("parked_peak", s.parked_peak)
        .with("occupancy", s.occupancy)
}

/// The `/stats` `store` gauge block — the durable checkpoint store's
/// record ledger and footprint.  `checkpoints == resumes + superseded +
/// corrupt_records_skipped + retained` is the store's sanitizer-checked
/// conservation law; `preempt_to_disk` and `parked_resident` track the
/// fourth admission tier (resident hibernated tickets evicted under pool
/// pressure).  All-zero when no `store_path` is configured.
pub fn store_json(s: &StoreStats) -> Json {
    Json::obj()
        .with("checkpoints", s.checkpoints)
        .with("resumes", s.resumes)
        .with("preempt_to_disk", s.preempt_to_disk)
        .with("store_bytes", s.store_bytes)
        .with("corrupt_records_skipped", s.corrupt_records_skipped)
        .with("retained", s.retained)
        .with("superseded", s.superseded)
        .with("parked_resident", s.parked_resident)
}

fn stats_json(cortex: &WarpCortex) -> Json {
    let mem = cortex.tracker.snapshot();
    let gate = cortex.gate.stats();
    let syn = cortex.synapse.stats();
    let step = cortex.step.stats();
    let sess = cortex.step.session_stats();
    let dev = cortex.engine.device().stats();
    let pool = cortex.pool.stats();
    Json::obj()
        .with(
            "memory",
            Json::obj()
                .with("total_bytes", mem.total())
                .with("weights", mem.per_kind[0])
                .with("main_kv", mem.per_kind[1])
                .with("side_kv", mem.per_kind[2])
                .with("synapse", mem.per_kind[3])
                .with("device_kv", mem.per_kind[5])
                .with("shared_kv", mem.per_kind[6])
                .with("host_kv", mem.per_kind[7]),
        )
        .with(
            "pool",
            Json::obj()
                .with("block_tokens", pool.block_tokens)
                .with("block_bytes", pool.block_bytes)
                .with("blocks_live", pool.blocks_live)
                .with("blocks_free", pool.blocks_free)
                .with("blocks_high_water", pool.blocks_high_water)
                .with("resident_bytes", pool.resident_bytes())
                .with("live_bytes", pool.live_bytes())
                .with("rents", pool.rents)
                .with("reuses", pool.reuses)
                .with("releases", pool.releases)
                .with("fragmentation", pool.fragmentation())
                .with("dev_blocks", pool.dev_blocks)
                .with("dev_bytes", pool.dev_bytes)
                .with("h2d_bytes", pool.h2d_bytes)
                .with("dev_gathers", pool.dev_gathers)
                // prefix-sharing gauges: registry occupancy (charged once
                // globally), hit/miss/eviction counters and CoW copies
                .with("shared_blocks", pool.shared_blocks)
                .with("shared_bytes", pool.shared_bytes())
                .with("prefix_hits", pool.prefix_hits)
                .with("prefix_misses", pool.prefix_misses)
                .with("prefix_mid_hits", pool.prefix_mid_hits)
                .with("prefix_evictions", pool.prefix_evictions)
                .with("cow_copies", pool.cow_copies)
                // admission reservations held by sessions mid-prefill
                .with("reserved_blocks", pool.reserved_blocks)
                // tiered-KV gauges: warm int8 occupancy and the bytes it
                // saves vs fp32, cold host-slab occupancy, and the swap
                // traffic counters (swap_out == swap_in + swap_dropped +
                // host_slab_bytes is a sanitizer-checked conservation law)
                .with("quantized_blocks", pool.quantized_blocks)
                .with("quant_saved_bytes", pool.quant_saved_bytes)
                .with("q8_block_bytes", pool.q8_block_bytes)
                .with("offloaded_blocks", pool.offloaded_blocks)
                .with("host_slab_bytes", pool.host_slab_bytes)
                .with("swap_out_bytes", pool.swap_out_bytes)
                .with("swap_in_bytes", pool.swap_in_bytes)
                .with("swap_dropped_bytes", pool.swap_dropped_bytes)
                .with("resume_page_ins", pool.resume_page_ins),
        )
        .with(
            "gate",
            Json::obj()
                .with("evaluated", gate.evaluated)
                .with("accepted", gate.accepted)
                .with("accept_rate", gate.accept_rate()),
        )
        .with(
            "synapse",
            Json::obj()
                .with("pushes", syn.pushes)
                .with("reads", syn.reads)
                .with("last_source_len", syn.last_source_len),
        )
        .with(
            "scheduler",
            Json::obj()
                .with("submitted", step.submitted)
                .with("completed", step.completed)
                .with("rejected_capacity", step.rejected_capacity)
                .with("active", step.active)
                .with("queued", step.parked),
        )
        // Step-scheduler gauges: continuous-batching health.  The figure
        // of merit is ops_per_token (→ 1/B as the population grows);
        // parked/parked_peak expose capacity-gated admission, and
        // main_deferred counts main steps that waited behind *other
        // mains* (never behind side work).
        .with(
            "step",
            Json::obj()
                .with("ticks", step.ticks)
                .with("device_ops", step.device_ops)
                .with("main_steps", step.main_steps)
                .with("side_steps", step.side_steps)
                .with("fused_ticks", step.fused_ticks)
                .with("main_ticks", step.main_ticks)
                .with("batch_occupancy", step.batch_occupancy())
                .with("ops_per_token", step.ops_per_token())
                .with("admitted", step.admitted)
                .with("parked", step.parked)
                .with("parked_peak", step.parked_peak)
                .with("main_deferred", step.main_deferred),
        )
        // Chunked-prefill gauges: chunks teacher-forced through the fused
        // tick, ticks that carried one, chunks the per-tick budget held
        // back, and prefix-registry hits landed *mid-prefill* (a
        // concurrent identical prompt adopting blocks as they publish).
        .with(
            "prefill",
            Json::obj()
                .with("chunks", step.prefill_steps)
                .with("ticks", step.prefill_ticks)
                .with("budget_deferred", step.prefill_deferred)
                .with("mid_prefix_hits", pool.prefix_mid_hits)
                .with("budget", cortex.cfg.prefill_budget.max(1))
                .with("chunked", cortex.cfg.chunked_prefill),
        )
        // Session-layer gauges: admitted == completed + active and
        // requested == admitted + rejected + parked at every instant —
        // the concurrent-client hammer test reconciles these.
        .with("sessions", sessions_json(&sess))
        // Durable-session gauges: the checkpoint store's record ledger
        // (see `store_json` for the conservation law it satisfies).
        .with(
            "store",
            store_json(&cortex.store.as_ref().map(|s| s.stats()).unwrap_or_default()),
        )
        // Main-stream token throughput: lifetime total plus the overall
        // and trailing-10s rates from the sliding window — the live
        // counterpart of the paper's tokens/sec figure.
        .with(
            "throughput",
            Json::obj()
                .with("main_tokens", cortex.main_throughput.total())
                .with("overall_per_sec", cortex.main_throughput.overall_per_sec())
                .with("recent_per_sec", cortex.main_throughput.recent_per_sec(10.0)),
        )
        .with(
            "device",
            Json::obj()
                .with("ops", dev.ops)
                .with("exec_ns", dev.exec_ns)
                .with("river_ops", dev.lane_ops[0])
                .with("stream_ops", dev.lane_ops[1])
                .with("background_ops", dev.lane_ops[2]),
        )
        .with("population", cortex.prism.population().total())
}

// End-to-end server tests live in rust/tests/integration_serve.rs
// (device-gated, real WarpCortex) and rust/tests/serve_sessions.rs
// (host-only: stub SessionSource over the real step scheduler — the
// concurrent-client hammer, streaming no-head-of-line-blocking, and the
// deterministic-stop regression).

#[cfg(test)]
mod tests {
    use super::{metrics_text, resolve_max_tokens};
    use crate::util::Json;

    #[test]
    fn metrics_flatten_numeric_leaves_only() {
        let stats = Json::obj()
            .with(
                "pool",
                Json::obj()
                    .with("prefix_mid_hits", 3u64)
                    .with("frag-pct", 0.5),
            )
            .with("prefill", Json::obj().with("chunked", true))
            .with("model", "tiny") // strings have no scalar type: skipped
            .with("events", Json::Arr(vec![Json::Num(1.0)])); // arrays too
        let text = metrics_text(&stats);
        assert!(text.contains("warp_pool_prefix_mid_hits 3\n"), "{text}");
        // non-[a-zA-Z0-9] key bytes sanitize to `_`
        assert!(text.contains("warp_pool_frag_pct 0.5\n"), "{text}");
        // booleans export as 0/1 gauges
        assert!(text.contains("warp_prefill_chunked 1\n"), "{text}");
        assert!(!text.contains("tiny"), "{text}");
        assert!(!text.contains("events"), "{text}");
        // every sample is `name value`, preceded by its TYPE metadata line
        let mut last_type: Option<String> = None;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let mut parts = rest.split(' ');
                let name = parts.next().unwrap();
                assert!(name.starts_with("warp_"), "{line}");
                assert_eq!(parts.next(), Some("gauge"), "{line}");
                assert!(parts.next().is_none(), "{line}");
                last_type = Some(name.to_string());
                continue;
            }
            let mut parts = line.split(' ');
            let name = parts.next().unwrap();
            assert!(name.starts_with("warp_"));
            assert!(parts.next().unwrap().parse::<f64>().is_ok());
            assert!(parts.next().is_none());
            // the metadata line announced exactly this sample
            assert_eq!(last_type.take().as_deref(), Some(name), "{text}");
        }
        assert!(last_type.is_none(), "dangling TYPE line: {text}");
    }

    #[test]
    fn max_tokens_clamping() {
        // absent → default
        assert_eq!(resolve_max_tokens(None, 48, 128).unwrap(), 48);
        // explicit, clamped by the server cap (the CONTEXT clamp lives in
        // the session itself, which ends cleanly at remaining() == 0)
        let big = Json::Num(1e6);
        assert_eq!(resolve_max_tokens(Some(&big), 48, 128).unwrap(), 128);
        // non-positive and non-numeric → clean 400-shaped errors
        assert!(resolve_max_tokens(Some(&Json::Num(0.0)), 48, 128).is_err());
        assert!(resolve_max_tokens(Some(&Json::Num(-3.0)), 48, 128).is_err());
        assert!(resolve_max_tokens(Some(&Json::Str("x".into())), 48, 128).is_err());
        assert!(resolve_max_tokens(Some(&Json::Num(0.4)), 48, 128).is_err());
        assert!(
            resolve_max_tokens(Some(&Json::Num(2.7)), 48, 128).is_err(),
            "fractional values must 400, not silently floor"
        );
    }
}
