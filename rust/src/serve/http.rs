//! Minimal HTTP/1.1 request/response handling over a `TcpStream`.
//!
//! Supports exactly what the API needs: GET/POST, Content-Length bodies,
//! and JSON responses.  Not a general web server — a serving substrate.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).context("request body is not UTF-8")
    }

    /// Read one request from the stream (None on clean EOF).
    pub fn read_from(stream: &mut TcpStream) -> Result<Option<HttpRequest>> {
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            return Ok(None);
        }
        let mut parts = line.split_whitespace();
        let method = parts.next().unwrap_or("").to_uppercase();
        let path = parts.next().unwrap_or("/").to_string();
        if method.is_empty() {
            bail!("malformed request line: {line:?}");
        }

        let mut headers = Vec::new();
        loop {
            let mut h = String::new();
            if reader.read_line(&mut h)? == 0 {
                break;
            }
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                headers.push((k.trim().to_string(), v.trim().to_string()));
            }
        }

        let len: usize = headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
            .and_then(|(_, v)| v.parse().ok())
            .unwrap_or(0);
        if len > 16 * 1024 * 1024 {
            bail!("request body too large: {len}");
        }
        let mut body = vec![0u8; len];
        if len > 0 {
            reader.read_exact(&mut body)?;
        }
        Ok(Some(HttpRequest {
            method,
            path,
            headers,
            body,
        }))
    }
}

/// Write an HTTP response with a JSON (or plain) body.
pub fn respond(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &str,
) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}

pub fn respond_json(stream: &mut TcpStream, status: u16, body: &crate::util::Json) -> Result<()> {
    respond(stream, status, "application/json", &body.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};

    fn roundtrip(raw: &str) -> Option<HttpRequest> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let raw = raw.to_string();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(raw.as_bytes()).unwrap();
            s.shutdown(std::net::Shutdown::Write).unwrap();
        });
        let (mut conn, _) = listener.accept().unwrap();
        let req = HttpRequest::read_from(&mut conn).unwrap();
        client.join().unwrap();
        req
    }

    #[test]
    fn parses_get() {
        let req = roundtrip("GET /stats HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/stats");
        assert_eq!(req.header("host"), Some("x"));
        assert!(req.body.is_empty());
    }

    #[test]
    fn parses_post_with_body() {
        let body = r#"{"prompt":"hi"}"#;
        let raw = format!(
            "POST /generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let req = roundtrip(&raw).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.body_str().unwrap(), body);
    }

    #[test]
    fn empty_connection_is_none() {
        assert!(roundtrip("").is_none());
    }
}
