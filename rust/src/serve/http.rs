//! Minimal HTTP/1.1 request/response handling over a `TcpStream`.
//!
//! Supports exactly what the API needs: GET/POST, Content-Length bodies,
//! and JSON responses.  Not a general web server — a serving substrate.
//!
//! Body handling is defensive: the `Content-Length` header is *validated*,
//! never trusted for the read allocation.  A missing header on a
//! body-carrying method, a non-numeric or negative value, or anything over
//! the [`MAX_BODY_BYTES`] hard cap surfaces as a typed [`BadRequest`]
//! error so the server answers `400` instead of allocating
//! attacker-controlled buffers (the pre-PR-4 parser mapped a *negative*
//! length to 0 via `parse::<usize>().ok()` and silently read no body, and
//! dropped the connection without a response on oversized ones).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

use anyhow::{Context, Result};

/// Hard cap on request bodies.  Generous for `/generate` prompts (the
/// only body-carrying endpoint) while bounding the per-connection
/// allocation an arbitrary client can force.
pub const MAX_BODY_BYTES: usize = 1024 * 1024;

/// A malformed request the server answers with `400 Bad Request`
/// (distinct from transport errors, which just drop the connection).
#[derive(Debug)]
pub struct BadRequest(pub String);

impl std::fmt::Display for BadRequest {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "bad request: {}", self.0)
    }
}

impl std::error::Error for BadRequest {}

fn bad(msg: impl Into<String>) -> anyhow::Error {
    anyhow::Error::new(BadRequest(msg.into()))
}

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).context("request body is not UTF-8")
    }

    /// Read one request from the stream (None on clean EOF).
    pub fn read_from(stream: &mut TcpStream) -> Result<Option<HttpRequest>> {
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            return Ok(None);
        }
        let mut parts = line.split_whitespace();
        let method = parts.next().unwrap_or("").to_uppercase();
        let path = parts.next().unwrap_or("/").to_string();
        if method.is_empty() {
            return Err(bad(format!("malformed request line: {line:?}")));
        }

        let mut headers = Vec::new();
        loop {
            let mut h = String::new();
            if reader.read_line(&mut h)? == 0 {
                break;
            }
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some((k, v)) = h.split_once(':') {
                headers.push((k.trim().to_string(), v.trim().to_string()));
            }
        }

        // Validate Content-Length instead of trusting it for the read
        // allocation: absent on a body-carrying method, non-numeric,
        // negative or over the hard cap are all 400s, not allocations.
        let declared = headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
            .map(|(_, v)| v.as_str());
        let len = match declared {
            None => {
                if matches!(method.as_str(), "POST" | "PUT" | "PATCH") {
                    return Err(bad(format!("{method} request without Content-Length")));
                }
                0
            }
            Some(raw) => {
                let n: i64 = raw
                    .trim()
                    .parse()
                    .map_err(|_| bad(format!("invalid Content-Length: {raw:?}")))?;
                if n < 0 {
                    return Err(bad(format!("negative Content-Length: {n}")));
                }
                // Compare BEFORE narrowing: on 32-bit targets an `as usize`
                // cast of a >= 2^32 value truncates under the cap and
                // desyncs body framing.
                if n > MAX_BODY_BYTES as i64 {
                    return Err(bad(format!(
                        "request body too large: {n} bytes (cap {MAX_BODY_BYTES})"
                    )));
                }
                n as usize
            }
        };
        let mut body = vec![0u8; len];
        if len > 0 {
            reader.read_exact(&mut body)?;
        }
        Ok(Some(HttpRequest {
            method,
            path,
            headers,
            body,
        }))
    }
}

/// Typed outcome of matching a path against the `/sessions/{id}/resume`
/// route — the 404-vs-400 distinction the server needs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SessionRoute {
    /// Exactly `/sessions/{id}/resume` with a well-formed u64 id.
    Resume(u64),
    /// The resume shape with an id that is not a u64 — `400`, because the
    /// client addressed the right route with a malformed argument (a 404
    /// would misreport "no such session" for a request that could never
    /// name one).  Carries the offending segment for the error body.
    Malformed(String),
    /// Not a session route at all — fall through to the server's `404`.
    NotSession,
}

/// Match `path` against the session routes by *path segments*, not string
/// prefix: `/sessions/7/resume` resumes session 7, while `/sessionsX/7`
/// and `/sessions/7/resume/extra` are `NotSession` (the prefix-match
/// idiom would have swallowed both), and a non-numeric or empty id
/// (`/sessions/abc/resume`, `/sessions//resume`) is `Malformed`.
/// Trailing-slash-only variants (`/sessions/7/resume/`) are accepted —
/// one empty trailing segment is a client formatting wobble, not a
/// different resource.
pub fn parse_session_route(path: &str) -> SessionRoute {
    // Ignore any query string; route identity is the path alone.
    let path = path.split('?').next().unwrap_or(path);
    let mut segs: Vec<&str> = path.split('/').collect();
    // Leading '/' yields an empty first segment; drop exactly one
    // trailing empty segment for a trailing slash.
    if segs.first() == Some(&"") {
        segs.remove(0);
    }
    if segs.last() == Some(&"") {
        segs.pop();
    }
    match segs.as_slice() {
        ["sessions", id, "resume"] => match id.parse::<u64>() {
            Ok(id) => SessionRoute::Resume(id),
            Err(_) => SessionRoute::Malformed((*id).to_string()),
        },
        _ => SessionRoute::NotSession,
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write an HTTP response with a JSON (or plain) body.
pub fn respond(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &str,
) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        reason(status),
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}

pub fn respond_json(stream: &mut TcpStream, status: u16, body: &crate::util::Json) -> Result<()> {
    respond(stream, status, "application/json", &body.to_string())
}

// ── Chunked (streaming) responses ───────────────────────────────────────
//
// The streaming `/generate` path: headers first (`Transfer-Encoding:
// chunked`), then one [`write_chunk`] per token delta as the session's
// fused ticks produce them, then [`finish_chunked`].  Each chunk is
// flushed immediately — the client sees the first token while other
// sessions are still mid-generation, and a failed write is the server's
// disconnect signal (the handler drops the session, cancelling only it).

/// Start a chunked response: status line + headers only; the body follows
/// via [`write_chunk`] and ends with [`finish_chunked`].
pub fn respond_chunked_head(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
) -> Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\n\
         Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
        reason(status)
    );
    stream.write_all(head.as_bytes())?;
    stream.flush()?;
    Ok(())
}

/// Write one chunk (hex size line + payload), flushed so it reaches the
/// client now.  Empty payloads are skipped — a zero-length chunk would
/// terminate the stream.
pub fn write_chunk(stream: &mut TcpStream, data: &str) -> Result<()> {
    if data.is_empty() {
        return Ok(());
    }
    stream.write_all(format!("{:x}\r\n", data.len()).as_bytes())?;
    stream.write_all(data.as_bytes())?;
    stream.write_all(b"\r\n")?;
    stream.flush()?;
    Ok(())
}

/// Terminate a chunked response (the zero-length chunk).
pub fn finish_chunked(stream: &mut TcpStream) -> Result<()> {
    stream.write_all(b"0\r\n\r\n")?;
    stream.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream};

    fn roundtrip_res(raw: &str) -> Result<Option<HttpRequest>> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let raw = raw.to_string();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(raw.as_bytes()).unwrap();
            s.shutdown(std::net::Shutdown::Write).unwrap();
        });
        let (mut conn, _) = listener.accept().unwrap();
        let req = HttpRequest::read_from(&mut conn);
        client.join().unwrap();
        req
    }

    fn roundtrip(raw: &str) -> Option<HttpRequest> {
        roundtrip_res(raw).unwrap()
    }

    /// The error must be the typed 400 marker, not a transport error.
    fn expect_bad_request(raw: &str, needle: &str) {
        let err = roundtrip_res(raw).unwrap_err();
        let bad = err
            .downcast_ref::<BadRequest>()
            .unwrap_or_else(|| panic!("not a BadRequest: {err:#}"));
        assert!(
            bad.0.contains(needle),
            "expected {needle:?} in {:?}",
            bad.0
        );
    }

    #[test]
    fn parses_get() {
        let req = roundtrip("GET /stats HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/stats");
        assert_eq!(req.header("host"), Some("x"));
        assert!(req.body.is_empty());
    }

    #[test]
    fn parses_post_with_body() {
        let body = r#"{"prompt":"hi"}"#;
        let raw = format!(
            "POST /generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let req = roundtrip(&raw).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.body_str().unwrap(), body);
    }

    #[test]
    fn empty_connection_is_none() {
        assert!(roundtrip("").is_none());
    }

    #[test]
    fn get_without_content_length_is_fine() {
        let req = roundtrip("GET / HTTP/1.1\r\n\r\n").unwrap();
        assert!(req.body.is_empty());
    }

    #[test]
    fn post_without_content_length_is_rejected() {
        expect_bad_request(
            "POST /generate HTTP/1.1\r\n\r\n{\"prompt\":\"hi\"}",
            "without Content-Length",
        );
    }

    #[test]
    fn negative_content_length_is_rejected() {
        // The old parser's parse::<usize>().ok() mapped this to len 0 and
        // silently dropped the body.
        expect_bad_request(
            "POST /generate HTTP/1.1\r\nContent-Length: -5\r\n\r\nhello",
            "negative Content-Length",
        );
    }

    #[test]
    fn non_numeric_content_length_is_rejected() {
        expect_bad_request(
            "POST /generate HTTP/1.1\r\nContent-Length: banana\r\n\r\nhello",
            "invalid Content-Length",
        );
        // numeric overflow of the parser is invalid too, not a huge alloc
        expect_bad_request(
            "POST /g HTTP/1.1\r\nContent-Length: 99999999999999999999\r\n\r\n",
            "invalid Content-Length",
        );
    }

    #[test]
    fn oversized_content_length_is_rejected_before_allocation() {
        let raw = format!(
            "POST /generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        );
        expect_bad_request(&raw, "too large");
        // exactly at the cap is allowed (the declared body just isn't there,
        // so the read errors at transport level — not a BadRequest)
        let raw = format!("POST /g HTTP/1.1\r\nContent-Length: {MAX_BODY_BYTES}\r\n\r\n");
        let err = roundtrip_res(&raw).unwrap_err();
        assert!(err.downcast_ref::<BadRequest>().is_none());
    }

    #[test]
    fn malformed_request_line_is_rejected() {
        expect_bad_request("   \r\n\r\n", "malformed request line");
    }

    #[test]
    fn session_route_matches_exact_segments_only() {
        assert_eq!(parse_session_route("/sessions/7/resume"), SessionRoute::Resume(7));
        assert_eq!(
            parse_session_route("/sessions/7/resume/"),
            SessionRoute::Resume(7),
            "one trailing slash is a formatting wobble, not a new resource"
        );
        assert_eq!(
            parse_session_route("/sessions/18446744073709551615/resume"),
            SessionRoute::Resume(u64::MAX)
        );
        assert_eq!(
            parse_session_route("/sessions/7/resume?verbose=1"),
            SessionRoute::Resume(7),
            "query strings are not part of route identity"
        );
    }

    #[test]
    fn session_route_distinguishes_malformed_from_unknown() {
        // Malformed ids hit the right route with a bad argument → 400;
        // a 404 here would misreport "no such session" for a request
        // that could never name one.
        for path in [
            "/sessions/abc/resume",
            "/sessions/-7/resume",
            "/sessions/7x/resume",
            "/sessions//resume",
            "/sessions/99999999999999999999999/resume", // > u64::MAX
        ] {
            match parse_session_route(path) {
                SessionRoute::Malformed(_) => {}
                other => panic!("{path} parsed as {other:?}, want Malformed"),
            }
        }
    }

    #[test]
    fn session_route_rejects_prefix_match_lookalikes() {
        // The prefix-match idiom (`path.starts_with("/sessions/")`) would
        // have swallowed every one of these.
        for path in [
            "/sessionsX/7/resume",
            "/sessions/7/resume/extra",
            "/sessions/7",
            "/sessions/7/pause",
            "/sessions",
            "/session/7/resume",
            "/x/sessions/7/resume",
            "/",
            "/generate",
        ] {
            assert_eq!(
                parse_session_route(path),
                SessionRoute::NotSession,
                "{path} must fall through to the server's 404"
            );
        }
    }

    #[test]
    fn chunked_stream_frames_and_terminates() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (mut conn, _) = listener.accept().unwrap();
            respond_chunked_head(&mut conn, 200, "application/x-ndjson").unwrap();
            write_chunk(&mut conn, "hello\n").unwrap();
            // empty deltas are skipped, NOT sent as the terminating chunk
            write_chunk(&mut conn, "").unwrap();
            write_chunk(&mut conn, "world\n").unwrap();
            finish_chunked(&mut conn).unwrap();
        });
        let mut s = TcpStream::connect(addr).unwrap();
        let mut raw = String::new();
        s.read_to_string(&mut raw).unwrap();
        server.join().unwrap();
        assert!(raw.starts_with("HTTP/1.1 200 OK\r\n"), "{raw}");
        assert!(raw.contains("Transfer-Encoding: chunked"), "{raw}");
        let body = &raw[raw.find("\r\n\r\n").unwrap() + 4..];
        assert_eq!(body, "6\r\nhello\n\r\n6\r\nworld\n\r\n0\r\n\r\n");
    }
}
