//! The serving layer: a minimal HTTP/1.1 server over `std::net` exposing
//! the Warp-Cortex orchestrator (no web-framework crates offline —
//! DESIGN §4).
//!
//! Since the multi-session refactor this is a **session layer**, not a
//! thread-per-episode front end:
//!
//! * Every `POST /generate` is admitted as a *session* — a schedulable
//!   unit over the shared weights and KV pool.  N concurrent requests'
//!   main decode steps fuse into the same per-tick device op in the
//!   [`crate::cortex::StepScheduler`]; there is no cross-request
//!   head-of-line blocking.
//! * Admission rules: sessions beyond `CortexConfig::max_sessions` park
//!   FIFO; beyond `max_parked_sessions` the server sheds load with a 503.
//!   Session admission also gates on KV-pool headroom for the prefill
//!   burst (with a [`crate::model::KvPool::reserve`] reservation closing
//!   the admit-then-rent race).
//! * Streaming protocol: `"stream": true` switches the response to
//!   chunked transfer encoding, `application/x-ndjson` — one
//!   `{"n": k, "delta": "..."}` line per token as ticks produce it, then
//!   one final summary line with `"done": true` (same fields as the
//!   non-streaming body).  Durable backends prepend a `{"session": id}`
//!   line announcing the resume id.
//! * Sessions are durable: a mid-stream disconnect *hibernates* the
//!   session (checkpoint to the store, KV parked to host) instead of
//!   cancelling it, and `POST /sessions/{id}/resume` reattaches —
//!   re-admitted first so a 503 never consumes the single-use record,
//!   then rebuilt with bit-identical logits.  Route matching is
//!   segment-exact with a typed 400/404 split: a malformed id is a 400
//!   (the route matched, the id didn't parse), an unknown path a 404.
//! * `GET /stats` carries a `sessions` gauge block
//!   (requested/admitted/rejected/completed/active/parked/occupancy) that
//!   reconciles: `admitted == completed + active`,
//!   `requested == admitted + rejected + parked` — plus a `prefill` block
//!   (chunks/ticks/budget_deferred/mid_prefix_hits) tracking the chunked
//!   prefill lanes interleaved with the decode tick, and a `store` block
//!   (checkpoints/resumes/preempt_to_disk/retained/…) whose ledger obeys
//!   `checkpoints == resumes + superseded + corrupt_records_skipped +
//!   retained`.  The operator-facing reference for every block is the
//!   handbook at [`crate::architecture`], CI-reconciled against the
//!   serializer by `rust/tests/docs_drift.rs`.
//! * `GET /metrics` renders the same snapshot in Prometheus text
//!   exposition format (version 0.0.4): every numeric leaf of the
//!   `/stats` document becomes one `warp_<path> <value>` sample via
//!   [`metrics_text`], so scrape dashboards can never drift from the
//!   JSON gauges.
//!
//! The substrate is generic over [`SessionSource`] so the HTTP paths are
//! testable host-only (`rust/tests/serve_sessions.rs` drives them over a
//! stub source backed by the real step scheduler).

pub mod http;
pub mod server;

pub use http::{parse_session_route, SessionRoute};
pub use server::{
    metrics_text, serve, sessions_json, store_json, OpenDenied, ResumeDenied, ServerConfig,
    ServerHandle, SessionSource, TokenStream,
};
