//! The serving layer: a minimal HTTP/1.1 server over `std::net` exposing
//! the Warp-Cortex orchestrator (no web-framework crates offline —
//! DESIGN §4).
//!
//! Since the multi-session refactor this is a **session layer**, not a
//! thread-per-episode front end:
//!
//! * Every `POST /generate` is admitted as a *session* — a schedulable
//!   unit over the shared weights and KV pool.  N concurrent requests'
//!   main decode steps fuse into the same per-tick device op in the
//!   [`crate::cortex::StepScheduler`]; there is no cross-request
//!   head-of-line blocking.
//! * Admission rules: sessions beyond `CortexConfig::max_sessions` park
//!   FIFO; beyond `max_parked_sessions` the server sheds load with a 503.
//!   Session admission also gates on KV-pool headroom for the prefill
//!   burst (with a [`crate::model::KvPool::reserve`] reservation closing
//!   the admit-then-rent race).
//! * Streaming protocol: `"stream": true` switches the response to
//!   chunked transfer encoding, `application/x-ndjson` — one
//!   `{"n": k, "delta": "..."}` line per token as ticks produce it, then
//!   one final summary line with `"done": true` (same fields as the
//!   non-streaming body).  A mid-stream disconnect cancels only that
//!   session.
//! * `GET /stats` carries a `sessions` gauge block
//!   (requested/admitted/rejected/completed/active/parked/occupancy) that
//!   reconciles: `admitted == completed + active`,
//!   `requested == admitted + rejected + parked` — plus a `prefill` block
//!   (chunks/ticks/budget_deferred/mid_prefix_hits) tracking the chunked
//!   prefill lanes interleaved with the decode tick.
//! * `GET /metrics` renders the same snapshot in Prometheus text
//!   exposition format (version 0.0.4): every numeric leaf of the
//!   `/stats` document becomes one `warp_<path> <value>` sample via
//!   [`metrics_text`], so scrape dashboards can never drift from the
//!   JSON gauges.
//!
//! The substrate is generic over [`SessionSource`] so the HTTP paths are
//! testable host-only (`rust/tests/serve_sessions.rs` drives them over a
//! stub source backed by the real step scheduler).

pub mod http;
pub mod server;

pub use server::{
    metrics_text, serve, sessions_json, OpenDenied, ServerConfig, ServerHandle, SessionSource,
    TokenStream,
};
