//! The serving layer: a minimal HTTP/1.1 server over `std::net` exposing the
//! Warp-Cortex orchestrator (no web-framework crates offline — DESIGN §4).

pub mod http;
pub mod server;

pub use server::{serve, ServerConfig};
