//! Serving metrics: counters, histograms with percentile queries, and
//! windowed throughput meters.  Everything is cheap enough for the decode
//! hot loop (atomics + a mutex-guarded histogram with bounded buckets).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::util::sync::{LockRank, RankedMutex};

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Log-bucketed latency histogram (ns).  ~60 buckets cover 1 ns .. 1000 s
/// with <8% relative error — plenty for p50/p95/p99 reporting.
#[derive(Debug)]
pub struct Histogram {
    buckets: RankedMutex<Vec<u64>>,
    count: AtomicU64,
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

const BUCKETS_PER_DECADE: usize = 5;
const DECADES: usize = 12;
const NBUCKETS: usize = BUCKETS_PER_DECADE * DECADES;

fn bucket_of(ns: u64) -> usize {
    if ns == 0 {
        return 0;
    }
    let log10 = (ns as f64).log10();
    let idx = (log10 * BUCKETS_PER_DECADE as f64) as usize;
    idx.min(NBUCKETS - 1)
}

fn bucket_upper_ns(idx: usize) -> f64 {
    10f64.powf((idx + 1) as f64 / BUCKETS_PER_DECADE as f64)
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: RankedMutex::new(LockRank::Metrics, vec![0; NBUCKETS]),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    pub fn record_ns(&self, ns: u64) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.max_ns.fetch_max(ns, Ordering::Relaxed);
        let mut b = self.buckets.lock();
        b[bucket_of(ns)] += 1;
    }

    pub fn record(&self, d: std::time::Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_ns(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_ns.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns.load(Ordering::Relaxed)
    }

    /// Approximate percentile in ns (`p` in [0, 100]).
    pub fn percentile_ns(&self, p: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (p / 100.0 * total as f64).ceil().max(1.0) as u64;
        let b = self.buckets.lock();
        let mut cum = 0u64;
        for (i, n) in b.iter().enumerate() {
            cum += n;
            if cum >= target {
                return bucket_upper_ns(i);
            }
        }
        bucket_upper_ns(NBUCKETS - 1)
    }

    pub fn summary(&self) -> HistSummary {
        HistSummary {
            count: self.count(),
            mean_ns: self.mean_ns(),
            p50_ns: self.percentile_ns(50.0),
            p95_ns: self.percentile_ns(95.0),
            p99_ns: self.percentile_ns(99.0),
            max_ns: self.max_ns(),
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct HistSummary {
    pub count: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    pub max_ns: u64,
}

/// Events-per-second meter over the process lifetime plus a sliding window.
#[derive(Debug)]
pub struct Throughput {
    start: Instant,
    events: Counter,
    window: RankedMutex<Vec<Instant>>,
    window_cap: usize,
}

impl Throughput {
    pub fn new() -> Throughput {
        Throughput {
            start: Instant::now(),
            events: Counter::default(),
            window: RankedMutex::new(LockRank::Metrics, Vec::new()),
            window_cap: 4096,
        }
    }

    pub fn tick(&self) {
        self.events.inc();
        let mut w = self.window.lock();
        w.push(Instant::now());
        if w.len() > self.window_cap {
            let drop_n = w.len() - self.window_cap;
            w.drain(..drop_n);
        }
    }

    pub fn total(&self) -> u64 {
        self.events.get()
    }

    /// Average rate since construction.
    pub fn overall_per_sec(&self) -> f64 {
        let dt = self.start.elapsed().as_secs_f64();
        if dt <= 0.0 {
            0.0
        } else {
            self.events.get() as f64 / dt
        }
    }

    /// Rate over the last `secs` seconds (from the sliding window).
    pub fn recent_per_sec(&self, secs: f64) -> f64 {
        let cutoff = Instant::now() - std::time::Duration::from_secs_f64(secs);
        let w = self.window.lock();
        let n = w.iter().rev().take_while(|t| **t >= cutoff).count();
        n as f64 / secs
    }
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_counts() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_percentiles_ordered() {
        let h = Histogram::new();
        for i in 1..=1000u64 {
            h.record_ns(i * 1000); // 1µs .. 1ms uniform
        }
        let s = h.summary();
        assert_eq!(s.count, 1000);
        assert!(s.p50_ns <= s.p95_ns && s.p95_ns <= s.p99_ns);
        // p50 should land near 500µs (within bucket error)
        assert!(s.p50_ns > 3e5 && s.p50_ns < 8e5, "p50={}", s.p50_ns);
        assert_eq!(s.max_ns, 1_000_000);
    }

    #[test]
    fn histogram_empty() {
        let h = Histogram::new();
        assert_eq!(h.percentile_ns(99.0), 0.0);
        assert_eq!(h.mean_ns(), 0.0);
    }

    #[test]
    fn throughput_total() {
        let t = Throughput::new();
        for _ in 0..10 {
            t.tick();
        }
        assert_eq!(t.total(), 10);
        assert!(t.overall_per_sec() > 0.0);
        assert!(t.recent_per_sec(10.0) >= 1.0);
    }

    #[test]
    fn bucket_monotone() {
        assert!(bucket_of(10) <= bucket_of(100));
        assert!(bucket_of(1_000_000) < bucket_of(100_000_000));
        assert_eq!(bucket_of(0), 0);
    }
}
