//! Minimal JSON: parser, writer and a small accessor API.
//!
//! Hand-rolled because `serde`/`serde_json` are unavailable offline.  Covers
//! the full JSON grammar (objects, arrays, strings with escapes, numbers,
//! bools, null) — enough for the artifact manifest, golden vectors, the HTTP
//! API and bench reports.  Object insertion order is preserved.

use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

/// Error raised by [`Json::parse`], with a byte offset into the input.
/// (Display/Error are hand-implemented — `thiserror` is not among this
/// crate's offline dependencies.)
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ── Constructors ───────────────────────────────────────────────────

    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Builder-style insert for object values.
    pub fn with(mut self, key: &str, value: impl Into<Json>) -> Json {
        if let Json::Obj(pairs) = &mut self {
            pairs.push((key.to_string(), value.into()));
        }
        self
    }

    pub fn set(&mut self, key: &str, value: impl Into<Json>) {
        if let Json::Obj(pairs) = self {
            if let Some(p) = pairs.iter_mut().find(|(k, _)| k == key) {
                p.1 = value.into();
            } else {
                pairs.push((key.to_string(), value.into()));
            }
        }
    }

    // ── Accessors ──────────────────────────────────────────────────────

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required manifest fields.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key `{key}`"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn members(&self) -> &[(String, Json)] {
        match self {
            Json::Obj(pairs) => pairs,
            _ => &[],
        }
    }

    /// Array of numbers → `Vec<f64>` (errors on any non-number element).
    pub fn num_vec(&self) -> anyhow::Result<Vec<f64>> {
        let arr = self
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("expected json array"))?;
        arr.iter()
            .map(|v| v.as_f64().ok_or_else(|| anyhow::anyhow!("expected number")))
            .collect()
    }

    pub fn usize_vec(&self) -> anyhow::Result<Vec<usize>> {
        Ok(self.num_vec()?.into_iter().map(|n| n as usize).collect())
    }

    // ── Parsing ────────────────────────────────────────────────────────

    pub fn parse(input: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            bytes: input.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

// ── From conversions for ergonomic construction ─────────────────────────

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i32> for Json {
    fn from(n: i32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl From<&[f32]> for Json {
    fn from(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a json value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            pairs.push((key, self.value()?));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len()
                        && (self.bytes[self.pos] & 0xC0) == 0x80
                    {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

// ── Serialisation ────────────────────────────────────────────────────────

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_num(n: f64, out: &mut String) {
    if n.is_finite() && n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{n}"));
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write_into(&mut s);
        f.write_str(&s)
    }
}

impl Json {
    pub fn write_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => escape_into(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_into(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(k, out);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".to_string())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("c")
        );
        assert!(v.get("d").unwrap().members().is_empty());
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(
            Json::parse("\"\\u0041\\u00e9\"").unwrap(),
            Json::Str("Aé".to_string())
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"x":[1,2.5,-3],"y":"he\"llo","z":null,"w":true}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn builder_and_accessors() {
        let v = Json::obj().with("n", 5usize).with("s", "hi");
        assert_eq!(v.get("n").unwrap().as_usize(), Some(5));
        assert_eq!(v.req("s").unwrap().as_str(), Some("hi"));
        assert!(v.req("missing").is_err());
    }
}
