//! Tiny CLI argument parser (no `clap` offline).
//!
//! Grammar: `prog <subcommand> [--key value]... [--flag]... [positional]...`
//! Flags and options may be interleaved; `--key=value` is accepted.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) — first element is argv[0].
    pub fn parse_from<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut it = argv.into_iter().skip(1).peekable();
        let mut out = Args::default();
        // subcommand = first non-flag token
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.options.insert(rest.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(rest.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn parse() -> Args {
        Self::parse_from(std::env::args())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(
            std::iter::once("prog".to_string()).chain(s.split_whitespace().map(String::from)),
        )
    }

    #[test]
    fn subcommand_options_flags() {
        let a = parse("serve --port 8080 --verbose --config=small extra");
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get_usize("port", 0), 8080);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("config"), Some("small"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_or("model", "tiny"), "tiny");
        assert_eq!(a.get_usize("n", 7), 7);
        assert!(!a.flag("x"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("x --a --b v");
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("v"));
    }
}
