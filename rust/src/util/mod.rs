//! Substrate utilities built from scratch (offline environment: no serde,
//! clap, rand or proptest — DESIGN.md §4 lists the substitutions).

pub mod args;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod sync;
pub mod timer;
pub mod vecmath;

pub use json::Json;
pub use rng::XorShift;
