//! Mini property-testing framework (`proptest` is unavailable offline —
//! DESIGN.md §4).  Seeded generators + a check loop that reports the failing
//! case and its seed, so failures are reproducible.
//!
//! ```ignore
//! use warp_cortex::util::proptest::{check, Gen};
//! check("sort is idempotent", 200, |g| {
//!     let v = g.vec_i64(0..50, -100..100);
//!     let mut a = v.clone();
//!     a.sort();
//!     let mut b = a.clone();
//!     b.sort();
//!     prop_assert!(a == b, "double sort differs: {v:?}");
//!     Ok(())
//! });
//! ```

use super::rng::XorShift;
use std::ops::Range;

/// Per-case random value source.
pub struct Gen {
    rng: XorShift,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        assert!(r.end > r.start);
        r.start + self.rng.below((r.end - r.start) as u64) as usize
    }

    pub fn i64_in(&mut self, r: Range<i64>) -> i64 {
        assert!(r.end > r.start);
        r.start + self.rng.below((r.end - r.start) as u64) as i64
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range_f32(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.below(2) == 1
    }

    pub fn vec_f32(&mut self, len: Range<usize>, lo: f32, hi: f32) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_i64(&mut self, len: Range<usize>, range: Range<i64>) -> Vec<i64> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.i64_in(range.clone())).collect()
    }

    pub fn vec_usize(&mut self, len: Range<usize>, range: Range<usize>) -> Vec<usize> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.usize_in(range.clone())).collect()
    }

    /// ASCII string drawn from the given alphabet.
    pub fn string_from(&mut self, len: Range<usize>, alphabet: &[u8]) -> String {
        let n = self.usize_in(len);
        (0..n)
            .map(|_| *self.rng.choice(alphabet) as char)
            .collect()
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        self.rng.choice(items)
    }
}

/// Case-count multiplier from the `WARP_PROPTEST_MULT` env var (default
/// 1).  The scheduled deep-proptest CI job sets it to ~20 to rerun every
/// property at elevated depth without slowing the PR path.
pub fn case_multiplier() -> usize {
    std::env::var("WARP_PROPTEST_MULT")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|m| *m > 0)
        .unwrap_or(1)
}

/// Run `cases` random cases of `property` (times [`case_multiplier`]).
/// Panics (with seed + case index) on the first failure.  The
/// `WARP_PROPTEST_SEED` env var pins the base seed for reproduction.
pub fn check<F>(name: &str, cases: usize, mut property: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base_seed: u64 = std::env::var("WARP_PROPTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE);
    let cases = cases * case_multiplier();
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut g = Gen {
            rng: XorShift::new(seed),
            case,
        };
        if let Err(msg) = property(&mut g) {
            panic!(
                "property `{name}` failed at case {case} \
                 (WARP_PROPTEST_SEED={base_seed}): {msg}"
            );
        }
    }
}

/// `prop_assert!(cond, "format", args...)` — returns `Err(String)` instead of
/// panicking so `check` can attach the case context.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !($cond) {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut ran = 0;
        check("reverse twice", 50, |g| {
            ran += 1;
            let v = g.vec_i64(0..20, -5..5);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            prop_assert!(v == w, "mismatch");
            Ok(())
        });
        // the deep-proptest CI job scales every property via the env var
        assert_eq!(ran, 50 * case_multiplier());
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn failing_property_panics_with_context() {
        check("always fails", 10, |_g| Err("nope".to_string()));
    }

    #[test]
    fn generators_respect_ranges() {
        check("ranges", 100, |g| {
            let u = g.usize_in(3..10);
            prop_assert!((3..10).contains(&u), "usize out of range: {u}");
            let f = g.f32_in(-1.0, 1.0);
            prop_assert!((-1.0..1.0).contains(&f), "f32 out of range: {f}");
            let s = g.string_from(0..8, b"ab");
            prop_assert!(s.chars().all(|c| c == 'a' || c == 'b'), "bad string {s}");
            Ok(())
        });
    }
}
