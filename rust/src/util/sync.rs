//! Poison-tolerant lock helpers and the ranked-lock deadlock detector.
//!
//! # Poison tolerance
//!
//! A worker that panics while holding a `Mutex` poisons it; every later
//! `lock().unwrap()` then panics too, cascading one agent's failure into
//! the whole serving loop (the step scheduler, the legacy batcher and the
//! stream worker pool all share locks across agent threads).  The locks
//! these helpers guard protect *restartable* state — channels, join
//! handles, task queues, pool bookkeeping — so the right response to
//! poison is to recover the guard and keep serving: the panicking
//! caller's own request surfaces as an `Err`/`Failed` outcome through the
//! normal reply path, and nobody else inherits the panic.  The
//! `poison-cascade` rule in `warp-audit` enforces that production code
//! reaches locks only through this module.
//!
//! # Lock ranks
//!
//! [`RankedMutex`] additionally encodes the crate's global lock hierarchy
//! (see [`LockRank`]).  The convention is **acquire-descending**: a thread
//! may acquire a ranked lock only while every lock it already holds has a
//! *strictly higher* rank.  Outer (coarse, long-held) locks therefore
//! carry high ranks and leaf locks low ranks, and any two threads that
//! both obey the rule can never deadlock on ranked mutexes: a cycle would
//! require someone to acquire upward.
//!
//! Under `debug_assertions` each thread keeps a held-rank stack and an
//! out-of-order acquisition panics immediately, naming both the rank
//! being acquired and the lowest rank already held — turning a
//! probabilistic deadlock hang into a deterministic test failure.  In
//! release builds the bookkeeping compiles out entirely and
//! `RankedMutex::lock` is exactly `lock_unpoisoned`.

use std::ops::{Deref, DerefMut};
use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// `Condvar::wait` that recovers the guard on poison instead of panicking.
pub fn wait_unpoisoned<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

/// `Condvar::wait_timeout` that recovers the guard on poison instead of
/// panicking (the timeout flag is dropped — callers re-check their
/// condition and their own deadline, which is the correct pattern against
/// spurious wakeups anyway).
pub fn wait_timeout_unpoisoned<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: std::time::Duration,
) -> MutexGuard<'a, T> {
    match cv.wait_timeout(guard, timeout) {
        Ok((g, _)) => g,
        Err(e) => e.into_inner().0,
    }
}

/// The crate-wide lock hierarchy, innermost (leaf) first.
///
/// A thread holding a lock of rank `R` may only acquire locks of rank
/// strictly *below* `R`.  Reading top to bottom: device queues are the
/// innermost locks (anyone may take them last), the process-lifetime
/// registries are the outermost.  The six core levels the runtime is
/// built on — device queues < pool state < scheduler session table <
/// side-results map < prism agents < metrics — appear here with two
/// plumbing levels (`SchedulerQueue`, `Registry`) slotted in.
///
/// Discriminants are spaced so future levels can land between existing
/// ones without renumbering call sites.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum LockRank {
    /// Innermost: per-device service queues (`runtime::device`).  Taken
    /// on every op submission; nothing may be acquired under them.
    DeviceQueue = 0,
    /// The KV pool's slab + prefix-registry state (`model::pool`).
    /// Acquired under the session table by the admission gate.
    PoolState = 10,
    /// Scheduler plumbing: command senders, result receivers and join
    /// handles in `cortex::{step,scheduler,batcher}`.
    SchedulerQueue = 20,
    /// The step scheduler's session table (`cortex::step::SessionTable`)
    /// — held across admission, which locks the pool underneath.
    SessionTable = 30,
    /// The per-session side-results map (`cortex::step`).
    SideResults = 40,
    /// The prism agent registry and the synapse memory guard
    /// (`cortex::{prism,synapse}`).  Ticket drop releases pool blocks
    /// underneath this rank.
    PrismAgents = 50,
    /// Metrics sinks (`metrics::{Histogram,Throughput}`).  Recorded from
    /// code that holds no other ranked lock or only `Registry`.
    Metrics = 60,
    /// Outermost: process-lifetime registries — the live-device table in
    /// `runtime::device` (held while shutting down per-device queues)
    /// and the serve layer's accept-queue handoff.
    Registry = 70,
}

impl LockRank {
    /// Every rank, innermost first.  `warp-audit`'s static `lock-order`
    /// pass parses the enum declaration above out of this file's source
    /// and asserts the parsed table equal to this one
    /// (`rust/tests/audit_roundtrip.rs`), so the static analyzer and the
    /// runtime detector can never drift.
    pub const ALL: [LockRank; 8] = [
        LockRank::DeviceQueue,
        LockRank::PoolState,
        LockRank::SchedulerQueue,
        LockRank::SessionTable,
        LockRank::SideResults,
        LockRank::PrismAgents,
        LockRank::Metrics,
        LockRank::Registry,
    ];

    /// The variant's source-level name, as the static pass sees it.
    pub const fn name(self) -> &'static str {
        match self {
            LockRank::DeviceQueue => "DeviceQueue",
            LockRank::PoolState => "PoolState",
            LockRank::SchedulerQueue => "SchedulerQueue",
            LockRank::SessionTable => "SessionTable",
            LockRank::SideResults => "SideResults",
            LockRank::PrismAgents => "PrismAgents",
            LockRank::Metrics => "Metrics",
            LockRank::Registry => "Registry",
        }
    }
}

#[cfg(debug_assertions)]
mod held {
    use super::LockRank;
    use std::cell::RefCell;

    thread_local! {
        static HELD: RefCell<Vec<LockRank>> = const { RefCell::new(Vec::new()) };
    }

    pub fn acquire(rank: LockRank) {
        HELD.with(|h| {
            let mut h = h.borrow_mut();
            if let Some(&floor) = h.iter().min() {
                assert!(
                    rank < floor,
                    "lock-rank violation: acquiring {rank:?} (rank {}) while holding \
                     {floor:?} (rank {}); ranked locks must be acquired in strictly \
                     descending rank order — see util::sync::LockRank",
                    rank as u8,
                    floor as u8,
                );
            }
            h.push(rank);
        });
    }

    pub fn release(rank: LockRank) {
        HELD.with(|h| {
            let mut h = h.borrow_mut();
            // Remove the *last* occurrence: guards may be dropped out of
            // declaration order, but rank release is by value so the
            // stack stays consistent either way.
            if let Some(i) = h.iter().rposition(|&r| r == rank) {
                h.remove(i);
            }
        });
    }
}

#[cfg(not(debug_assertions))]
mod held {
    use super::LockRank;
    #[inline(always)]
    pub fn acquire(_rank: LockRank) {}
    #[inline(always)]
    pub fn release(_rank: LockRank) {}
}

/// A poison-tolerant mutex that enforces the global [`LockRank`]
/// hierarchy under `debug_assertions`.
///
/// In release builds this is a zero-cost wrapper over
/// [`lock_unpoisoned`]; in debug builds every acquisition is checked
/// against the thread's held-rank stack and an inversion panics with
/// both ranks named.
pub struct RankedMutex<T> {
    rank: LockRank,
    inner: Mutex<T>,
}

impl<T> RankedMutex<T> {
    /// `const` so ranked mutexes can back process-lifetime `static`s
    /// (e.g. the live-device registry).
    pub const fn new(rank: LockRank, value: T) -> RankedMutex<T> {
        RankedMutex {
            rank,
            inner: Mutex::new(value),
        }
    }

    /// Acquire the lock: rank-checked (debug) and poison-tolerant.
    ///
    /// The rank check runs *before* blocking on the inner mutex, so an
    /// inversion panics instead of demonstrating the deadlock it guards
    /// against.
    pub fn lock(&self) -> RankedGuard<'_, T> {
        held::acquire(self.rank);
        RankedGuard {
            inner: Some(lock_unpoisoned(&self.inner)),
            rank: self.rank,
        }
    }

    pub fn rank(&self) -> LockRank {
        self.rank
    }

    /// Whether a holder has panicked with the lock held.  Ranked locks
    /// keep serving after poison; this is observability for tests and
    /// `/stats`.
    pub fn is_poisoned(&self) -> bool {
        self.inner.is_poisoned()
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for RankedMutex<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("RankedMutex")
            .field("rank", &self.rank)
            .finish_non_exhaustive()
    }
}

/// Guard for a [`RankedMutex`]; pops the rank off the thread's held
/// stack on drop.
pub struct RankedGuard<'a, T> {
    // `Option` so `ranked_wait` can move the inner guard out without
    // running the rank-release twice.
    inner: Option<MutexGuard<'a, T>>,
    rank: LockRank,
}

impl<T> Deref for RankedGuard<'_, T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("guard present")
    }
}

impl<T> DerefMut for RankedGuard<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("guard present")
    }
}

impl<T> Drop for RankedGuard<'_, T> {
    fn drop(&mut self) {
        if self.inner.is_some() {
            held::release(self.rank);
        }
    }
}

/// `Condvar::wait` over a [`RankedGuard`]: the rank is released for the
/// duration of the wait (the mutex is unlocked while blocked) and
/// re-checked on wakeup.  Poison-tolerant like [`wait_unpoisoned`].
pub fn ranked_wait<'a, T>(cv: &Condvar, mut guard: RankedGuard<'a, T>) -> RankedGuard<'a, T> {
    let rank = guard.rank;
    let inner = guard.inner.take().expect("guard present");
    held::release(rank);
    drop(guard);
    let inner = cv.wait(inner).unwrap_or_else(PoisonError::into_inner);
    held::acquire(rank);
    RankedGuard {
        inner: Some(inner),
        rank,
    }
}

/// `Condvar::wait_timeout` over a [`RankedGuard`]; same contract as
/// [`wait_timeout_unpoisoned`] (timeout flag dropped, callers re-check
/// their condition and deadline).
pub fn ranked_wait_timeout<'a, T>(
    cv: &Condvar,
    mut guard: RankedGuard<'a, T>,
    timeout: std::time::Duration,
) -> RankedGuard<'a, T> {
    let rank = guard.rank;
    let inner = guard.inner.take().expect("guard present");
    held::release(rank);
    drop(guard);
    let inner = match cv.wait_timeout(inner, timeout) {
        Ok((g, _)) => g,
        Err(e) => e.into_inner().0,
    };
    held::acquire(rank);
    RankedGuard {
        inner: Some(inner),
        rank,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        // lock_unpoisoned still hands out the data
        assert_eq!(*lock_unpoisoned(&m), 7);
        *lock_unpoisoned(&m) = 9;
        assert_eq!(*lock_unpoisoned(&m), 9);
    }

    #[test]
    fn ranked_mutex_recovers_from_poison() {
        let m = Arc::new(RankedMutex::new(LockRank::PoolState, 3usize));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        *m.lock() = 11;
        assert_eq!(*m.lock(), 11);
    }

    #[test]
    fn descending_acquisition_is_legal() {
        let outer = RankedMutex::new(LockRank::SessionTable, ());
        let inner = RankedMutex::new(LockRank::PoolState, ());
        let leaf = RankedMutex::new(LockRank::DeviceQueue, ());
        let g1 = outer.lock();
        let g2 = inner.lock();
        let g3 = leaf.lock();
        // Out-of-order *release* must also be fine.
        drop(g2);
        drop(g3);
        drop(g1);
        // And the stack must be clean afterwards: re-acquiring the
        // outermost rank succeeds.
        let _g = outer.lock();
    }

    #[cfg(debug_assertions)]
    #[test]
    fn inverted_acquisition_panics_naming_both_ranks() {
        let err = std::thread::spawn(|| {
            let inner = RankedMutex::new(LockRank::PoolState, ());
            let outer = RankedMutex::new(LockRank::SessionTable, ());
            let _g1 = inner.lock();
            let _g2 = outer.lock(); // inversion: 30 acquired while holding 10
        })
        .join()
        .expect_err("inverted acquisition must panic");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| err.downcast_ref::<&str>().unwrap_or(&"").to_string());
        assert!(msg.contains("lock-rank violation"), "got: {msg}");
        assert!(msg.contains("SessionTable"), "got: {msg}");
        assert!(msg.contains("PoolState"), "got: {msg}");
    }

    #[cfg(debug_assertions)]
    #[test]
    fn equal_rank_nesting_panics() {
        let err = std::thread::spawn(|| {
            let a = RankedMutex::new(LockRank::Metrics, ());
            let b = RankedMutex::new(LockRank::Metrics, ());
            let _g1 = a.lock();
            let _g2 = b.lock();
        })
        .join()
        .expect_err("equal-rank nesting must panic");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| err.downcast_ref::<&str>().unwrap_or(&"").to_string());
        assert!(msg.contains("lock-rank violation"), "got: {msg}");
    }

    #[test]
    fn ranked_wait_timeout_releases_and_reacquires_rank() {
        let m = RankedMutex::new(LockRank::SessionTable, 0u32);
        let cv = Condvar::new();
        let g = m.lock();
        let g = ranked_wait_timeout(&cv, g, std::time::Duration::from_millis(5));
        assert_eq!(*g, 0);
        drop(g);
        // Stack must be balanced: outer rank re-acquirable.
        let _g = m.lock();
    }

    #[test]
    fn ranked_wait_wakes_on_notify() {
        let pair = Arc::new((RankedMutex::new(LockRank::SideResults, false), Condvar::new()));
        let p2 = pair.clone();
        let h = std::thread::spawn(move || {
            let (m, cv) = &*p2;
            let mut g = m.lock();
            *g = true;
            drop(g);
            cv.notify_one();
        });
        let (m, cv) = &*pair;
        let mut g = m.lock();
        while !*g {
            g = ranked_wait(cv, g);
        }
        assert!(*g);
        drop(g);
        h.join().unwrap();
    }
}
