//! Poison-tolerant lock helpers for the decode path.
//!
//! A worker that panics while holding a `Mutex` poisons it; every later
//! `lock().unwrap()` then panics too, cascading one agent's failure into
//! the whole serving loop (the step scheduler, the legacy batcher and the
//! stream worker pool all share locks across agent threads).  The locks
//! these helpers guard protect *restartable* state — channels, join
//! handles, task queues — so the right response to poison is to recover
//! the guard and keep serving: the panicking caller's own request surfaces
//! as an `Err`/`Failed` outcome through the normal reply path, and nobody
//! else inherits the panic.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// `Condvar::wait` that recovers the guard on poison instead of panicking.
pub fn wait_unpoisoned<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(PoisonError::into_inner)
}

/// `Condvar::wait_timeout` that recovers the guard on poison instead of
/// panicking (the timeout flag is dropped — callers re-check their
/// condition and their own deadline, which is the correct pattern against
/// spurious wakeups anyway).
pub fn wait_timeout_unpoisoned<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: std::time::Duration,
) -> MutexGuard<'a, T> {
    match cv.wait_timeout(guard, timeout) {
        Ok((g, _)) => g,
        Err(e) => e.into_inner().0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7usize));
        let m2 = m.clone();
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison it");
        })
        .join();
        assert!(m.is_poisoned());
        // lock_unpoisoned still hands out the data
        assert_eq!(*lock_unpoisoned(&m), 7);
        *lock_unpoisoned(&m) = 9;
        assert_eq!(*lock_unpoisoned(&m), 9);
    }
}
