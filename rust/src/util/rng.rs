//! Deterministic xorshift64* PRNG — mirrors `python/compile/corpus.py` so the
//! two sides can generate identical workloads.  Used for sampling, workload
//! generation and the property-test framework (no `rand` crate offline).

/// xorshift64* with the same constants as the Python build path.
#[derive(Debug, Clone)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    pub fn new(seed: u64) -> Self {
        Self {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Current generator state, for checkpointing (`cortex::store`).
    /// Round-trips through [`XorShift::from_state`] bit-exactly.
    pub fn state(&self) -> u64 {
        self.state
    }

    /// Rebuild a generator at a previously captured [`XorShift::state`].
    /// Unlike [`XorShift::new`], zero is preserved verbatim — a captured
    /// state is already post-seed-mapping and must not be remapped.
    pub fn from_state(state: u64) -> Self {
        Self { state }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, n)`.  `n` must be non-zero.
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.unit() as f32
    }

    /// Exponentially distributed sample with the given rate (for Poisson
    /// arrival processes in the workload generator).
    pub fn exp(&mut self, rate: f64) -> f64 {
        let u = self.unit().max(1e-12);
        -u.ln() / rate
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len() as u64) as usize]
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.unit().max(1e-12);
        let u2 = self.unit();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_python_reference() {
        // First three outputs of compile/corpus.py's XorShift(7).
        let mut r = XorShift::new(7);
        let a = r.next_u64();
        let b = r.next_u64();
        let c = r.next_u64();
        // Recompute the python algorithm inline to lock the semantics.
        let mut state: u64 = 7;
        let mut py = || {
            let mut x = state;
            x ^= x >> 12;
            x = x ^ (x << 25);
            x ^= x >> 27;
            state = x;
            x.wrapping_mul(0x2545F4914F6CDD1D)
        };
        assert_eq!(a, py());
        assert_eq!(b, py());
        assert_eq!(c, py());
    }

    #[test]
    fn deterministic_and_spread() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // unit() stays in range and isn't constant
        let mut seen_low = false;
        let mut seen_high = false;
        for _ in 0..1000 {
            let u = a.unit();
            assert!((0.0..1.0).contains(&u));
            seen_low |= u < 0.4;
            seen_high |= u > 0.6;
        }
        assert!(seen_low && seen_high);
    }

    #[test]
    fn exp_mean_roughly_inverse_rate() {
        let mut r = XorShift::new(9);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.exp(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.02, "mean {mean}");
    }
}
