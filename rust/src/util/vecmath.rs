//! Small dense-vector helpers used on the coordinator hot path (validation
//! gate, sampler, memory projections).  Everything is f32 row-major.

/// Cosine similarity between two equal-length vectors (paper Eq. 2, the
/// Validation Gate score).  Returns 0 when either vector is all-zero.
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut dot = 0.0f64;
    let mut na = 0.0f64;
    let mut nb = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        dot += (*x as f64) * (*y as f64);
        na += (*x as f64) * (*x as f64);
        nb += (*y as f64) * (*y as f64);
    }
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    (dot / (na.sqrt() * nb.sqrt())) as f32
}

/// Index of the maximum element (first on ties).  Panics on empty input.
pub fn argmax(v: &[f32]) -> usize {
    let mut best = 0;
    for (i, x) in v.iter().enumerate() {
        if *x > v[best] {
            best = i;
        }
    }
    best
}

/// In-place numerically-stable softmax.
pub fn softmax_inplace(v: &mut [f32]) {
    let m = v.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for x in v.iter_mut() {
        *x = (*x - m).exp();
        sum += *x;
    }
    if sum > 0.0 {
        for x in v.iter_mut() {
            *x /= sum;
        }
    }
}

/// log-sum-exp of a slice (stable).
pub fn logsumexp(v: &[f32]) -> f32 {
    let m = v.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return m;
    }
    m + v.iter().map(|x| (x - m).exp()).sum::<f32>().ln()
}

/// Euclidean distance squared.
pub fn dist2(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = x - y;
            d * d
        })
        .sum()
}

/// Mean of a slice (0 for empty).
pub fn mean(v: &[f32]) -> f32 {
    if v.is_empty() {
        0.0
    } else {
        v.iter().sum::<f32>() / v.len() as f32
    }
}

/// Percentile (nearest-rank) over an unsorted slice; `p` in [0, 100].
pub fn percentile(values: &[f64], p: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mut sorted = values.to_vec();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cosine_basics() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!((cosine(&[1.0, 0.0], &[0.0, 1.0])).abs() < 1e-6);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-6);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 1.0]), 0.0);
    }

    #[test]
    fn cosine_scale_invariant() {
        let a = [0.3, -1.2, 2.5, 0.01];
        let b = [1.1, 0.4, -0.2, 0.9];
        let scaled: Vec<f32> = a.iter().map(|x| x * 37.5).collect();
        assert!((cosine(&a, &b) - cosine(&scaled, &b)).abs() < 1e-5);
    }

    #[test]
    fn argmax_and_softmax() {
        assert_eq!(argmax(&[0.1, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0, 5.0]), 0);
        let mut v = [1.0f32, 2.0, 3.0];
        softmax_inplace(&mut v);
        assert!((v.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(v[2] > v[1] && v[1] > v[0]);
    }

    #[test]
    fn softmax_handles_large_values() {
        let mut v = [1000.0f32, 1001.0];
        softmax_inplace(&mut v);
        assert!((v.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(v[1] > v[0]);
    }

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=101).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 50.0), 51.0);
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 100.0), 101.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn logsumexp_stable() {
        let v = [1000.0f32, 1000.0];
        let out = logsumexp(&v);
        assert!((out - (1000.0 + 2f32.ln())).abs() < 1e-3);
    }
}
