//! Timing helpers for the bench harness (no `criterion` offline; DESIGN §4).
//!
//! `bench_median` follows criterion's discipline: warmup phase, then N timed
//! iterations, reporting median / p10 / p90 — robust to scheduler noise.

use std::time::{Duration, Instant};

/// Result of a [`bench_median`] run.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub iters: usize,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
    pub mean_ns: f64,
}

impl BenchStats {
    pub fn median(&self) -> Duration {
        Duration::from_nanos(self.median_ns as u64)
    }

    /// Ops/sec at the median.
    pub fn throughput(&self) -> f64 {
        if self.median_ns <= 0.0 {
            0.0
        } else {
            1e9 / self.median_ns
        }
    }

    pub fn format_time(&self) -> String {
        format_ns(self.median_ns)
    }
}

pub fn format_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark `f`, returning robust statistics.
///
/// Runs `warmup` untimed iterations, then `iters` timed ones.
pub fn bench_median<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<f64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let at = |p: f64| samples[((p * (samples.len() - 1) as f64).round() as usize).min(samples.len() - 1)];
    BenchStats {
        iters,
        median_ns: at(0.5),
        p10_ns: at(0.1),
        p90_ns: at(0.9),
        mean_ns: samples.iter().sum::<f64>() / samples.len() as f64,
    }
}

/// Simple scope timer: `let _t = ScopeTimer::new("phase");` prints on drop.
pub struct ScopeTimer {
    label: String,
    start: Instant,
}

impl ScopeTimer {
    pub fn new(label: &str) -> Self {
        Self {
            label: label.to_string(),
            start: Instant::now(),
        }
    }
}

impl Drop for ScopeTimer {
    fn drop(&mut self) {
        eprintln!(
            "[timer] {}: {}",
            self.label,
            format_ns(self.start.elapsed().as_nanos() as f64)
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders() {
        let mut count = 0u64;
        let stats = bench_median(2, 20, || {
            count += 1;
            std::hint::black_box(count);
        });
        assert_eq!(count, 22);
        assert!(stats.p10_ns <= stats.median_ns && stats.median_ns <= stats.p90_ns);
        assert!(stats.throughput() > 0.0);
    }

    #[test]
    fn format_ns_units() {
        assert!(format_ns(500.0).ends_with("ns"));
        assert!(format_ns(5_000.0).ends_with("µs"));
        assert!(format_ns(5_000_000.0).ends_with("ms"));
        assert!(format_ns(5e9).ends_with(" s"));
    }
}
