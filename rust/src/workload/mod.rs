//! Synthetic request workloads: deterministic traces with Poisson or burst
//! arrivals and configurable prompt/generation mixes — the input side of the
//! throughput and E2E serving benches (no production traces exist for this
//! paper; DESIGN.md §4).

use crate::util::rng::XorShift;

/// One serving request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Offset from trace start when the request arrives.
    pub arrival: std::time::Duration,
    pub prompt: String,
    pub max_tokens: usize,
}

/// Arrival process shape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrivals {
    /// Poisson with the given rate (req/s).
    Poisson(f64),
    /// Fixed inter-arrival gap.
    Uniform(f64),
    /// Everything at t=0 (closed-loop saturation).
    Burst,
}

/// Trace generator configuration.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub seed: u64,
    pub requests: usize,
    pub arrivals: Arrivals,
    /// Range of generation lengths.
    pub min_tokens: usize,
    pub max_tokens: usize,
    /// Probability a prompt embeds a router trigger.
    pub trigger_prob: f64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            seed: 11,
            requests: 32,
            arrivals: Arrivals::Poisson(8.0),
            min_tokens: 16,
            max_tokens: 48,
            trigger_prob: 0.3,
        }
    }
}

const TOPICS: &[&str] = &[
    "the kv cache",
    "rotary embeddings",
    "the synapse",
    "landmark tokens",
    "the validation gate",
    "referential injection",
    "weight sharing",
    "the memory budget",
    "the scheduler",
    "the router",
];

const TASKS: &[&str] = &[
    "verify the arithmetic",
    "check the last claim",
    "recall the definition",
    "summarize the context",
    "estimate the memory",
    "validate the bounds",
];

/// Generate a deterministic request trace.
pub fn generate(cfg: &WorkloadConfig) -> Vec<Request> {
    let mut rng = XorShift::new(cfg.seed);
    let mut t = 0.0f64;
    (0..cfg.requests)
        .map(|i| {
            t += match cfg.arrivals {
                Arrivals::Poisson(rate) => rng.exp(rate),
                Arrivals::Uniform(gap) => gap,
                Arrivals::Burst => 0.0,
            };
            let topic = rng.choice(TOPICS);
            let mut prompt = format!("user: tell me about {topic}.\nriver: ");
            if rng.unit() < cfg.trigger_prob {
                let task = rng.choice(TASKS);
                prompt = format!("user: tell me about {topic}. [TASK: {task}]\nriver: ");
            }
            let span = (cfg.max_tokens - cfg.min_tokens).max(1) as u64;
            Request {
                id: i as u64,
                arrival: std::time::Duration::from_secs_f64(t),
                prompt,
                max_tokens: cfg.min_tokens + rng.below(span) as usize,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let cfg = WorkloadConfig::default();
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.max_tokens, y.max_tokens);
        }
    }

    #[test]
    fn arrivals_monotone_and_rate_roughly_right() {
        let cfg = WorkloadConfig {
            requests: 2000,
            arrivals: Arrivals::Poisson(50.0),
            ..Default::default()
        };
        let trace = generate(&cfg);
        for w in trace.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let span = trace.last().unwrap().arrival.as_secs_f64();
        let rate = cfg.requests as f64 / span;
        assert!((rate - 50.0).abs() < 8.0, "empirical rate {rate}");
    }

    #[test]
    fn burst_all_at_zero() {
        let cfg = WorkloadConfig {
            arrivals: Arrivals::Burst,
            ..Default::default()
        };
        assert!(generate(&cfg).iter().all(|r| r.arrival.as_nanos() == 0));
    }

    #[test]
    fn token_bounds_respected() {
        let cfg = WorkloadConfig {
            requests: 500,
            min_tokens: 5,
            max_tokens: 9,
            ..Default::default()
        };
        for r in generate(&cfg) {
            assert!((5..9).contains(&r.max_tokens));
        }
    }

    #[test]
    fn trigger_probability_respected() {
        let cfg = WorkloadConfig {
            requests: 2000,
            trigger_prob: 0.5,
            ..Default::default()
        };
        let n = generate(&cfg)
            .iter()
            .filter(|r| r.prompt.contains("[TASK:"))
            .count();
        assert!((800..1200).contains(&n), "trigger count {n}");
    }
}
