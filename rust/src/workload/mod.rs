//! Synthetic request workloads: deterministic traces with Poisson or burst
//! arrivals and configurable prompt/generation mixes — the input side of the
//! throughput and E2E serving benches (no production traces exist for this
//! paper; DESIGN.md §4).

use crate::util::rng::XorShift;

/// Why a [`WorkloadConfig`] cannot produce a trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WorkloadError {
    /// `max_tokens < min_tokens`: the generation-length range is empty, and
    /// the span subtraction in the generator would underflow (panic in debug
    /// builds, silently wrap in release).
    EmptyTokenRange { min: usize, max: usize },
    /// Trigger probability outside `[0, 1]` or non-finite.
    InvalidTriggerProb(f64),
    /// Poisson rate or uniform gap that is non-finite or non-positive (a
    /// non-positive Poisson rate divides by zero in the exponential sampler).
    InvalidArrivalRate(f64),
}

impl std::fmt::Display for WorkloadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WorkloadError::EmptyTokenRange { min, max } => write!(
                f,
                "empty generation range: max_tokens ({max}) < min_tokens ({min})"
            ),
            WorkloadError::InvalidTriggerProb(p) => {
                write!(f, "trigger_prob {p} outside [0, 1]")
            }
            WorkloadError::InvalidArrivalRate(r) => {
                write!(f, "arrival rate/gap {r} must be finite and positive")
            }
        }
    }
}

impl std::error::Error for WorkloadError {}

/// One serving request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Offset from trace start when the request arrives.
    pub arrival: std::time::Duration,
    pub prompt: String,
    pub max_tokens: usize,
}

/// Arrival process shape.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrivals {
    /// Poisson with the given rate (req/s).
    Poisson(f64),
    /// Fixed inter-arrival gap.
    Uniform(f64),
    /// Everything at t=0 (closed-loop saturation).
    Burst,
}

/// Trace generator configuration.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub seed: u64,
    pub requests: usize,
    pub arrivals: Arrivals,
    /// Range of generation lengths.
    pub min_tokens: usize,
    pub max_tokens: usize,
    /// Probability a prompt embeds a router trigger.
    pub trigger_prob: f64,
}

impl WorkloadConfig {
    /// Reject configs the generator cannot honor: an empty token range
    /// (`max < min`), an out-of-range trigger probability, or a degenerate
    /// arrival process. `min_tokens == max_tokens` is allowed and yields a
    /// constant generation length.
    pub fn validate(&self) -> Result<(), WorkloadError> {
        if self.max_tokens < self.min_tokens {
            return Err(WorkloadError::EmptyTokenRange {
                min: self.min_tokens,
                max: self.max_tokens,
            });
        }
        if !self.trigger_prob.is_finite() || !(0.0..=1.0).contains(&self.trigger_prob) {
            return Err(WorkloadError::InvalidTriggerProb(self.trigger_prob));
        }
        match self.arrivals {
            Arrivals::Poisson(rate) if !rate.is_finite() || rate <= 0.0 => {
                Err(WorkloadError::InvalidArrivalRate(rate))
            }
            Arrivals::Uniform(gap) if !gap.is_finite() || gap < 0.0 => {
                Err(WorkloadError::InvalidArrivalRate(gap))
            }
            _ => Ok(()),
        }
    }
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            seed: 11,
            requests: 32,
            arrivals: Arrivals::Poisson(8.0),
            min_tokens: 16,
            max_tokens: 48,
            trigger_prob: 0.3,
        }
    }
}

const TOPICS: &[&str] = &[
    "the kv cache",
    "rotary embeddings",
    "the synapse",
    "landmark tokens",
    "the validation gate",
    "referential injection",
    "weight sharing",
    "the memory budget",
    "the scheduler",
    "the router",
];

const TASKS: &[&str] = &[
    "verify the arithmetic",
    "check the last claim",
    "recall the definition",
    "summarize the context",
    "estimate the memory",
    "validate the bounds",
];

/// Generate a deterministic request trace.
///
/// Panics on an invalid config (previously `max_tokens < min_tokens`
/// underflowed: debug panic, release wrap to a huge span). Callers that want
/// the typed error instead use [`try_generate`].
pub fn generate(cfg: &WorkloadConfig) -> Vec<Request> {
    try_generate(cfg).unwrap_or_else(|e| panic!("workload::generate: {e}"))
}

/// Generate a deterministic request trace, rejecting invalid configs with a
/// typed [`WorkloadError`] instead of panicking.
pub fn try_generate(cfg: &WorkloadConfig) -> Result<Vec<Request>, WorkloadError> {
    cfg.validate()?;
    let mut rng = XorShift::new(cfg.seed);
    let mut t = 0.0f64;
    let trace = (0..cfg.requests)
        .map(|i| {
            t += match cfg.arrivals {
                Arrivals::Poisson(rate) => rng.exp(rate),
                Arrivals::Uniform(gap) => gap,
                Arrivals::Burst => 0.0,
            };
            let topic = rng.choice(TOPICS);
            let mut prompt = format!("user: tell me about {topic}.\nriver: ");
            if rng.unit() < cfg.trigger_prob {
                let task = rng.choice(TASKS);
                prompt = format!("user: tell me about {topic}. [TASK: {task}]\nriver: ");
            }
            // validate() guarantees max >= min, so this cannot underflow.
            let span = (cfg.max_tokens - cfg.min_tokens).max(1) as u64;
            Request {
                id: i as u64,
                arrival: std::time::Duration::from_secs_f64(t),
                prompt,
                max_tokens: cfg.min_tokens + rng.below(span) as usize,
            }
        })
        .collect();
    Ok(trace)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let cfg = WorkloadConfig::default();
        let a = generate(&cfg);
        let b = generate(&cfg);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.max_tokens, y.max_tokens);
        }
    }

    #[test]
    fn arrivals_monotone_and_rate_roughly_right() {
        let cfg = WorkloadConfig {
            requests: 2000,
            arrivals: Arrivals::Poisson(50.0),
            ..Default::default()
        };
        let trace = generate(&cfg);
        for w in trace.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        let span = trace.last().unwrap().arrival.as_secs_f64();
        let rate = cfg.requests as f64 / span;
        assert!((rate - 50.0).abs() < 8.0, "empirical rate {rate}");
    }

    #[test]
    fn burst_all_at_zero() {
        let cfg = WorkloadConfig {
            arrivals: Arrivals::Burst,
            ..Default::default()
        };
        assert!(generate(&cfg).iter().all(|r| r.arrival.as_nanos() == 0));
    }

    #[test]
    fn token_bounds_respected() {
        let cfg = WorkloadConfig {
            requests: 500,
            min_tokens: 5,
            max_tokens: 9,
            ..Default::default()
        };
        for r in generate(&cfg) {
            assert!((5..9).contains(&r.max_tokens));
        }
    }

    #[test]
    fn inverted_token_range_is_a_typed_error_not_an_underflow() {
        // Regression: max < min used to underflow the span subtraction
        // (debug panic, release wrap to a ~usize::MAX token budget).
        let cfg = WorkloadConfig {
            min_tokens: 48,
            max_tokens: 16,
            ..Default::default()
        };
        assert_eq!(
            try_generate(&cfg).unwrap_err(),
            WorkloadError::EmptyTokenRange { min: 48, max: 16 }
        );
        // A degenerate-but-valid range is fine and constant.
        let flat = WorkloadConfig {
            min_tokens: 7,
            max_tokens: 7,
            requests: 20,
            ..Default::default()
        };
        assert!(try_generate(&flat).unwrap().iter().all(|r| r.max_tokens == 7));
    }

    #[test]
    fn invalid_rate_and_probability_are_rejected() {
        let bad_prob = WorkloadConfig {
            trigger_prob: 1.5,
            ..Default::default()
        };
        assert_eq!(
            bad_prob.validate().unwrap_err(),
            WorkloadError::InvalidTriggerProb(1.5)
        );
        let bad_rate = WorkloadConfig {
            arrivals: Arrivals::Poisson(0.0),
            ..Default::default()
        };
        assert_eq!(
            bad_rate.validate().unwrap_err(),
            WorkloadError::InvalidArrivalRate(0.0)
        );
        assert!(WorkloadConfig::default().validate().is_ok());
    }

    #[test]
    fn trigger_probability_respected() {
        let cfg = WorkloadConfig {
            requests: 2000,
            trigger_prob: 0.5,
            ..Default::default()
        };
        let n = generate(&cfg)
            .iter()
            .filter(|r| r.prompt.contains("[TASK:"))
            .count();
        assert!((800..1200).contains(&n), "trigger count {n}");
    }
}
