//! warp-audit: the project-native static-analysis pass.
//!
//! Enforces the concurrency-core conventions the compiler cannot see —
//! each rule is distilled from a real past bug in this tree:
//!
//! - `poison-cascade` — no `.lock().unwrap()` / `.lock().expect(...)`
//!   outside `util/sync.rs`.  One panicking session would poison the
//!   shared mutex and wedge every later session; use
//!   `util::sync::lock_unpoisoned` or `RankedMutex::lock` (both
//!   poison-tolerant).
//! - `nan-sort` — no `partial_cmp` in comparator position.  A single NaN
//!   panicked the sampler (PR 4) and the synapse selector (PR 2); use
//!   `total_cmp`.
//! - `raw-mutex` — no bare `std::sync::Mutex::new` in decode-path
//!   modules: those locks must be `util::sync::RankedMutex` so the
//!   debug-build lock-rank detector covers them.
//! - `panic-in-serve` — no `unwrap` / `expect` / `panic!` in `serve/`
//!   request handling: a request must fail as an error response, never by
//!   unwinding a worker.
//! - `float-eq` — no `==` / `!=` against a float expression (float
//!   literal or `as f32`/`as f64` cast operand) in `model/` and `cortex/`
//!   production code.  The tiered KV store round-trips values through
//!   int8 and mixed host/device paths; exact equality on computed floats
//!   is either a latent tolerance bug or, where bit-identity IS the
//!   contract, should compare `to_bits()` explicitly.
//!
//! `#[cfg(test)]` / `#[test]` items are skipped (tests may panic freely);
//! a deliberate exception is written as `// audit-allow: <rule>` on the
//! offending line or the line above it.  Self-contained on purpose: a
//! line/token scanner over stripped source (comments, strings and char
//! literals blanked), no parser dependencies — the crate builds offline.
//!
//! Usage: `cargo run --bin warp-audit -- rust/src` (the CI `audit` job).
//! Exits 0 on a clean tree, 1 with `file:line: rule: message` findings.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Modules on the fused-tick decode path: every mutex here must be ranked
/// (see `util::sync::LockRank`) so the deadlock detector covers it.
const DECODE_PATH_MODULES: [&str; 8] = [
    "model/pool.rs",
    "cortex/step.rs",
    "cortex/scheduler.rs",
    "cortex/batcher.rs",
    "cortex/prism.rs",
    "cortex/synapse.rs",
    "runtime/device.rs",
    "metrics/mod.rs",
];

/// Comparator-position sinks for the `nan-sort` rule: `partial_cmp`
/// appearing near one of these is a NaN-unsafe ordering.
const SORTERS: [&str; 5] = [
    "sort_by(",
    "sort_unstable_by(",
    "min_by(",
    "max_by(",
    "binary_search_by(",
];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Rule {
    PoisonCascade,
    NanSort,
    RawMutex,
    PanicInServe,
    FloatEq,
}

impl Rule {
    fn name(self) -> &'static str {
        match self {
            Rule::PoisonCascade => "poison-cascade",
            Rule::NanSort => "nan-sort",
            Rule::RawMutex => "raw-mutex",
            Rule::PanicInServe => "panic-in-serve",
            Rule::FloatEq => "float-eq",
        }
    }

    fn from_name(name: &str) -> Option<Rule> {
        match name {
            "poison-cascade" => Some(Rule::PoisonCascade),
            "nan-sort" => Some(Rule::NanSort),
            "raw-mutex" => Some(Rule::RawMutex),
            "panic-in-serve" => Some(Rule::PanicInServe),
            "float-eq" => Some(Rule::FloatEq),
            _ => None,
        }
    }
}

#[derive(Debug)]
struct Finding {
    line: usize,
    rule: Rule,
    message: &'static str,
}

/// Source split into lines with comments, string contents and char
/// literals blanked (`code`), plus the comment text per line (`comments`,
/// for `audit-allow:` detection).  Line numbers are preserved exactly.
struct Stripped {
    code: Vec<String>,
    comments: Vec<String>,
}

fn newline(out: &mut Stripped) {
    out.code.push(String::new());
    out.comments.push(String::new());
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_')
}

/// If a raw (byte) string literal starts at `i` (`r"`, `r#"`, `br##"`,
/// ...), return the index one past its closing quote.
fn raw_string_end(chars: &[char], i: usize) -> Option<usize> {
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) != Some(&'"') {
        return None;
    }
    j += 1;
    while j < chars.len() {
        if chars[j] == '"'
            && chars
                .get(j + 1..j + 1 + hashes)
                .is_some_and(|t| t.iter().all(|&c| c == '#'))
        {
            return Some(j + 1 + hashes);
        }
        j += 1;
    }
    Some(chars.len())
}

fn strip(src: &str) -> Stripped {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = Stripped {
        code: vec![String::new()],
        comments: vec![String::new()],
    };
    let mut i = 0;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            newline(&mut out);
            i += 1;
            continue;
        }
        // Line comment (covers `///` and `//!` doc comments too).
        if c == '/' && chars.get(i + 1) == Some(&'/') {
            while i < n && chars[i] != '\n' {
                out.comments.last_mut().expect("line present").push(chars[i]);
                i += 1;
            }
            continue;
        }
        // Block comment, nested.
        if c == '/' && chars.get(i + 1) == Some(&'*') {
            let mut depth = 1;
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '\n' {
                    newline(&mut out);
                    i += 1;
                } else if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    out.comments.last_mut().expect("line present").push(chars[i]);
                    i += 1;
                }
            }
            continue;
        }
        // Raw / byte-string prefixes.
        if (c == 'r' || c == 'b') && !prev_is_ident(&chars, i) {
            if let Some(end) = raw_string_end(&chars, i) {
                for &ch in &chars[i..end] {
                    if ch == '\n' {
                        newline(&mut out);
                    }
                }
                i = end;
                continue;
            }
            // `b"..."` / `b'x'`: step past the prefix; the quote handlers
            // below take over on the next iteration.
            if chars.get(i + 1) == Some(&'"') || chars.get(i + 1) == Some(&'\'') {
                i += 1;
                continue;
            }
        }
        // Plain string.
        if c == '"' {
            i += 1;
            while i < n {
                if chars[i] == '\\' {
                    i += 2;
                } else if chars[i] == '"' {
                    i += 1;
                    break;
                } else {
                    if chars[i] == '\n' {
                        newline(&mut out);
                    }
                    i += 1;
                }
            }
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if chars.get(i + 1) == Some(&'\\') {
                // Escaped char: skip past `'\x`, then scan to the close.
                i += 3;
                while i < n && chars[i] != '\'' {
                    i += 1;
                }
                i = (i + 1).min(n);
                continue;
            }
            if chars.get(i + 2) == Some(&'\'') && chars.get(i + 1) != Some(&'\'') {
                i += 3; // 'x'
                continue;
            }
            // Lifetime: drop the quote, keep scanning.
            i += 1;
            continue;
        }
        out.code.last_mut().expect("line present").push(c);
        i += 1;
    }
    out
}

/// Rules suppressed by an `audit-allow:` marker in this comment.
fn allowed_rules(comment: &str) -> Vec<Rule> {
    let Some(pos) = comment.find("audit-allow:") else {
        return Vec::new();
    };
    comment[pos + "audit-allow:".len()..]
        .split([',', ' '].as_slice())
        .filter_map(|name| Rule::from_name(name.trim()))
        .collect()
}

/// Brace-tracking skip state for `#[cfg(test)]` / `#[test]` items.
#[derive(Default)]
struct TestSkip {
    /// Saw the attribute; waiting for the item body to open.
    pending: bool,
    /// Inside the item body at this brace depth.
    depth: usize,
    active: bool,
}

impl TestSkip {
    /// Feed one stripped line; true when it belongs to a test item
    /// (including the attribute lines themselves).
    fn observe(&mut self, line: &str) -> bool {
        let trimmed = line.trim();
        if self.active {
            for c in trimmed.chars() {
                match c {
                    '{' => self.depth += 1,
                    '}' if self.depth > 0 => {
                        self.depth -= 1;
                        if self.depth == 0 {
                            self.active = false;
                        }
                    }
                    _ => {}
                }
            }
            return true;
        }
        if self.pending {
            let mut saw_open = false;
            for c in trimmed.chars() {
                match c {
                    '{' => {
                        saw_open = true;
                        self.depth += 1;
                    }
                    '}' if self.depth > 0 => self.depth -= 1,
                    ';' if self.depth == 0 && !saw_open => {
                        // Bodyless item (`mod tests;`, `use ...;`).
                        self.pending = false;
                        return true;
                    }
                    _ => {}
                }
            }
            if saw_open {
                self.pending = false;
                if self.depth > 0 {
                    self.active = true;
                }
            }
            return true;
        }
        if trimmed.starts_with("#[cfg(test)")
            || trimmed.starts_with("#[test]")
            || trimmed.starts_with("#[cfg(all(test")
        {
            self.pending = true;
            return true;
        }
        false
    }
}

/// True when `s` contains a float-typed expression shape: a float literal
/// (`1.0`, `2.5e-3`, `1f32`) or an `as f32` / `as f64` cast.  Operates on
/// stripped code, so strings and comments never match.
fn has_float_expr(s: &str) -> bool {
    if s.contains("as f32") || s.contains("as f64") {
        return true;
    }
    let c: Vec<char> = s.chars().collect();
    for i in 0..c.len() {
        if !c[i].is_ascii_digit() {
            continue;
        }
        // Must start a numeric token (not `x2`, `0x1E`, tuple index `.0`).
        if i > 0 && (c[i - 1].is_alphanumeric() || c[i - 1] == '_' || c[i - 1] == '.') {
            continue;
        }
        let mut j = i;
        while j < c.len() && (c[j].is_ascii_digit() || c[j] == '_') {
            j += 1;
        }
        match c.get(j) {
            Some('.') if c.get(j + 1).is_some_and(|d| d.is_ascii_digit()) => return true,
            Some('e') | Some('E') => {
                let mut k = j + 1;
                if matches!(c.get(k), Some('+') | Some('-')) {
                    k += 1;
                }
                if c.get(k).is_some_and(|d| d.is_ascii_digit()) {
                    return true;
                }
            }
            Some('f') => {
                let suffix = c.get(j + 1..j + 3);
                if (suffix == Some(&['3', '2']) || suffix == Some(&['6', '4']))
                    && c.get(j + 3).map_or(true, |ch| !(ch.is_alphanumeric() || *ch == '_'))
                {
                    return true;
                }
            }
            _ => {}
        }
    }
    false
}

/// Does the `==`/`!=` at byte `p` compare a float expression?  Operands
/// are bounded by the nearest expression delimiter on each side, so a
/// float literal elsewhere on the line cannot condemn an integer compare.
fn float_eq_at(line: &str, p: usize) -> bool {
    let left_all = &line[..p];
    let right_all = &line[p + 2..];
    let lb = ["(", "{", "[", ",", ";", "&&", "||"]
        .iter()
        .filter_map(|d| left_all.rfind(d).map(|q| q + d.len()))
        .max()
        .unwrap_or(0);
    let rb = [")", "}", "]", ",", ";", "&&", "||", "{"]
        .iter()
        .filter_map(|d| right_all.find(d))
        .min()
        .unwrap_or(right_all.len());
    has_float_expr(&left_all[lb..]) || has_float_expr(&right_all[..rb])
}

/// Run every rule over one file's source.  `module` is the path relative
/// to `src/` (e.g. `util/sync.rs`), which scopes the per-module rules.
fn scan_source(module: &str, src: &str) -> Vec<Finding> {
    let stripped = strip(src);
    let mut findings: Vec<Finding> = Vec::new();
    let mut skip = TestSkip::default();
    let decode_path = DECODE_PATH_MODULES.contains(&module);
    let in_serve = module.starts_with("serve/");
    let in_sync = module == "util/sync.rs";
    let float_scope = module.starts_with("model/") || module.starts_with("cortex/");
    for (idx, line) in stripped.code.iter().enumerate() {
        if skip.observe(line) {
            continue;
        }
        let mut report = |rule: Rule, message: &'static str| {
            let allowed = allowed_rules(&stripped.comments[idx]).contains(&rule)
                || (idx > 0 && allowed_rules(&stripped.comments[idx - 1]).contains(&rule));
            if !allowed {
                findings.push(Finding {
                    line: idx + 1,
                    rule,
                    message,
                });
            }
        };
        if !in_sync {
            // Merge with the next line so a formatter-split
            // `.lock()\n.unwrap()` chain is still caught; only matches
            // that *start* on this line are reported here.
            let here = line.trim_end();
            let next = stripped.code.get(idx + 1).map_or("", |l| l.trim());
            let merged = format!("{here}{next}");
            for pat in [".lock().unwrap()", ".lock().expect("] {
                if let Some(p) = merged.find(pat) {
                    if p < here.len() {
                        report(
                            Rule::PoisonCascade,
                            "poison-intolerant lock: use util::sync::lock_unpoisoned \
                             or a RankedMutex",
                        );
                        break;
                    }
                }
            }
        }
        if line.contains(".partial_cmp(") {
            let window = idx.saturating_sub(2);
            let in_comparator = stripped.code[window..=idx]
                .iter()
                .any(|l| SORTERS.iter().any(|s| l.contains(s)));
            if in_comparator {
                report(Rule::NanSort, "NaN-unsafe comparator: use total_cmp");
            }
        }
        if decode_path {
            let mut start = 0;
            while let Some(p) = line[start..].find("Mutex::new(") {
                let abs = start + p;
                if line[..abs].ends_with("Ranked") {
                    start = abs + "Mutex::new(".len();
                    continue;
                }
                report(
                    Rule::RawMutex,
                    "bare std::sync::Mutex in a decode-path module: \
                     use util::sync::RankedMutex",
                );
                break;
            }
        }
        if in_serve {
            for pat in [".unwrap()", ".expect(", "panic!"] {
                if line.contains(pat) {
                    report(
                        Rule::PanicInServe,
                        "panic path in request handling: return an error \
                         response instead",
                    );
                    break;
                }
            }
        }
        if float_scope {
            for op in ["==", "!="] {
                let mut start = 0;
                let mut fired = false;
                while let Some(rel) = line[start..].find(op) {
                    let abs = start + rel;
                    // Not part of `<=`, `>=`, `=>`, compound assignment…
                    let before = line[..abs].chars().next_back();
                    let after = line[abs + 2..].chars().next();
                    let neighbor = matches!(
                        before,
                        Some('<' | '>' | '=' | '!' | '+' | '-' | '*' | '/' | '%' | '&' | '|' | '^')
                    ) || after == Some('=');
                    if !neighbor && float_eq_at(line, abs) {
                        report(
                            Rule::FloatEq,
                            "exact float equality: compare within a bound, \
                             or on to_bits() where bit-identity is the contract",
                        );
                        fired = true;
                        break;
                    }
                    start = abs + 2;
                }
                if fired {
                    break;
                }
            }
        }
    }
    findings
}

/// Module path relative to the last `/src/` component (the scope key the
/// per-module rules match on); the raw path when there is none.
fn normalize_module(path: &Path) -> String {
    let s = path.to_string_lossy().replace('\\', "/");
    match s.rfind("/src/") {
        Some(p) => s[p + "/src/".len()..].to_string(),
        None => s,
    }
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let roots = if args.is_empty() {
        vec!["rust/src".to_string()]
    } else {
        args
    };
    let mut files = Vec::new();
    for root in &roots {
        let path = PathBuf::from(root);
        let result = if path.is_file() {
            files.push(path);
            Ok(())
        } else {
            walk(&path, &mut files)
        };
        if let Err(e) = result {
            eprintln!("warp-audit: cannot read {root}: {e}");
            return ExitCode::from(2);
        }
    }
    files.sort();
    let mut total = 0usize;
    for file in &files {
        let src = match std::fs::read_to_string(file) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("warp-audit: cannot read {}: {e}", file.display());
                return ExitCode::from(2);
            }
        };
        for f in scan_source(&normalize_module(file), &src) {
            println!("{}:{}: {}: {}", file.display(), f.line, f.rule.name(), f.message);
            total += 1;
        }
    }
    if total == 0 {
        println!("warp-audit: clean ({} files)", files.len());
        ExitCode::SUCCESS
    } else {
        eprintln!("warp-audit: {total} finding(s)");
        ExitCode::FAILURE
    }
}

// Fixture-driven self-tests: each rule must both FIRE on its fixture and
// SUPPRESS under `audit-allow:` / `#[cfg(test)]`.
#[cfg(test)]
mod tests {
    use super::*;

    fn rules(module: &str, src: &str) -> Vec<(usize, Rule)> {
        scan_source(module, src)
            .into_iter()
            .map(|f| (f.line, f.rule))
            .collect()
    }

    #[test]
    fn poison_cascade_fires_with_file_and_line() {
        let src = "fn f() {\n    let g = m.lock().unwrap();\n}\n";
        assert_eq!(rules("model/pool.rs", src), vec![(2, Rule::PoisonCascade)]);
        let src = "fn f() {\n    let g = m.lock().expect(\"locked\");\n}\n";
        assert_eq!(rules("cortex/prism.rs", src), vec![(2, Rule::PoisonCascade)]);
    }

    #[test]
    fn poison_cascade_catches_a_formatter_split_chain() {
        let src = "fn f() {\n    let g = m\n        .lock()\n        .unwrap();\n}\n";
        assert_eq!(rules("model/pool.rs", src), vec![(3, Rule::PoisonCascade)]);
    }

    #[test]
    fn poison_cascade_exempts_util_sync() {
        let src = "fn f() {\n    let g = m.lock().unwrap();\n}\n";
        assert!(rules("util/sync.rs", src).is_empty());
    }

    #[test]
    fn audit_allow_suppresses_on_the_same_and_preceding_line() {
        let same = "fn f() {\n    let g = m.lock().unwrap(); // audit-allow: poison-cascade\n}\n";
        assert!(rules("model/pool.rs", same).is_empty());
        let above =
            "fn f() {\n    // audit-allow: poison-cascade\n    let g = m.lock().unwrap();\n}\n";
        assert!(rules("model/pool.rs", above).is_empty());
    }

    #[test]
    fn audit_allow_for_another_rule_does_not_suppress() {
        let src = "fn f() {\n    let g = m.lock().unwrap(); // audit-allow: nan-sort\n}\n";
        assert_eq!(rules("model/pool.rs", src), vec![(2, Rule::PoisonCascade)]);
    }

    #[test]
    fn cfg_test_items_are_skipped() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f() {\n        m.lock().unwrap();\n    }\n}\n\
                   fn prod() {\n    m.lock().unwrap();\n}\n";
        assert_eq!(rules("model/pool.rs", src), vec![(8, Rule::PoisonCascade)]);
        let src = "#[test]\nfn t() {\n    m.lock().unwrap();\n}\n";
        assert!(rules("model/pool.rs", src).is_empty());
    }

    #[test]
    fn comments_and_strings_never_fire() {
        let src = "fn f() {\n    // m.lock().unwrap()\n    let s = \".lock().unwrap()\";\n\
                   \n    let r = r#\".lock().unwrap()\"#;\n}\n";
        assert!(rules("model/pool.rs", src).is_empty());
    }

    #[test]
    fn nan_sort_fires_in_comparator_position() {
        let src = "fn f(v: &mut Vec<f32>) {\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
        assert_eq!(rules("util/timer.rs", src), vec![(2, Rule::NanSort)]);
        let split = "fn f(v: &mut Vec<f32>) {\n    v.sort_by(|a, b| {\n        \
                     a.partial_cmp(b).unwrap()\n    });\n}\n";
        assert_eq!(rules("util/timer.rs", split), vec![(3, Rule::NanSort)]);
    }

    #[test]
    fn nan_sort_ignores_non_comparator_uses_and_total_cmp() {
        let src = "fn f(a: f32, b: f32) -> bool {\n    \
                   a.partial_cmp(&b) == Some(std::cmp::Ordering::Less)\n}\n";
        assert!(rules("util/timer.rs", src).is_empty());
        let src = "fn f(v: &mut Vec<f32>) {\n    v.sort_by(|a, b| a.total_cmp(b));\n}\n";
        assert!(rules("util/timer.rs", src).is_empty());
    }

    #[test]
    fn raw_mutex_fires_only_in_decode_path_modules() {
        let src = "fn f() {\n    let m = Mutex::new(0);\n}\n";
        assert_eq!(rules("cortex/step.rs", src), vec![(2, Rule::RawMutex)]);
        assert_eq!(rules("metrics/mod.rs", src), vec![(2, Rule::RawMutex)]);
        assert!(rules("util/timer.rs", src).is_empty());
        let qualified = "fn f() {\n    let m = std::sync::Mutex::new(0);\n}\n";
        assert_eq!(rules("model/pool.rs", qualified), vec![(2, Rule::RawMutex)]);
    }

    #[test]
    fn ranked_mutex_is_not_a_raw_mutex() {
        let src = "fn f() {\n    let m = RankedMutex::new(LockRank::Metrics, 0);\n}\n";
        assert!(rules("metrics/mod.rs", src).is_empty());
    }

    #[test]
    fn panic_in_serve_fires_and_suppresses() {
        let src = "fn handle() {\n    let v = parse().unwrap();\n}\n";
        assert_eq!(rules("serve/server.rs", src), vec![(2, Rule::PanicInServe)]);
        let src = "fn handle() {\n    panic!(\"bad request\");\n}\n";
        assert_eq!(rules("serve/http.rs", src), vec![(2, Rule::PanicInServe)]);
        let src = "fn handle() {\n    let v = parse().unwrap(); // audit-allow: panic-in-serve\n}\n";
        assert!(rules("serve/server.rs", src).is_empty());
        // Outside serve/, a bare unwrap is not this rule's business.
        let src = "fn f() {\n    let v = parse().unwrap();\n}\n";
        assert!(rules("util/timer.rs", src).is_empty());
    }

    #[test]
    fn unwrap_or_variants_do_not_fire() {
        let src = "fn handle() {\n    let v = parse().unwrap_or(0);\n    \
                   let w = lock().unwrap_or_else(std::sync::PoisonError::into_inner);\n}\n";
        assert!(rules("serve/server.rs", src).is_empty());
    }

    #[test]
    fn float_eq_fires_on_literal_and_cast_comparisons() {
        let src = "fn f(x: f32) -> bool {\n    x == 1.0\n}\n";
        assert_eq!(rules("model/pool.rs", src), vec![(2, Rule::FloatEq)]);
        let src = "fn f(x: f64, n: usize) -> bool {\n    x != n as f64\n}\n";
        assert_eq!(rules("cortex/capacity.rs", src), vec![(2, Rule::FloatEq)]);
        let src = "fn f(x: f32) -> bool {\n    x == 2.5e-3\n}\n";
        assert_eq!(rules("model/engine.rs", src), vec![(2, Rule::FloatEq)]);
        let src = "fn f(x: f32) -> bool {\n    1f32 != x\n}\n";
        assert_eq!(rules("cortex/step.rs", src), vec![(2, Rule::FloatEq)]);
    }

    #[test]
    fn float_eq_ignores_integer_compares_and_other_scopes() {
        // integer comparisons, float-free
        let src = "fn f(n: usize) -> bool {\n    n == 0\n}\n";
        assert!(rules("model/pool.rs", src).is_empty());
        // ordered float comparisons are fine — only exact equality fires
        let src = "fn f(x: f32) -> bool {\n    x <= 1.0 && x >= -1.0\n}\n";
        assert!(rules("model/pool.rs", src).is_empty());
        // a float elsewhere on the line does not condemn an integer compare
        let src = "fn f(n: usize) {\n    if n == 0 { g(1.0) }\n}\n";
        assert!(rules("model/pool.rs", src).is_empty());
        let src = "fn f(n: usize, e: f32) -> bool {\n    n == 0 && e < 1e-6\n}\n";
        assert!(rules("cortex/step.rs", src).is_empty());
        // hex literals and tuple indexing are not float literals
        let src = "fn f(n: u32, t: (u32, u32)) -> bool {\n    n == 0x1E3 && t.0 != 2\n}\n";
        assert!(rules("model/pool.rs", src).is_empty());
        // outside model/ and cortex/, exact float equality is allowed
        let src = "fn f(x: f32) -> bool {\n    x == 1.0\n}\n";
        assert!(rules("util/timer.rs", src).is_empty());
        assert!(rules("serve/server.rs", src).is_empty());
    }

    #[test]
    fn float_eq_suppresses_under_audit_allow_and_in_tests() {
        let src = "fn f(x: f32) -> bool {\n    x == 0.0 // audit-allow: float-eq\n}\n";
        assert!(rules("model/pool.rs", src).is_empty());
        let src = "#[test]\nfn t() {\n    assert!(x == 1.0);\n}\n";
        assert!(rules("model/pool.rs", src).is_empty());
        let src = "#[cfg(test)]\nmod tests {\n    fn close(x: f32) -> bool {\n        x == 1.0\n    }\n}\n";
        assert!(rules("cortex/capacity.rs", src).is_empty());
    }

    #[test]
    fn module_normalization_scopes_rules() {
        assert_eq!(
            normalize_module(Path::new("rust/src/util/sync.rs")),
            "util/sync.rs"
        );
        assert_eq!(
            normalize_module(Path::new("/abs/repo/rust/src/serve/server.rs")),
            "serve/server.rs"
        );
    }

    #[test]
    fn lifetimes_and_char_literals_do_not_derail_the_scanner() {
        let src = "fn f<'a>(x: &'a str) -> char {\n    let c = '{';\n    let d = '\\'';\n    \
                   m.lock().unwrap();\n    c\n}\n";
        assert_eq!(rules("model/pool.rs", src), vec![(4, Rule::PoisonCascade)]);
    }
}
