//! warp-audit: the project-native static-analysis CLI (the CI `audit`
//! job), a thin front-end over the crate-graph analyzer in
//! [`warp_cortex::audit`].
//!
//! Eight rules run on every invocation: the five token rules distilled
//! from real past bugs (`poison-cascade`, `nan-sort`, `raw-mutex`,
//! `panic-in-serve`, `float-eq`), the whole-crate passes (`lock-order` —
//! static strictly-descending acquisition over the call graph,
//! `gauge-lineage` — every pool/step gauge reaches `/stats` and a
//! consistency check, `hot-tick` — nothing reachable from the fused
//! decode tick blocks), and `stale-allow`, which flags suppression
//! markers that no longer suppress anything.  `--list-rules` prints each
//! rule's id, rationale and suppression syntax.
//!
//! `#[cfg(test)]` / `#[test]` items are skipped (tests may panic and
//! block freely); a deliberate exception is written as
//! `// audit-allow: <rule>` on the offending line or the line above it.
//! Self-contained on purpose — no parser dependencies, the crate builds
//! offline.
//!
//! Usage:
//!
//! ```text
//! warp-audit [--format text|json] [--list-rules] [roots...]
//! ```
//!
//! Roots default to `rust/src`.  When run from the repo root,
//! `rust/tests/`, `rust/benches/` and `ci/thresholds.json` are picked up
//! automatically as gauge-lineage reference context (they are not
//! themselves scanned for findings).
//!
//! # Exit-code contract
//!
//! - `0` — clean: every rule ran, no findings.
//! - `1` — findings were reported (text mode: `file:line: rule: message`
//!   per line; json mode: a report object on stdout).
//! - `2` — environment error: unreadable root/file, unknown flag, or the
//!   static rank table drifting from the runtime `LockRank` enum.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use warp_cortex::audit::{self, AuditInput, Rule, SourceFile};
use warp_cortex::util::json::Json;
use warp_cortex::util::sync::LockRank;

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn list_rules() {
    println!("{:<16} {:<72} suppression", "rule", "rationale");
    for rule in Rule::ALL {
        println!(
            "{:<16} {:<72} {}",
            rule.name(),
            rule.rationale().split_whitespace().collect::<Vec<_>>().join(" "),
            rule.suppression()
        );
    }
}

/// Reference-only context for gauge-lineage: test/bench sources and the
/// CI threshold table, when run from the repo root.
fn load_context(input: &mut AuditInput) {
    for dir in ["rust/tests", "rust/benches"] {
        let mut files = Vec::new();
        if walk(Path::new(dir), &mut files).is_ok() {
            files.sort();
            for f in files {
                if let Ok(src) = std::fs::read_to_string(&f) {
                    input.extras.push((f.display().to_string(), src));
                }
            }
        }
    }
    if let Ok(t) = std::fs::read_to_string("ci/thresholds.json") {
        input.thresholds = Some(t);
    }
}

/// The static rank table parsed from source must match the runtime enum
/// exactly — a drift means the analyzer is checking a different
/// hierarchy than the one debug builds enforce.
fn rank_drift(parsed: &[(String, u8)]) -> Option<String> {
    if parsed.is_empty() {
        // util/sync.rs outside the scanned roots: nothing to compare.
        return None;
    }
    let runtime: Vec<(String, u8)> = LockRank::ALL
        .iter()
        .map(|r| (r.name().to_string(), *r as u8))
        .collect();
    if parsed == runtime.as_slice() {
        None
    } else {
        Some(format!(
            "static/runtime LockRank drift: parsed {parsed:?}, runtime {runtime:?}"
        ))
    }
}

fn main() -> ExitCode {
    let mut format = "text".to_string();
    let mut roots: Vec<String> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--list-rules" => {
                list_rules();
                return ExitCode::SUCCESS;
            }
            "--format" => match args.next() {
                Some(f) if f == "text" || f == "json" => format = f,
                other => {
                    eprintln!(
                        "warp-audit: --format expects `text` or `json`, got {other:?}"
                    );
                    return ExitCode::from(2);
                }
            },
            flag if flag.starts_with("--") => {
                eprintln!("warp-audit: unknown flag {flag}");
                return ExitCode::from(2);
            }
            root => roots.push(root.to_string()),
        }
    }
    if roots.is_empty() {
        roots.push("rust/src".to_string());
    }

    let mut paths = Vec::new();
    for root in &roots {
        let path = PathBuf::from(root);
        let result = if path.is_file() {
            paths.push(path);
            Ok(())
        } else {
            walk(&path, &mut paths)
        };
        if let Err(e) = result {
            eprintln!("warp-audit: cannot read {root}: {e}");
            return ExitCode::from(2);
        }
    }
    paths.sort();
    let mut input = AuditInput::default();
    for path in &paths {
        match std::fs::read_to_string(path) {
            Ok(src) => input
                .files
                .push(SourceFile::parse(&path.display().to_string(), &src)),
            Err(e) => {
                eprintln!("warp-audit: cannot read {}: {e}", path.display());
                return ExitCode::from(2);
            }
        }
    }
    load_context(&mut input);

    let report = audit::run(&input);
    if let Some(drift) = rank_drift(&report.rank_table) {
        eprintln!("warp-audit: {drift}");
        return ExitCode::from(2);
    }

    if format == "json" {
        let findings: Vec<Json> = report
            .findings
            .iter()
            .map(|f| {
                Json::obj()
                    .with("file", f.path.as_str())
                    .with("line", f.line as f64)
                    .with("rule", f.rule.name())
                    .with("message", f.message.as_str())
            })
            .collect();
        let doc = Json::obj()
            .with("tool", "warp-audit")
            .with("files_scanned", report.files_scanned as f64)
            .with(
                "rules",
                Json::Arr(Rule::ALL.iter().map(|r| Json::from(r.name())).collect()),
            )
            .with("findings", Json::Arr(findings));
        let mut out = String::new();
        doc.write_into(&mut out);
        println!("{out}");
    } else {
        for f in &report.findings {
            println!("{}:{}: {}: {}", f.path, f.line, f.rule.name(), f.message);
        }
        if report.findings.is_empty() {
            println!(
                "warp-audit: clean ({} files, {} rules)",
                report.files_scanned,
                Rule::ALL.len()
            );
        } else {
            eprintln!("warp-audit: {} finding(s)", report.findings.len());
        }
    }
    if report.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_rank_table_matches_runtime_enum() {
        let src = std::fs::read_to_string("rust/src/util/sync.rs").expect("sync source");
        let files = vec![SourceFile::parse("rust/src/util/sync.rs", &src)];
        let parsed = warp_cortex::audit::passes::parse_rank_enum(&files);
        assert!(rank_drift(&parsed).is_none(), "{:?}", rank_drift(&parsed));
    }

    #[test]
    fn rank_drift_detects_a_renamed_or_renumbered_variant() {
        let mut parsed: Vec<(String, u8)> = LockRank::ALL
            .iter()
            .map(|r| (r.name().to_string(), *r as u8))
            .collect();
        parsed[1].1 = 11;
        assert!(rank_drift(&parsed).is_some());
        let mut renamed: Vec<(String, u8)> = LockRank::ALL
            .iter()
            .map(|r| (r.name().to_string(), *r as u8))
            .collect();
        renamed[0].0 = "DeviceQueues".to_string();
        assert!(rank_drift(&renamed).is_some());
    }
}
