//! Artifact manifest: the contract between the Python build path and the
//! rust runtime.  Parsed from `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) with the hand-rolled JSON module.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::tensor::Dtype;
use crate::util::json::Json;

/// Architecture of one model variant (mirrors `python/compile/configs.py`).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub vocab_size: usize,
    pub head_dim: usize,
    pub rope_theta: f64,
    pub param_count: u64,
}

impl ModelConfig {
    fn from_json(j: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: j.req("name")?.as_str().unwrap_or("").to_string(),
            d_model: j.req("d_model")?.as_usize().context("d_model")?,
            n_layers: j.req("n_layers")?.as_usize().context("n_layers")?,
            n_heads: j.req("n_heads")?.as_usize().context("n_heads")?,
            n_kv_heads: j.req("n_kv_heads")?.as_usize().context("n_kv_heads")?,
            d_ff: j.req("d_ff")?.as_usize().context("d_ff")?,
            vocab_size: j.req("vocab_size")?.as_usize().context("vocab_size")?,
            head_dim: j.req("head_dim")?.as_usize().context("head_dim")?,
            rope_theta: j.req("rope_theta")?.as_f64().context("rope_theta")?,
            param_count: j.req("param_count")?.as_i64().context("param_count")? as u64,
        })
    }

    /// Bytes of one KV-cache row (all layers, K+V) at the given dtype width.
    pub fn kv_row_bytes(&self, dtype_bytes: usize) -> u64 {
        (self.n_layers * self.n_kv_heads * self.head_dim * 2 * dtype_bytes) as u64
    }

    /// Bytes of a full KV cache with `ctx` rows.
    pub fn kv_cache_bytes(&self, ctx: usize, dtype_bytes: usize) -> u64 {
        self.kv_row_bytes(dtype_bytes) * ctx as u64
    }

    /// Weight bytes at the given dtype width.
    pub fn weight_bytes(&self, dtype_bytes: usize) -> u64 {
        self.param_count * dtype_bytes as u64
    }
}

/// Buffer capacities fixed at AOT time (shapes of the compiled programs).
#[derive(Debug, Clone, Copy)]
pub struct Capacities {
    pub prefill_len: usize,
    pub main_ctx: usize,
    pub side_ctx: usize,
    pub synapse_k: usize,
    pub inject_len: usize,
    pub decode_batch: usize,
}

impl Capacities {
    fn from_json(j: &Json) -> Result<Capacities> {
        Ok(Capacities {
            prefill_len: j.req("prefill_len")?.as_usize().context("prefill_len")?,
            main_ctx: j.req("main_ctx")?.as_usize().context("main_ctx")?,
            side_ctx: j.req("side_ctx")?.as_usize().context("side_ctx")?,
            synapse_k: j.req("synapse_k")?.as_usize().context("synapse_k")?,
            inject_len: j.req("inject_len")?.as_usize().context("inject_len")?,
            decode_batch: j.req("decode_batch")?.as_usize().context("decode_batch")?,
        })
    }
}

/// One tensor in a program signature.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.req("name")?.as_str().unwrap_or("").to_string(),
            shape: j.req("shape")?.usize_vec()?,
            dtype: Dtype::parse(j.req("dtype")?.as_str().unwrap_or("f32"))?,
        })
    }

    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled program (an HLO-text file + its signature).
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Globally unique name, e.g. `tiny_decode_c512`.
    pub name: String,
    /// Program kind name, e.g. `decode_c512`.
    pub program: String,
    pub config: String,
    pub file: String,
    /// Step inputs (the weights tuple precedes these in the HLO signature).
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Analytic FLOPs per invocation (perf accounting).
    pub flops: u64,
}

impl ArtifactSpec {
    fn from_json(j: &Json) -> Result<ArtifactSpec> {
        Ok(ArtifactSpec {
            name: j.req("name")?.as_str().unwrap_or("").to_string(),
            program: j.req("program")?.as_str().unwrap_or("").to_string(),
            config: j.req("config")?.as_str().unwrap_or("").to_string(),
            file: j.req("file")?.as_str().unwrap_or("").to_string(),
            inputs: j
                .req("inputs")?
                .as_arr()
                .context("inputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<_>>()?,
            outputs: j
                .req("outputs")?
                .as_arr()
                .context("outputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<_>>()?,
            flops: j.req("flops")?.as_i64().unwrap_or(0) as u64,
        })
    }
}

/// Synapse/gate defaults chosen at build time.
#[derive(Debug, Clone, Copy)]
pub struct Defaults {
    pub alpha: f32,
    pub inv2sig2: f32,
    pub gate_theta: f32,
}

/// Everything belonging to one runnable config.
#[derive(Debug, Clone)]
pub struct ConfigBundle {
    pub model: ModelConfig,
    pub caps: Capacities,
    pub weights_file: String,
    pub golden_file: String,
    pub fingerprint: String,
    pub defaults: Defaults,
    pub artifacts: Vec<ArtifactSpec>,
}

impl ConfigBundle {
    pub fn artifact(&self, program_prefix: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.program.starts_with(program_prefix))
            .with_context(|| format!("no artifact with program prefix `{program_prefix}`"))
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub configs: BTreeMap<String, ConfigBundle>,
    /// Analytic-only configs (e.g. qwen2_5_0_5b) for memory projections.
    pub analytic: BTreeMap<String, ModelConfig>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;

        let mut configs = BTreeMap::new();
        for (name, cj) in j.req("configs")?.members() {
            let dj = cj.req("defaults")?;
            configs.insert(
                name.clone(),
                ConfigBundle {
                    model: ModelConfig::from_json(cj.req("model")?)?,
                    caps: Capacities::from_json(cj.req("capacities")?)?,
                    weights_file: cj.req("weights_file")?.as_str().unwrap_or("").to_string(),
                    golden_file: cj.req("golden_file")?.as_str().unwrap_or("").to_string(),
                    fingerprint: cj.req("fingerprint")?.as_str().unwrap_or("").to_string(),
                    defaults: Defaults {
                        alpha: dj.req("alpha")?.as_f64().unwrap_or(0.5) as f32,
                        inv2sig2: dj.req("inv2sig2")?.as_f64().unwrap_or(0.0) as f32,
                        gate_theta: dj.req("gate_theta")?.as_f64().unwrap_or(0.5) as f32,
                    },
                    artifacts: cj
                        .req("artifacts")?
                        .as_arr()
                        .context("artifacts")?
                        .iter()
                        .map(ArtifactSpec::from_json)
                        .collect::<Result<_>>()?,
                },
            );
        }

        let mut analytic = BTreeMap::new();
        if let Some(aj) = j.get("analytic_configs") {
            for (name, cj) in aj.members() {
                analytic.insert(name.clone(), ModelConfig::from_json(cj)?);
            }
        }

        Ok(Manifest { dir, configs, analytic })
    }

    /// Default artifacts directory: `$WARP_ARTIFACTS_DIR` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("WARP_ARTIFACTS_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn config(&self, name: &str) -> Result<&ConfigBundle> {
        self.configs
            .get(name)
            .with_context(|| format!("config `{name}` not in manifest (have: {:?})",
                self.configs.keys().collect::<Vec<_>>()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_manifest_json() -> String {
        r#"{
          "version": 1,
          "configs": {
            "tiny": {
              "model": {"name":"tiny","d_model":64,"n_layers":2,"n_heads":4,
                        "n_kv_heads":2,"d_ff":192,"vocab_size":260,
                        "head_dim":16,"rope_theta":10000.0,"norm_eps":1e-5,
                        "param_count":116032},
              "capacities": {"prefill_len":128,"main_ctx":512,"side_ctx":96,
                             "synapse_k":64,"inject_len":16,"decode_batch":4},
              "weights_file": "weights_tiny.npz",
              "golden_file": "golden_tiny.json",
              "fingerprint": "abc",
              "defaults": {"alpha":0.5,"inv2sig2":0.015625,"gate_theta":0.5},
              "artifacts": [
                {"name":"tiny_decode_c512","program":"decode_c512",
                 "config":"tiny","file":"tiny_decode_c512.hlo.txt",
                 "inputs":[{"name":"token","shape":[],"dtype":"s32"}],
                 "outputs":[{"name":"logits","shape":[260],"dtype":"f32"}],
                 "flops":232064}
              ]
            }
          },
          "analytic_configs": {
            "qwen2_5_0_5b": {"name":"qwen2_5_0_5b","d_model":896,"n_layers":24,
              "n_heads":14,"n_kv_heads":2,"d_ff":4864,"vocab_size":151936,
              "head_dim":64,"rope_theta":1e6,"norm_eps":1e-5,
              "param_count":494032768}
          }
        }"#
        .to_string()
    }

    #[test]
    fn parses_mini_manifest() {
        let dir = std::env::temp_dir().join(format!("wc_manifest_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), mini_manifest_json()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let cfg = m.config("tiny").unwrap();
        assert_eq!(cfg.model.d_model, 64);
        assert_eq!(cfg.caps.synapse_k, 64);
        assert_eq!(cfg.artifacts.len(), 1);
        let a = cfg.artifact("decode_c512").unwrap();
        assert_eq!(a.inputs[0].dtype, Dtype::I32);
        assert!(cfg.artifact("nonexistent").is_err());
        assert!(m.analytic.contains_key("qwen2_5_0_5b"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn kv_math() {
        let m = Manifest::load({
            let dir = std::env::temp_dir().join(format!("wc_manifest_kv_{}", std::process::id()));
            std::fs::create_dir_all(&dir).unwrap();
            std::fs::write(dir.join("manifest.json"), mini_manifest_json()).unwrap();
            dir
        })
        .unwrap();
        let cfg = &m.config("tiny").unwrap().model;
        // 2 layers * 2 kv heads * 16 hd * 2 (K+V) * 4 bytes = 512 B/row
        assert_eq!(cfg.kv_row_bytes(4), 512);
        assert_eq!(cfg.kv_cache_bytes(512, 4), 512 * 512);
        // qwen: 24 * 2 * 64 * 2 * 2B = 12288 B/row; 32k ctx ≈ 402 MB (paper's ~0.5 GB)
        let q = &m.analytic["qwen2_5_0_5b"];
        assert_eq!(q.kv_row_bytes(2), 12288);
        let full = q.kv_cache_bytes(32768, 2);
        assert!(full > 380_000_000 && full < 420_000_000, "{full}");
    }

    #[test]
    fn missing_manifest_errors() {
        assert!(Manifest::load("/nonexistent/path").is_err());
    }
}
