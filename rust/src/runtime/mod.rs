//! PJRT runtime bridge: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `weights_*.npz` + `manifest.json`) produced by the Python build path and
//! executes them on the XLA PJRT CPU client.
//!
//! Threading model: all PJRT objects (client, executables, device buffers)
//! live on ONE dedicated *device service thread* — the `xla` crate's handles
//! are `Rc`-based and must not cross threads.  Other threads talk to the
//! device through [`device::DeviceHandle`], which enqueues operations into
//! the three priority lanes of the paper's River & Stream topology (§3.1):
//! the River lane preempts the Stream lane at op granularity, exactly the
//! scheduling semantics the paper gets from prioritized CUDA streams.

pub mod device;
pub mod manifest;
pub mod tensor;
pub mod xla_stub;

pub use device::{DeviceHandle, DeviceOptions, Lane, OpResult};
pub use manifest::{ArtifactSpec, Capacities, ConfigBundle, Manifest, ModelConfig, TensorSpec};
pub use tensor::{Dtype, HostTensor};
