//! Build-anywhere stand-in for the `xla` (PJRT) crate's API surface.
//!
//! The real backend binds LaurentMazare's `xla` bindings to a PJRT CPU/GPU
//! plugin — a native dependency that cannot be fetched or built in the
//! offline environments this repo targets (DESIGN.md §4 lists the same
//! substitution policy for serde/clap/rand).  `runtime::device` imports
//! this module under the name `xla`, so the whole serving stack compiles
//! and every host-side component (pool, caches, cortex, scheduler, HTTP
//! layer) is testable; only actual program execution is unavailable:
//! [`PjRtClient::cpu`] fails with a descriptive error, which surfaces as a
//! clean `DeviceHandle::new` error and lets callers (benches, integration
//! tests) skip device-dependent paths.
//!
//! Swapping in the real crate is a one-line change at the import site —
//! every type and method signature here mirrors `xla` 0.1.x as used by
//! `device.rs`.
//!
//! One genuine (non-failing) piece of device semantics also lives here:
//! [`paged_gather_prefix`], the reference implementation of the device-side
//! paged-attention gather that the KV pool's device slab runs against its
//! resident block copies.  Keeping it in this module makes the substitution
//! boundary explicit: it is exactly the program a real backend would
//! compile, expressed on host floats.

#![allow(dead_code)]

use std::path::Path;

/// Error type mirroring the real crate's (only `Debug`/`Display` are used).
#[derive(Debug)]
pub struct XlaError(pub String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type StubResult<T> = std::result::Result<T, XlaError>;

fn unavailable<T>() -> StubResult<T> {
    Err(XlaError(
        "PJRT backend unavailable: this build uses the offline `xla` stub \
         (link the real `xla` crate to execute compiled artifacts)"
            .to_string(),
    ))
}

/// Reference semantics of the device-side **paged-attention gather**: build
/// the contiguous `[L, c, row]` prefix of one cache from its block table,
/// where `blocks[i]` is the `[L, block_tokens, row]` buffer of the i-th
/// table entry and only positions `< len` are valid (the remainder of `out`
/// is left untouched — callers hand in zeroed buffers, and every compiled
/// program masks attention past `cache_len` anyway).
///
/// On a real PJRT backend this is a compiled gather program reading
/// device-resident block buffers, so a decode step ships only the block
/// table and the new token — not the cache.  The offline build has no
/// device, so [`crate::model::KvPool`]'s device slab calls this host
/// implementation instead; the semantics are proven bit-identical to the
/// flat `[L, C, KV, hd]` reference layout by the tests in `model/kv.rs`,
/// which is what lets host-only tests and benches stand in for the XLA
/// path.
pub fn paged_gather_prefix(
    blocks: &[&[f32]],
    n_layers: usize,
    block_tokens: usize,
    row: usize,
    len: usize,
    c: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), n_layers * c * row);
    let valid = len.min(c);
    for (b, buf) in blocks.iter().enumerate() {
        let start = b * block_tokens;
        if start >= valid {
            break;
        }
        let run = (valid - start).min(block_tokens);
        for layer in 0..n_layers {
            let dst = layer * c * row + start * row;
            let src = layer * block_tokens * row;
            out[dst..dst + run * row].copy_from_slice(&buf[src..src + run * row]);
        }
    }
}

/// One device-resident block as seen by the tiered gather: either a raw
/// fp32 buffer (hot tier) or an int8 buffer with per-`(layer, position)`
/// scales (warm tier).  Mirrors how a real backend would keep quantized
/// pages resident and dequantize inside the gather kernel rather than
/// materializing fp32 copies.
pub enum PagedBlock<'a> {
    /// `[L, block_tokens, row]` fp32 buffer.
    F32(&'a [f32]),
    /// `[L, block_tokens, row]` int8 buffer plus `[L, block_tokens]`
    /// per-row symmetric scales (`x ≈ q as f32 * scale`).
    Q8 {
        q: &'a [i8],
        scales: &'a [f32],
    },
}

/// Mixed-tier variant of [`paged_gather_prefix`]: identical contiguous
/// `[L, c, row]` output, but each table entry may be fp32 or int8.  Warm
/// (int8) entries are dequantized row-by-row during the copy — on a real
/// backend this fusion is what makes the quantized tier free at gather
/// time (no fp32 staging buffer, ~4× less device traffic per warm block).
///
/// Dequantization here (`q as f32 * scale`) is the *only* definition of
/// the warm tier's value semantics: the pool's host-side gathers use the
/// same expression, which is what makes host and device reads of a
/// quantized block bit-identical (`model/pool.rs` proves it in tests).
pub fn paged_gather_prefix_tiered(
    blocks: &[PagedBlock<'_>],
    n_layers: usize,
    block_tokens: usize,
    row: usize,
    len: usize,
    c: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), n_layers * c * row);
    let valid = len.min(c);
    for (b, blk) in blocks.iter().enumerate() {
        let start = b * block_tokens;
        if start >= valid {
            break;
        }
        let run = (valid - start).min(block_tokens);
        for layer in 0..n_layers {
            let dst = layer * c * row + start * row;
            let src = layer * block_tokens * row;
            match blk {
                PagedBlock::F32(buf) => {
                    out[dst..dst + run * row].copy_from_slice(&buf[src..src + run * row]);
                }
                PagedBlock::Q8 { q, scales } => {
                    for tok in 0..run {
                        let scale = scales[layer * block_tokens + tok];
                        let s = src + tok * row;
                        let d = dst + tok * row;
                        for i in 0..row {
                            out[d + i] = q[s + i] as f32 * scale;
                        }
                    }
                }
            }
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    F16,
    F64,
    U8,
    Pred,
}

pub struct PjRtDevice {
    _private: (),
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> StubResult<Literal> {
        unavailable()
    }
}

pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn ty(&self) -> StubResult<ElementType> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> StubResult<Vec<T>> {
        unavailable()
    }

    pub fn to_tuple(self) -> StubResult<Vec<Literal>> {
        unavailable()
    }
}

/// Mirrors the real crate's npz-loading entry point.
pub trait FromRawBytes: Sized {
    fn read_npz(path: impl AsRef<Path>, ctx: &()) -> StubResult<Vec<(String, Self)>>;
}

impl FromRawBytes for Literal {
    fn read_npz(_path: impl AsRef<Path>, _ctx: &()) -> StubResult<Vec<(String, Literal)>> {
        unavailable()
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> StubResult<HloModuleProto> {
        unavailable()
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> StubResult<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Always fails in the stub — the device bring-up error every
    /// device-dependent caller is expected to handle (or skip on).
    pub fn cpu() -> StubResult<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<&PjRtDevice>,
        _literal: &Literal,
    ) -> StubResult<PjRtBuffer> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> StubResult<PjRtBuffer> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> StubResult<PjRtLoadedExecutable> {
        unavailable()
    }
}
