//! Host-side tensors: the plain-data currency between coordinator threads
//! and the device service thread (PJRT literals never cross threads).

use anyhow::{bail, Result};

/// Element type of a [`HostTensor`] (only the two the artifacts use).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "s32" | "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype `{other}`"),
        }
    }

    pub fn size_bytes(&self) -> usize {
        4
    }
}

/// A dense row-major host tensor.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { data: Vec<f32>, shape: Vec<usize> },
    I32 { data: Vec<i32>, shape: Vec<usize> },
}

impl HostTensor {
    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::F32 { data: vec![v], shape: vec![] }
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::I32 { data: vec![v], shape: vec![] }
    }

    pub fn f32(data: Vec<f32>, shape: Vec<usize>) -> HostTensor {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::F32 { data, shape }
    }

    pub fn i32(data: Vec<i32>, shape: Vec<usize>) -> HostTensor {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        HostTensor::I32 { data, shape }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> HostTensor {
        let n = shape.iter().product();
        HostTensor::F32 { data: vec![0.0; n], shape }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostTensor::F32 { .. } => Dtype::F32,
            HostTensor::I32 { .. } => Dtype::I32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32 { data, .. } => data.len(),
            HostTensor::I32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Payload size — the number the memory tracker accounts for.
    pub fn num_bytes(&self) -> usize {
        self.len() * 4
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn into_i32(self) -> Result<Vec<i32>> {
        match self {
            HostTensor::I32 { data, .. } => Ok(data),
            _ => bail!("tensor is not i32"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = HostTensor::f32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.dtype(), Dtype::F32);
        assert_eq!(t.num_bytes(), 16);
        assert_eq!(t.as_f32().unwrap()[3], 4.0);
        assert!(t.as_i32().is_err());

        let s = HostTensor::scalar_i32(7);
        assert!(s.shape().is_empty());
        assert_eq!(s.as_i32().unwrap(), &[7]);
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(Dtype::parse("f32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("s32").unwrap(), Dtype::I32);
        assert!(Dtype::parse("f64").is_err());
    }

    #[test]
    fn zeros() {
        let z = HostTensor::zeros_f32(vec![3, 4]);
        assert_eq!(z.len(), 12);
        assert!(z.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }
}
