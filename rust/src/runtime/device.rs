//! The device service thread: owns every PJRT object (client, compiled
//! executables, resident weight buffers) and executes operations pulled from
//! three priority lanes.
//!
//! Why a single thread: the `xla` crate's handles are `Rc`-based (not
//! `Send`), and the paper's testbed is likewise a single physical GPU fed by
//! prioritized CUDA streams (§3.1 "River & Stream").  The lanes reproduce
//! those semantics at op granularity: a queued River op always runs before
//! any Stream op, which always runs before Background work.
//!
//! The Prism (§3.2 Singleton Weight Sharing) is literal here: each config's
//! weights are uploaded to device buffers ONCE at startup and every
//! subsequent `execute_b` call — no matter which agent issued it — shares
//! those buffers.  Per-op marshalling covers only the step inputs (token,
//! positions, KV cache).

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Once};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{ArtifactSpec, Manifest};
use super::tensor::HostTensor;
use crate::util::sync::{ranked_wait, LockRank, RankedMutex};
// The PJRT surface.  Offline builds use the API-compatible stub (device
// bring-up fails cleanly with "PJRT backend unavailable"); swapping in the
// real `xla` crate is this one import line.
use super::xla_stub as xla;

/// Priority lane of the River & Stream topology (paper §3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Lane {
    /// The Main Agent's user-facing stream — highest priority.
    River = 0,
    /// Side-agent reasoning streams — medium priority.
    Stream = 1,
    /// Maintenance work (synapse refresh, speculative prefill) — lowest.
    Background = 2,
}

pub const LANES: [Lane; 3] = [Lane::River, Lane::Stream, Lane::Background];

impl Lane {
    pub fn name(&self) -> &'static str {
        match self {
            Lane::River => "river",
            Lane::Stream => "stream",
            Lane::Background => "background",
        }
    }
}

/// Identifier of a compiled program on the device.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProgramId(pub usize);

/// Result of one executed operation.
#[derive(Debug)]
pub struct OpResult {
    pub outputs: Vec<HostTensor>,
    /// Time spent waiting in the lane queue.
    pub queue_ns: u64,
    /// Device execution time (marshalling + run + readback).
    pub exec_ns: u64,
}

/// Options controlling device bring-up.
#[derive(Debug, Clone)]
pub struct DeviceOptions {
    pub artifacts_dir: PathBuf,
    /// Configs to load (e.g. `["tiny"]`); empty = all in the manifest.
    pub configs: Vec<String>,
    /// If false, compile artifacts lazily on first use (faster startup).
    pub eager_compile: bool,
}

impl DeviceOptions {
    pub fn from_env() -> DeviceOptions {
        DeviceOptions {
            artifacts_dir: Manifest::default_dir(),
            configs: vec![],
            eager_compile: true,
        }
    }

    pub fn with_configs(mut self, configs: &[&str]) -> Self {
        self.configs = configs.iter().map(|s| s.to_string()).collect();
        self
    }
}

struct Op {
    program: usize,
    lane: usize,
    inputs: Vec<HostTensor>,
    reply: mpsc::Sender<Result<OpResult>>,
    enqueued: Instant,
}

struct QueueState {
    lanes: [std::collections::VecDeque<Op>; 3],
    shutdown: bool,
}

/// Cumulative device statistics (lock-free reads for the hot counters).
#[derive(Debug, Default)]
pub struct DeviceStats {
    pub ops: AtomicU64,
    pub exec_ns: AtomicU64,
    pub queue_ns: AtomicU64,
    pub lane_ops: [AtomicU64; 3],
    pub lane_queue_ns: [AtomicU64; 3],
    pub flops: AtomicU64,
}

#[derive(Debug, Clone)]
pub struct DeviceStatsSnapshot {
    pub ops: u64,
    pub exec_ns: u64,
    pub queue_ns: u64,
    pub lane_ops: [u64; 3],
    pub lane_queue_ns: [u64; 3],
    pub flops: u64,
}

// ── Exit-time cleanup ───────────────────────────────────────────────────
// A PJRT CPU client that is still alive while libc runs the C++ library's
// static destructors crashes intermittently (its internal thread pools race
// the teardown).  Every device registers here; an `atexit` hook — installed
// AFTER the C++ handlers, hence run BEFORE them — shuts the service threads
// down and drops all PJRT objects first.

static CLEANUP_ONCE: Once = Once::new();
type DeviceRegistry = Vec<(std::sync::Weak<Shared>, Option<std::thread::JoinHandle<()>>)>;
/// Ranked [`LockRank::Registry`]: the highest rank, legal to hold while
/// shutting each device's [`LockRank::DeviceQueue`] down underneath.
static LIVE_DEVICES: RankedMutex<DeviceRegistry> =
    RankedMutex::new(LockRank::Registry, Vec::new());

extern "C" fn cleanup_devices_at_exit() {
    let mut devices = LIVE_DEVICES.lock();
    for (weak, handle) in devices.drain(..) {
        if let Some(shared) = weak.upgrade() {
            shared.queues.lock().shutdown = true;
            shared.cv.notify_all();
        }
        if let Some(h) = handle {
            let _ = h.join();
        }
    }
}

fn register_device_for_cleanup(shared: &Arc<Shared>, handle: std::thread::JoinHandle<()>) {
    CLEANUP_ONCE.call_once(|| unsafe {
        libc::atexit(cleanup_devices_at_exit);
    });
    LIVE_DEVICES
        .lock()
        .push((Arc::downgrade(shared), Some(handle)));
}

struct Shared {
    specs: Vec<ArtifactSpec>,
    name_to_id: HashMap<String, usize>,
    /// Ranked [`LockRank::DeviceQueue`]: the lowest rank — every other
    /// subsystem may hold its own lock while enqueueing an op here.
    queues: RankedMutex<QueueState>,
    cv: Condvar,
    stats: DeviceStats,
    /// Bytes of weights resident on the device (the Prism), per config.
    weight_bytes: HashMap<String, u64>,
}

/// Clonable, `Send` handle to the device service thread.
#[derive(Clone)]
pub struct DeviceHandle {
    shared: Arc<Shared>,
    manifest: Arc<Manifest>,
}

impl DeviceHandle {
    /// Bring up the device: spawn the service thread, load + compile the
    /// requested configs' artifacts, upload weights.  Blocks until ready.
    pub fn new(options: DeviceOptions) -> Result<DeviceHandle> {
        let manifest = Arc::new(Manifest::load(&options.artifacts_dir)?);
        let configs: Vec<String> = if options.configs.is_empty() {
            manifest.configs.keys().cloned().collect()
        } else {
            options.configs.clone()
        };

        let mut specs = Vec::new();
        let mut name_to_id = HashMap::new();
        let mut weight_bytes = HashMap::new();
        for cname in &configs {
            let bundle = manifest.config(cname)?;
            weight_bytes.insert(cname.clone(), bundle.model.weight_bytes(4));
            for a in &bundle.artifacts {
                name_to_id.insert(a.name.clone(), specs.len());
                specs.push(a.clone());
            }
        }

        let shared = Arc::new(Shared {
            specs,
            name_to_id,
            queues: RankedMutex::new(
                LockRank::DeviceQueue,
                QueueState {
                    lanes: Default::default(),
                    shutdown: false,
                },
            ),
            cv: Condvar::new(),
            stats: DeviceStats::default(),
            weight_bytes,
        });

        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let handle = {
            let shared = shared.clone();
            let manifest = manifest.clone();
            let configs = configs.clone();
            let eager = options.eager_compile;
            std::thread::Builder::new()
                .name("warp-device".to_string())
                .spawn(move || device_thread(shared, manifest, configs, eager, ready_tx))
                .context("spawning device thread")?
        };
        ready_rx
            .recv()
            .map_err(|_| anyhow!("device thread died during startup"))??;
        register_device_for_cleanup(&shared, handle);
        Ok(DeviceHandle { shared, manifest })
    }

    /// Convenience: default options + a single config.
    pub fn for_config(config: &str) -> Result<DeviceHandle> {
        DeviceHandle::new(DeviceOptions::from_env().with_configs(&[config]))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn program_id(&self, name: &str) -> Result<ProgramId> {
        self.shared
            .name_to_id
            .get(name)
            .copied()
            .map(ProgramId)
            .with_context(|| format!("program `{name}` not loaded"))
    }

    pub fn program_spec(&self, id: ProgramId) -> &ArtifactSpec {
        &self.shared.specs[id.0]
    }

    /// Bytes of resident weights (the Prism) for a config.
    pub fn weight_bytes(&self, config: &str) -> u64 {
        self.shared.weight_bytes.get(config).copied().unwrap_or(0)
    }

    /// Enqueue an op on a lane; returns a receiver for the result.
    pub fn submit(
        &self,
        id: ProgramId,
        inputs: Vec<HostTensor>,
        lane: Lane,
    ) -> mpsc::Receiver<Result<OpResult>> {
        let (tx, rx) = mpsc::channel();
        let op = Op {
            program: id.0,
            lane: op_lane_index(lane),
            inputs,
            reply: tx,
            enqueued: Instant::now(),
        };
        {
            let mut q = self.shared.queues.lock();
            if q.shutdown {
                let _ = op.reply.send(Err(anyhow!("device is shut down")));
            } else {
                q.lanes[op.lane].push_back(op);
            }
        }
        self.shared.cv.notify_one();
        rx
    }

    /// Blocking execute.
    pub fn call(&self, id: ProgramId, inputs: Vec<HostTensor>, lane: Lane) -> Result<OpResult> {
        self.submit(id, inputs, lane)
            .recv()
            .map_err(|_| anyhow!("device thread dropped the reply channel"))?
    }

    pub fn stats(&self) -> DeviceStatsSnapshot {
        let s = &self.shared.stats;
        DeviceStatsSnapshot {
            ops: s.ops.load(Ordering::Relaxed),
            exec_ns: s.exec_ns.load(Ordering::Relaxed),
            queue_ns: s.queue_ns.load(Ordering::Relaxed),
            lane_ops: [
                s.lane_ops[0].load(Ordering::Relaxed),
                s.lane_ops[1].load(Ordering::Relaxed),
                s.lane_ops[2].load(Ordering::Relaxed),
            ],
            lane_queue_ns: [
                s.lane_queue_ns[0].load(Ordering::Relaxed),
                s.lane_queue_ns[1].load(Ordering::Relaxed),
                s.lane_queue_ns[2].load(Ordering::Relaxed),
            ],
            flops: s.flops.load(Ordering::Relaxed),
        }
    }

    /// Number of ops currently waiting, per lane (for backpressure).
    pub fn queue_depths(&self) -> [usize; 3] {
        let q = self.shared.queues.lock();
        [q.lanes[0].len(), q.lanes[1].len(), q.lanes[2].len()]
    }

    /// Stop the service thread (pending ops receive errors).
    pub fn shutdown(&self) {
        let mut q = self.shared.queues.lock();
        q.shutdown = true;
        drop(q);
        self.shared.cv.notify_all();
    }
}

fn op_lane_index(lane: Lane) -> usize {
    lane as usize
}

// ── Device thread ───────────────────────────────────────────────────────

struct LoadedProgram {
    exe: xla::PjRtLoadedExecutable,
    /// Index into `weights` for this program's config.
    weights_idx: usize,
    flops: u64,
}

fn device_thread(
    shared: Arc<Shared>,
    manifest: Arc<Manifest>,
    configs: Vec<String>,
    eager: bool,
    ready_tx: mpsc::Sender<Result<()>>,
) {
    #[allow(clippy::type_complexity)]
    let setup = || -> Result<(
        xla::PjRtClient,
        Vec<Vec<xla::PjRtBuffer>>,
        Vec<xla::Literal>,
        Vec<Option<LoadedProgram>>,
    )> {
        let client = xla::PjRtClient::cpu()?;
        log::info!(
            "device up: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );

        // The Prism: upload each config's weights ONCE.
        //
        // SAFETY NOTE: `buffer_from_host_literal` enqueues an ASYNC copy on
        // the PJRT thread pool; the source Literal must outlive that copy
        // (dropping it immediately is a use-after-free that release builds
        // reliably hit).  We retain all weight literals for the device
        // thread's lifetime — a few MB, and the buffers stay valid forever.
        let mut weights: Vec<Vec<xla::PjRtBuffer>> = Vec::new();
        let mut pinned_literals: Vec<xla::Literal> = Vec::new();
        let mut weights_idx_of: HashMap<String, usize> = HashMap::new();
        for cname in &configs {
            let bundle = manifest.config(cname)?;
            let path = manifest.dir.join(&bundle.weights_file);
            // NOTE: read via Literal, not PjRtBuffer::read_npz — the latter
            // passes `ElementType as i32` where a `PrimitiveType` is expected
            // (xla 0.1.6 bug), silently creating F16 buffers from F32 data.
            let mut named = <xla::Literal as xla::FromRawBytes>::read_npz(&path, &())
                .map_err(|e| anyhow!("loading weights {path:?}: {e:?}"))?;
            // keys are `w000_embed`, `w001_...` — lexicographic == ABI order
            named.sort_by(|a, b| a.0.cmp(&b.0));
            weights_idx_of.insert(cname.clone(), weights.len());
            let mut bufs = Vec::with_capacity(named.len());
            for (_, lit) in named {
                bufs.push(
                    client
                        .buffer_from_host_literal(None, &lit)
                        .map_err(|e| anyhow!("uploading weights: {e:?}"))?,
                );
                pinned_literals.push(lit);
            }
            weights.push(bufs);
        }

        // Compile artifacts.
        let mut programs: Vec<Option<LoadedProgram>> = Vec::new();
        for spec in &shared.specs {
            if eager {
                let t0 = Instant::now();
                let exe = compile_program(&client, &manifest.dir, spec)?;
                log::info!(
                    "compiled {} in {:.0} ms",
                    spec.name,
                    t0.elapsed().as_secs_f64() * 1e3
                );
                programs.push(Some(LoadedProgram {
                    exe,
                    weights_idx: weights_idx_of[&spec.config],
                    flops: spec.flops,
                }));
            } else {
                programs.push(None);
            }
        }
        Ok((client, weights, pinned_literals, programs))
    };

    let (client, weights, _pinned_literals, mut programs) = match setup() {
        Ok(v) => {
            let _ = ready_tx.send(Ok(()));
            v
        }
        Err(e) => {
            let _ = ready_tx.send(Err(e));
            return;
        }
    };

    let weights_idx_of: HashMap<String, usize> = {
        // reconstruct mapping (config order == upload order)
        let mut m = HashMap::new();
        let mut idx = 0;
        for cname in &configs {
            m.insert(cname.clone(), idx);
            idx += 1;
        }
        m
    };

    loop {
        let op = {
            let mut q = shared.queues.lock();
            loop {
                if q.shutdown {
                    for lane in q.lanes.iter_mut() {
                        for op in lane.drain(..) {
                            let _ = op.reply.send(Err(anyhow!("device shut down")));
                        }
                    }
                    return;
                }
                // Strict priority: River, then Stream, then Background.
                if let Some(op) = q.lanes.iter_mut().find_map(|l| l.pop_front()) {
                    break op;
                }
                q = ranked_wait(&shared.cv, q);
            }
        };

        let queue_ns = op.enqueued.elapsed().as_nanos() as u64;
        let spec = &shared.specs[op.program];

        // Lazy compile if needed.
        if programs[op.program].is_none() {
            match compile_program(&client, &manifest.dir, spec) {
                Ok(exe) => {
                    programs[op.program] = Some(LoadedProgram {
                        exe,
                        weights_idx: weights_idx_of[&spec.config],
                        flops: spec.flops,
                    });
                }
                Err(e) => {
                    let _ = op.reply.send(Err(e));
                    continue;
                }
            }
        }
        let prog = programs[op.program].as_ref().unwrap();

        let t0 = Instant::now();
        let result = execute_op(&client, prog, &weights[prog.weights_idx], spec, &op.inputs);
        let exec_ns = t0.elapsed().as_nanos() as u64;

        record_stats(&shared.stats, op.lane, prog.flops, queue_ns, exec_ns);

        let _ = op.reply.send(result.map(|outputs| OpResult {
            outputs,
            queue_ns,
            exec_ns,
        }));
    }
}

fn record_stats(stats: &DeviceStats, lane: usize, flops: u64, queue_ns: u64, exec_ns: u64) {
    stats.ops.fetch_add(1, Ordering::Relaxed);
    stats.exec_ns.fetch_add(exec_ns, Ordering::Relaxed);
    stats.queue_ns.fetch_add(queue_ns, Ordering::Relaxed);
    stats.lane_ops[lane].fetch_add(1, Ordering::Relaxed);
    stats.lane_queue_ns[lane].fetch_add(queue_ns, Ordering::Relaxed);
    stats.flops.fetch_add(flops, Ordering::Relaxed);
}

fn compile_program(
    client: &xla::PjRtClient,
    dir: &std::path::Path,
    spec: &ArtifactSpec,
) -> Result<xla::PjRtLoadedExecutable> {
    let path = dir.join(&spec.file);
    let proto = xla::HloModuleProto::from_text_file(&path)
        .map_err(|e| anyhow!("parsing HLO {path:?}: {e:?}"))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compiling {}: {e:?}", spec.name))
}

fn execute_op(
    client: &xla::PjRtClient,
    prog: &LoadedProgram,
    weights: &[xla::PjRtBuffer],
    spec: &ArtifactSpec,
    inputs: &[HostTensor],
) -> Result<Vec<HostTensor>> {
    if inputs.len() != spec.inputs.len() {
        bail!(
            "{}: expected {} step inputs, got {}",
            spec.name,
            spec.inputs.len(),
            inputs.len()
        );
    }
    // Validate + upload step inputs.
    let mut step_buffers = Vec::with_capacity(inputs.len());
    for (tensor, ispec) in inputs.iter().zip(&spec.inputs) {
        if tensor.shape() != ispec.shape.as_slice() {
            bail!(
                "{}: input `{}` shape mismatch: got {:?}, want {:?}",
                spec.name,
                ispec.name,
                tensor.shape(),
                ispec.shape
            );
        }
        let buf = match tensor {
            HostTensor::F32 { data, shape } => {
                client.buffer_from_host_buffer::<f32>(data, shape, None)
            }
            HostTensor::I32 { data, shape } => {
                client.buffer_from_host_buffer::<i32>(data, shape, None)
            }
        }
        .map_err(|e| anyhow!("{}: uploading input: {e:?}", spec.name))?;
        step_buffers.push(buf);
    }

    let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(weights.len() + step_buffers.len());
    args.extend(weights.iter());
    args.extend(step_buffers.iter());

    let result = prog
        .exe
        .execute_b(&args)
        .map_err(|e| anyhow!("{}: execute: {e:?}", spec.name))?;
    let tuple = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("{}: readback: {e:?}", spec.name))?;
    let literals = tuple
        .to_tuple()
        .map_err(|e| anyhow!("{}: untuple: {e:?}", spec.name))?;

    let mut outputs = Vec::with_capacity(literals.len());
    for (lit, ospec) in literals.iter().zip(&spec.outputs) {
        let ty = lit
            .ty()
            .map_err(|e| anyhow!("{}: output type: {e:?}", spec.name))?;
        let t = match ty {
            xla::ElementType::F32 => HostTensor::F32 {
                data: lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("{}: output read: {e:?}", spec.name))?,
                shape: ospec.shape.clone(),
            },
            xla::ElementType::S32 => HostTensor::I32 {
                data: lit
                    .to_vec::<i32>()
                    .map_err(|e| anyhow!("{}: output read: {e:?}", spec.name))?,
                shape: ospec.shape.clone(),
            },
            other => bail!("{}: unsupported output type {other:?}", spec.name),
        };
        outputs.push(t);
    }
    Ok(outputs)
}
