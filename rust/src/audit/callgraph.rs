//! Per-function call-site extraction and a whole-crate name-resolution
//! graph.  Resolution is deliberately conservative (an over-approximation):
//! a call edge that might exist is included, so the reachability passes
//! (lock-order, hot-tick) can miss nothing a human would consider
//! reachable.  The price is occasional spurious edges through common
//! method names; those are bounded by the same-file preference, the
//! qualifier/owner match, and the [`AMBIENT`] damping rule below, and any
//! residual false positive is waivable with `// audit-allow:`.
//!
//! **Ambient names.**  Without type information, `out.push(r)` on a local
//! `Vec` is indistinguishable from `self.synapse.push(..)` — and a crate
//! that defines `Synapse::push` would acquire every `Vec::push` in the
//! tree as a spurious edge into rank-50 territory.  Names on the
//! [`AMBIENT`] list (std container / iterator / atomic / channel method
//! vocabulary) therefore resolve only through an *explicit* receiver:
//! `Owner::name(..)` by qualifier match, or `self.name(..)` to a method
//! of the enclosing impl.  A real cross-object call through such a name
//! (`table.drain(..)` meaning a crate method) is a lost edge — the
//! documented price for not drowning lock-order in Vec noise.  Free
//! `drop(x)` is the extreme case: it releases a guard (the lock-order
//! simulation models that separately) and must never resolve to the
//! crate's `Drop` impls, whose bodies the runtime checker covers.
//!
//! Known seams the resolver cannot cross (documented limitation): calls
//! through closures and `fn`-pointer fields (the scheduler's `spawner` /
//! `admit` / `exec` hooks), and trait-object dispatch.  Lock-order and
//! hot-tick therefore also scan every function *body* for direct lock /
//! blocking tokens, so a seam hides an edge but never a site.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use super::items::{FnInfo, SourceFile};

/// Words that look like calls but never are (keywords, prelude
/// constructors, control flow).
const NON_CALLS: &[&str] = &[
    "if", "else", "match", "while", "for", "loop", "return", "break", "continue", "in", "let",
    "mut", "as", "fn", "impl", "struct", "enum", "union", "trait", "mod", "use", "pub", "const",
    "static", "ref", "move", "async", "await", "dyn", "where", "unsafe", "type", "Some", "None",
    "Ok", "Err", "self", "Self", "super", "crate", "true", "false",
];

/// Method names shadowed by the std prelude vocabulary (Vec, HashMap,
/// Option/Result, atomics, mpsc, Condvar, iterators).  Unqualified calls
/// through these names resolve only to `self.name(..)` on the enclosing
/// impl or via an explicit `Owner::name(..)` qualifier — never through
/// the cross-file fallback.  Sorted; extend when a crate fn adopts a new
/// std-colliding name and starts leaking spurious edges.
const AMBIENT: &[&str] = &[
    "abs", "all", "any", "clear", "clone", "cloned", "collect", "contains", "count", "drain",
    "drop", "entry", "expect", "extend", "filter", "find", "first", "flush", "fold", "get",
    "get_mut", "insert", "is_empty", "iter", "join", "last", "len", "load", "lock", "map", "max",
    "min", "next", "peek", "pop", "position", "push", "read", "recv", "remove", "retain", "send",
    "set", "split", "store", "sum", "swap", "take", "unwrap", "wait", "write",
];

/// One syntactic call site inside a function body.
#[derive(Debug, Clone)]
pub struct CallSite {
    /// 0-based source line.
    pub line: usize,
    /// Callee name (`route`, `println` for macros).
    pub callee: String,
    /// `Q` in `Q::callee(...)`, when present.
    pub qualifier: Option<String>,
    /// Preceded by `.` — a method call.
    pub is_method: bool,
    /// The ident immediately before the dot of a method call (`self` in
    /// `self.route(..)`, `state` in `self.state.lock()`); `None` for a
    /// chained receiver (`)` / `]`) or a non-method call.
    pub receiver: Option<String>,
    /// `name!(...)` — macro invocation.
    pub is_macro: bool,
}

/// One `.lock()` acquisition site: the receiver field name it resolves
/// through (`state` in `self.state.lock()` / `table.state.lock()`).
#[derive(Debug, Clone)]
pub struct LockSite {
    pub line: usize,
    pub receiver: String,
    /// `true` when the guard is bound with `let` (held beyond the line).
    pub bound: bool,
}

/// Extract call sites from the stripped body of `f`.
pub fn call_sites(file: &SourceFile, f: &FnInfo) -> Vec<CallSite> {
    let mut out = Vec::new();
    for line in f.start..=f.end.min(file.stripped.code.len().saturating_sub(1)) {
        let code = &file.stripped.code[line];
        let words = super::lexer::idents(code);
        for (wi, &(start, word)) in words.iter().enumerate() {
            if NON_CALLS.contains(&word) {
                continue;
            }
            // The token right before the name tells us what it is.
            let before = code[..start].trim_end();
            // Item definitions (`fn name(`, `struct Name(`) are not calls.
            if let Some(&(_, prev)) = wi.checked_sub(1).and_then(|p| words.get(p)) {
                if before.ends_with(prev)
                    && matches!(prev, "fn" | "struct" | "enum" | "union" | "trait" | "mod")
                {
                    continue;
                }
            }
            let after = &code[start + word.len()..];
            let after_trim = after.trim_start();
            let is_macro = after_trim.starts_with('!')
                && after_trim[1..]
                    .trim_start()
                    .starts_with(['(', '[', '{']);
            let is_call = after_trim.starts_with('(');
            if !is_macro && !is_call {
                continue;
            }
            let is_method = before.ends_with('.');
            let receiver = if is_method {
                let head = before[..before.len() - 1].trim_end();
                let r: String = head
                    .chars()
                    .rev()
                    .take_while(|c| c.is_alphanumeric() || *c == '_')
                    .collect::<Vec<_>>()
                    .into_iter()
                    .rev()
                    .collect();
                if r.is_empty() { None } else { Some(r) }
            } else {
                None
            };
            let qualifier = if before.ends_with("::") {
                let q = before[..before.len() - 2].trim_end();
                let qname: String = q
                    .chars()
                    .rev()
                    .take_while(|c| c.is_alphanumeric() || *c == '_')
                    .collect::<Vec<_>>()
                    .into_iter()
                    .rev()
                    .collect();
                if qname.is_empty() { None } else { Some(qname) }
            } else {
                None
            };
            out.push(CallSite {
                line,
                callee: word.to_string(),
                qualifier,
                is_method,
                receiver,
                is_macro,
            });
        }
    }
    out
}

/// Extract `.lock()` sites from the stripped body of `f`.
pub fn lock_sites(file: &SourceFile, f: &FnInfo) -> Vec<LockSite> {
    let mut out = Vec::new();
    for line in f.start..=f.end.min(file.stripped.code.len().saturating_sub(1)) {
        let code = &file.stripped.code[line];
        let mut from = 0;
        while let Some(rel) = code[from..].find(".lock()") {
            let abs = from + rel;
            from = abs + ".lock()".len();
            // Walk back over the receiver path: idents joined by `.`.
            let head = &code[..abs];
            let receiver: String = head
                .chars()
                .rev()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect::<Vec<_>>()
                .into_iter()
                .rev()
                .collect();
            if receiver.is_empty() {
                continue;
            }
            let bound = code.trim_start().starts_with("let ")
                || code.trim_start().starts_with("let(")
                || code.contains("= ranked_wait");
            out.push(LockSite {
                line,
                receiver,
                bound,
            });
        }
    }
    out
}

/// Stable function identity across the scanned file set.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct FnId {
    pub file: usize,
    pub idx: usize,
}

/// Whole-crate call graph over a set of parsed files.
pub struct CrateGraph<'a> {
    pub files: &'a [SourceFile],
    /// name → all non-test fns bearing it.
    by_name: BTreeMap<&'a str, Vec<FnId>>,
    /// Resolved call edges per function, with the originating line.
    pub edges: BTreeMap<FnId, Vec<(usize, FnId)>>,
    /// All call sites per function (resolved or not) for token passes.
    pub sites: BTreeMap<FnId, Vec<CallSite>>,
}

impl<'a> CrateGraph<'a> {
    pub fn build(files: &'a [SourceFile]) -> CrateGraph<'a> {
        let mut by_name: BTreeMap<&str, Vec<FnId>> = BTreeMap::new();
        for (fi, file) in files.iter().enumerate() {
            for (xi, f) in file.fns.iter().enumerate() {
                if f.is_test {
                    continue;
                }
                by_name
                    .entry(f.name.as_str())
                    .or_default()
                    .push(FnId { file: fi, idx: xi });
            }
        }
        let mut graph = CrateGraph {
            files,
            by_name,
            edges: BTreeMap::new(),
            sites: BTreeMap::new(),
        };
        for (fi, file) in files.iter().enumerate() {
            for (xi, f) in file.fns.iter().enumerate() {
                if f.is_test {
                    continue;
                }
                let id = FnId { file: fi, idx: xi };
                let sites = call_sites(file, f);
                let mut edges = Vec::new();
                for s in &sites {
                    for callee in graph.resolve(s, fi, f.owner.as_deref()) {
                        edges.push((s.line, callee));
                    }
                }
                graph.edges.insert(id, edges);
                graph.sites.insert(id, sites);
            }
        }
        graph
    }

    pub fn info(&self, id: FnId) -> &FnInfo {
        &self.files[id.file].fns[id.idx]
    }

    /// Display form: `module::Owner::name`.
    pub fn label(&self, id: FnId) -> String {
        format!(
            "{}::{}",
            self.files[id.file].module.trim_end_matches(".rs"),
            self.info(id).qualified()
        )
    }

    /// Resolve one call site to candidate crate functions.
    fn resolve(&self, site: &CallSite, caller_file: usize, caller_owner: Option<&str>) -> Vec<FnId> {
        if site.is_macro {
            return Vec::new();
        }
        let Some(cands) = self.by_name.get(site.callee.as_str()) else {
            return Vec::new();
        };
        // `Q::f(...)`: only fns whose impl owner is `Q` (`Self::f` maps to
        // the caller's own impl).  A lowercase qualifier is a module path;
        // owner matching still applies (and usually yields nothing — std
        // calls stay unresolved).
        if let Some(q) = &site.qualifier {
            let want = if q == "Self" { caller_owner } else { Some(q.as_str()) };
            let Some(want) = want else { return Vec::new() };
            return cands
                .iter()
                .copied()
                .filter(|id| self.info(*id).owner.as_deref() == Some(want))
                .collect();
        }
        // Std-shadowed vocabulary: only `self.name(..)` to the enclosing
        // impl resolves; everything else is Vec/HashMap/atomic noise.
        if AMBIENT.contains(&site.callee.as_str()) {
            if site.is_method && site.receiver.as_deref() == Some("self") {
                if let Some(owner) = caller_owner {
                    return cands
                        .iter()
                        .copied()
                        .filter(|id| {
                            id.file == caller_file
                                && self.info(*id).owner.as_deref() == Some(owner)
                        })
                        .collect();
                }
            }
            return Vec::new();
        }
        // Unqualified / method call: prefer same-file candidates (the
        // overwhelmingly common case for `self.helper()` and free calls),
        // else link every crate candidate — conservative.
        let same_file: Vec<FnId> = cands
            .iter()
            .copied()
            .filter(|id| id.file == caller_file)
            .collect();
        if !same_file.is_empty() {
            return same_file;
        }
        if site.is_method {
            // Cross-file method call: only methods can match.
            return cands
                .iter()
                .copied()
                .filter(|id| self.info(*id).owner.is_some())
                .collect();
        }
        cands.clone()
    }

    /// All functions reachable from `roots` (inclusive), BFS order.
    pub fn reachable(&self, roots: &[FnId]) -> Vec<FnId> {
        let mut seen: BTreeSet<FnId> = roots.iter().copied().collect();
        let mut queue: VecDeque<FnId> = roots.iter().copied().collect();
        let mut order = Vec::new();
        while let Some(id) = queue.pop_front() {
            order.push(id);
            if let Some(edges) = self.edges.get(&id) {
                for &(_, callee) in edges {
                    if seen.insert(callee) {
                        queue.push_back(callee);
                    }
                }
            }
        }
        order
    }

    /// Find every non-test fn named `name` (optionally owner-qualified).
    pub fn find(&self, name: &str) -> Vec<FnId> {
        self.by_name.get(name).cloned().unwrap_or_default()
    }

    /// Shortest call path root → target, as display labels; None when
    /// unreachable.
    pub fn path(&self, root: FnId, target: FnId) -> Option<Vec<String>> {
        let mut prev: BTreeMap<FnId, FnId> = BTreeMap::new();
        let mut queue = VecDeque::from([root]);
        let mut seen = BTreeSet::from([root]);
        while let Some(id) = queue.pop_front() {
            if id == target {
                let mut chain = vec![self.label(id)];
                let mut cur = id;
                while let Some(&p) = prev.get(&cur) {
                    chain.push(self.label(p));
                    cur = p;
                }
                chain.reverse();
                return Some(chain);
            }
            if let Some(edges) = self.edges.get(&id) {
                for &(_, callee) in edges {
                    if seen.insert(callee) {
                        prev.insert(callee, id);
                        queue.push_back(callee);
                    }
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::audit::items::SourceFile;

    fn single(src: &str) -> Vec<SourceFile> {
        vec![SourceFile::parse("rust/src/a.rs", src)]
    }

    #[test]
    fn direct_and_method_calls_resolve_same_file() {
        let files = single(
            "fn root() {\n    helper();\n    self.dispatch();\n}\n\
             fn helper() {}\n\
             struct S;\nimpl S {\n    fn dispatch(&self) {}\n}\n",
        );
        let g = CrateGraph::build(&files);
        let root = g.find("root")[0];
        let names: Vec<String> = g.edges[&root]
            .iter()
            .map(|&(_, id)| g.info(id).name.clone())
            .collect();
        assert_eq!(names, vec!["helper", "dispatch"]);
    }

    #[test]
    fn qualified_calls_require_owner_match() {
        let files = single(
            "fn root() {\n    S::build();\n    VecDeque::new();\n}\n\
             struct S;\nimpl S {\n    fn build() {}\n}\n\
             struct T;\nimpl T {\n    fn new() {}\n}\n",
        );
        let g = CrateGraph::build(&files);
        let root = g.find("root")[0];
        let names: Vec<String> = g.edges[&root]
            .iter()
            .map(|&(_, id)| g.info(id).name.clone())
            .collect();
        // S::build resolves; VecDeque::new must NOT resolve to T::new.
        assert_eq!(names, vec!["build"]);
    }

    #[test]
    fn macros_are_sites_but_not_edges() {
        let files = single("fn root() {\n    println!(\"x\");\n}\nfn println() {}\n");
        let g = CrateGraph::build(&files);
        let root = g.find("root")[0];
        assert!(g.edges[&root].is_empty());
        let site = &g.sites[&root][0];
        assert!(site.is_macro);
        assert_eq!(site.callee, "println");
    }

    #[test]
    fn reachability_and_paths() {
        let files = single(
            "fn a() {\n    b();\n}\nfn b() {\n    c();\n}\nfn c() {}\nfn lonely() {}\n",
        );
        let g = CrateGraph::build(&files);
        let a = g.find("a")[0];
        let c = g.find("c")[0];
        let lonely = g.find("lonely")[0];
        let reach = g.reachable(&[a]);
        assert!(reach.contains(&c));
        assert!(!reach.contains(&lonely));
        let path = g.path(a, c).unwrap();
        assert_eq!(path.len(), 3);
        assert!(path[2].ends_with("::c"));
    }

    #[test]
    fn lock_sites_recover_the_receiver_field() {
        let files = single(
            "struct S;\nimpl S {\n    fn f(&self) {\n        let st = self.state.lock();\n        table.results.lock().push(1);\n    }\n}\n",
        );
        let g = CrateGraph::build(&files);
        let f = &files[0].fns[0];
        let sites = lock_sites(&files[0], f);
        assert_eq!(sites.len(), 2);
        assert_eq!(sites[0].receiver, "state");
        assert!(sites[0].bound);
        assert_eq!(sites[1].receiver, "results");
        assert!(!sites[1].bound);
    }

    #[test]
    fn ambient_names_do_not_cross_resolve() {
        // `out.push(r)` on a local Vec must NOT link to Synapse::push in
        // another file; `self.push(..)` inside the impl must.
        let files = vec![
            SourceFile::parse(
                "rust/src/cortex/synapse.rs",
                "struct Synapse;\nimpl Synapse {\n    pub fn push(&self) {\n        self.push();\n    }\n}\n",
            ),
            SourceFile::parse(
                "rust/src/cortex/scheduler.rs",
                "struct Sched;\nimpl Sched {\n    fn poll(&self) {\n        let mut out = Vec::new();\n        out.push(1);\n        drop(out);\n    }\n}\n",
            ),
        ];
        let g = CrateGraph::build(&files);
        let poll = g.find("poll")[0];
        assert!(g.edges[&poll].is_empty(), "Vec::push / drop must stay unresolved");
        let push = g.find("push")[0];
        let self_edges: Vec<String> = g.edges[&push]
            .iter()
            .map(|&(_, id)| g.info(id).name.clone())
            .collect();
        assert_eq!(self_edges, vec!["push"], "self.push resolves to the enclosing impl");
    }

    #[test]
    fn free_drop_never_resolves_to_drop_impls() {
        let files = vec![
            SourceFile::parse(
                "rust/src/a.rs",
                "struct Permit;\nimpl Drop for Permit {\n    fn drop(&mut self) {\n        helper();\n    }\n}\nfn helper() {}\n",
            ),
            SourceFile::parse("rust/src/b.rs", "fn release(x: Permit) {\n    drop(x);\n}\n"),
        ];
        let g = CrateGraph::build(&files);
        let release = g.find("release")[0];
        assert!(g.edges[&release].is_empty());
    }

    #[test]
    fn self_qualified_calls_resolve_to_the_enclosing_impl() {
        let files = single(
            "struct S;\nimpl S {\n    fn a(&self) {\n        Self::b();\n    }\n    fn b() {}\n}\n\
             struct T;\nimpl T {\n    fn b() {}\n}\n",
        );
        let g = CrateGraph::build(&files);
        let a = g.find("a")[0];
        let edges: Vec<FnId> = g.edges[&a].iter().map(|&(_, id)| id).collect();
        assert_eq!(edges.len(), 1);
        assert_eq!(g.info(edges[0]).owner.as_deref(), Some("S"));
    }

    #[test]
    fn call_sites_record_the_method_receiver() {
        let files = single(
            "fn f() {\n    self.route(1);\n    self.state.lock();\n    make().chain();\n}\n",
        );
        let sites = call_sites(&files[0], &files[0].fns[0]);
        let by_name: std::collections::BTreeMap<&str, &CallSite> =
            sites.iter().map(|s| (s.callee.as_str(), s)).collect();
        assert_eq!(by_name["route"].receiver.as_deref(), Some("self"));
        assert_eq!(by_name["lock"].receiver.as_deref(), Some("state"));
        assert_eq!(by_name["chain"].receiver, None);
        assert_eq!(by_name["make"].receiver, None);
    }

    #[test]
    fn test_fns_are_excluded_from_the_graph() {
        let files = single("#[test]\nfn t() {\n    prod();\n}\nfn prod() {}\n");
        let g = CrateGraph::build(&files);
        assert!(g.find("t").is_empty());
        assert_eq!(g.find("prod").len(), 1);
    }
}
