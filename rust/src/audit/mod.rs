//! warp-audit v2: the crate-graph static analyzer behind the `warp-audit`
//! bin and the CI `audit` job.
//!
//! Self-contained on purpose — no external parser dependencies, same
//! offline constraint as `xla_stub`.  The pipeline:
//!
//! 1. [`lexer`] splits each source file into per-line `code` / `comments`
//!    / `strings` channels (raw strings, nested block comments, char
//!    literals and lifetimes handled; never panics on arbitrary bytes).
//! 2. [`items`] recovers item structure from the stripped code:
//!    `#[cfg(test)]` regions, `fn` boundaries with their `impl` owner,
//!    and a per-line innermost-function map.
//! 3. [`callgraph`] extracts call and `.lock()` sites per function and
//!    resolves them crate-wide (conservative over-approximation;
//!    qualifier/owner matching, same-file preference).
//! 4. [`passes`] runs the rules: the five PR 7 token rules (re-hosted,
//!    findings identical — see `rust/tests/audit_roundtrip.rs`), the
//!    whole-crate `lock-order` / `gauge-lineage` / `hot-tick` passes,
//!    and the `stale-allow` suppression audit.
//!
//! The static `LockRank` table is parsed out of `util/sync.rs` source and
//! cross-checked against the runtime enum ([`crate::util::sync::LockRank::ALL`])
//! so the static and dynamic checkers can never drift.  See the
//! "Correctness tooling" section in [`crate::cortex`] for which checker —
//! static pass, runtime sanitizer, or proptest — owns each invariant.

pub mod callgraph;
pub mod items;
pub mod lexer;
pub mod passes;

pub use items::SourceFile;
pub use passes::{allowed_rules, run, AuditInput, AuditReport, Finding, Rule};
