//! Item extraction over the stripped source: test-region marking,
//! `fn` / `impl` boundaries, and a per-line map to the innermost
//! enclosing function — the substrate the call-graph and the whole-crate
//! passes (lock-order, gauge-lineage, hot-tick) are built on.
//!
//! This is a brace-tracking heuristic parser, not a grammar: it only
//! needs item *boundaries* and owner types, which brace/paren depth
//! recovers exactly on stripped code (strings and comments can no longer
//! confuse the depth counters).  Trait-method declarations without a
//! body (`fn f();`) are skipped; nested `fn` items are recorded and own
//! their lines (the enclosing function resumes after them).

use super::lexer::{strip, Stripped};

/// Brace-tracking skip state for `#[cfg(test)]` / `#[test]` items —
/// byte-for-byte the legacy scanner's semantics, so the re-hosted token
/// rules reproduce its findings exactly.
#[derive(Default)]
pub struct TestSkip {
    /// Saw the attribute; waiting for the item body to open.
    pending: bool,
    /// Inside the item body at this brace depth.
    depth: usize,
    active: bool,
}

impl TestSkip {
    /// Feed one stripped line; true when it belongs to a test item
    /// (including the attribute lines themselves).
    pub fn observe(&mut self, line: &str) -> bool {
        let trimmed = line.trim();
        if self.active {
            for c in trimmed.chars() {
                match c {
                    '{' => self.depth += 1,
                    '}' if self.depth > 0 => {
                        self.depth -= 1;
                        if self.depth == 0 {
                            self.active = false;
                        }
                    }
                    _ => {}
                }
            }
            return true;
        }
        if self.pending {
            let mut saw_open = false;
            for c in trimmed.chars() {
                match c {
                    '{' => {
                        saw_open = true;
                        self.depth += 1;
                    }
                    '}' if self.depth > 0 => self.depth -= 1,
                    ';' if self.depth == 0 && !saw_open => {
                        // Bodyless item (`mod tests;`, `use ...;`).
                        self.pending = false;
                        return true;
                    }
                    _ => {}
                }
            }
            if saw_open {
                self.pending = false;
                if self.depth > 0 {
                    self.active = true;
                }
            }
            return true;
        }
        if trimmed.starts_with("#[cfg(test)")
            || trimmed.starts_with("#[test]")
            || trimmed.starts_with("#[cfg(all(test")
        {
            self.pending = true;
            return true;
        }
        false
    }
}

/// One extracted function item.
#[derive(Debug, Clone)]
pub struct FnInfo {
    /// Bare name (`route`).
    pub name: String,
    /// Owning `impl` type, when the fn is a method (`SessionTable`).
    pub owner: Option<String>,
    /// 0-based line of the `fn` keyword.
    pub start: usize,
    /// 0-based line of the closing brace (inclusive).
    pub end: usize,
    /// Declared inside a `#[cfg(test)]` / `#[test]` region.
    pub is_test: bool,
}

impl FnInfo {
    /// `Owner::name` or bare `name` — the display form findings use.
    pub fn qualified(&self) -> String {
        match &self.owner {
            Some(o) => format!("{o}::{}", self.name),
            None => self.name.clone(),
        }
    }
}

/// One scanned source file with its extracted structure.
pub struct SourceFile {
    /// Path as given to the scanner (display form).
    pub path: String,
    /// Path relative to the last `/src/` component — the scope key the
    /// per-module rules match on.
    pub module: String,
    pub stripped: Stripped,
    pub fns: Vec<FnInfo>,
    /// Per line: index into `fns` of the innermost enclosing function.
    pub line_fn: Vec<Option<usize>>,
    /// Per line: inside a `#[cfg(test)]` / `#[test]` region.
    pub test_lines: Vec<bool>,
}

impl SourceFile {
    pub fn parse(path: &str, src: &str) -> SourceFile {
        let stripped = strip(src);
        let mut skip = TestSkip::default();
        let test_lines: Vec<bool> = stripped.code.iter().map(|l| skip.observe(l)).collect();
        let (fns, line_fn) = extract_fns(&stripped, &test_lines);
        SourceFile {
            path: path.to_string(),
            module: normalize_module(path),
            stripped,
            fns,
            line_fn,
            test_lines,
        }
    }

    /// The function declaring `line` (0-based), innermost first.
    pub fn fn_at(&self, line: usize) -> Option<&FnInfo> {
        self.line_fn.get(line).copied().flatten().map(|i| &self.fns[i])
    }
}

/// Module path relative to the last `/src/` component; the raw path when
/// there is none.
pub fn normalize_module(path: &str) -> String {
    let s = path.replace('\\', "/");
    match s.rfind("/src/") {
        Some(p) => s[p + "/src/".len()..].to_string(),
        None => s,
    }
}

/// After `impl`, recover the implemented type: skip generics, and for
/// `impl Trait for Type` take the segment after `for`.  `rest` is the
/// text following the `impl` keyword on its line (signatures that wrap
/// are joined by the caller).
fn impl_type(rest: &str) -> Option<String> {
    // Strip a leading generics list `<...>` (depth-balanced).
    let rest = rest.trim_start();
    let rest = if let Some(s) = rest.strip_prefix('<') {
        let mut depth = 1usize;
        let mut end = 0;
        for (i, c) in s.char_indices() {
            match c {
                '<' => depth += 1,
                '>' => {
                    depth -= 1;
                    if depth == 0 {
                        end = i + 1;
                        break;
                    }
                }
                _ => {}
            }
        }
        &s[end.min(s.len())..]
    } else {
        rest
    };
    // Body of the impl header up to `{` or `where`.
    let head = rest.split('{').next().unwrap_or(rest);
    let head = head.split(" where ").next().unwrap_or(head);
    let subject = match head.find(" for ") {
        Some(p) => &head[p + " for ".len()..],
        None => head,
    };
    // Last path segment, generics dropped: `kv::KvCache<'a>` → `KvCache`.
    let subject = subject.split('<').next().unwrap_or(subject).trim();
    let name = subject.rsplit("::").next().unwrap_or(subject).trim();
    let name: String = name
        .chars()
        .take_while(|c| c.is_alphanumeric() || *c == '_')
        .collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

enum Frame {
    /// Opened by `impl X {` — `depth` is the brace depth *inside* it.
    Impl { name: Option<String>, depth: usize },
    /// Opened by `fn name(...) {` — index into the output fn table.
    Fn { index: usize, depth: usize },
    /// Any other brace (block, struct, match arm, closure, ...).
    Other { depth: usize },
}

/// Waiting for a pending item header's body brace.
enum Pending {
    None,
    Impl { name: Option<String> },
    Fn { index: usize, paren_depth: i32 },
}

fn extract_fns(
    stripped: &Stripped,
    test_lines: &[bool],
) -> (Vec<FnInfo>, Vec<Option<usize>>) {
    let mut fns: Vec<FnInfo> = Vec::new();
    let mut line_fn: Vec<Option<usize>> = vec![None; stripped.code.len()];
    let mut stack: Vec<Frame> = Vec::new();
    let mut depth: usize = 0;
    let mut pending = Pending::None;

    for (lineno, line) in stripped.code.iter().enumerate() {
        // Record the innermost enclosing fn for this line BEFORE scanning
        // it (the `fn` line itself belongs to the new fn — patched below).
        let mut innermost = stack
            .iter()
            .rev()
            .find_map(|f| match f {
                Frame::Fn { index, .. } => Some(*index),
                _ => None,
            });

        let chars: Vec<char> = line.chars().collect();
        let mut i = 0;
        while i < chars.len() {
            let c = chars[i];
            // Identifier scan: catch `impl` / `fn` keywords.
            if (c.is_ascii_alphabetic() || c == '_') && !prev_ident(&chars, i) {
                let start = i;
                while i < chars.len() && (chars[i].is_alphanumeric() || chars[i] == '_') {
                    i += 1;
                }
                let word: String = chars[start..i].iter().collect();
                if matches!(pending, Pending::None) {
                    if word == "impl" {
                        let rest: String = chars[i..].iter().collect();
                        // The header may wrap; a None name is tolerated
                        // and refined when the brace opens on this line.
                        pending = Pending::Impl {
                            name: impl_type(&rest),
                        };
                        // Keep scanning this line for the opening brace.
                        continue;
                    }
                    if word == "fn" {
                        // Next token must be the name (a bare `fn(` is a
                        // function-pointer type, not an item).
                        let mut j = i;
                        while j < chars.len() && chars[j].is_whitespace() {
                            j += 1;
                        }
                        let name_start = j;
                        while j < chars.len() && (chars[j].is_alphanumeric() || chars[j] == '_')
                        {
                            j += 1;
                        }
                        if j > name_start {
                            let name: String = chars[name_start..j].iter().collect();
                            let owner = stack.iter().rev().find_map(|f| match f {
                                Frame::Impl { name, .. } => name.clone(),
                                _ => None,
                            });
                            fns.push(FnInfo {
                                name,
                                owner,
                                start: lineno,
                                end: lineno,
                                is_test: test_lines.get(lineno).copied().unwrap_or(false),
                            });
                            pending = Pending::Fn {
                                index: fns.len() - 1,
                                paren_depth: 0,
                            };
                            innermost = Some(fns.len() - 1);
                            i = j;
                        }
                        continue;
                    }
                }
                continue;
            }
            match c {
                '(' => {
                    if let Pending::Fn { paren_depth, .. } = &mut pending {
                        *paren_depth += 1;
                    }
                }
                ')' => {
                    if let Pending::Fn { paren_depth, .. } = &mut pending {
                        *paren_depth -= 1;
                    }
                }
                ';' => {
                    // Bodyless declaration at paren depth 0 cancels the
                    // pending item (trait method, fn-pointer alias).
                    match &pending {
                        Pending::Fn { paren_depth: 0, .. } | Pending::Impl { .. } => {
                            pending = Pending::None;
                        }
                        _ => {}
                    }
                }
                '{' => {
                    depth += 1;
                    match std::mem::replace(&mut pending, Pending::None) {
                        Pending::Impl { name } => stack.push(Frame::Impl { name, depth }),
                        Pending::Fn { index, .. } => {
                            stack.push(Frame::Fn { index, depth });
                        }
                        Pending::None => stack.push(Frame::Other { depth }),
                    }
                }
                '}' => {
                    depth = depth.saturating_sub(1);
                    while let Some(top) = stack.last() {
                        let d = match top {
                            Frame::Impl { depth, .. }
                            | Frame::Fn { depth, .. }
                            | Frame::Other { depth } => *depth,
                        };
                        if d > depth {
                            if let Some(Frame::Fn { index, .. }) = stack.pop().map(|f| match f {
                                Frame::Fn { index, depth } => Frame::Fn { index, depth },
                                other => other,
                            }) {
                                fns[index].end = lineno;
                            }
                        } else {
                            break;
                        }
                    }
                }
                _ => {}
            }
            i += 1;
        }
        line_fn[lineno] = innermost;
    }
    // Unclosed fns (truncated input) end at the last line.
    let last = stripped.code.len().saturating_sub(1);
    for f in stack {
        if let Frame::Fn { index, .. } = f {
            fns[index].end = last;
        }
    }
    (fns, line_fn)
}

fn prev_ident(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_')
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(src: &str) -> SourceFile {
        SourceFile::parse("rust/src/x/y.rs", src)
    }

    #[test]
    fn extracts_free_and_impl_fns() {
        let src = "fn free() {\n    body();\n}\n\
                   struct S;\n\
                   impl S {\n    fn method(&self) -> u32 {\n        1\n    }\n}\n\
                   impl Drop for S {\n    fn drop(&mut self) {}\n}\n";
        let f = parse(src);
        let names: Vec<String> = f.fns.iter().map(|x| x.qualified()).collect();
        assert_eq!(names, vec!["free", "S::method", "S::drop"]);
        assert_eq!(f.fns[0].start, 0);
        assert_eq!(f.fns[0].end, 2);
        assert_eq!(f.fns[1].start, 5);
        assert_eq!(f.fns[1].end, 7);
    }

    #[test]
    fn line_fn_maps_to_innermost() {
        let src = "fn outer() {\n    fn inner() {\n        x();\n    }\n    y();\n}\n";
        let f = parse(src);
        assert_eq!(f.fn_at(2).unwrap().name, "inner");
        assert_eq!(f.fn_at(4).unwrap().name, "outer");
        assert!(f.fn_at(5).is_some()); // closing brace line still outer's
    }

    #[test]
    fn generic_impls_and_trait_impls_resolve_the_type() {
        let src = "impl<T> Deref for RankedGuard<'_, T> {\n    fn deref(&self) -> &T { x() }\n}\n\
                   impl<'a> Wrapper<'a> {\n    fn get(&self) {}\n}\n";
        let f = parse(src);
        let names: Vec<String> = f.fns.iter().map(|x| x.qualified()).collect();
        assert_eq!(names, vec!["RankedGuard::deref", "Wrapper::get"]);
    }

    #[test]
    fn trait_method_declarations_without_body_are_skipped() {
        let src = "trait T {\n    fn decl(&self);\n    fn with_default(&self) {\n        1;\n    }\n}\n";
        let f = parse(src);
        let names: Vec<&str> = f.fns.iter().map(|x| x.name.as_str()).collect();
        assert_eq!(names, vec!["with_default"]);
    }

    #[test]
    fn test_regions_mark_fns() {
        let src = "fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn helper() {}\n    #[test]\n    fn t() {}\n}\n";
        let f = parse(src);
        assert!(!f.fns[0].is_test);
        assert!(f.fns[1].is_test, "helper inside cfg(test) mod");
        assert!(f.fns[2].is_test);
    }

    #[test]
    fn fn_pointer_types_are_not_items() {
        let src = "type Cb = fn(u32) -> u32;\nfn real(cb: fn() -> ()) {\n    cb();\n}\n";
        let f = parse(src);
        let names: Vec<&str> = f.fns.iter().map(|x| x.name.as_str()).collect();
        assert_eq!(names, vec!["real"]);
    }

    #[test]
    fn multiline_signatures_attach_the_body() {
        let src = "fn long(\n    a: u32,\n    b: u32,\n) -> u32 {\n    a + b\n}\n";
        let f = parse(src);
        assert_eq!(f.fns.len(), 1);
        assert_eq!(f.fns[0].start, 0);
        assert_eq!(f.fns[0].end, 5);
        assert_eq!(f.fn_at(4).unwrap().name, "long");
    }

    #[test]
    fn module_normalization() {
        assert_eq!(normalize_module("rust/src/util/sync.rs"), "util/sync.rs");
        assert_eq!(
            normalize_module("/abs/repo/rust/src/serve/server.rs"),
            "serve/server.rs"
        );
        assert_eq!(normalize_module("fixture.rs"), "fixture.rs");
    }
}
