//! The audit lexer: a char-level scanner that splits Rust source into
//! three per-line channels — `code` (comments, string contents and char
//! literals blanked), `comments` (comment text, for `audit-allow:`
//! markers) and `strings` (string-literal contents, for serialization-key
//! and threshold-key lineage checks).
//!
//! Line numbers are preserved exactly: every channel has one entry per
//! source line, so a finding computed on channel `i` reports source line
//! `i + 1`.  The scanner handles raw/byte strings (`r"…"`, `br##"…"##`),
//! nested block comments, escaped char literals and the
//! lifetime-vs-char-literal ambiguity, and never panics on arbitrary
//! input (a proptest drives it with random byte soup) — unterminated
//! literals simply run to end of input.
//!
//! Everything downstream of the legacy token rules AND the crate-graph
//! passes (item extraction, call-graph building, lock-order, gauge
//! lineage) consumes this one representation, so the old scanner and the
//! new passes can never disagree about what is code and what is text.

/// Source split into per-line channels; see the module doc.
#[derive(Debug, Default)]
pub struct Stripped {
    /// Code with comments, string contents and char literals blanked.
    pub code: Vec<String>,
    /// Comment text per line (`//`, `///`, `//!` and block-comment body).
    pub comments: Vec<String>,
    /// String-literal contents per line, space-joined.
    pub strings: Vec<String>,
}

impl Stripped {
    pub fn lines(&self) -> usize {
        self.code.len()
    }
}

fn newline(out: &mut Stripped) {
    out.code.push(String::new());
    out.comments.push(String::new());
    out.strings.push(String::new());
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_')
}

/// If a raw (byte) string literal starts at `i` (`r"`, `r#"`, `br##"`,
/// ...), return the index one past its closing quote.
fn raw_string_end(chars: &[char], i: usize) -> Option<usize> {
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) != Some(&'"') {
        return None;
    }
    j += 1;
    while j < chars.len() {
        if chars[j] == '"'
            && chars
                .get(j + 1..j + 1 + hashes)
                .is_some_and(|t| t.iter().all(|&c| c == '#'))
        {
            return Some(j + 1 + hashes);
        }
        j += 1;
    }
    Some(chars.len())
}

/// Split `src` into the three per-line channels.  Total work is O(len):
/// every character is visited a bounded number of times.
pub fn strip(src: &str) -> Stripped {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = Stripped::default();
    newline(&mut out);
    let mut i = 0;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            newline(&mut out);
            i += 1;
            continue;
        }
        // Line comment (covers `///` and `//!` doc comments too).
        if c == '/' && chars.get(i + 1) == Some(&'/') {
            while i < n && chars[i] != '\n' {
                out.comments.last_mut().expect("line present").push(chars[i]);
                i += 1;
            }
            continue;
        }
        // Block comment, nested.
        if c == '/' && chars.get(i + 1) == Some(&'*') {
            let mut depth = 1;
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '\n' {
                    newline(&mut out);
                    i += 1;
                } else if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    i += 2;
                } else {
                    out.comments.last_mut().expect("line present").push(chars[i]);
                    i += 1;
                }
            }
            continue;
        }
        // Raw / byte-string prefixes.
        if (c == 'r' || c == 'b') && !prev_is_ident(&chars, i) {
            if let Some(end) = raw_string_end(&chars, i) {
                for &ch in &chars[i..end] {
                    if ch == '\n' {
                        newline(&mut out);
                    } else if ch != '"' && ch != '#' {
                        out.strings.last_mut().expect("line present").push(ch);
                    }
                }
                out.strings.last_mut().expect("line present").push(' ');
                i = end;
                continue;
            }
            // `b"..."` / `b'x'`: step past the prefix; the quote handlers
            // below take over on the next iteration.
            if chars.get(i + 1) == Some(&'"') || chars.get(i + 1) == Some(&'\'') {
                i += 1;
                continue;
            }
        }
        // Plain string.
        if c == '"' {
            i += 1;
            while i < n {
                if chars[i] == '\\' {
                    if let Some(&esc) = chars.get(i + 1) {
                        out.strings.last_mut().expect("line present").push(esc);
                    }
                    i += 2;
                } else if chars[i] == '"' {
                    i += 1;
                    break;
                } else {
                    if chars[i] == '\n' {
                        newline(&mut out);
                    } else {
                        out.strings.last_mut().expect("line present").push(chars[i]);
                    }
                    i += 1;
                }
            }
            out.strings.last_mut().expect("line present").push(' ');
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            if chars.get(i + 1) == Some(&'\\') {
                // Escaped char: skip past `'\x`, then scan to the close.
                i += 3;
                while i < n && chars[i] != '\'' {
                    i += 1;
                }
                i = (i + 1).min(n);
                continue;
            }
            if chars.get(i + 2) == Some(&'\'') && chars.get(i + 1) != Some(&'\'') {
                i += 3; // 'x'
                continue;
            }
            // Lifetime: drop the quote, keep scanning.
            i += 1;
            continue;
        }
        out.code.last_mut().expect("line present").push(c);
        i += 1;
    }
    out
}

/// Iterate identifiers in one stripped-code line as `(start_col, ident)`.
pub fn idents(line: &str) -> Vec<(usize, &str)> {
    let bytes = line.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        let c = bytes[i] as char;
        if c.is_ascii_alphabetic() || c == '_' {
            let start = i;
            while i < bytes.len() && {
                let d = bytes[i] as char;
                d.is_ascii_alphanumeric() || d == '_'
            } {
                i += 1;
            }
            out.push((start, &line[start..i]));
        } else {
            i += 1;
        }
    }
    out
}

/// True when `line[pos..]` starts an identifier boundary (the char before
/// `pos` is not part of an identifier).
pub fn at_ident_start(line: &str, pos: usize) -> bool {
    pos == 0
        || !line[..pos]
            .chars()
            .next_back()
            .is_some_and(|c| c.is_alphanumeric() || c == '_')
}

/// True when the identifier ending at `end` is not followed by more
/// identifier characters.
pub fn at_ident_end(line: &str, end: usize) -> bool {
    !line[end..]
        .chars()
        .next()
        .is_some_and(|c| c.is_alphanumeric() || c == '_')
}

/// Whole-word containment: `needle` appears in `hay` at identifier
/// boundaries.
pub fn contains_word(hay: &str, needle: &str) -> bool {
    let mut start = 0;
    while let Some(rel) = hay[start..].find(needle) {
        let abs = start + rel;
        if at_ident_start(hay, abs) && at_ident_end(hay, abs + needle.len()) {
            return true;
        }
        start = abs + needle.len().max(1);
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channels_stay_line_aligned() {
        let src = "fn f() { // hi\n    let s = \"a\nb\";\n}\n/* multi\nline */\n";
        let s = strip(src);
        let n = src.lines().count() + 1; // trailing newline opens a last, empty line
        assert_eq!(s.code.len(), n);
        assert_eq!(s.comments.len(), n);
        assert_eq!(s.strings.len(), n);
    }

    #[test]
    fn string_contents_land_in_the_string_channel() {
        let s = strip("let k = \"prefix_hits\";\nlet r = r#\"raw_key\"#;\n");
        assert!(s.strings[0].contains("prefix_hits"));
        assert!(!s.code[0].contains("prefix_hits"));
        assert!(s.strings[1].contains("raw_key"));
    }

    #[test]
    fn comments_land_in_the_comment_channel() {
        let s = strip("let x = 1; // audit-allow: nan-sort\n");
        assert!(s.comments[0].contains("audit-allow: nan-sort"));
        assert!(!s.code[0].contains("audit-allow"));
    }

    #[test]
    fn lifetimes_survive_char_literals() {
        let s = strip("fn f<'a>(x: &'a str) { let c = '{'; let d = '\\''; }\n");
        // the brace inside the char literal must not appear as code
        assert_eq!(s.code[0].matches('{').count(), 1);
        assert!(s.code[0].contains("fn f<"));
    }

    #[test]
    fn word_boundaries() {
        assert!(contains_word("a.prefix_hits + 1", "prefix_hits"));
        assert!(!contains_word("a.prefix_hits_total", "prefix_hits"));
        assert!(!contains_word("my_prefix_hits", "prefix_hits"));
    }

    #[test]
    fn unterminated_literals_do_not_panic() {
        for src in ["\"abc", "r#\"abc", "'", "b'", "/* never closed", "'\\x"] {
            let s = strip(src);
            assert!(s.lines() >= 1);
        }
    }
}
