//! The audit rules: the five legacy token rules re-hosted onto the
//! shared lexer (findings bit-identical to the PR 7 scanner — a
//! round-trip test in `rust/tests/audit_roundtrip.rs` proves it), plus
//! the three crate-graph passes and the stale-suppression check.
//!
//! Pass architecture: every rule first produces *raw* findings (before
//! suppression).  Suppression is then applied centrally — a
//! `// audit-allow: <rule>` comment on the finding's line or the line
//! above it silences the finding — and the stale-suppression pass runs
//! over the raw set, flagging any marker that silences nothing.  That
//! ordering is what makes `stale-allow` sound: it sees the findings the
//! markers were written against, not the post-suppression residue.
//!
//! The whole-crate passes need context beyond one file, carried by
//! [`AuditInput`]: the parsed file set, the raw text of
//! `ci/thresholds.json`, and "extra" sources (`rust/tests/`,
//! `rust/benches/`) that count as verification references for
//! gauge-lineage but are not themselves scanned for findings.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use super::callgraph::{lock_sites, CrateGraph, FnId, LockSite};
use super::items::SourceFile;
use super::lexer::contains_word;

/// Modules on the fused-tick decode path: every mutex here must be ranked
/// (see `util::sync::LockRank`) so the deadlock detector covers it.
pub const DECODE_PATH_MODULES: [&str; 8] = [
    "model/pool.rs",
    "cortex/step.rs",
    "cortex/scheduler.rs",
    "cortex/batcher.rs",
    "cortex/prism.rs",
    "cortex/synapse.rs",
    "runtime/device.rs",
    "metrics/mod.rs",
];

/// Comparator-position sinks for the `nan-sort` rule: `partial_cmp`
/// appearing near one of these is a NaN-unsafe ordering.
const SORTERS: [&str; 5] = [
    "sort_by(",
    "sort_unstable_by(",
    "min_by(",
    "max_by(",
    "binary_search_by(",
];

/// Entry points of the fused decode tick for the `hot-tick` pass.
const HOT_ROOTS: [&str; 3] = ["step_loop", "decode_fused", "prefill_step"];

/// Tokens that mean filesystem / network IO when they appear on a
/// hot-tick-reachable line of stripped code.
const IO_TOKENS: [&str; 9] = [
    "std::fs::",
    "File::open",
    "File::create",
    "OpenOptions",
    "read_to_string",
    "TcpStream",
    "TcpListener",
    "UdpSocket",
    "stdin()",
];

/// Output macros banned on the hot tick (they take a global stdio lock).
const PRINT_MACROS: [&str; 4] = ["println", "eprintln", "print", "eprint"];

/// Gauge-struct home modules for the gauge-lineage pass.
const GAUGE_MODULES: [&str; 3] = ["model/pool.rs", "cortex/step.rs", "cortex/store.rs"];

/// Read methods of the `metrics` sinks: a `Counter` / `Histogram` /
/// `Throughput` field nobody calls one of these on is write-only.
const SINK_READS: [&str; 9] = [
    "summary",
    "percentile_ns",
    "mean_ns",
    "count",
    "total",
    "overall_per_sec",
    "recent_per_sec",
    "get",
    "snapshot",
];

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    PoisonCascade,
    NanSort,
    RawMutex,
    PanicInServe,
    FloatEq,
    LockOrder,
    GaugeLineage,
    HotTick,
    StaleAllow,
}

impl Rule {
    pub const ALL: [Rule; 9] = [
        Rule::PoisonCascade,
        Rule::NanSort,
        Rule::RawMutex,
        Rule::PanicInServe,
        Rule::FloatEq,
        Rule::LockOrder,
        Rule::GaugeLineage,
        Rule::HotTick,
        Rule::StaleAllow,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Rule::PoisonCascade => "poison-cascade",
            Rule::NanSort => "nan-sort",
            Rule::RawMutex => "raw-mutex",
            Rule::PanicInServe => "panic-in-serve",
            Rule::FloatEq => "float-eq",
            Rule::LockOrder => "lock-order",
            Rule::GaugeLineage => "gauge-lineage",
            Rule::HotTick => "hot-tick",
            Rule::StaleAllow => "stale-allow",
        }
    }

    pub fn from_name(name: &str) -> Option<Rule> {
        Rule::ALL.into_iter().find(|r| r.name() == name)
    }

    /// One-line rationale for `--list-rules`.
    pub fn rationale(self) -> &'static str {
        match self {
            Rule::PoisonCascade => {
                "a panicking session poisons a shared mutex and wedges every later \
                 session; use util::sync::lock_unpoisoned or RankedMutex::lock"
            }
            Rule::NanSort => {
                "partial_cmp in comparator position panics on NaN (sampler PR 4, \
                 synapse selector PR 2); use total_cmp"
            }
            Rule::RawMutex => {
                "bare std::sync::Mutex in a decode-path module escapes the lock-rank \
                 detector; use util::sync::RankedMutex"
            }
            Rule::PanicInServe => {
                "a request must fail as an error response, never by unwinding a \
                 serve worker"
            }
            Rule::FloatEq => {
                "exact float equality in model//cortex/ is a latent tolerance bug \
                 across the int8/host round-trips; compare within a bound or on \
                 to_bits()"
            }
            Rule::LockOrder => {
                "static lock-order check: every reachable RankedMutex acquisition \
                 path must be strictly rank-descending, even on paths no test \
                 executes"
            }
            Rule::GaugeLineage => {
                "every pool/step gauge must reach the /stats serialization and be \
                 referenced by check_invariants, a test, or ci/thresholds.json; \
                 metric sinks must be read somewhere"
            }
            Rule::HotTick => {
                "functions reachable from the fused decode tick must not do IO, \
                 sleep, print, or acquire locks ranked above SchedulerQueue"
            }
            Rule::StaleAllow => {
                "an audit-allow marker that no longer suppresses a real finding is \
                 a lie in the source; remove it"
            }
        }
    }

    /// Suppression syntax for `--list-rules`.
    pub fn suppression(self) -> &'static str {
        match self {
            Rule::StaleAllow => "not suppressible — delete the stale marker",
            _ => "// audit-allow: <rule> on the offending line or the line above",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Finding {
    /// Display path of the offending file.
    pub path: String,
    /// 1-based line.
    pub line: usize,
    pub rule: Rule,
    pub message: String,
}

/// Everything the passes need: parsed sources plus out-of-crate context.
#[derive(Default)]
pub struct AuditInput {
    pub files: Vec<SourceFile>,
    /// Raw text of `ci/thresholds.json`, when in scope.
    pub thresholds: Option<String>,
    /// `(path, source)` of reference-only texts (tests/, benches/): they
    /// count as gauge verification sites and threshold-key producers but
    /// are not scanned for findings.
    pub extras: Vec<(String, String)>,
}

pub struct AuditReport {
    /// Post-suppression findings, sorted by (path, line, rule).
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    /// The static `LockRank` table parsed out of `util/sync.rs`
    /// (name, value) — cross-checked against the runtime enum by the bin
    /// and by `rust/tests/audit_roundtrip.rs` so the two can never drift.
    pub rank_table: Vec<(String, u8)>,
}

/// Rules suppressed by an `audit-allow:` marker in this comment.
pub fn allowed_rules(comment: &str) -> Vec<Rule> {
    let Some(pos) = comment.find("audit-allow:") else {
        return Vec::new();
    };
    comment[pos + "audit-allow:".len()..]
        .split([',', ' '].as_slice())
        .filter_map(|name| Rule::from_name(name.trim()))
        .collect()
}

/// Run every pass over the input and apply suppression.
pub fn run(input: &AuditInput) -> AuditReport {
    let files = &input.files;
    let graph = CrateGraph::build(files);
    let ranks = parse_rank_enum(files);
    let tables = RankTables::build(files, &ranks);

    let mut raw: Vec<Finding> = Vec::new();
    for file in files {
        raw.extend(legacy_pass(file));
    }
    raw.extend(lock_order_pass(files, &graph, &ranks, &tables));
    raw.extend(hot_tick_pass(files, &graph, &ranks, &tables));
    raw.extend(gauge_lineage_pass(input));

    let by_path: BTreeMap<&str, &SourceFile> =
        files.iter().map(|f| (f.path.as_str(), f)).collect();
    let mut findings: Vec<Finding> = raw
        .iter()
        .filter(|f| !suppressed(f, &by_path))
        .cloned()
        .collect();
    findings.extend(stale_allow_pass(files, &raw));
    findings.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.rule.name()).cmp(&(b.path.as_str(), b.line, b.rule.name()))
    });
    AuditReport {
        findings,
        files_scanned: files.len(),
        rank_table: ranks,
    }
}

fn suppressed(f: &Finding, by_path: &BTreeMap<&str, &SourceFile>) -> bool {
    if f.rule == Rule::StaleAllow {
        return false;
    }
    let Some(file) = by_path.get(f.path.as_str()) else {
        return false; // non-source findings (thresholds.json) have no markers
    };
    let idx = f.line - 1;
    let on = |i: usize| {
        file.stripped
            .comments
            .get(i)
            .is_some_and(|c| allowed_rules(c).contains(&f.rule))
    };
    on(idx) || (idx > 0 && on(idx - 1))
}

/// Flag markers that silence no raw finding (same line or the line
/// below — the two positions suppression honors).
fn stale_allow_pass(files: &[SourceFile], raw: &[Finding]) -> Vec<Finding> {
    let mut out = Vec::new();
    for file in files {
        for (idx, comment) in file.stripped.comments.iter().enumerate() {
            // Markers inside test regions are dead by construction (rules
            // skip tests); they are noise, not lies — ignore them.
            if file.test_lines.get(idx).copied().unwrap_or(false) {
                continue;
            }
            for rule in allowed_rules(comment) {
                let used = raw.iter().any(|f| {
                    f.path == file.path
                        && f.rule == rule
                        && (f.line == idx + 1 || f.line == idx + 2)
                });
                if !used {
                    out.push(Finding {
                        path: file.path.clone(),
                        line: idx + 1,
                        rule: Rule::StaleAllow,
                        message: format!(
                            "stale suppression: no {} finding on this line or the \
                             next — remove the marker",
                            rule.name()
                        ),
                    });
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Legacy token rules (PR 7 semantics, re-hosted on the shared lexer).
// ---------------------------------------------------------------------------

/// True when `s` contains a float-typed expression shape: a float literal
/// (`1.0`, `2.5e-3`, `1f32`) or an `as f32` / `as f64` cast.  Operates on
/// stripped code, so strings and comments never match.
fn has_float_expr(s: &str) -> bool {
    if s.contains("as f32") || s.contains("as f64") {
        return true;
    }
    let c: Vec<char> = s.chars().collect();
    for i in 0..c.len() {
        if !c[i].is_ascii_digit() {
            continue;
        }
        // Must start a numeric token (not `x2`, `0x1E`, tuple index `.0`).
        if i > 0 && (c[i - 1].is_alphanumeric() || c[i - 1] == '_' || c[i - 1] == '.') {
            continue;
        }
        let mut j = i;
        while j < c.len() && (c[j].is_ascii_digit() || c[j] == '_') {
            j += 1;
        }
        match c.get(j) {
            Some('.') if c.get(j + 1).is_some_and(|d| d.is_ascii_digit()) => return true,
            Some('e') | Some('E') => {
                let mut k = j + 1;
                if matches!(c.get(k), Some('+') | Some('-')) {
                    k += 1;
                }
                if c.get(k).is_some_and(|d| d.is_ascii_digit()) {
                    return true;
                }
            }
            Some('f') => {
                let suffix = c.get(j + 1..j + 3);
                if (suffix == Some(&['3', '2']) || suffix == Some(&['6', '4']))
                    && c.get(j + 3)
                        .map_or(true, |ch| !(ch.is_alphanumeric() || *ch == '_'))
                {
                    return true;
                }
            }
            _ => {}
        }
    }
    false
}

/// Does the `==`/`!=` at byte `p` compare a float expression?  Operands
/// are bounded by the nearest expression delimiter on each side, so a
/// float literal elsewhere on the line cannot condemn an integer compare.
fn float_eq_at(line: &str, p: usize) -> bool {
    let left_all = &line[..p];
    let right_all = &line[p + 2..];
    let lb = ["(", "{", "[", ",", ";", "&&", "||"]
        .iter()
        .filter_map(|d| left_all.rfind(d).map(|q| q + d.len()))
        .max()
        .unwrap_or(0);
    let rb = [")", "}", "]", ",", ";", "&&", "||", "{"]
        .iter()
        .filter_map(|d| right_all.find(d))
        .min()
        .unwrap_or(right_all.len());
    has_float_expr(&left_all[lb..]) || has_float_expr(&right_all[..rb])
}

/// The five PR 7 rules over one file, emitting RAW findings (suppression
/// is applied centrally by [`run`]).
pub fn legacy_pass(file: &SourceFile) -> Vec<Finding> {
    let module = file.module.as_str();
    let mut findings: Vec<Finding> = Vec::new();
    let decode_path = DECODE_PATH_MODULES.contains(&module);
    let in_serve = module.starts_with("serve/");
    let in_sync = module == "util/sync.rs";
    let float_scope = module.starts_with("model/") || module.starts_with("cortex/");
    for (idx, line) in file.stripped.code.iter().enumerate() {
        if file.test_lines[idx] {
            continue;
        }
        let mut report = |rule: Rule, message: &str| {
            findings.push(Finding {
                path: file.path.clone(),
                line: idx + 1,
                rule,
                message: message.to_string(),
            });
        };
        if !in_sync {
            // Merge with the next line so a formatter-split
            // `.lock()\n.unwrap()` chain is still caught; only matches
            // that *start* on this line are reported here.
            let here = line.trim_end();
            let next = file.stripped.code.get(idx + 1).map_or("", |l| l.trim());
            let merged = format!("{here}{next}");
            for pat in [".lock().unwrap()", ".lock().expect("] {
                if let Some(p) = merged.find(pat) {
                    if p < here.len() {
                        report(
                            Rule::PoisonCascade,
                            "poison-intolerant lock: use util::sync::lock_unpoisoned \
                             or a RankedMutex",
                        );
                        break;
                    }
                }
            }
        }
        if line.contains(".partial_cmp(") {
            let window = idx.saturating_sub(2);
            let in_comparator = file.stripped.code[window..=idx]
                .iter()
                .any(|l| SORTERS.iter().any(|s| l.contains(s)));
            if in_comparator {
                report(Rule::NanSort, "NaN-unsafe comparator: use total_cmp");
            }
        }
        if decode_path {
            let mut start = 0;
            while let Some(p) = line[start..].find("Mutex::new(") {
                let abs = start + p;
                if line[..abs].ends_with("Ranked") {
                    start = abs + "Mutex::new(".len();
                    continue;
                }
                report(
                    Rule::RawMutex,
                    "bare std::sync::Mutex in a decode-path module: \
                     use util::sync::RankedMutex",
                );
                break;
            }
        }
        if in_serve {
            for pat in [".unwrap()", ".expect(", "panic!"] {
                if line.contains(pat) {
                    report(
                        Rule::PanicInServe,
                        "panic path in request handling: return an error \
                         response instead",
                    );
                    break;
                }
            }
        }
        if float_scope {
            'ops: for op in ["==", "!="] {
                let mut start = 0;
                while let Some(rel) = line[start..].find(op) {
                    let abs = start + rel;
                    // Not part of `<=`, `>=`, `=>`, compound assignment…
                    let before = line[..abs].chars().next_back();
                    let after = line[abs + 2..].chars().next();
                    let neighbor = matches!(
                        before,
                        Some('<' | '>' | '=' | '!' | '+' | '-' | '*' | '/' | '%' | '&' | '|' | '^')
                    ) || after == Some('=');
                    if !neighbor && float_eq_at(line, abs) {
                        report(
                            Rule::FloatEq,
                            "exact float equality: compare within a bound, \
                             or on to_bits() where bit-identity is the contract",
                        );
                        break 'ops;
                    }
                    start = abs + 2;
                }
            }
        }
    }
    findings
}

// ---------------------------------------------------------------------------
// Lock-rank tables.
// ---------------------------------------------------------------------------

/// Parse the `enum LockRank { Name = value, ... }` declaration out of
/// `util/sync.rs` stripped code.  Empty when the file is out of scope.
pub fn parse_rank_enum(files: &[SourceFile]) -> Vec<(String, u8)> {
    let Some(sync) = files.iter().find(|f| f.module == "util/sync.rs") else {
        return Vec::new();
    };
    let joined = sync.stripped.code.join("\n");
    let Some(p) = joined.find("enum LockRank") else {
        return Vec::new();
    };
    let Some(open) = joined[p..].find('{') else {
        return Vec::new();
    };
    let body = &joined[p + open + 1..];
    let mut out = Vec::new();
    let mut next_value: u8 = 0;
    let chars: Vec<char> = body.chars().collect();
    let mut i = 0;
    while i < chars.len() && chars[i] != '}' {
        let c = chars[i];
        if c.is_ascii_alphabetic() || c == '_' {
            let start = i;
            while i < chars.len() && (chars[i].is_alphanumeric() || chars[i] == '_') {
                i += 1;
            }
            let name: String = chars[start..i].iter().collect();
            // Optional `= value`.
            let mut j = i;
            while j < chars.len() && chars[j].is_whitespace() {
                j += 1;
            }
            let mut value = next_value;
            if chars.get(j) == Some(&'=') {
                j += 1;
                while j < chars.len() && chars[j].is_whitespace() {
                    j += 1;
                }
                let num_start = j;
                while j < chars.len() && chars[j].is_ascii_digit() {
                    j += 1;
                }
                if let Ok(v) = chars[num_start..j].iter().collect::<String>().parse() {
                    value = v;
                }
                i = j;
            }
            out.push((name, value));
            next_value = value.saturating_add(1);
            // Skip to the variant separator.
            while i < chars.len() && chars[i] != ',' && chars[i] != '}' {
                i += 1;
            }
        } else {
            i += 1;
        }
    }
    out
}

/// Per-file `field → rank` tables from `RankedMutex::new(LockRank::X, ..)`
/// construction sites, plus a global fallback for names that are unique
/// crate-wide.
pub struct RankTables {
    per_file: Vec<BTreeMap<String, u8>>,
    /// `None` marks a crate-ambiguous name (e.g. `state` in both the pool
    /// and the session table) — unusable as a fallback.
    global: BTreeMap<String, Option<u8>>,
}

impl RankTables {
    pub fn build(files: &[SourceFile], ranks: &[(String, u8)]) -> RankTables {
        let rank_of = |name: &str| ranks.iter().find(|(n, _)| n == name).map(|(_, v)| *v);
        let mut per_file = Vec::with_capacity(files.len());
        let mut global: BTreeMap<String, Option<u8>> = BTreeMap::new();
        for file in files {
            let mut table: BTreeMap<String, u8> = BTreeMap::new();
            let joined = file.stripped.code.join("\n");
            // Line starts, to skip construction sites inside test regions.
            let mut line_starts = vec![0usize];
            for (i, b) in joined.bytes().enumerate() {
                if b == b'\n' {
                    line_starts.push(i + 1);
                }
            }
            let mut from = 0;
            while let Some(rel) = joined[from..].find("RankedMutex::new") {
                let abs = from + rel;
                from = abs + "RankedMutex::new".len();
                let line = line_starts.partition_point(|&s| s <= abs) - 1;
                if file.test_lines.get(line).copied().unwrap_or(false) {
                    continue;
                }
                let after = &joined[from..];
                let Some(lr) = after.find("LockRank::") else {
                    continue;
                };
                // The rank argument sits right in the call; a far-away
                // LockRank mention is some other expression.
                if lr > 80 {
                    continue;
                }
                let rank_name: String = after[lr + "LockRank::".len()..]
                    .chars()
                    .take_while(|c| c.is_alphanumeric() || *c == '_')
                    .collect();
                let Some(rank) = rank_of(&rank_name) else {
                    continue;
                };
                if let Some(name) = binding_ident(&joined[..abs]) {
                    table.insert(name.clone(), rank);
                    global
                        .entry(name)
                        .and_modify(|v| {
                            if *v != Some(rank) {
                                *v = None;
                            }
                        })
                        .or_insert(Some(rank));
                }
            }
            per_file.push(table);
        }
        RankTables { per_file, global }
    }

    /// Resolve a `.lock()` receiver to a rank: same-file first, then the
    /// global table when the name is unambiguous crate-wide.
    pub fn resolve(&self, file_idx: usize, receiver: &str) -> Option<u8> {
        if let Some(r) = self.per_file.get(file_idx).and_then(|t| t.get(receiver)) {
            return Some(*r);
        }
        self.global.get(receiver).copied().flatten()
    }
}

/// Walk backwards from a `RankedMutex::new` site to the ident it is bound
/// to: `let x = …`, `field: …` (struct literal), `static N: T = …`, and
/// wrapper calls (`Arc::new(…)`) are all recognized.
fn binding_ident(head: &str) -> Option<String> {
    let c: Vec<char> = head.chars().collect();
    let mut i = c.len();
    loop {
        while i > 0 && c[i - 1].is_whitespace() {
            i -= 1;
        }
        if i == 0 {
            return None;
        }
        match c[i - 1] {
            '(' => {
                // A wrapper call: skip `(` and the call path, keep looking.
                i -= 1;
                while i > 0 && c[i - 1].is_whitespace() {
                    i -= 1;
                }
                while i > 0
                    && (c[i - 1].is_alphanumeric()
                        || c[i - 1] == '_'
                        || c[i - 1] == ':'
                        || c[i - 1] == '<'
                        || c[i - 1] == '>')
                {
                    i -= 1;
                }
            }
            '=' => {
                i -= 1;
                while i > 0 && c[i - 1].is_whitespace() {
                    i -= 1;
                }
                if i > 0 && c[i - 1] == '>' {
                    // Generic type annotation: skip the balanced `<…>` and
                    // the type path back through the `:`.
                    let mut depth = 0i32;
                    while i > 0 {
                        match c[i - 1] {
                            '>' => depth += 1,
                            '<' => depth -= 1,
                            _ => {}
                        }
                        i -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                    while i > 0
                        && (c[i - 1].is_alphanumeric() || c[i - 1] == '_' || c[i - 1] == ':')
                    {
                        i -= 1;
                    }
                    while i > 0 && c[i - 1].is_whitespace() {
                        i -= 1;
                    }
                    return ident_ending_at(&c, i);
                }
                let end = i;
                let name = ident_ending_at(&c, end)?;
                // `x: Type =` — the ident we just read is the type; the
                // binding sits before the `:`.
                let mut j = end - name.chars().count();
                while j > 0 && c[j - 1].is_whitespace() {
                    j -= 1;
                }
                if j > 0 && c[j - 1] == ':' && !(j > 1 && c[j - 2] == ':') {
                    let mut k = j - 1;
                    while k > 0 && c[k - 1].is_whitespace() {
                        k -= 1;
                    }
                    return ident_ending_at(&c, k);
                }
                return Some(name);
            }
            ':' => {
                // Struct-literal field `name: RankedMutex::new(…)`; a `::`
                // here would be a path, which cannot precede the match.
                if i > 1 && c[i - 2] == ':' {
                    return None;
                }
                let mut k = i - 1;
                while k > 0 && c[k - 1].is_whitespace() {
                    k -= 1;
                }
                return ident_ending_at(&c, k);
            }
            _ => return None,
        }
    }
}

fn ident_ending_at(c: &[char], end: usize) -> Option<String> {
    let mut start = end;
    while start > 0 && (c[start - 1].is_alphanumeric() || c[start - 1] == '_') {
        start -= 1;
    }
    if start == end {
        None
    } else {
        Some(c[start..end].iter().collect())
    }
}

fn rank_label(ranks: &[(String, u8)], v: u8) -> String {
    ranks
        .iter()
        .find(|(_, x)| *x == v)
        .map(|(n, _)| format!("{n}({v})"))
        .unwrap_or_else(|| format!("rank {v}"))
}

// ---------------------------------------------------------------------------
// lock-order: strictly-descending acquisition, whole crate.
// ---------------------------------------------------------------------------

/// Ranks a function may acquire directly (its own `.lock()` sites).
fn direct_ranks(
    files: &[SourceFile],
    graph: &CrateGraph,
    tables: &RankTables,
) -> BTreeMap<FnId, BTreeSet<u8>> {
    let mut out: BTreeMap<FnId, BTreeSet<u8>> = BTreeMap::new();
    for (&id, _) in graph.edges.iter() {
        let file = &files[id.file];
        if file.module == "util/sync.rs" {
            continue; // the rank machinery's own internals
        }
        let mut ranks = BTreeSet::new();
        for site in lock_sites(file, graph.info(id)) {
            if let Some(r) = tables.resolve(id.file, &site.receiver) {
                ranks.insert(r);
            }
        }
        if !ranks.is_empty() {
            out.insert(id, ranks);
        }
    }
    out
}

/// Fixpoint closure of `direct` over the call graph: every rank a
/// function may acquire transitively.
fn transitive_ranks(
    graph: &CrateGraph,
    direct: &BTreeMap<FnId, BTreeSet<u8>>,
) -> BTreeMap<FnId, BTreeSet<u8>> {
    let mut acq = direct.clone();
    loop {
        let mut changed = false;
        for (&id, edges) in graph.edges.iter() {
            let mut add: BTreeSet<u8> = BTreeSet::new();
            for &(_, callee) in edges {
                if let Some(rs) = acq.get(&callee) {
                    add.extend(rs.iter().copied());
                }
            }
            if add.is_empty() {
                continue;
            }
            let entry = acq.entry(id).or_default();
            let before = entry.len();
            entry.extend(add);
            changed |= entry.len() != before;
        }
        if !changed {
            return acq;
        }
    }
}

/// Shortest chain from `from` to a fn that directly acquires a rank
/// `>= floor`; returns (labels, acquired rank).
fn chain_to_acquisition(
    graph: &CrateGraph,
    direct: &BTreeMap<FnId, BTreeSet<u8>>,
    from: FnId,
    floor: u8,
) -> Option<(Vec<String>, u8)> {
    let offending = |id: FnId| {
        direct
            .get(&id)
            .and_then(|rs| rs.iter().copied().find(|&r| r >= floor))
    };
    let mut prev: BTreeMap<FnId, FnId> = BTreeMap::new();
    let mut queue = VecDeque::from([from]);
    let mut seen = BTreeSet::from([from]);
    while let Some(id) = queue.pop_front() {
        if let Some(rank) = offending(id) {
            let mut chain = vec![graph.label(id)];
            let mut cur = id;
            while let Some(&p) = prev.get(&cur) {
                chain.push(graph.label(p));
                cur = p;
            }
            chain.reverse();
            return Some((chain, rank));
        }
        if let Some(edges) = graph.edges.get(&id) {
            for &(_, callee) in edges {
                if seen.insert(callee) {
                    prev.insert(callee, id);
                    queue.push_back(callee);
                }
            }
        }
    }
    None
}

struct Held {
    rank: u8,
    receiver: String,
    /// Bound guard ident (`let g = …`); `None` for expression guards that
    /// die at end of line.
    ident: Option<String>,
    /// Brace depth at the binding — leaving that scope releases the guard.
    depth: i32,
    line: usize,
}

/// Parse `let [mut] IDENT` at the start of a stripped line.
fn let_ident(code: &str) -> Option<String> {
    let t = code.trim_start();
    let t = t.strip_prefix("let ")?;
    let t = t.trim_start();
    let t = t.strip_prefix("mut ").unwrap_or(t).trim_start();
    let name: String = t
        .chars()
        .take_while(|c| c.is_alphanumeric() || *c == '_')
        .collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

fn lock_order_pass(
    files: &[SourceFile],
    graph: &CrateGraph,
    ranks: &[(String, u8)],
    tables: &RankTables,
) -> Vec<Finding> {
    let direct = direct_ranks(files, graph, tables);
    let acq = transitive_ranks(graph, &direct);
    let mut out = Vec::new();
    for &id in graph.sites.keys() {
        let file = &files[id.file];
        if file.module == "util/sync.rs" {
            continue;
        }
        let info = graph.info(id);
        let locks: Vec<LockSite> = lock_sites(file, info);
        let edges = graph.edges.get(&id);
        let mut held: Vec<Held> = Vec::new();
        let mut depth: i32 = 0;
        for line in info.start..=info.end.min(file.stripped.code.len().saturating_sub(1)) {
            let code = &file.stripped.code[line];
            // 1. Direct acquisitions on this line, strict-descent checked.
            for site in locks.iter().filter(|s| s.line == line) {
                let Some(rank) = tables.resolve(id.file, &site.receiver) else {
                    continue;
                };
                if let Some(h) = held.iter().filter(|h| h.rank <= rank).min_by_key(|h| h.rank)
                {
                    out.push(Finding {
                        path: file.path.clone(),
                        line: line + 1,
                        rule: Rule::LockOrder,
                        message: format!(
                            "{} acquires {} while holding {} (taken line {}): \
                             ranks must strictly descend",
                            graph.label(id),
                            rank_label(ranks, rank),
                            rank_label(ranks, h.rank),
                            h.line + 1,
                        ),
                    });
                }
                held.push(Held {
                    rank,
                    receiver: site.receiver.clone(),
                    ident: if site.bound { let_ident(code) } else { None },
                    depth,
                    line,
                });
            }
            // 2. Calls made while holding: the callee's transitive
            //    acquisitions must stay strictly below the held floor.
            if let (Some(edges), Some(floor)) =
                (edges, held.iter().map(|h| h.rank).min())
            {
                let holder = held
                    .iter()
                    .min_by_key(|h| h.rank)
                    .map(|h| h.receiver.clone())
                    .unwrap_or_default();
                for &(l, callee) in edges.iter().filter(|(l, _)| *l == line) {
                    let Some(reachable) = acq.get(&callee) else {
                        continue;
                    };
                    if reachable.iter().any(|&r| r >= floor) {
                        if let Some((chain, rank)) =
                            chain_to_acquisition(graph, &direct, callee, floor)
                        {
                            out.push(Finding {
                                path: file.path.clone(),
                                line: l + 1,
                                rule: Rule::LockOrder,
                                message: format!(
                                    "{} calls {} while holding {} via `{}`; the \
                                     callee can acquire {} — chain: {} -> {}",
                                    graph.label(id),
                                    graph.label(callee),
                                    rank_label(ranks, floor),
                                    holder,
                                    rank_label(ranks, rank),
                                    graph.label(id),
                                    chain.join(" -> "),
                                ),
                            });
                        }
                    }
                }
            }
            // 3. Explicit `drop(guard)` releases.
            let mut from = 0;
            while let Some(rel) = code[from..].find("drop(") {
                let abs = from + rel;
                from = abs + "drop(".len();
                if !super::lexer::at_ident_start(code, abs) {
                    continue;
                }
                let arg: String = code[from..]
                    .chars()
                    .take_while(|c| c.is_alphanumeric() || *c == '_')
                    .collect();
                held.retain(|h| h.ident.as_deref() != Some(arg.as_str()));
            }
            // 4. Scope tracking: leaving the binding scope releases bound
            //    guards; expression guards die with their line (their call
            //    checks above already ran).
            for ch in code.chars() {
                match ch {
                    '{' => depth += 1,
                    '}' => depth -= 1,
                    _ => {}
                }
            }
            held.retain(|h| h.ident.is_some() && depth >= h.depth);
        }
    }
    out
}

// ---------------------------------------------------------------------------
// hot-tick: no blocking work reachable from the fused decode tick.
// ---------------------------------------------------------------------------

fn hot_tick_pass(
    files: &[SourceFile],
    graph: &CrateGraph,
    ranks: &[(String, u8)],
    tables: &RankTables,
) -> Vec<Finding> {
    let sched_rank = ranks
        .iter()
        .find(|(n, _)| n == "SchedulerQueue")
        .map(|(_, v)| *v)
        .unwrap_or(20);
    let roots: Vec<FnId> = HOT_ROOTS.iter().flat_map(|n| graph.find(n)).collect();
    if roots.is_empty() {
        return Vec::new();
    }
    let reachable = graph.reachable(&roots);
    let chain_from_root = |target: FnId| -> String {
        roots
            .iter()
            .filter_map(|&r| graph.path(r, target))
            .min_by_key(|p| p.len())
            .map(|p| p.join(" -> "))
            .unwrap_or_else(|| graph.label(target))
    };
    let mut out = Vec::new();
    for &id in &reachable {
        let file = &files[id.file];
        if file.module == "util/sync.rs" {
            continue; // rank machinery internals, runtime-checked
        }
        let info = graph.info(id);
        let mut report = |line: usize, what: String| {
            out.push(Finding {
                path: file.path.clone(),
                line: line + 1,
                rule: Rule::HotTick,
                message: format!("{what} on the hot tick path ({})", chain_from_root(id)),
            });
        };
        if let Some(sites) = graph.sites.get(&id) {
            for s in sites {
                if s.is_macro && PRINT_MACROS.contains(&s.callee.as_str()) {
                    report(s.line, format!("`{}!` takes the global stdio lock", s.callee));
                } else if !s.is_macro && s.callee == "sleep" {
                    report(s.line, "blocking `sleep`".to_string());
                }
            }
        }
        for line in info.start..=info.end.min(file.stripped.code.len().saturating_sub(1)) {
            let code = &file.stripped.code[line];
            if file.test_lines[line] {
                continue;
            }
            for tok in IO_TOKENS {
                if code.contains(tok) {
                    report(line, format!("IO (`{tok}`)"));
                    break;
                }
            }
        }
        for site in lock_sites(file, info) {
            if let Some(rank) = tables.resolve(id.file, &site.receiver) {
                if rank > sched_rank {
                    report(
                        site.line,
                        format!(
                            "acquires `{}` at {}, above {}",
                            site.receiver,
                            rank_label(ranks, rank),
                            rank_label(ranks, sched_rank),
                        ),
                    );
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// gauge-lineage: every gauge reaches /stats and some consistency check.
// ---------------------------------------------------------------------------

#[derive(Debug)]
struct FieldInfo {
    line: usize,
    strukt: String,
    name: String,
    ty: String,
}

/// Struct fields in one file's stripped code (non-test regions only).
fn struct_fields(file: &SourceFile) -> Vec<FieldInfo> {
    let mut out = Vec::new();
    let mut i = 0;
    let lines = &file.stripped.code;
    while i < lines.len() {
        let line = lines[i].trim();
        if file.test_lines[i] || !contains_word(line, "struct") {
            i += 1;
            continue;
        }
        let Some(pos) = line.find("struct ") else {
            i += 1;
            continue;
        };
        let name: String = line[pos + "struct ".len()..]
            .chars()
            .take_while(|c| c.is_alphanumeric() || *c == '_')
            .collect();
        if name.is_empty() || !line.contains('{') {
            i += 1; // tuple / unit struct, or a body opening later — skip
            continue;
        }
        let strukt = name;
        let mut depth = line.matches('{').count() as i32 - line.matches('}').count() as i32;
        i += 1;
        while i < lines.len() && depth > 0 {
            let body_line = lines[i].trim();
            if depth == 1 && !body_line.starts_with('#') {
                if let Some(colon) = body_line.find(':') {
                    let head = body_line[..colon].trim();
                    let field = head.rsplit(' ').next().unwrap_or(head);
                    let valid = !field.is_empty()
                        && field.chars().all(|c| c.is_alphanumeric() || c == '_')
                        && !field.chars().next().is_some_and(|c| c.is_ascii_digit());
                    if valid {
                        let ty = body_line[colon + 1..].trim_end_matches(',').trim();
                        out.push(FieldInfo {
                            line: i,
                            strukt: strukt.clone(),
                            name: field.to_string(),
                            ty: ty.to_string(),
                        });
                    }
                }
            }
            depth += body_line.matches('{').count() as i32;
            depth -= body_line.matches('}').count() as i32;
            i += 1;
        }
    }
    out
}

fn gauge_lineage_pass(input: &AuditInput) -> Vec<Finding> {
    let files = &input.files;
    // The pass needs the serve layer in scope to say anything about
    // serialization; on partial trees it stays quiet.
    let Some(server) = files.iter().find(|f| f.module == "serve/server.rs") else {
        return Vec::new();
    };
    // Words mentioned by the serve layer's production code or string keys.
    let mut server_words: BTreeSet<String> = BTreeSet::new();
    for (idx, code) in server.stripped.code.iter().enumerate() {
        if server.test_lines[idx] {
            continue;
        }
        for (_, w) in super::lexer::idents(code) {
            server_words.insert(w.to_string());
        }
        for w in server.stripped.strings[idx]
            .split(|c: char| !(c.is_alphanumeric() || c == '_'))
        {
            if !w.is_empty() {
                server_words.insert(w.to_string());
            }
        }
    }
    // Verification corpus: invariant checkers, test regions, extras,
    // thresholds.
    let mut verify_text = String::new();
    for file in files {
        for f in &file.fns {
            if f.name == "check_invariants" || f.name == "validate_gauges" {
                for l in f.start..=f.end.min(file.stripped.code.len() - 1) {
                    verify_text.push_str(&file.stripped.code[l]);
                    verify_text.push('\n');
                }
            }
        }
        for (idx, is_test) in file.test_lines.iter().enumerate() {
            if *is_test {
                verify_text.push_str(&file.stripped.code[idx]);
                verify_text.push(' ');
                verify_text.push_str(&file.stripped.strings[idx]);
                verify_text.push('\n');
            }
        }
    }
    for (_, text) in &input.extras {
        verify_text.push_str(text);
        verify_text.push('\n');
    }
    if let Some(t) = &input.thresholds {
        verify_text.push_str(t);
    }

    let mut out = Vec::new();
    for file in files.iter().filter(|f| GAUGE_MODULES.contains(&f.module.as_str())) {
        for field in struct_fields(file) {
            if !field.strukt.ends_with("Stats") {
                continue;
            }
            let ty_head = field.ty.split('<').next().unwrap_or("").trim();
            if !matches!(ty_head, "usize" | "u64" | "u32" | "f32" | "f64") {
                continue;
            }
            let serialized = server_words.contains(&field.name)
                || derived_through_method(file, &field.name, &server_words);
            if !serialized {
                out.push(Finding {
                    path: file.path.clone(),
                    line: field.line + 1,
                    rule: Rule::GaugeLineage,
                    message: format!(
                        "orphaned gauge {}.{}: never serialized by \
                         serve/server.rs (/stats and /metrics cannot see it)",
                        field.strukt, field.name
                    ),
                });
            }
            if !contains_word(&verify_text, &field.name) {
                out.push(Finding {
                    path: file.path.clone(),
                    line: field.line + 1,
                    rule: Rule::GaugeLineage,
                    message: format!(
                        "unverified gauge {}.{}: not referenced by \
                         check_invariants, any test, or ci/thresholds.json",
                        field.strukt, field.name
                    ),
                });
            }
        }
    }

    // Metric sinks that are written but never read anywhere.
    for file in files {
        if file.module == "metrics/mod.rs" {
            continue; // the sink library itself
        }
        for field in struct_fields(file) {
            let ty_head = field.ty.split('<').next().unwrap_or("").trim();
            let last = ty_head.rsplit("::").next().unwrap_or(ty_head);
            if !matches!(last, "Counter" | "Histogram" | "Throughput") {
                continue;
            }
            let read = files.iter().any(|f| {
                f.stripped.code.iter().any(|l| {
                    SINK_READS
                        .iter()
                        .any(|m| l.contains(&format!("{}.{m}(", field.name)))
                })
            }) || input.extras.iter().any(|(_, text)| {
                SINK_READS
                    .iter()
                    .any(|m| text.contains(&format!("{}.{m}(", field.name)))
            });
            if !read {
                out.push(Finding {
                    path: file.path.clone(),
                    line: field.line + 1,
                    rule: Rule::GaugeLineage,
                    message: format!(
                        "write-only metric sink {}.{} ({}): no read method \
                         ({}) is ever called on it",
                        field.strukt,
                        field.name,
                        last,
                        SINK_READS.join("/"),
                    ),
                });
            }
        }
    }

    out.extend(threshold_keys_pass(input));
    out
}

/// A gauge can legitimately reach `/stats` through a derived method
/// (`fragmentation()`, `live_bytes()`): the field is read by a method of
/// its own file whose *name* the serve layer mentions.
fn derived_through_method(
    file: &SourceFile,
    field: &str,
    server_words: &BTreeSet<String>,
) -> bool {
    file.fns.iter().any(|f| {
        !f.is_test
            && server_words.contains(&f.name)
            && (f.start..=f.end.min(file.stripped.code.len() - 1))
                .any(|l| contains_word(&file.stripped.code[l], field))
    })
}

/// Every key (and bound-expression identifier) in `ci/thresholds.json`
/// must be produced by some bench/test source, and every report filename
/// must appear in a source string — a renamed bench key otherwise turns
/// the CI gate into a no-op.
fn threshold_keys_pass(input: &AuditInput) -> Vec<Finding> {
    let Some(text) = &input.thresholds else {
        return Vec::new();
    };
    if input.extras.is_empty() {
        return Vec::new(); // no producers in scope (fixture runs)
    }
    let Ok(json) = crate::util::json::Json::parse(text) else {
        return vec![Finding {
            path: "ci/thresholds.json".to_string(),
            line: 1,
            rule: Rule::GaugeLineage,
            message: "ci/thresholds.json does not parse as JSON".to_string(),
        }];
    };
    let crate::util::json::Json::Obj(sections) = &json else {
        return Vec::new();
    };
    let line_of = |needle: &str| {
        text.lines()
            .position(|l| l.contains(&format!("\"{needle}\"")))
            .map(|i| i + 1)
            .unwrap_or(1)
    };
    let mut corpus = String::new();
    for (_, t) in &input.extras {
        corpus.push_str(t);
        corpus.push('\n');
    }
    for file in &input.files {
        for s in &file.stripped.strings {
            corpus.push_str(s);
            corpus.push(' ');
        }
    }
    let mut out = Vec::new();
    let mut check = |word: &str, what: &str| {
        if !contains_word(&corpus, word) {
            out.push(Finding {
                path: "ci/thresholds.json".to_string(),
                line: line_of(word),
                rule: Rule::GaugeLineage,
                message: format!(
                    "dangling threshold {what} `{word}`: no bench or test \
                     source produces it — the CI gate silently passes"
                ),
            });
        }
    };
    for (report, entries) in sections {
        check(report, "report");
        let crate::util::json::Json::Arr(entries) = entries else {
            continue;
        };
        for entry in entries {
            let crate::util::json::Json::Obj(kv) = entry else {
                continue;
            };
            for (k, v) in kv {
                match (k.as_str(), v) {
                    ("key", crate::util::json::Json::Str(s)) => check(s, "key"),
                    ("bound", crate::util::json::Json::Str(expr)) => {
                        for w in expr
                            .split(|c: char| !(c.is_alphanumeric() || c == '_'))
                            .filter(|w| {
                                !w.is_empty()
                                    && !w.chars().next().is_some_and(|c| c.is_ascii_digit())
                            })
                        {
                            check(w, "bound identifier");
                        }
                    }
                    _ => {}
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::audit::items::SourceFile;

    /// A minimal `util/sync.rs` stand-in declaring the rank enum, so the
    /// fixture crates resolve ranks without the real tree.
    const SYNC_FIXTURE: &str = "pub enum LockRank {\n    DeviceQueue = 0,\n    PoolState = 10,\n    SchedulerQueue = 20,\n    SessionTable = 30,\n    SideResults = 40,\n}\n";

    fn audit(files: Vec<(&str, &str)>) -> Vec<Finding> {
        let input = AuditInput {
            files: files
                .into_iter()
                .map(|(p, s)| SourceFile::parse(p, s))
                .collect(),
            thresholds: None,
            extras: Vec::new(),
        };
        run(&input).findings
    }

    fn rules(module: &str, src: &str) -> Vec<(usize, Rule)> {
        let path = format!("rust/src/{module}");
        audit(vec![(path.as_str(), src)])
            .into_iter()
            .map(|f| (f.line, f.rule))
            .collect()
    }

    // -- legacy rules: the PR 7 fixtures, preserved verbatim ---------------

    #[test]
    fn poison_cascade_fires_with_file_and_line() {
        let src = "fn f() {\n    let g = m.lock().unwrap();\n}\n";
        assert_eq!(rules("model/pool.rs", src), vec![(2, Rule::PoisonCascade)]);
        let src = "fn f() {\n    let g = m.lock().expect(\"locked\");\n}\n";
        assert_eq!(rules("cortex/prism.rs", src), vec![(2, Rule::PoisonCascade)]);
    }

    #[test]
    fn poison_cascade_catches_a_formatter_split_chain() {
        let src = "fn f() {\n    let g = m\n        .lock()\n        .unwrap();\n}\n";
        assert_eq!(rules("model/pool.rs", src), vec![(3, Rule::PoisonCascade)]);
    }

    #[test]
    fn poison_cascade_exempts_util_sync() {
        let src = "fn f() {\n    let g = m.lock().unwrap();\n}\n";
        assert!(rules("util/sync.rs", src).is_empty());
    }

    #[test]
    fn audit_allow_suppresses_on_the_same_and_preceding_line() {
        let same = "fn f() {\n    let g = m.lock().unwrap(); // audit-allow: poison-cascade\n}\n";
        assert!(rules("model/pool.rs", same).is_empty());
        let above =
            "fn f() {\n    // audit-allow: poison-cascade\n    let g = m.lock().unwrap();\n}\n";
        assert!(rules("model/pool.rs", above).is_empty());
    }

    #[test]
    fn audit_allow_for_another_rule_does_not_suppress() {
        let src = "fn f() {\n    let g = m.lock().unwrap(); // audit-allow: nan-sort\n}\n";
        // The poison finding survives, and the nan-sort marker is now
        // itself a finding: it suppresses nothing.
        assert_eq!(
            rules("model/pool.rs", src),
            vec![(2, Rule::PoisonCascade), (2, Rule::StaleAllow)]
        );
    }

    #[test]
    fn cfg_test_items_are_skipped() {
        let src = "#[cfg(test)]\nmod tests {\n    fn f() {\n        m.lock().unwrap();\n    }\n}\n\
                   fn prod() {\n    m.lock().unwrap();\n}\n";
        assert_eq!(rules("model/pool.rs", src), vec![(8, Rule::PoisonCascade)]);
        let src = "#[test]\nfn t() {\n    m.lock().unwrap();\n}\n";
        assert!(rules("model/pool.rs", src).is_empty());
    }

    #[test]
    fn comments_and_strings_never_fire() {
        let src = "fn f() {\n    // m.lock().unwrap()\n    let s = \".lock().unwrap()\";\n\
                   \n    let r = r#\".lock().unwrap()\"#;\n}\n";
        assert!(rules("model/pool.rs", src).is_empty());
    }

    #[test]
    fn nan_sort_fires_in_comparator_position() {
        let src = "fn f(v: &mut Vec<f32>) {\n    v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n}\n";
        assert_eq!(rules("util/timer.rs", src), vec![(2, Rule::NanSort)]);
        let split = "fn f(v: &mut Vec<f32>) {\n    v.sort_by(|a, b| {\n        \
                     a.partial_cmp(b).unwrap()\n    });\n}\n";
        assert_eq!(rules("util/timer.rs", split), vec![(3, Rule::NanSort)]);
    }

    #[test]
    fn nan_sort_ignores_non_comparator_uses_and_total_cmp() {
        let src = "fn f(a: f32, b: f32) -> bool {\n    \
                   a.partial_cmp(&b) == Some(std::cmp::Ordering::Less)\n}\n";
        assert!(rules("util/timer.rs", src).is_empty());
        let src = "fn f(v: &mut Vec<f32>) {\n    v.sort_by(|a, b| a.total_cmp(b));\n}\n";
        assert!(rules("util/timer.rs", src).is_empty());
    }

    #[test]
    fn raw_mutex_fires_only_in_decode_path_modules() {
        let src = "fn f() {\n    let m = Mutex::new(0);\n}\n";
        assert_eq!(rules("cortex/step.rs", src), vec![(2, Rule::RawMutex)]);
        assert_eq!(rules("metrics/mod.rs", src), vec![(2, Rule::RawMutex)]);
        assert!(rules("util/timer.rs", src).is_empty());
        let qualified = "fn f() {\n    let m = std::sync::Mutex::new(0);\n}\n";
        assert_eq!(rules("model/pool.rs", qualified), vec![(2, Rule::RawMutex)]);
    }

    #[test]
    fn ranked_mutex_is_not_a_raw_mutex() {
        let src = "fn f() {\n    let m = RankedMutex::new(LockRank::Metrics, 0);\n}\n";
        assert!(rules("metrics/mod.rs", src).is_empty());
    }

    #[test]
    fn panic_in_serve_fires_and_suppresses() {
        let src = "fn handle() {\n    let v = parse().unwrap();\n}\n";
        assert_eq!(rules("serve/http.rs", src), vec![(2, Rule::PanicInServe)]);
        let src = "fn handle() {\n    panic!(\"bad request\");\n}\n";
        assert_eq!(rules("serve/http.rs", src), vec![(2, Rule::PanicInServe)]);
        let src = "fn handle() {\n    let v = parse().unwrap(); // audit-allow: panic-in-serve\n}\n";
        assert!(rules("serve/http.rs", src).is_empty());
        // Outside serve/, a bare unwrap is not this rule's business.
        let src = "fn f() {\n    let v = parse().unwrap();\n}\n";
        assert!(rules("util/timer.rs", src).is_empty());
    }

    #[test]
    fn unwrap_or_variants_do_not_fire() {
        let src = "fn handle() {\n    let v = parse().unwrap_or(0);\n    \
                   let w = lock().unwrap_or_else(std::sync::PoisonError::into_inner);\n}\n";
        assert!(rules("serve/http.rs", src).is_empty());
    }

    #[test]
    fn float_eq_fires_on_literal_and_cast_comparisons() {
        let src = "fn f(x: f32) -> bool {\n    x == 1.0\n}\n";
        assert_eq!(rules("model/pool.rs", src), vec![(2, Rule::FloatEq)]);
        let src = "fn f(x: f64, n: usize) -> bool {\n    x != n as f64\n}\n";
        assert_eq!(rules("cortex/capacity.rs", src), vec![(2, Rule::FloatEq)]);
        let src = "fn f(x: f32) -> bool {\n    x == 2.5e-3\n}\n";
        assert_eq!(rules("model/engine.rs", src), vec![(2, Rule::FloatEq)]);
        let src = "fn f(x: f32) -> bool {\n    1f32 != x\n}\n";
        assert_eq!(rules("cortex/step.rs", src), vec![(2, Rule::FloatEq)]);
    }

    #[test]
    fn float_eq_ignores_integer_compares_and_other_scopes() {
        let src = "fn f(n: usize) -> bool {\n    n == 0\n}\n";
        assert!(rules("model/pool.rs", src).is_empty());
        let src = "fn f(x: f32) -> bool {\n    x <= 1.0 && x >= -1.0\n}\n";
        assert!(rules("model/pool.rs", src).is_empty());
        let src = "fn f(n: usize) {\n    if n == 0 { g(1.0) }\n}\n";
        assert!(rules("model/pool.rs", src).is_empty());
        let src = "fn f(n: usize, e: f32) -> bool {\n    n == 0 && e < 1e-6\n}\n";
        assert!(rules("cortex/step.rs", src).is_empty());
        let src = "fn f(n: u32, t: (u32, u32)) -> bool {\n    n == 0x1E3 && t.0 != 2\n}\n";
        assert!(rules("model/pool.rs", src).is_empty());
        let src = "fn f(x: f32) -> bool {\n    x == 1.0\n}\n";
        assert!(rules("util/timer.rs", src).is_empty());
        assert!(rules("serve/http.rs", src).is_empty());
    }

    #[test]
    fn float_eq_suppresses_under_audit_allow_and_in_tests() {
        let src = "fn f(x: f32) -> bool {\n    x == 0.0 // audit-allow: float-eq\n}\n";
        assert!(rules("model/pool.rs", src).is_empty());
        let src = "#[test]\nfn t() {\n    assert!(x == 1.0);\n}\n";
        assert!(rules("model/pool.rs", src).is_empty());
        let src =
            "#[cfg(test)]\nmod tests {\n    fn close(x: f32) -> bool {\n        x == 1.0\n    }\n}\n";
        assert!(rules("cortex/capacity.rs", src).is_empty());
    }

    #[test]
    fn lifetimes_and_char_literals_do_not_derail_the_scanner() {
        let src = "fn f<'a>(x: &'a str) -> char {\n    let c = '{';\n    let d = '\\'';\n    \
                   m.lock().unwrap();\n    c\n}\n";
        assert_eq!(rules("model/pool.rs", src), vec![(4, Rule::PoisonCascade)]);
    }

    // -- rank table parsing -------------------------------------------------

    #[test]
    fn rank_enum_parses_names_and_values() {
        let files = vec![SourceFile::parse("rust/src/util/sync.rs", SYNC_FIXTURE)];
        let ranks = parse_rank_enum(&files);
        assert_eq!(ranks.len(), 5);
        assert_eq!(ranks[0], ("DeviceQueue".to_string(), 0));
        assert_eq!(ranks[3], ("SessionTable".to_string(), 30));
    }

    #[test]
    fn binding_ident_recovers_all_declaration_shapes() {
        assert_eq!(binding_ident("    let tx = ").as_deref(), Some("tx"));
        assert_eq!(binding_ident("    let mut tx = ").as_deref(), Some("tx"));
        assert_eq!(binding_ident("        state: ").as_deref(), Some("state"));
        assert_eq!(
            binding_ident("    let rx = Arc::new(").as_deref(),
            Some("rx")
        );
        assert_eq!(
            binding_ident("static QUEUE: RankedMutex<Vec<u8>> =\n    ").as_deref(),
            Some("QUEUE")
        );
        assert_eq!(binding_ident("some_fn(").as_deref(), None);
    }

    // -- lock-order ---------------------------------------------------------

    #[test]
    fn lock_order_intra_fn_inversion_fires_with_both_ranks() {
        let src = "struct T { state: u8, results: u8 }\n\
                   impl T {\n\
                   fn build() -> T {\n    T { state: RankedMutex::new(LockRank::SessionTable, 0), results: RankedMutex::new(LockRank::SideResults, 0) }\n}\n\
                   fn bad(&self) {\n    let st = self.state.lock();\n    let rs = self.results.lock();\n}\n\
                   }\n";
        let found = audit(vec![
            ("rust/src/util/sync.rs", SYNC_FIXTURE),
            ("rust/src/cortex/fixture.rs", src),
        ]);
        let lock: Vec<&Finding> =
            found.iter().filter(|f| f.rule == Rule::LockOrder).collect();
        assert_eq!(lock.len(), 1, "findings: {found:?}");
        assert_eq!(lock[0].line, 8);
        assert!(lock[0].message.contains("SideResults(40)"));
        assert!(lock[0].message.contains("SessionTable(30)"));
    }

    #[test]
    fn lock_order_descending_and_scoped_sequences_are_clean() {
        let src = "struct T { state: u8, results: u8 }\n\
                   impl T {\n\
                   fn build() -> T {\n    T { state: RankedMutex::new(LockRank::SessionTable, 0), results: RankedMutex::new(LockRank::SideResults, 0) }\n}\n\
                   fn good(&self) {\n    let rs = self.results.lock();\n    let st = self.state.lock();\n}\n\
                   fn scoped(&self) {\n    {\n        let st = self.state.lock();\n    }\n    let rs = self.results.lock();\n}\n\
                   fn dropped(&self) {\n    let st = self.state.lock();\n    drop(st);\n    let rs = self.results.lock();\n}\n\
                   }\n";
        let found = audit(vec![
            ("rust/src/util/sync.rs", SYNC_FIXTURE),
            ("rust/src/cortex/fixture.rs", src),
        ]);
        assert!(
            found.iter().all(|f| f.rule != Rule::LockOrder),
            "spurious: {found:?}"
        );
    }

    #[test]
    fn lock_order_reports_the_cross_function_chain() {
        let src = "struct T { state: u8, results: u8 }\n\
                   impl T {\n\
                   fn build() -> T {\n    T { state: RankedMutex::new(LockRank::SessionTable, 0), results: RankedMutex::new(LockRank::SideResults, 0) }\n}\n\
                   fn outer(&self) {\n    let st = self.state.lock();\n    self.middle();\n}\n\
                   fn middle(&self) {\n    self.inner();\n}\n\
                   fn inner(&self) {\n    let rs = self.results.lock();\n}\n\
                   }\n";
        let found = audit(vec![
            ("rust/src/util/sync.rs", SYNC_FIXTURE),
            ("rust/src/cortex/fixture.rs", src),
        ]);
        let lock: Vec<&Finding> =
            found.iter().filter(|f| f.rule == Rule::LockOrder).collect();
        assert_eq!(lock.len(), 1, "findings: {found:?}");
        let msg = &lock[0].message;
        assert!(msg.contains("T::outer"), "{msg}");
        assert!(msg.contains("T::middle"), "{msg}");
        assert!(msg.contains("T::inner"), "{msg}");
        assert!(msg.contains("SideResults(40)"), "{msg}");
    }

    #[test]
    fn lock_order_equal_rank_reacquisition_fires() {
        let src = "struct T { a: u8, b: u8 }\n\
                   impl T {\n\
                   fn build() -> T {\n    T { a: RankedMutex::new(LockRank::PoolState, 0), b: RankedMutex::new(LockRank::PoolState, 0) }\n}\n\
                   fn bad(&self) {\n    let x = self.a.lock();\n    let y = self.b.lock();\n}\n\
                   }\n";
        let found = audit(vec![
            ("rust/src/util/sync.rs", SYNC_FIXTURE),
            ("rust/src/cortex/fixture.rs", src),
        ]);
        assert!(
            found.iter().any(|f| f.rule == Rule::LockOrder),
            "equal-rank double acquisition must fire: {found:?}"
        );
    }

    // -- hot-tick -----------------------------------------------------------

    #[test]
    fn hot_tick_flags_sleep_print_io_and_high_locks_with_chain() {
        let src = "struct T { results: u8 }\n\
                   impl T {\n\
                   fn build() -> T {\n    T { results: RankedMutex::new(LockRank::SideResults, 0) }\n}\n\
                   fn step_loop(&self) {\n    self.deliver();\n}\n\
                   fn deliver(&self) {\n    thread::sleep(ms);\n    println!(\"x\");\n    let s = std::fs::read_to_string(p);\n    let r = self.results.lock();\n}\n\
                   fn cold(&self) {\n    thread::sleep(ms);\n}\n\
                   }\n";
        let found = audit(vec![
            ("rust/src/util/sync.rs", SYNC_FIXTURE),
            ("rust/src/cortex/fixture.rs", src),
        ]);
        let hot: Vec<&Finding> = found.iter().filter(|f| f.rule == Rule::HotTick).collect();
        // sleep + println + IO + high lock, all inside deliver; cold's
        // sleep is unreachable and must stay quiet.
        assert_eq!(hot.len(), 4, "findings: {found:?}");
        assert!(hot.iter().all(|f| f.message.contains("step_loop")));
        assert!(hot.iter().any(|f| f.message.contains("sleep")));
        assert!(hot.iter().any(|f| f.message.contains("println")));
        assert!(hot.iter().any(|f| f.message.contains("IO")));
        assert!(hot.iter().any(|f| f.message.contains("SideResults(40)")));
        assert!(!found.iter().any(|f| f.line == 16), "cold's sleep is unreachable");
    }

    #[test]
    fn hot_tick_waiver_suppresses_and_is_not_stale() {
        let src = "struct T { results: u8 }\n\
                   impl T {\n\
                   fn build() -> T {\n    T { results: RankedMutex::new(LockRank::SideResults, 0) }\n}\n\
                   fn step_loop(&self) {\n    // audit-allow: hot-tick\n    let r = self.results.lock();\n}\n\
                   }\n";
        let found = audit(vec![
            ("rust/src/util/sync.rs", SYNC_FIXTURE),
            ("rust/src/cortex/fixture.rs", src),
        ]);
        assert!(
            found.iter().all(|f| f.rule != Rule::HotTick && f.rule != Rule::StaleAllow),
            "findings: {found:?}"
        );
    }

    // -- stale-allow --------------------------------------------------------

    #[test]
    fn stale_allow_flags_a_marker_with_no_finding() {
        let src = "fn f() {\n    // audit-allow: poison-cascade\n    let x = 1;\n}\n";
        assert_eq!(rules("model/pool.rs", src), vec![(2, Rule::StaleAllow)]);
    }

    #[test]
    fn stale_allow_ignores_markers_in_tests_and_invalid_rules() {
        let src = "#[test]\nfn t() {\n    // audit-allow: poison-cascade\n    x();\n}\n";
        assert!(rules("model/pool.rs", src).is_empty());
        // `<rule>` in prose is not a valid rule name, hence not a marker.
        let src = "// A waiver is written as `audit-allow: <rule>`.\nfn f() {}\n";
        assert!(rules("model/pool.rs", src).is_empty());
    }

    // -- gauge-lineage ------------------------------------------------------

    const SERVER_FIXTURE: &str = "fn stats_json() {\n    let j = obj().with(\"good_gauge\", s.good_gauge).with(\"ratio\", s.ratio());\n}\n";

    #[test]
    fn gauge_lineage_flags_orphaned_and_unverified_gauges() {
        let pool = "pub struct PoolStats {\n    pub good_gauge: usize,\n    pub orphan_gauge: usize,\n}\n\
                    impl PoolStats {\n    pub fn check_invariants(&self) {\n        assert!(self.good_gauge + self.orphan_gauge > 0);\n    }\n}\n";
        let found = audit(vec![
            ("rust/src/model/pool.rs", pool),
            ("rust/src/serve/server.rs", SERVER_FIXTURE),
        ]);
        let gauge: Vec<&Finding> =
            found.iter().filter(|f| f.rule == Rule::GaugeLineage).collect();
        assert_eq!(gauge.len(), 1, "findings: {found:?}");
        assert!(gauge[0].message.contains("orphan_gauge"));
        assert!(gauge[0].message.contains("never serialized"));
        assert_eq!(gauge[0].line, 3);
    }

    #[test]
    fn gauge_lineage_accepts_derived_methods_and_flags_unverified() {
        let pool = "pub struct PoolStats {\n    pub hidden: usize,\n}\n\
                    impl PoolStats {\n    pub fn ratio(&self) -> f64 {\n        self.hidden as f64\n    }\n}\n";
        let found = audit(vec![
            ("rust/src/model/pool.rs", pool),
            ("rust/src/serve/server.rs", SERVER_FIXTURE),
        ]);
        let gauge: Vec<&Finding> =
            found.iter().filter(|f| f.rule == Rule::GaugeLineage).collect();
        // Serialized through ratio() — but verified nowhere.
        assert_eq!(gauge.len(), 1, "findings: {found:?}");
        assert!(gauge[0].message.contains("unverified gauge"));
        assert!(gauge[0].message.contains("hidden"));
    }

    #[test]
    fn gauge_lineage_flags_write_only_metric_sinks() {
        let cortex = "pub struct Cx {\n    pub dead_histo: Histogram,\n    pub live_histo: Histogram,\n}\n\
                      fn report(cx: &Cx) {\n    let p = cx.live_histo.percentile_ns(0.5);\n}\n";
        let found = audit(vec![
            ("rust/src/cortex/cortex.rs", cortex),
            ("rust/src/serve/server.rs", SERVER_FIXTURE),
        ]);
        let gauge: Vec<&Finding> =
            found.iter().filter(|f| f.rule == Rule::GaugeLineage).collect();
        assert_eq!(gauge.len(), 1, "findings: {found:?}");
        assert!(gauge[0].message.contains("dead_histo"));
        assert!(gauge[0].message.contains("write-only"));
    }

    #[test]
    fn threshold_keys_must_have_producers() {
        let input = AuditInput {
            files: vec![SourceFile::parse(
                "rust/src/serve/server.rs",
                SERVER_FIXTURE,
            )],
            thresholds: Some(
                "{\n  \"BENCH_x.json\": [\n    { \"key\": \"real_key\", \"op\": \">\", \"bound\": 0 },\n    { \"key\": \"ghost_key\", \"op\": \">\", \"bound\": \"other_ghost / 2\" }\n  ]\n}\n"
                    .to_string(),
            ),
            extras: vec![(
                "rust/benches/x.rs".to_string(),
                "emit(\"BENCH_x.json\"); write(\"real_key\", v);".to_string(),
            )],
        };
        let found = run(&input).findings;
        let msgs: Vec<&str> = found.iter().map(|f| f.message.as_str()).collect();
        assert_eq!(found.len(), 2, "findings: {msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("ghost_key")));
        assert!(msgs.iter().any(|m| m.contains("other_ghost")));
        assert!(found.iter().all(|f| f.path == "ci/thresholds.json"));
    }
}
