//! Host-side model state and the typed inference API over the runtime.
//!
//! * `pool`   — the shared KV block pool (demand-paged context memory)
//! * `kv`     — per-agent cache views (block tables into the pool)
//! * `engine` — the typed inference API shared by every agent

pub mod engine;
pub mod kv;
pub mod pool;

pub use engine::{DecodeOut, Engine, InjectOut, PrefillOut, SynapseOut};
pub use kv::KvCache;
pub use pool::{KvPool, KvPoolConfig, PagedKv, PoolStats};
