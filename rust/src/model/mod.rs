//! Host-side model state and the typed inference API over the runtime.
//!
//! * `pool`   — the shared KV block pool (demand-paged, refcounted
//!   copy-on-write context memory + the content-addressed prefix registry)
//! * `kv`     — per-agent cache views (block tables into the pool; entries
//!   may reference registry-shared blocks)
//! * `engine` — the typed inference API shared by every agent
//!   (`prefill_shared` turns identical prompt prefixes into one cold
//!   prefill + N by-reference warm starts)

pub mod engine;
pub mod kv;
pub mod pool;

pub use engine::{
    DecodeOut, Engine, FusedOut, FusedReq, InjectOut, MainLane, PrefillOut, PrefillReuse,
    RawDecode, SynapseOut, PROMPT_CHAIN_SALT,
};
pub use kv::KvCache;
pub use pool::{
    chain_hash, BlockReservation, KvPool, KvPoolConfig, PagedKv, PoolStats, PREFIX_SEED,
};
