//! Host-side model state and the typed inference API over the runtime.

pub mod engine;
pub mod kv;

pub use engine::{DecodeOut, Engine, InjectOut, PrefillOut, SynapseOut};
pub use kv::KvCache;
