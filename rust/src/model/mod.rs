//! Host-side model state and the typed inference API over the runtime.
//!
//! * `pool`   — the shared KV block pool (demand-paged, refcounted
//!   copy-on-write context memory + the content-addressed prefix registry)
//! * `kv`     — per-agent cache views (block tables into the pool; entries
//!   may reference registry-shared blocks)
//! * `engine` — the typed inference API shared by every agent
//!   (`prefill_shared` turns identical prompt prefixes into one cold
//!   prefill + N by-reference warm starts; `ChunkedPrefill` is the same
//!   mechanism split into per-token lanes the step scheduler interleaves
//!   with decode under a per-tick budget, publishing completed blocks
//!   incrementally so concurrent identical prompts hit the registry
//!   mid-prefill)

pub mod engine;
pub mod kv;
pub mod pool;

pub use engine::{
    ChunkedPrefill, DecodeOut, Engine, FusedOut, FusedReq, InjectOut, MainLane, PrefillOut,
    PrefillReuse, RawDecode, SynapseOut, PROMPT_CHAIN_SALT,
};
pub use kv::KvCache;
pub use pool::{
    chain_hash, BlockReservation, KvPool, KvPoolConfig, PagedKv, PoolStats, PREFIX_SEED,
};
