//! The typed inference API: shapes the coordinator's intents into device ops.
//!
//! One `Engine` per model config.  All agents share it (`Arc<Engine>` — the
//! Prism of §3.2): it holds no per-agent state, only program ids and the
//! device handle.  Every method takes the [`Lane`] the op should run on, so
//! the River & Stream scheduler controls priority end-to-end.
//!
//! Decode is **device-resident**: cache rows are written through to the
//! pool's device copies as they are produced, so a step ships a token, a
//! position and a block table ([`PagedKv`]) — the K/V itself comes from the
//! paged-attention gather over resident blocks (`O(new row + table)`
//! host→device traffic per step instead of the seed's `O(capacity)`
//! re-upload; see `model::pool` for the slab design and
//! `benches/decode_upload.rs` for the measured claim).
//!
//! The gather is **tier-transparent**: a block table may mix hot fp32
//! blocks with warm int8 (quantized parked/registry) blocks, and the
//! paged gather dequantizes warm blocks inline with the same arithmetic
//! on every path (`runtime::xla_stub::paged_gather_prefix_tiered` and the
//! pool's host gather share one expression), so decode over a mixed-tier
//! table is bit-identical between host and device.  Cold (host-slab)
//! blocks never appear in a gather — the pool pages them in before any
//! read or write touches them.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::kv::KvCache;
use super::pool::{KvPool, KvPoolConfig, PagedKv};
use crate::runtime::device::ProgramId;
use crate::runtime::{
    Capacities, DeviceHandle, HostTensor, Lane, ModelConfig,
};

/// Output of a prefill op.
#[derive(Debug)]
pub struct PrefillOut {
    /// `[S, V]` logits over the padded prompt.
    pub logits: Vec<f32>,
    /// Final-layer hidden state at the last real position.
    pub hidden_last: Vec<f32>,
    /// Number of real tokens.
    pub len: usize,
}

/// Output of a prefix-cache-aware prefill ([`Engine::prefill_shared`]).
#[derive(Debug)]
pub struct PrefillReuse {
    /// `[V]` logits at the last real position (feeds the first sample).
    pub last_logits: Vec<f32>,
    /// Final-layer hidden state at the last real position.
    pub hidden_last: Vec<f32>,
    /// Rows now in the cache (== token count).
    pub len: usize,
    /// Rows adopted from the shared prefix registry — zero device work,
    /// zero host→device bytes, O(1) fresh blocks.
    pub cached_rows: usize,
    /// Whether the monolithic prefill program ran (the cold path).
    pub cold_prefill: bool,
    /// Teacher-forced decode steps run for the uncovered tail (warm path).
    pub tail_steps: usize,
}

/// Domain salt for prompt-token chains in the pool's prefix registry
/// (synapse landmark seeds use their own salt — see `cortex::synapse`).
pub const PROMPT_CHAIN_SALT: u64 = 0x5741_5250_434f_5254; // "WARPCORT"

/// Incremental (chunked) prefill driver: the bookkeeping half of a
/// teacher-forced prompt prefill, split out so the *caller* owns each
/// decode step.  [`Engine::prefill_shared`] drives it in-thread (the warm
/// tail below), and the step scheduler drives it one budgeted lane per
/// fused tick (`StepScheduler::prefill_step` in `cortex::step`) so a long
/// prompt admits without stalling concurrent sessions' decode.
///
/// It holds no engine or device handle — everything here is cache/pool
/// bookkeeping (chain hashes, the coverage cursor, incremental block
/// registration, mid-prefill registry adoption) — which is also what lets
/// the host-only proptests and benches drive the identical mechanism over
/// the stub executor.
///
/// Protocol per lane: call [`ChunkedPrefill::next_lane`] for the next
/// `(token, position)` to decode (it may first jump the cursor over blocks
/// a concurrent identical prompt registered since the last step — the
/// mid-prefill registry hit), run the decode, append the produced K/V row
/// to the cache, then call [`ChunkedPrefill::advance`].  Coverage always
/// stops before the last token: its live decode produces the logits and
/// hidden state that seed generation, so `next_lane` yields at least once.
#[derive(Debug)]
pub struct ChunkedPrefill {
    tokens: Vec<i32>,
    /// Chain hashes over the full prompt ([`PROMPT_CHAIN_SALT`] domain).
    hashes: Vec<u64>,
    /// Blocks adoption may cover — `min(hashes.len(), (len-1)/bt)`, so the
    /// last token is always decoded live.
    usable: usize,
    block_tokens: usize,
    /// Index of the next token to teacher-force (== the cache fill).
    next: usize,
    begin_cached_rows: usize,
    mid_hit_rows: usize,
    tail_steps: usize,
}

impl ChunkedPrefill {
    /// Begin a chunked prefill over an empty cache: attach the longest
    /// registered prefix of the prompt by reference, with the same
    /// sliver-of-coverage fallback as [`Engine::prefill_shared`] (a sliver
    /// is dropped; whatever the registry has by the first block boundary
    /// is re-adopted there by the mid-prefill probe).
    pub fn begin(tokens: &[i32], kv: &mut KvCache) -> Result<ChunkedPrefill> {
        if tokens.is_empty() {
            bail!("chunked prefill: empty prompt");
        }
        if tokens.len() > kv.capacity() {
            bail!(
                "chunked prefill: prompt length {} > cache capacity {}",
                tokens.len(),
                kv.capacity()
            );
        }
        if !kv.is_empty() {
            bail!("chunked prefill requires an empty cache");
        }
        let pool = kv.pool().clone();
        let bt = pool.block_tokens();
        let hashes = pool.prefix_hashes(PROMPT_CHAIN_SALT, tokens);
        let usable = hashes.len().min((tokens.len() - 1) / bt);
        let mut cached_rows = kv.attach_shared_prefix(&hashes[..usable], tokens)?;
        if cached_rows > 0 && cached_rows * 2 < tokens.len() {
            kv.clear();
            cached_rows = 0;
        }
        Ok(ChunkedPrefill {
            tokens: tokens.to_vec(),
            hashes,
            usable,
            block_tokens: bt,
            next: cached_rows,
            begin_cached_rows: cached_rows,
            mid_hit_rows: 0,
            tail_steps: 0,
        })
    }

    /// The next teacher-forced lane as `(token, position)`, or `None` once
    /// every prompt token is in the cache.  At a block boundary this first
    /// probes the registry for continuation blocks a concurrent identical
    /// prompt registered since the last step and jumps the cursor over any
    /// it adopts — the mid-prefill hit that replaces a duplicate prefill.
    pub fn next_lane(&mut self, kv: &mut KvCache) -> Option<(i32, i32)> {
        let bt = self.block_tokens;
        if self.next % bt == 0 && self.next < self.usable * bt {
            let adopted = kv.extend_shared_prefix(&self.hashes[..self.usable], &self.tokens);
            self.next += adopted;
            self.mid_hit_rows += adopted;
        }
        if self.next >= self.tokens.len() {
            return None;
        }
        Some((self.tokens[self.next], self.next as i32))
    }

    /// Account one completed lane: the caller has decoded the token from
    /// the last [`ChunkedPrefill::next_lane`] and appended its K/V row.
    /// If the row completed a block, that block is published in the prefix
    /// registry *now* — not at prompt end — so a concurrent identical
    /// prompt attaches or mid-adopts it immediately.
    pub fn advance(&mut self, kv: &mut KvCache) {
        self.next += 1;
        self.tail_steps += 1;
        debug_assert_eq!(
            kv.len(),
            self.next,
            "advance: the decoded row must be appended before advancing"
        );
        if self.next % self.block_tokens == 0 {
            kv.register_prefix(&self.hashes, &self.tokens);
        }
    }

    pub fn is_done(&self) -> bool {
        self.next >= self.tokens.len()
    }

    /// Prompt tokens not yet in the cache.
    pub fn remaining(&self) -> usize {
        self.tokens.len() - self.next
    }

    pub fn prompt_len(&self) -> usize {
        self.tokens.len()
    }

    /// Chain hashes over the full prompt (for registration by callers that
    /// bypass the per-lane protocol, e.g. the cold monolithic path).
    pub fn hashes(&self) -> &[u64] {
        &self.hashes
    }

    /// Total rows adopted from the registry (at begin + mid-prefill).
    pub fn adopted_rows(&self) -> usize {
        self.begin_cached_rows + self.mid_hit_rows
    }

    /// Rows adopted when the prefill began.
    pub fn begin_cached_rows(&self) -> usize {
        self.begin_cached_rows
    }

    /// Rows adopted mid-prefill from concurrent registrations.
    pub fn mid_hit_rows(&self) -> usize {
        self.mid_hit_rows
    }

    /// Teacher-forced decode steps actually run so far.
    pub fn tail_steps(&self) -> usize {
        self.tail_steps
    }
}

/// Output of a decode op.
#[derive(Debug)]
pub struct DecodeOut {
    /// `[V]` next-token logits.
    pub logits: Vec<f32>,
    /// `[D]` final hidden state (Validation Gate input).
    pub hidden: Vec<f32>,
    pub queue_ns: u64,
    pub exec_ns: u64,
}

/// Raw output of one paged decode step: everything a caller needs to append
/// the freshly produced row and continue, without the engine touching any
/// `KvCache`.  The step scheduler fans these back to per-agent completion
/// queues; the owning agent appends the row (which writes it through to the
/// device copy).
#[derive(Debug)]
pub struct RawDecode {
    /// `[V]` next-token logits.
    pub logits: Vec<f32>,
    /// `[D]` final hidden state.
    pub hidden: Vec<f32>,
    /// `[L, KV, hd]` new K row.
    pub k_new: Vec<f32>,
    /// `[L, KV, hd]` new V row.
    pub v_new: Vec<f32>,
}

/// One agent's work item in a fused decode tick: the next token, its RoPE
/// position and the O(k) paged view of the agent's cache.
#[derive(Debug, Clone)]
pub struct FusedReq {
    pub token: i32,
    pub pos: i32,
    pub paged: PagedKv,
}

/// One *main stream*'s work item in a fused tick: a [`FusedReq`] plus the
/// cache capacity its tier dispatch is bounded by.  Since the multi-session
/// scheduler there can be several of these per tick — one per concurrent
/// session — all riding the same batch program at River priority.
#[derive(Debug, Clone)]
pub struct MainLane {
    pub req: FusedReq,
    /// The owning cache's capacity (`kv.capacity()`): the tier-dispatch
    /// bound when this main runs as its own op.
    pub capacity: usize,
}

/// Result of one fused decode tick ([`Engine::decode_fused`]).
#[derive(Debug)]
pub struct FusedOut {
    /// One result per main lane, in submission order.  Per-lane `Err`
    /// isolates a single session's fault (bad table, tier miss) to that
    /// session — the other mains of the tick still get their step.
    pub mains: Vec<Result<RawDecode, String>>,
    /// One result per side item, in submission order (empty when
    /// `side_error` is set).
    pub sides: Vec<RawDecode>,
    /// Set when the tick's side half failed while the main half succeeded:
    /// the scheduler fails the side lanes and the main episodes continue —
    /// a side-only device fault must not abort any River.
    pub side_error: Option<String>,
    /// Device ops the tick actually issued: 1 when fully fused, +1 per
    /// main whose context no longer fits a batch lane (each runs its own
    /// River op ahead of the batch).
    pub device_ops: u64,
}

/// Output of a synapse extraction (§3.3).
#[derive(Debug, Clone)]
pub struct SynapseOut {
    /// `[L, K, KV, hd]` landmark keys.
    pub lm_k: Vec<f32>,
    /// `[L, K, KV, hd]` landmark values.
    pub lm_v: Vec<f32>,
    /// Original cache positions of the landmarks (ascending).
    pub indices: Vec<i32>,
    /// Hybrid scores of the selected landmarks.
    pub scores: Vec<f32>,
    /// Length of the source context when extracted.
    pub source_len: usize,
    /// Model layer count (fixes the `[L, K, KV, hd]` buffer geometry).
    pub n_layers: usize,
}

/// Output of a referential-injection encode (§3.6).
#[derive(Debug)]
pub struct InjectOut {
    /// `[L, T, KV, hd]` keys at virtual positions.
    pub k: Vec<f32>,
    /// `[L, T, KV, hd]` values.
    pub v: Vec<f32>,
    /// Hidden state of the thought's last token.
    pub hidden_last: Vec<f32>,
    /// Number of real thought tokens (<= T).
    pub len: usize,
}

struct ProgramIds {
    prefill: ProgramId,
    /// Decode ladder: (cache capacity, program), ascending capacity.  The
    /// dispatcher picks the smallest tier that fits the live context
    /// (§Perf opt A: upload + attention cost scale with the tier, not the
    /// full cache capacity).
    decode_tiers: Vec<(usize, ProgramId)>,
    decode_side: ProgramId,
    decode_batch: ProgramId,
    synapse: ProgramId,
    inject: ProgramId,
}

/// Shared, stateless inference engine for one model config.
///
/// ("Stateless" still holds for per-agent state; the engine does carry a
/// default [`KvPool`] so every cache it hands out is demand-paged.  The
/// orchestrator typically supplies its own pool via
/// [`Engine::new_with_pool`]-configured construction or
/// [`crate::cortex::Prism::with_pool`].)
pub struct Engine {
    device: DeviceHandle,
    cfg: ModelConfig,
    caps: Capacities,
    ids: ProgramIds,
    pool: Arc<KvPool>,
    pub alpha: f32,
    pub inv2sig2: f32,
    pub gate_theta: f32,
    pad_id: i32,
}

pub const PAD_ID: i32 = 256;
pub const BOS_ID: i32 = 257;
pub const EOS_ID: i32 = 258;
pub const REF_ID: i32 = 259;

impl Engine {
    /// Build an engine for `config` on an already-started device, with a
    /// default-configured KV block pool.
    pub fn new(device: DeviceHandle, config: &str) -> Result<Arc<Engine>> {
        Engine::new_with_pool(device, config, KvPoolConfig::default())
    }

    /// Build an engine with explicit pool knobs (block size, capacity,
    /// reclaim policy).
    pub fn new_with_pool(
        device: DeviceHandle,
        config: &str,
        pool_cfg: KvPoolConfig,
    ) -> Result<Arc<Engine>> {
        let bundle = device.manifest().config(config)?.clone();
        let caps = bundle.caps;
        let find = |prefix: &str| -> Result<ProgramId> {
            let spec = bundle.artifact(prefix)?;
            device.program_id(&spec.name)
        };
        // Collect the decode capacity ladder from the manifest (capacity =
        // dim 1 of the k_cache input).
        let mut decode_tiers = Vec::new();
        for a in &bundle.artifacts {
            if a.program.starts_with("decode_c") {
                let cap = a.inputs[2].shape[1];
                decode_tiers.push((cap, device.program_id(&a.name)?));
            }
        }
        decode_tiers.sort_by_key(|(c, _)| *c);
        if decode_tiers.is_empty() {
            bail!("no decode artifacts for config `{config}`");
        }
        let ids = ProgramIds {
            prefill: find(&format!("prefill_s{}", caps.prefill_len))?,
            decode_tiers,
            decode_side: find(&format!("decode_c{}", caps.side_ctx))?,
            decode_batch: find(&format!("decode_batch_b{}", caps.decode_batch))?,
            synapse: find("synapse_extract")?,
            inject: find("inject_encode")?,
        };
        let pool = KvPool::new(&bundle.model, pool_cfg);
        Ok(Arc::new(Engine {
            device,
            cfg: bundle.model,
            caps,
            ids,
            pool,
            alpha: bundle.defaults.alpha,
            inv2sig2: bundle.defaults.inv2sig2,
            gate_theta: bundle.defaults.gate_theta,
            pad_id: PAD_ID,
        }))
    }

    pub fn config(&self) -> &ModelConfig {
        &self.cfg
    }

    pub fn caps(&self) -> &Capacities {
        &self.caps
    }

    pub fn device(&self) -> &DeviceHandle {
        &self.device
    }

    /// The engine's shared KV block pool.
    pub fn pool(&self) -> &Arc<KvPool> {
        &self.pool
    }

    /// Fresh pool-backed main-agent cache (capacity `main_ctx`).
    pub fn new_main_cache(&self) -> KvCache {
        self.pool.new_cache(self.caps.main_ctx)
    }

    /// Fresh pool-backed side-agent cache (capacity `side_ctx`).
    pub fn new_side_cache(&self) -> KvCache {
        self.pool.new_cache(self.caps.side_ctx)
    }

    // ── Prefill ────────────────────────────────────────────────────────

    /// Run the prompt through the model; fills `kv` (must be a main cache).
    pub fn prefill(&self, tokens: &[i32], kv: &mut KvCache, lane: Lane) -> Result<PrefillOut> {
        let s = self.caps.prefill_len;
        if tokens.is_empty() || tokens.len() > s {
            bail!("prefill: prompt length {} not in 1..={s}", tokens.len());
        }
        if kv.capacity() != self.caps.main_ctx {
            bail!("prefill requires a main-capacity cache");
        }
        let mut padded = vec![self.pad_id; s];
        padded[..tokens.len()].copy_from_slice(tokens);

        let out = self.device.call(
            self.ids.prefill,
            vec![
                HostTensor::i32(padded, vec![s]),
                HostTensor::scalar_i32(tokens.len() as i32),
            ],
            lane,
        )?;
        let [logits, hidden, k_full, v_full]: [HostTensor; 4] = take4(out.outputs)?;
        kv.load_full(tokens.len(), k_full.as_f32()?, v_full.as_f32()?)?;
        Ok(PrefillOut {
            logits: logits.into_f32()?,
            hidden_last: hidden.into_f32()?,
            len: tokens.len(),
        })
    }

    /// Prefix-cache-aware prefill: the content-addressed fast path behind
    /// [`crate::cortex::WarpCortex::start_main`].
    ///
    /// The prompt is chain-hashed per block against `kv`'s pool.  Blocks
    /// already registered (an earlier agent ran the same prefix) are
    /// adopted *by reference* — no device execution, no upload, no fresh
    /// memory — and only the uncovered tail runs, as teacher-forced decode
    /// steps over the shared prefix.  On a total miss the monolithic
    /// prefill program runs once and the prompt's full blocks are published
    /// for every later agent: one cold prefill, N warm starts.
    ///
    /// Coverage always stops before the last token (its decode produces
    /// the next-token logits and hidden state generation needs), and a
    /// sliver of coverage falls back to the cold path — one fused prefill
    /// beats a long teacher-forced tail.
    pub fn prefill_shared(
        &self,
        tokens: &[i32],
        kv: &mut KvCache,
        lane: Lane,
    ) -> Result<PrefillReuse> {
        let s = self.caps.prefill_len;
        if tokens.is_empty() || tokens.len() > s {
            bail!("prefill: prompt length {} not in 1..={s}", tokens.len());
        }
        if kv.capacity() != self.caps.main_ctx {
            bail!("prefill requires a main-capacity cache");
        }
        if !kv.is_empty() {
            bail!("prefill_shared requires an empty cache");
        }
        let mut chunked = ChunkedPrefill::begin(tokens, kv)?;
        if chunked.adopted_rows() == 0 {
            let out = self.prefill(tokens, kv, lane)?;
            kv.register_prefix(chunked.hashes(), tokens);
            let v = self.cfg.vocab_size;
            let last = out.logits[(out.len - 1) * v..out.len * v].to_vec();
            return Ok(PrefillReuse {
                last_logits: last,
                hidden_last: out.hidden_last,
                len: out.len,
                cached_rows: 0,
                cold_prefill: true,
                tail_steps: 0,
            });
        }
        // Warm path: rows [0, cached_rows) are already resident (host and
        // device side) — teacher-force only the uncovered tail, driven
        // through the same [`ChunkedPrefill`] protocol the scheduler's
        // budgeted prefill lanes use.  Each step appends its K/V row
        // through the pool's O(row) write-through and attends over the
        // shared prefix via the paged gather; completed blocks publish
        // incrementally and concurrent registrations are adopted at block
        // boundaries instead of being recomputed.
        let mut last: Option<DecodeOut> = None;
        while let Some((tok, pos)) = chunked.next_lane(kv) {
            let out = self.decode(tok, pos, kv, lane)?;
            chunked.advance(kv);
            last = Some(out);
        }
        let out = last.expect("tail is non-empty: coverage stops before the last token");
        // Publish any remaining full private blocks (typically a no-op:
        // boundaries registered incrementally as the tail crossed them).
        kv.register_prefix(chunked.hashes(), tokens);
        Ok(PrefillReuse {
            last_logits: out.logits,
            hidden_last: out.hidden,
            len: tokens.len(),
            cached_rows: chunked.adopted_rows(),
            cold_prefill: false,
            tail_steps: chunked.tail_steps(),
        })
    }

    // ── Decode ─────────────────────────────────────────────────────────

    /// One decode step at RoPE position `pos`; appends the new row to `kv`.
    ///
    /// `pos` is passed separately from `kv.len()` because side agents decode
    /// at *continuation* positions (after the landmark positions), and
    /// injected rows occupy cache rows without advancing the text position.
    pub fn decode(&self, token: i32, pos: i32, kv: &mut KvCache, lane: Lane) -> Result<DecodeOut> {
        if kv.remaining() == 0 {
            bail!("decode: kv cache full");
        }
        let (tier, _id) = self.select_decode_tier(kv.len() + 1, kv.capacity())?;
        self.decode_at_tier(token, pos, kv, tier, lane)
    }

    /// Decode pinned to an explicit capacity tier (tests + tier ablation;
    /// normal callers use [`Engine::decode`], which picks the tier).
    pub fn decode_at_tier(
        &self,
        token: i32,
        pos: i32,
        kv: &mut KvCache,
        tier: usize,
        lane: Lane,
    ) -> Result<DecodeOut> {
        let (_, id) = self
            .ids
            .decode_tiers
            .iter()
            .find(|(c, _)| *c == tier)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("no decode program at tier {tier}"))?;
        if kv.len() >= tier {
            bail!("decode_at_tier: {} rows do not fit tier {tier}", kv.len());
        }

        // Device-resident paged path: the cache rows already live on the
        // device (written through at append time), so this step ships only
        // the block table + scalars; the `[L, tier, KV, hd]` K/V comes from
        // the paged-attention gather over resident blocks.  (On the offline
        // stub the gather runs host-side with identical semantics — see
        // `runtime::xla_stub::paged_gather_prefix`.)
        let (k_up, v_up) = kv.device_gather(tier)?;
        let shape = vec![
            self.cfg.n_layers,
            tier,
            self.cfg.n_kv_heads,
            self.cfg.head_dim,
        ];
        let out = self.device.call(
            id,
            vec![
                HostTensor::scalar_i32(token),
                HostTensor::scalar_i32(pos),
                HostTensor::f32(k_up, shape.clone()),
                HostTensor::f32(v_up, shape),
                HostTensor::scalar_i32(kv.len() as i32),
            ],
            lane,
        )?;
        let queue_ns = out.queue_ns;
        let exec_ns = out.exec_ns;
        let [logits, hidden, k_new, v_new]: [HostTensor; 4] = take4(out.outputs)?;
        kv.append_row(k_new.as_f32()?, v_new.as_f32()?)?;
        Ok(DecodeOut {
            logits: logits.into_f32()?,
            hidden: hidden.into_f32()?,
            queue_ns,
            exec_ns,
        })
    }

    /// Single side-agent decode over a paged view (the batcher's straggler
    /// path).  `paged` must address blocks of **this engine's pool** — the
    /// batcher's requests come from prism-rented caches, which always do.
    /// Returns `(logits, hidden, k_new, v_new)` without touching any
    /// `KvCache`; the caller appends the new row (which writes it through
    /// to the device copy).
    #[allow(clippy::type_complexity)]
    pub fn decode_side_raw(
        &self,
        token: i32,
        pos: i32,
        paged: &PagedKv,
        lane: Lane,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        let cs = self.caps.side_ctx;
        let (k, v) = self.pool.dev_gather_prefix(&paged.table, paged.len, cs)?;
        let shape = vec![
            self.cfg.n_layers,
            cs,
            self.cfg.n_kv_heads,
            self.cfg.head_dim,
        ];
        let out = self.device.call(
            self.ids.decode_side,
            vec![
                HostTensor::scalar_i32(token),
                HostTensor::scalar_i32(pos),
                HostTensor::f32(k, shape.clone()),
                HostTensor::f32(v, shape),
                HostTensor::scalar_i32(paged.len as i32),
            ],
            lane,
        )?;
        let [logits, hidden, k_new, v_new]: [HostTensor; 4] = take4(out.outputs)?;
        Ok((
            logits.into_f32()?,
            hidden.into_f32()?,
            k_new.into_f32()?,
            v_new.into_f32()?,
        ))
    }

    /// The tier dispatcher shared by [`Engine::decode`] and
    /// [`Engine::decode_raw`]: the smallest compiled capacity that (a)
    /// holds the rows the step must attend over and (b) does not exceed
    /// the cache's own capacity (so side caches use the side program),
    /// falling back to the exact-capacity program.  One home, so the
    /// scheduler-routed path can never drift from the in-thread one.
    fn select_decode_tier(&self, needed: usize, capacity: usize) -> Result<(usize, ProgramId)> {
        self.ids
            .decode_tiers
            .iter()
            .find(|(c, _)| *c >= needed && *c <= capacity)
            .copied()
            .or_else(|| {
                self.ids
                    .decode_tiers
                    .iter()
                    .find(|(c, _)| *c == capacity)
                    .copied()
            })
            .ok_or_else(|| anyhow::anyhow!("no decode tier for cache capacity {capacity}"))
    }

    /// One tier-dispatched decode step over a paged view, without touching
    /// any `KvCache` — the step scheduler's main-lane building block.
    ///
    /// Tier selection matches [`Engine::decode`] exactly (`capacity` plays
    /// the role of `kv.capacity()` — both go through
    /// `Engine::select_decode_tier`), so a main-agent step routed through
    /// the scheduler hits the same compiled program as the old in-thread
    /// `engine.decode` call.  The caller appends the returned row.
    pub fn decode_raw(
        &self,
        token: i32,
        pos: i32,
        paged: &PagedKv,
        capacity: usize,
        lane: Lane,
    ) -> Result<RawDecode> {
        let (tier, id) = self.select_decode_tier(paged.len + 1, capacity)?;
        if paged.len >= tier {
            bail!("decode_raw: {} rows do not fit tier {tier}", paged.len);
        }
        let (k_up, v_up) = self.pool.dev_gather_prefix(&paged.table, paged.len, tier)?;
        let shape = vec![
            self.cfg.n_layers,
            tier,
            self.cfg.n_kv_heads,
            self.cfg.head_dim,
        ];
        let out = self.device.call(
            id,
            vec![
                HostTensor::scalar_i32(token),
                HostTensor::scalar_i32(pos),
                HostTensor::f32(k_up, shape.clone()),
                HostTensor::f32(v_up, shape),
                HostTensor::scalar_i32(paged.len as i32),
            ],
            lane,
        )?;
        let [logits, hidden, k_new, v_new]: [HostTensor; 4] = take4(out.outputs)?;
        Ok(RawDecode {
            logits: logits.into_f32()?,
            hidden: hidden.into_f32()?,
            k_new: k_new.into_f32()?,
            v_new: v_new.into_f32()?,
        })
    }

    /// One step-scheduler tick: any number of main lanes (one per
    /// concurrent session) plus any number of side items, fused into as
    /// few device ops as the compiled programs allow — the mixed-lane
    /// entry point behind [`crate::cortex::StepScheduler`].
    ///
    /// Fusion rules:
    /// * Every main whose context still fits a batch lane
    ///   (`len + 1 <= side_ctx`, `fuse_main` on) is *fusable*: fusable
    ///   mains ride the leading lanes of ONE `decode_batch` op together
    ///   with the side items, and the whole op runs at River priority —
    ///   this is how S concurrent sessions share one device op per tick.
    /// * A main that has outgrown a lane runs as its own tier-dispatched
    ///   River op FIRST, ahead of any batched work (+1 op each; mains are
    ///   never queued behind side work).
    /// * A lone main with no sides runs the cheaper single-decode program.
    /// * Sides with no fusable main batch on the Stream lane.
    ///
    /// Fault isolation: an unfusable main's op error fails only that lane
    /// (`mains[i]` is `Err`); a batch failure with mains aboard reruns
    /// each of those mains alone and reports `side_error`; a side-only
    /// batch failure after any successful main op is `side_error` too.
    /// The scheduler guarantees `fusable mains + sides <= batch_width`.
    pub fn decode_fused(
        &self,
        mains: &[MainLane],
        sides: &[FusedReq],
        fuse_main: bool,
    ) -> Result<FusedOut> {
        let b = self.caps.decode_batch;
        if mains.is_empty() && sides.is_empty() {
            bail!("decode_fused: empty tick");
        }
        let cs = self.caps.side_ctx;
        let fusable = |m: &MainLane| fuse_main && m.req.paged.len + 1 <= cs;
        let n_fusable = mains.iter().filter(|m| fusable(m)).count();
        if n_fusable + sides.len() > b {
            bail!(
                "decode_fused: {n_fusable} fusable mains + {} sides exceed batch width {b}",
                sides.len()
            );
        }
        let mut device_ops = 0u64;
        let mut main_out: Vec<Option<Result<RawDecode, String>>> =
            (0..mains.len()).map(|_| None).collect();

        // A lone main with no sides: the cheaper single-decode program,
        // exactly the pre-session behaviour.
        let force_own = mains.len() == 1 && sides.is_empty();

        // Unfusable mains first — their own River ops, ahead of the batch.
        for (i, m) in mains.iter().enumerate() {
            if fusable(m) && !force_own {
                continue;
            }
            device_ops += 1;
            main_out[i] = Some(
                self.decode_raw(m.req.token, m.req.pos, &m.req.paged, m.capacity, Lane::River)
                    .map_err(|e| format!("{e:#}")),
            );
        }
        if force_own {
            return Ok(FusedOut {
                mains: main_out.into_iter().map(|r| r.expect("lone main ran")).collect(),
                sides: Vec::new(),
                side_error: None,
                device_ops,
            });
        }

        // The batched half: fusable mains lead the lanes, sides follow.
        let fused_idx: Vec<usize> = mains
            .iter()
            .enumerate()
            .filter(|(_, m)| fusable(m))
            .map(|(i, _)| i)
            .collect();
        let mut side_out = Vec::new();
        let mut side_error = None;
        if !fused_idx.is_empty() {
            let n = fused_idx.len() + sides.len();
            let mut tokens = Vec::with_capacity(n);
            let mut pos = Vec::with_capacity(n);
            let mut views = Vec::with_capacity(n);
            for &i in &fused_idx {
                tokens.push(mains[i].req.token);
                pos.push(mains[i].req.pos);
                views.push(mains[i].req.paged.clone());
            }
            for s in sides {
                tokens.push(s.token);
                pos.push(s.pos);
                views.push(s.paged.clone());
            }
            device_ops += 1;
            match self.decode_batch_raw(n, tokens, pos, &views, Lane::River) {
                Ok(results) => {
                    let mut it = results.into_iter();
                    for &i in &fused_idx {
                        let (logits, hidden, k_new, v_new) =
                            it.next().expect("one result per fused main lane");
                        main_out[i] = Some(Ok(RawDecode { logits, hidden, k_new, v_new }));
                    }
                    side_out = it
                        .map(|(logits, hidden, k_new, v_new)| RawDecode {
                            logits,
                            hidden,
                            k_new,
                            v_new,
                        })
                        .collect();
                }
                Err(e) => {
                    // A lane's fault must not sink the Rivers: rerun each
                    // fused main alone and report the side half failed.
                    // Nothing was appended by the failed call, so the
                    // reruns are side-effect-safe.
                    for &i in &fused_idx {
                        let m = &mains[i];
                        device_ops += 1;
                        main_out[i] = Some(
                            self.decode_raw(
                                m.req.token,
                                m.req.pos,
                                &m.req.paged,
                                m.capacity,
                                Lane::River,
                            )
                            .map_err(|e| format!("{e:#}")),
                        );
                    }
                    side_error = Some(format!("{e:#}"));
                }
            }
        } else if !sides.is_empty() {
            // No fusable main aboard: one side batch on Stream.
            device_ops += 1;
            match self.run_side_batch(sides) {
                Ok(out) => side_out = out,
                Err(e) => {
                    if mains.is_empty() {
                        // Pure side tick: the whole tick failed.
                        return Err(e);
                    }
                    // Some main op already succeeded — isolate the fault.
                    side_error = Some(format!("{e:#}"));
                }
            }
        }

        Ok(FusedOut {
            mains: main_out
                .into_iter()
                .map(|r| r.expect("every main lane ran own-op or batched"))
                .collect(),
            sides: side_out,
            side_error,
            device_ops,
        })
    }

    /// One device op over side items only: the cheaper single-decode
    /// program for a lone straggler, the batch program otherwise.  Shared
    /// by [`Engine::decode_fused`] and the legacy batcher's executor.
    pub fn run_side_batch(&self, sides: &[FusedReq]) -> Result<Vec<RawDecode>> {
        if sides.len() == 1 {
            let s = &sides[0];
            let (logits, hidden, k_new, v_new) =
                self.decode_side_raw(s.token, s.pos, &s.paged, Lane::Stream)?;
            return Ok(vec![RawDecode {
                logits,
                hidden,
                k_new,
                v_new,
            }]);
        }
        let n = sides.len();
        let mut tokens = Vec::with_capacity(n);
        let mut pos = Vec::with_capacity(n);
        let mut views = Vec::with_capacity(n);
        for s in sides {
            tokens.push(s.token);
            pos.push(s.pos);
            views.push(s.paged.clone());
        }
        let results = self.decode_batch_raw(n, tokens, pos, &views, Lane::Stream)?;
        Ok(results
            .into_iter()
            .map(|(logits, hidden, k_new, v_new)| RawDecode {
                logits,
                hidden,
                k_new,
                v_new,
            })
            .collect())
    }

    /// Batched side-agent decode over paged views (the dynamic batcher's
    /// entry point — requests carry block tables, not flat copies).
    ///
    /// `n` is the number of real slots; the remaining `B - n` lanes are
    /// padded.  `views[i]` must address blocks of this engine's pool; each
    /// lane's `[L, Cs, KV, hd]` K/V is gathered device-side from the
    /// resident block copies.  Returns `n` tuples
    /// `(logits, hidden, k_new, v_new)`.
    #[allow(clippy::type_complexity)]
    pub fn decode_batch_raw(
        &self,
        n: usize,
        mut tokens: Vec<i32>,
        mut pos: Vec<i32>,
        views: &[PagedKv],
        lane: Lane,
    ) -> Result<Vec<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)>> {
        let b = self.caps.decode_batch;
        if n == 0 || n > b {
            bail!("decode_batch_raw: {n} slots not in 1..={b}");
        }
        if views.len() != n {
            bail!("decode_batch_raw: {} views for {n} slots", views.len());
        }
        let cs = self.caps.side_ctx;
        let per = self.cfg.n_layers * cs * self.cfg.n_kv_heads * self.cfg.head_dim;
        tokens.resize(b, self.pad_id);
        pos.resize(b, 0);
        let mut lens = vec![0i32; b];
        let mut k_all = vec![0.0f32; b * per];
        let mut v_all = vec![0.0f32; b * per];
        for (i, view) in views.iter().enumerate() {
            lens[i] = view.len as i32;
            self.pool.dev_gather_prefix_into(
                &view.table,
                view.len,
                cs,
                &mut k_all[i * per..(i + 1) * per],
                &mut v_all[i * per..(i + 1) * per],
            )?;
        }

        let shape = vec![
            b,
            self.cfg.n_layers,
            cs,
            self.cfg.n_kv_heads,
            self.cfg.head_dim,
        ];
        let out = self.device.call(
            self.ids.decode_batch,
            vec![
                HostTensor::i32(tokens, vec![b]),
                HostTensor::i32(pos, vec![b]),
                HostTensor::f32(k_all, shape.clone()),
                HostTensor::f32(v_all, shape),
                HostTensor::i32(lens, vec![b]),
            ],
            lane,
        )?;
        let [logits, hidden, k_new, v_new]: [HostTensor; 4] = take4(out.outputs)?;
        let logits = logits.into_f32()?;
        let hidden = hidden.into_f32()?;
        let k_new = k_new.into_f32()?;
        let v_new = v_new.into_f32()?;

        let v_dim = self.cfg.vocab_size;
        let d = self.cfg.d_model;
        let row = self.cfg.n_layers * self.cfg.n_kv_heads * self.cfg.head_dim;
        Ok((0..n)
            .map(|i| {
                (
                    logits[i * v_dim..(i + 1) * v_dim].to_vec(),
                    hidden[i * d..(i + 1) * d].to_vec(),
                    k_new[i * row..(i + 1) * row].to_vec(),
                    v_new[i * row..(i + 1) * row].to_vec(),
                )
            })
            .collect())
    }

    /// Batched side-agent decode over `KvCache` slots (same order results).
    pub fn decode_batch(
        &self,
        slots: &mut [(i32, i32, &mut KvCache)],
        lane: Lane,
    ) -> Result<Vec<DecodeOut>> {
        let b = self.caps.decode_batch;
        if slots.is_empty() || slots.len() > b {
            bail!("decode_batch: {} slots not in 1..={b}", slots.len());
        }
        let cs = self.caps.side_ctx;
        let n = slots.len();
        let mut tokens = Vec::with_capacity(n);
        let mut pos = Vec::with_capacity(n);
        let mut views = Vec::with_capacity(n);
        for (i, (tok, p, kv)) in slots.iter().enumerate() {
            if kv.capacity() != cs {
                bail!("decode_batch: slot {i} is not side-capacity");
            }
            if kv.remaining() == 0 {
                bail!("decode_batch: slot {i} cache full");
            }
            tokens.push(*tok);
            pos.push(*p);
            // No copy at all: each slot contributes its block table; the
            // lane K/V is gathered from the device-resident blocks.
            views.push(kv.paged());
        }
        let results = self.decode_batch_raw(n, tokens, pos, &views, lane)?;
        let mut outs = Vec::with_capacity(n);
        for ((logits, hidden, k_new, v_new), (_, _, kv)) in
            results.into_iter().zip(slots.iter_mut())
        {
            kv.append_row(&k_new, &v_new)?;
            outs.push(DecodeOut {
                logits,
                hidden,
                queue_ns: 0,
                exec_ns: 0,
            });
        }
        Ok(outs)
    }

    // ── Synapse (§3.3) ─────────────────────────────────────────────────

    /// Extract K landmarks from a main-agent cache, driven by its current
    /// hidden state.  Uses the engine-default hybrid parameters.
    pub fn synapse_extract(
        &self,
        hidden: &[f32],
        kv: &KvCache,
        lane: Lane,
    ) -> Result<SynapseOut> {
        self.synapse_extract_with(hidden, kv, self.alpha, self.inv2sig2, lane)
    }

    /// Extraction with explicit hybrid parameters (ablation entry point:
    /// `alpha=1` = attention-only, `alpha=0` = density/coverage-only).
    pub fn synapse_extract_with(
        &self,
        hidden: &[f32],
        kv: &KvCache,
        alpha: f32,
        inv2sig2: f32,
        lane: Lane,
    ) -> Result<SynapseOut> {
        if kv.capacity() != self.caps.main_ctx {
            bail!("synapse_extract requires a main-capacity cache");
        }
        if kv.len() < self.caps.synapse_k {
            bail!(
                "synapse_extract: cache has {} rows < K={}",
                kv.len(),
                self.caps.synapse_k
            );
        }
        // The landmark scan reads the same device-resident rows decode
        // attends over — ships the block table, not the full cache.
        let (k_up, v_up) = kv.device_gather(kv.capacity())?;
        let kv_shape = kv.shape();
        let out = self.device.call(
            self.ids.synapse,
            vec![
                HostTensor::f32(hidden.to_vec(), vec![self.cfg.d_model]),
                HostTensor::f32(k_up, kv_shape.clone()),
                HostTensor::f32(v_up, kv_shape),
                HostTensor::scalar_i32(kv.len() as i32),
                HostTensor::scalar_f32(alpha),
                HostTensor::scalar_f32(inv2sig2),
            ],
            lane,
        )?;
        let [lm_k, lm_v, indices, scores]: [HostTensor; 4] = take4(out.outputs)?;
        // indices arrive as f32 (mixed-dtype output tuples crash the 0.5.1
        // readback path — see python/compile/model.py); exact below 2^24.
        let indices = indices.into_f32()?.iter().map(|x| *x as i32).collect();
        Ok(SynapseOut {
            lm_k: lm_k.into_f32()?,
            lm_v: lm_v.into_f32()?,
            indices,
            scores: scores.into_f32()?,
            source_len: kv.len(),
            n_layers: self.cfg.n_layers,
        })
    }

    // ── Referential Injection (§3.6) ───────────────────────────────────

    /// Encode a thought at virtual base position `pos_base`, returning the
    /// K/V rows to append to a main cache.
    pub fn inject_encode(
        &self,
        tokens: &[i32],
        pos_base: i32,
        lane: Lane,
    ) -> Result<InjectOut> {
        let t = self.caps.inject_len;
        if tokens.is_empty() {
            bail!("inject_encode: empty thought");
        }
        let len = tokens.len().min(t);
        let mut padded = vec![self.pad_id; t];
        padded[..len].copy_from_slice(&tokens[..len]);
        let out = self.device.call(
            self.ids.inject,
            vec![
                HostTensor::i32(padded, vec![t]),
                HostTensor::scalar_i32(len as i32),
                HostTensor::scalar_i32(pos_base),
            ],
            lane,
        )?;
        let [k, v, hidden]: [HostTensor; 3] = take3(out.outputs)?;
        Ok(InjectOut {
            k: k.into_f32()?,
            v: v.into_f32()?,
            hidden_last: hidden.into_f32()?,
            len,
        })
    }

    /// Slice the first `n` rows out of `[L, T, KV, hd]` inject output so the
    /// caller can append exactly the real thought rows.
    pub fn slice_inject_rows(&self, out: &InjectOut, n: usize) -> (Vec<f32>, Vec<f32>) {
        let t = self.caps.inject_len;
        let row = self.cfg.n_kv_heads * self.cfg.head_dim;
        let mut k = Vec::with_capacity(self.cfg.n_layers * n * row);
        let mut v = Vec::with_capacity(self.cfg.n_layers * n * row);
        for layer in 0..self.cfg.n_layers {
            let start = layer * t * row;
            k.extend_from_slice(&out.k[start..start + n * row]);
            v.extend_from_slice(&out.v[start..start + n * row]);
        }
        (k, v)
    }
}

fn take4(v: Vec<HostTensor>) -> Result<[HostTensor; 4]> {
    let arr: [HostTensor; 4] = v
        .try_into()
        .map_err(|v: Vec<HostTensor>| anyhow::anyhow!("expected 4 outputs, got {}", v.len()))?;
    Ok(arr)
}

fn take3(v: Vec<HostTensor>) -> Result<[HostTensor; 3]> {
    let arr: [HostTensor; 3] = v
        .try_into()
        .map_err(|v: Vec<HostTensor>| anyhow::anyhow!("expected 3 outputs, got {}", v.len()))?;
    Ok(arr)
}
