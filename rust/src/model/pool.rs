//! The shared KV block pool: demand-paged context memory for every agent.
//!
//! The seed architecture gave each agent a full-capacity flat `[L, C, KV, hd]`
//! buffer, so resident bytes scaled with *configured* capacity rather than
//! *actual* fill.  `KvPool` replaces that with virtual-memory-style paging:
//! one shared slab of fixed-size blocks (`block_tokens` positions × all
//! layers, K+V), a free-list allocator, and per-cache block tables
//! ([`super::kv::KvCache`]).  Caches rent blocks as they grow and return
//! them when truncated, cleared or dropped, so
//!
//! * an idle or short-context agent costs a handful of blocks, not `C` rows;
//! * blocks released by finished side agents are immediately reused by new
//!   ones (the Table-2 "high-water < sum of capacities" property);
//! * the pool's gauges (blocks live / free / high-water, fragmentation) are
//!   the measured side of the paper's O(N·k) context-memory claim.
//!
//! Invariant: a rented block is exclusively owned by one cache, and readers
//! only ever observe rows `< len` of a cache — recycled blocks may therefore
//! carry stale floats beyond the fill without being re-zeroed (the decode
//! programs mask attention past `cache_len`, and every host-side gather
//! copies only the valid prefix).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Result};

use super::kv::KvCache;
use crate::runtime::ModelConfig;

/// Pool sizing + reclaim knobs (surfaced on [`crate::cortex::CortexConfig`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvPoolConfig {
    /// Positions per block (the paging granularity).
    pub block_tokens: usize,
    /// Hard cap on simultaneously rented blocks; `0` = unbounded.  When the
    /// cap is hit, cache growth fails with a pool-exhaustion error — the
    /// backpressure signal schedulers act on.
    pub max_blocks: usize,
    /// Reclaim policy: how many released blocks the free list may retain for
    /// reuse before further releases return their memory to the allocator.
    pub retain_free_blocks: usize,
}

impl Default for KvPoolConfig {
    fn default() -> Self {
        KvPoolConfig {
            block_tokens: 16,
            max_blocks: 0,
            retain_free_blocks: usize::MAX,
        }
    }
}

/// One fixed-size block: `block_tokens` positions × all layers, K and V.
/// Each buffer is `[L, block_tokens, KV*hd]`, row-major.
#[derive(Debug)]
pub struct KvBlock {
    pub(crate) k: Box<[f32]>,
    pub(crate) v: Box<[f32]>,
}

#[derive(Debug, Default)]
struct PoolState {
    free: Vec<KvBlock>,
    live: usize,
    high_water: usize,
}

/// Live gauges of one pool (the `/stats` and Table-2 reporting unit).
#[derive(Debug, Clone, Default)]
pub struct PoolStats {
    pub block_tokens: usize,
    /// Bytes of one block (K + V, all layers).
    pub block_bytes: u64,
    /// Blocks currently rented by caches.
    pub blocks_live: usize,
    /// Released blocks held for reuse.
    pub blocks_free: usize,
    /// Peak simultaneously-rented blocks.
    pub blocks_high_water: usize,
    /// Total rents (fresh allocations + reuses).
    pub rents: u64,
    /// Rents served from the free list instead of a fresh allocation.
    pub reuses: u64,
    pub releases: u64,
    /// Filled positions across all live caches.
    pub rows_live: u64,
}

impl PoolStats {
    /// Bytes held by rented blocks (the resident-context figure).
    pub fn live_bytes(&self) -> u64 {
        self.blocks_live as u64 * self.block_bytes
    }

    /// Bytes held by the pool overall (rented + retained free blocks).
    pub fn resident_bytes(&self) -> u64 {
        (self.blocks_live + self.blocks_free) as u64 * self.block_bytes
    }

    pub fn high_water_bytes(&self) -> u64 {
        self.blocks_high_water as u64 * self.block_bytes
    }

    /// Internal fragmentation: the fraction of rented positions that hold no
    /// row yet (allocated-but-unfilled block tails).
    pub fn fragmentation(&self) -> f64 {
        let cap = (self.blocks_live * self.block_tokens) as f64;
        if cap <= 0.0 {
            0.0
        } else {
            (1.0 - self.rows_live as f64 / cap).max(0.0)
        }
    }
}

/// The shared block allocator.  Exactly one per [`super::Engine`] — every
/// cache the engine or the orchestrator hands out rents from it, so the
/// capacity cap and the occupancy gauges cover the whole system.  The
/// paging granularity (`block_tokens`) is fixed at construction; the
/// limits (`max_blocks`, `retain_free_blocks`) are runtime-adjustable via
/// [`KvPool::set_limits`] so [`crate::cortex::WarpCortex`] can apply its
/// config knobs to an already-built engine's pool.
pub struct KvPool {
    block_tokens: usize,
    max_blocks: AtomicUsize,
    retain_free_blocks: AtomicUsize,
    n_layers: usize,
    kv_heads: usize,
    head_dim: usize,
    state: Mutex<PoolState>,
    rents: AtomicU64,
    reuses: AtomicU64,
    releases: AtomicU64,
    rows_live: AtomicU64,
}

impl std::fmt::Debug for KvPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("KvPool")
            .field("block_tokens", &s.block_tokens)
            .field("blocks_live", &s.blocks_live)
            .field("blocks_free", &s.blocks_free)
            .field("blocks_high_water", &s.blocks_high_water)
            .finish()
    }
}

impl KvPool {
    pub fn new(model: &ModelConfig, cfg: KvPoolConfig) -> Arc<KvPool> {
        assert!(cfg.block_tokens > 0, "block_tokens must be positive");
        Arc::new(KvPool {
            block_tokens: cfg.block_tokens,
            max_blocks: AtomicUsize::new(cfg.max_blocks),
            retain_free_blocks: AtomicUsize::new(cfg.retain_free_blocks),
            n_layers: model.n_layers,
            kv_heads: model.n_kv_heads,
            head_dim: model.head_dim,
            state: Mutex::new(PoolState::default()),
            rents: AtomicU64::new(0),
            reuses: AtomicU64::new(0),
            releases: AtomicU64::new(0),
            rows_live: AtomicU64::new(0),
        })
    }

    pub fn config(&self) -> KvPoolConfig {
        KvPoolConfig {
            block_tokens: self.block_tokens,
            max_blocks: self.max_blocks.load(Ordering::Relaxed),
            retain_free_blocks: self.retain_free_blocks.load(Ordering::Relaxed),
        }
    }

    /// Adjust the runtime limits (capacity cap + reclaim policy).  The
    /// paging granularity is fixed at construction — changing it would
    /// invalidate every live block table.
    pub fn set_limits(&self, max_blocks: usize, retain_free_blocks: usize) {
        self.max_blocks.store(max_blocks, Ordering::Relaxed);
        self.retain_free_blocks
            .store(retain_free_blocks, Ordering::Relaxed);
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub(crate) fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub(crate) fn kv_heads(&self) -> usize {
        self.kv_heads
    }

    pub(crate) fn head_dim(&self) -> usize {
        self.head_dim
    }

    /// Floats per (layer, position): `KV * hd`.
    pub(crate) fn row(&self) -> usize {
        self.kv_heads * self.head_dim
    }

    /// Floats in one block buffer (K or V alone).
    pub(crate) fn block_floats(&self) -> usize {
        self.n_layers * self.block_tokens * self.row()
    }

    /// Bytes of one block, K + V.
    pub fn block_bytes(&self) -> u64 {
        (self.block_floats() * 2 * 4) as u64
    }

    /// Blocks needed to hold `rows` positions (round up; 0 rows → 0 blocks).
    /// (Spelled out instead of `div_ceil` to keep the MSRV permissive.)
    #[allow(clippy::manual_div_ceil)]
    pub fn blocks_for(&self, rows: usize) -> usize {
        (rows + self.block_tokens - 1) / self.block_tokens
    }

    /// Rent one block: reuse a freed block if available, otherwise allocate
    /// a fresh zeroed one.  Fails when the pool is at `max_blocks` — the
    /// caller surfaces this as cache-growth backpressure.
    pub(crate) fn rent_block(&self) -> Result<KvBlock> {
        let mut st = self.state.lock().unwrap();
        // The cap binds on LIVE blocks, so it must be checked before the
        // free list too — parked free blocks don't grant cap headroom.
        let max_blocks = self.max_blocks.load(Ordering::Relaxed);
        if max_blocks > 0 && st.live >= max_blocks {
            bail!(
                "kv pool exhausted: {} blocks live (max {max_blocks}, block_tokens {})",
                st.live,
                self.block_tokens
            );
        }
        if let Some(b) = st.free.pop() {
            st.live += 1;
            st.high_water = st.high_water.max(st.live);
            drop(st);
            self.rents.fetch_add(1, Ordering::Relaxed);
            self.reuses.fetch_add(1, Ordering::Relaxed);
            return Ok(b);
        }
        st.live += 1;
        st.high_water = st.high_water.max(st.live);
        drop(st);
        self.rents.fetch_add(1, Ordering::Relaxed);
        let n = self.block_floats();
        Ok(KvBlock {
            k: vec![0.0; n].into_boxed_slice(),
            v: vec![0.0; n].into_boxed_slice(),
        })
    }

    /// Return a block.  Retained on the free list up to
    /// `retain_free_blocks`; past that the block's memory goes back to the
    /// allocator (the reclaim policy).
    pub(crate) fn release_block(&self, block: KvBlock) {
        self.releases.fetch_add(1, Ordering::Relaxed);
        let mut st = self.state.lock().unwrap();
        st.live = st.live.saturating_sub(1);
        if st.free.len() < self.retain_free_blocks.load(Ordering::Relaxed) {
            st.free.push(block);
        }
    }

    pub(crate) fn note_rows_added(&self, n: usize) {
        self.rows_live.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub(crate) fn note_rows_removed(&self, n: usize) {
        // Saturating: a miscounted release must not wrap the gauge.
        let _ = self
            .rows_live
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
                Some(cur.saturating_sub(n as u64))
            });
    }

    /// A fresh pool-backed cache able to hold up to `capacity` rows.
    pub fn new_cache(self: &Arc<Self>, capacity: usize) -> KvCache {
        KvCache::with_pool(self.clone(), capacity)
    }

    pub fn stats(&self) -> PoolStats {
        let st = self.state.lock().unwrap();
        PoolStats {
            block_tokens: self.block_tokens,
            block_bytes: self.block_bytes(),
            blocks_live: st.live,
            blocks_free: st.free.len(),
            blocks_high_water: st.high_water,
            rents: self.rents.load(Ordering::Relaxed),
            reuses: self.reuses.load(Ordering::Relaxed),
            releases: self.releases.load(Ordering::Relaxed),
            rows_live: self.rows_live.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 192,
            vocab_size: 260,
            head_dim: 16,
            rope_theta: 1e4,
            param_count: 0,
        }
    }

    fn pool(block_tokens: usize, max_blocks: usize) -> Arc<KvPool> {
        KvPool::new(
            &tiny_cfg(),
            KvPoolConfig {
                block_tokens,
                max_blocks,
                retain_free_blocks: usize::MAX,
            },
        )
    }

    #[test]
    fn rent_release_reuse_round_trip() {
        let p = pool(4, 0);
        assert_eq!(p.block_bytes(), (2 * 4 * 32 * 2 * 4) as u64);

        let a = p.rent_block().unwrap();
        let b = p.rent_block().unwrap();
        let s = p.stats();
        assert_eq!(s.blocks_live, 2);
        assert_eq!(s.blocks_free, 0);
        assert_eq!(s.blocks_high_water, 2);
        assert_eq!(s.reuses, 0);

        p.release_block(a);
        p.release_block(b);
        let s = p.stats();
        assert_eq!(s.blocks_live, 0);
        assert_eq!(s.blocks_free, 2);

        // the next rents come from the free list, not fresh allocations
        let _c = p.rent_block().unwrap();
        let _d = p.rent_block().unwrap();
        let s = p.stats();
        assert_eq!(s.reuses, 2);
        assert_eq!(s.blocks_live, 2);
        assert_eq!(s.blocks_free, 0);
        assert_eq!(s.blocks_high_water, 2, "reuse must not raise the peak");
    }

    #[test]
    fn exhaustion_backpressure() {
        let p = pool(4, 2);
        let a = p.rent_block().unwrap();
        let _b = p.rent_block().unwrap();
        let err = p.rent_block().unwrap_err();
        assert!(format!("{err:#}").contains("exhausted"));
        // releasing frees capacity again
        p.release_block(a);
        assert!(p.rent_block().is_ok());
    }

    #[test]
    fn set_limits_applies_at_runtime() {
        // The orchestrator adopts an engine's pool and applies its knobs
        // after construction — the cap must bind immediately.
        let p = pool(4, 0);
        let _a = p.rent_block().unwrap();
        p.set_limits(1, usize::MAX);
        assert!(p.rent_block().is_err(), "cap of 1 with 1 live must refuse");
        assert_eq!(p.config().max_blocks, 1);
        p.set_limits(0, usize::MAX);
        assert!(p.rent_block().is_ok(), "lifting the cap unblocks growth");
    }

    #[test]
    fn cap_binds_even_when_free_blocks_are_parked() {
        // A retained free list must not grant headroom past max_blocks:
        // the cap is on LIVE blocks.
        let p = pool(4, 0);
        let blocks: Vec<_> = (0..5).map(|_| p.rent_block().unwrap()).collect();
        for b in blocks {
            p.release_block(b);
        }
        assert_eq!(p.stats().blocks_free, 5);
        p.set_limits(2, usize::MAX);
        let _a = p.rent_block().unwrap();
        let _b = p.rent_block().unwrap();
        let err = p.rent_block().unwrap_err();
        assert!(
            format!("{err:#}").contains("exhausted"),
            "free-list rent bypassed the cap"
        );
    }

    #[test]
    fn reclaim_policy_caps_free_list() {
        let p = KvPool::new(
            &tiny_cfg(),
            KvPoolConfig {
                block_tokens: 4,
                max_blocks: 0,
                retain_free_blocks: 1,
            },
        );
        let a = p.rent_block().unwrap();
        let b = p.rent_block().unwrap();
        let c = p.rent_block().unwrap();
        p.release_block(a);
        p.release_block(b);
        p.release_block(c);
        let s = p.stats();
        assert_eq!(s.blocks_free, 1, "free list capped by retain_free_blocks");
        assert_eq!(s.blocks_live, 0);
    }

    #[test]
    fn blocks_for_rounds_up() {
        let p = pool(16, 0);
        assert_eq!(p.blocks_for(0), 0);
        assert_eq!(p.blocks_for(1), 1);
        assert_eq!(p.blocks_for(16), 1);
        assert_eq!(p.blocks_for(17), 2);
    }

    #[test]
    fn fragmentation_gauge() {
        let p = pool(8, 0);
        let _b = p.rent_block().unwrap();
        p.note_rows_added(6);
        let s = p.stats();
        assert_eq!(s.rows_live, 6);
        assert!((s.fragmentation() - 0.25).abs() < 1e-9, "{}", s.fragmentation());
        p.note_rows_removed(6);
        assert_eq!(p.stats().rows_live, 0);
    }

    #[test]
    fn random_rent_release_sequences_reuse_without_growth() {
        // Fragmentation-free reuse: after any interleaving of rents and
        // releases, demand that never exceeds a prior peak is served
        // entirely from the free list — the high-water mark stays put.
        check("pool reuse under churn", 50, |g| {
            let p = pool(4, 0);
            let mut held = Vec::new();
            let mut peak = 0usize;
            // phase 1: random churn
            for _ in 0..g.usize_in(10..60) {
                if g.bool() || held.is_empty() {
                    held.push(p.rent_block().map_err(|e| e.to_string())?);
                    peak = peak.max(held.len());
                } else {
                    let i = g.usize_in(0..held.len());
                    p.release_block(held.swap_remove(i));
                }
            }
            let hw = p.stats().blocks_high_water;
            crate::prop_assert!(hw == peak, "high-water {hw} != observed peak {peak}");
            // phase 2: drop everything, then re-rent up to the peak
            for b in held.drain(..) {
                p.release_block(b);
            }
            let before = p.stats();
            crate::prop_assert!(
                before.blocks_free == peak,
                "free list {} != peak {peak}",
                before.blocks_free
            );
            for _ in 0..peak {
                held.push(p.rent_block().map_err(|e| e.to_string())?);
            }
            let after = p.stats();
            crate::prop_assert!(
                after.blocks_high_water == peak,
                "re-renting to the old peak grew the pool: {} > {peak}",
                after.blocks_high_water
            );
            crate::prop_assert!(
                after.reuses - before.reuses >= peak as u64,
                "expected {} reuses, got {}",
                peak,
                after.reuses - before.reuses
            );
            for b in held.drain(..) {
                p.release_block(b);
            }
            Ok(())
        });
    }
}
