//! The shared KV block pool: demand-paged, copy-on-write context memory for
//! every agent.
//!
//! The seed architecture gave each agent a full-capacity flat `[L, C, KV, hd]`
//! buffer, so resident bytes scaled with *configured* capacity rather than
//! *actual* fill.  `KvPool` replaces that with virtual-memory-style paging:
//! one shared slab of fixed-size blocks (`block_tokens` positions × all
//! layers, K+V), a free-list allocator, and per-cache block tables
//! ([`super::kv::KvCache`]).  Caches rent blocks as they grow and return
//! them when truncated, cleared or dropped, so
//!
//! * an idle or short-context agent costs a handful of blocks, not `C` rows;
//! * blocks released by finished side agents are immediately reused by new
//!   ones (the Table-2 "high-water < sum of capacities" property);
//! * the pool's gauges (blocks live / free / high-water, fragmentation) are
//!   the measured side of the paper's O(N·k) context-memory claim.
//!
//! # Ownership model: refcounted blocks + copy-on-write
//!
//! Since the prefix-sharing refactor the pool owns all block storage: a
//! cache's table holds block *ids*, and each slab slot carries a refcount.
//! A block referenced by exactly one table and absent from the prefix
//! registry is *private* — writes go in place, exactly as before.  A block
//! that is registered (content-addressed) or referenced by more than one
//! table is *shared* and immutable: any write through `KvPool::write_run`
//! first copies the block into a fresh private one, swaps it into the
//! writing cache's table and drops one reference on the original
//! (copy-on-write).  A physical block is freed only when its last table
//! reference is gone *and* it is not registered — a referenced block can
//! never be reclaimed out from under a reader.
//!
//! # The content-addressed prefix registry
//!
//! [`KvPool::prefix_hashes`] maps a key sequence (prompt token ids, synapse
//! landmark indices) to one chain hash per *full* block: `h[i]` commits to
//! every key in blocks `0..=i`, so a hit on `h[i]` proves the whole prefix
//! matches.  [`super::kv::KvCache::register_prefix`] publishes a cache's
//! full blocks under those hashes; [`super::kv::KvCache::attach_shared_prefix`]
//! lets a later cache adopt the longest registered prefix by reference —
//! O(1) memory and zero host→device traffic for the shared rows, the
//! "one prefill, N agents" property measured by `benches/prefix_share.rs`.
//! Registered blocks whose refcount drops to zero stay *parked* in the
//! registry (still resident, still hittable); when the pool is at its
//! `max_blocks` cap, a rent evicts the least-recently-used parked entry
//! before failing with backpressure.  Shared (registered) blocks are
//! charged once globally (`MemKind::SharedKv` via [`KvPool::track_shared`])
//! so Table-2 accounting never multiply-counts a block that N caches
//! reference.
//!
//! # Device residency
//!
//! Each block also owns a **lazily materialised device copy** in the pool's
//! *device slab*, addressed by the block's stable `id` and recycled with the
//! block through the free list.  Every host write goes through
//! `KvPool::write_run`, which writes **only the touched rows** through to
//! the device copy (a CoW copy re-syncs the whole block once), so the
//! per-decode-step host→device traffic is `O(new row + block table)` instead
//! of the seed's `O(capacity)` full-cache re-upload.  Decode-time K/V then
//! comes from [`KvPool::dev_gather_prefix`] — the paged-attention gather
//! over resident blocks (reference semantics in
//! [`crate::runtime::xla_stub::paged_gather_prefix`]); only the block table
//! itself counts as upload bytes.  On this offline substrate the slab's
//! buffers are host memory standing in for PJRT buffers with identical
//! layout and life-cycle; the `h2d_bytes` gauge measures the traffic a real
//! backend would pay, and the O(k)-per-step property is asserted by
//! `benches/decode_upload.rs`.
//!
//! # The memory-tier hierarchy
//!
//! Since the tiered-KV refactor a block's payload lives in exactly one of
//! three tiers, and the `max_blocks` cap binds on **bytes** (a budget of
//! `max_blocks × block_bytes`, i.e. fp32-block-equivalents) rather than on
//! a block count — which is what lets the warm tier multiply blocks-per-GB:
//!
//! * **hot — fp32 device** (`Payload::F32`): every private, writable
//!   block.  Full-precision host rows plus the lazily materialised device
//!   copy; all writes land here (`write_run` promotes first if needed).
//! * **warm — int8 quantized** (`Payload::Q8`): registered blocks whose
//!   refcount dropped to zero (parked prefixes, synapse seeds) demote to
//!   block-granular int8 with one f32 scale per (layer, position) row when
//!   [`KvPoolConfig::quantize_parked`] is set — ~3.5× more blocks per GB
//!   for exactly the state that dominates at scale.  Quantized blocks are
//!   immutable (registered ⇒ CoW): gathers dequantize transparently, host
//!   and device bit-identically; a write CoW-promotes a private fp32 copy.
//! * **cold — host slab** (`Payload::Offloaded`): under cap pressure (and
//!   on session park via [`super::kv::KvCache::park_to_host`]) a block's
//!   payload moves *verbatim* — losslessly — into a bounded host slab
//!   ([`KvPoolConfig::host_slab_blocks`], the stand-in for pinned-host PJRT
//!   buffers) and its device copy is dropped.  Offloaded registry entries
//!   stay hittable: a chain hit (or a session resume) pages the payload
//!   back in, re-uploads the device copy, and counts `swap_in_bytes` /
//!   `resume_page_ins`.  Because the move is verbatim, a park → offload →
//!   resume round trip decodes bit-identically.
//!
//! Demotion order under pressure is offload-first (lossless, keeps the
//! entry) then LRU-evict (drops it); admission ([`KvPool::can_admit`])
//! counts both as reclaimable headroom and sheds only when the budget,
//! the slab and the parked set are all exhausted.  Accounting counts every
//! physical byte once in its tier: resident payload bytes under the byte
//! budget (`SharedKv`/`MainKv`/`SideKv` at their actual tier size), slab
//! bytes under `HostKv`, device copies under `DeviceKv`;
//! [`KvPool::check_invariants`] re-proves the tier partition and every
//! gauge reconciliation.

use std::collections::HashMap;
use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::{anyhow, bail, Result};

use super::kv::KvCache;
use crate::cortex::memory::MemGuard;
use crate::runtime::xla_stub;
use crate::runtime::ModelConfig;
use crate::util::sync::{LockRank, RankedMutex};

/// Pool sizing + reclaim knobs (surfaced on [`crate::cortex::CortexConfig`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvPoolConfig {
    /// Positions per block (the paging granularity).
    pub block_tokens: usize,
    /// Hard cap on simultaneously live blocks; `0` = unbounded.  When the
    /// cap is hit, the pool first evicts the least-recently-used *parked*
    /// prefix-registry entry (refcount 0); only if none exists does cache
    /// growth fail with a pool-exhaustion error — the backpressure signal
    /// schedulers act on.
    pub max_blocks: usize,
    /// Reclaim policy: how many released blocks the free list may retain for
    /// reuse before further releases return their memory to the allocator.
    pub retain_free_blocks: usize,
    /// Warm tier: demote a registered block to block-granular int8 (one f32
    /// scale per (layer, position) row) when its refcount drops to zero —
    /// parked prefixes and synapse seeds then cost
    /// [`KvPool::q8_block_bytes`] instead of [`KvPool::block_bytes`]
    /// against the byte budget (~3.5× more blocks per GB).  Off by default:
    /// quantization is lossy (bounded by max|x|/127 per row).
    pub quantize_parked: bool,
    /// Cold tier: capacity (in blocks) of the host slab that parked
    /// sessions and refcount-0 registry entries spill to under cap
    /// pressure.  `0` disables offload.  Offloaded payloads move verbatim
    /// (lossless) and cost zero device-budget bytes until paged back in.
    pub host_slab_blocks: usize,
}

impl Default for KvPoolConfig {
    fn default() -> Self {
        KvPoolConfig {
            block_tokens: 16,
            max_blocks: 0,
            retain_free_blocks: usize::MAX,
            quantize_parked: false,
            host_slab_blocks: 0,
        }
    }
}

/// Base seed of every prefix hash chain (domain-salted per use).
pub const PREFIX_SEED: u64 = 0xcbf2_9ce4_8422_2325;

/// Extend a chain hash with a run of i32 keys (FNV-1a over the parent hash
/// and the keys' little-endian bytes).  Stable across runs — registry keys
/// are reproducible for a given (salt, key sequence).
pub fn chain_hash(prev: u64, keys: &[i32]) -> u64 {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = prev;
    for b in prev.to_le_bytes() {
        h = (h ^ b as u64).wrapping_mul(PRIME);
    }
    for &k in keys {
        for b in k.to_le_bytes() {
            h = (h ^ b as u64).wrapping_mul(PRIME);
        }
    }
    h
}

/// A block's K/V payload in one of the three memory tiers (see the
/// module-level tier hierarchy).  `F32` buffers are `[L, block_tokens,
/// KV*hd]` row-major; `Q8` stores the same elements as int8 with one f32
/// scale per (layer, position) row (`[L, block_tokens]`), so host- and
/// device-side dequantization are bit-identical by construction.
#[derive(Debug)]
enum Payload {
    /// Hot tier: full-precision, writable.
    F32 { k: Box<[f32]>, v: Box<[f32]> },
    /// Warm tier: block-granular symmetric int8, immutable (only registered
    /// blocks demote, and registered ⇒ copy-on-write).
    Q8 {
        k: Box<[i8]>,
        v: Box<[i8]>,
        k_scales: Box<[f32]>,
        v_scales: Box<[f32]>,
    },
    /// Cold tier: the payload lives verbatim in `PoolState::host_slab`
    /// under this block's id; no device copy exists until page-in.
    Offloaded,
}

impl Payload {
    fn is_offloaded(&self) -> bool {
        matches!(self, Payload::Offloaded)
    }

    fn tier_name(&self) -> &'static str {
        match self {
            Payload::F32 { .. } => "f32",
            Payload::Q8 { .. } => "q8",
            Payload::Offloaded => "offloaded",
        }
    }
}

/// Symmetric per-row int8 quantization: each `row`-float row gets one f32
/// scale `max|x|/127` (0 for all-zero rows); elements quantize to
/// `round(x/scale)` clamped to `[-127, 127]`.  The per-element round-trip
/// error is bounded by `scale/2 = max|x|/254` — the bound the proptests
/// assert and the reason exact float equality on gathered K/V is a lint
/// (`float-eq` in warp-audit).
fn q8_quantize(src: &[f32], row: usize) -> (Box<[i8]>, Box<[f32]>) {
    debug_assert_eq!(src.len() % row, 0);
    let rows = src.len() / row;
    let mut q = vec![0i8; src.len()];
    let mut scales = vec![0f32; rows];
    for r in 0..rows {
        let s = &src[r * row..(r + 1) * row];
        let max = s.iter().fold(0f32, |m, &x| m.max(x.abs()));
        if !(max > 0.0) {
            continue; // all-zero (or NaN-only) row: scale 0, elements 0
        }
        let scale = max / 127.0;
        scales[r] = scale;
        for (i, &x) in s.iter().enumerate() {
            q[r * row + i] = (x / scale).round().clamp(-127.0, 127.0) as i8;
        }
    }
    (q.into_boxed_slice(), scales.into_boxed_slice())
}

/// Inverse of [`q8_quantize`]: `x̂ = q * scale[row]`.  Used identically by
/// the host gathers and the device-slab re-encode, so both sides of the
/// substitution boundary reconstruct the same floats bit-for-bit.
fn q8_dequantize(q: &[i8], scales: &[f32], row: usize) -> Box<[f32]> {
    debug_assert_eq!(q.len(), scales.len() * row);
    let mut out = vec![0f32; q.len()];
    for (r, &scale) in scales.iter().enumerate() {
        for i in 0..row {
            out[r * row + i] = q[r * row + i] as f32 * scale;
        }
    }
    out.into_boxed_slice()
}

/// A read-only f32 view of one block's K/V, produced by
/// `KvPool::tier_view`: borrowed straight from a hot-tier slot, or owned
/// (dequantized / slab-resolved) for the other tiers.
enum TierView<'a> {
    Hot { k: &'a [f32], v: &'a [f32] },
    Warm { k: Box<[f32]>, v: Box<[f32]> },
}

impl TierView<'_> {
    fn k(&self) -> &[f32] {
        match self {
            TierView::Hot { k, .. } => k,
            TierView::Warm { k, .. } => k,
        }
    }

    fn v(&self) -> &[f32] {
        match self {
            TierView::Hot { v, .. } => v,
            TierView::Warm { v, .. } => v,
        }
    }
}

/// One slab slot: the block's host-side K/V payload (in whichever tier it
/// currently occupies) plus its sharing state.
#[derive(Debug)]
struct HostBlock {
    payload: Payload,
    /// Cache-table references.  The prefix registry's own hold is NOT
    /// counted here — a registered block with `refs == 0` is *parked*
    /// (resident, hittable, evictable under cap pressure).
    refs: u32,
    /// Content-chain key while the block is registered in the prefix
    /// registry; `None` for private blocks.
    hash: Option<u64>,
    /// The registered block's own key run (`block_tokens` i32s), kept so
    /// every chain hit is VERIFIED against the caller's keys — a 64-bit
    /// FNV collision (accidental or adversarial via untrusted prompts)
    /// must degrade to a miss, never attach another prompt's KV.
    keys: Option<Box<[i32]>>,
    /// LRU recency stamp (bumped on registration and on every chain hit).
    last_used: u64,
}

#[derive(Debug, Default)]
struct PoolState {
    /// Host-side block storage, indexed by block id (the same id addresses
    /// the block's device-slab slot).  `None` = id free for recycling.
    slots: Vec<Option<HostBlock>>,
    /// Ids of allocated-but-unreferenced blocks retained for reuse.
    free: Vec<u32>,
    /// Physical blocks referenced by caches and/or parked in the registry.
    live: usize,
    high_water: usize,
    /// Content-addressed prefix registry: chain hash → block id.
    registry: HashMap<u64, u32>,
    /// Monotone recency counter backing the registry's LRU policy.
    tick: u64,
    /// Registered blocks (each charged once globally, however many caches
    /// reference it).
    shared: usize,
    prefix_hits: u64,
    prefix_misses: u64,
    /// Chain hits resolved *mid-prefill* ([`KvPool::lookup_chain_mid`]): a
    /// partially-prefilled cache adopted a continuation block a concurrent
    /// identical prompt registered after this cache attached its prefix.
    prefix_mid_hits: u64,
    prefix_evictions: u64,
    cow_copies: u64,
    /// Accounting hook ([`crate::cortex::memory::MemKind::SharedKv`]):
    /// resized on every registration and eviction.
    shared_guard: Option<MemGuard>,
    /// Cold tier: block id → payload moved verbatim off the device budget
    /// (the stand-in for pinned-host PJRT buffers).
    host_slab: HashMap<u32, Payload>,
    /// Bytes currently held by the host slab (Σ payload bytes of
    /// `host_slab` entries).
    host_slab_bytes: u64,
    /// Accounting hook ([`crate::cortex::memory::MemKind::HostKv`]):
    /// resized on every offload, page-in and slab-entry drop.
    host_guard: Option<MemGuard>,
    /// Resident payload bytes of LIVE blocks (referenced + parked) at their
    /// actual tier size — the quantity the byte budget
    /// (`max_blocks × block_bytes`) binds on.  Free-listed blocks (always
    /// fp32) and offloaded payloads do not count.
    resident_bytes: u64,
    /// Resident payload bytes of *registered* blocks (the `SharedKv`
    /// charge); excludes offloaded registry entries (charged to `HostKv`).
    shared_bytes: u64,
    /// Live blocks currently at the warm int8 tier.
    quantized: usize,
    /// Cumulative bytes moved device → host slab.
    swap_out_bytes: u64,
    /// Cumulative bytes paged host slab → device.
    swap_in_bytes: u64,
    /// Cumulative slab bytes dropped with their block (a parked session's
    /// cache released while offloaded) — never paged back in.  Closes the
    /// swap conservation law:
    /// `swap_out == swap_in + swap_dropped + host_slab_bytes`.
    swap_dropped_bytes: u64,
    /// Page-ins served (chain hits on offloaded entries + session resumes).
    page_ins: u64,
}

/// One block's device-resident K/V copy, at the same tier as its host
/// payload (a quantized block's device copy stores the identical ints and
/// scales, so gathers dequantize bit-identically on either side).  Same
/// `[L, block_tokens, KV*hd]` layout as the host buffers; on a real PJRT
/// backend these would be `PjRtBuffer`s owned by the device thread.
#[derive(Debug)]
enum DevBuf {
    F32 {
        k: Box<[f32]>,
        v: Box<[f32]>,
    },
    Q8 {
        k: Box<[i8]>,
        v: Box<[i8]>,
        k_scales: Box<[f32]>,
        v_scales: Box<[f32]>,
    },
}

/// The device slab: block id → resident device buffer.
#[derive(Debug, Default)]
struct DevSlab {
    /// `None` until the block's first write-through materialises the copy.
    slots: Vec<Option<DevBuf>>,
    /// Ids of fully-dropped blocks, recycled by future rents.
    free_ids: Vec<u32>,
    /// Bytes held by materialised device buffers.
    bytes: u64,
    /// Accounting hook ([`crate::cortex::memory::MemKind::DeviceKv`]):
    /// resized on every materialisation and release.
    guard: Option<MemGuard>,
}

impl DevSlab {
    fn sync_guard(&mut self) {
        let bytes = self.bytes;
        if let Some(g) = self.guard.as_mut() {
            g.resize(bytes);
        }
    }
}

/// A device-addressable view of one cache: its block table plus the valid
/// length.  This — not multi-megabyte K/V vectors — is what a paged decode
/// request ships across threads and (conceptually) to the device.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PagedKv {
    /// Device-slab ids of the blocks covering positions `[0, len)`.
    pub table: Vec<u32>,
    /// Valid rows (`cache_len` of the decode program).
    pub len: usize,
}

impl PagedKv {
    /// Host→device bytes one decode step pays for this view: the i32 block
    /// table plus the length scalar — the O(k) figure the upload bench
    /// asserts against.
    pub fn upload_bytes(&self) -> u64 {
        PagedKv::upload_bytes_for(self.table.len())
    }

    /// Single home of the per-step table-upload formula; the gather path's
    /// `h2d_bytes` charge and the bench assertions both pin to it.
    pub(crate) fn upload_bytes_for(table_len: usize) -> u64 {
        (table_len * 4 + 8) as u64
    }
}

/// Live gauges of one pool (the `/stats` and Table-2 reporting unit).
#[derive(Debug, Clone, Default)]
pub struct PoolStats {
    pub block_tokens: usize,
    /// Bytes of one block (K + V, all layers).
    pub block_bytes: u64,
    /// Physical blocks referenced by caches or parked in the registry.
    pub blocks_live: usize,
    /// Released blocks held for reuse.
    pub blocks_free: usize,
    /// Peak simultaneously-live blocks.
    pub blocks_high_water: usize,
    /// Total rents (fresh allocations + reuses).
    pub rents: u64,
    /// Rents served from the free list (or an evicted registry entry)
    /// instead of a fresh allocation.
    pub reuses: u64,
    pub releases: u64,
    /// Sum of filled positions across all live caches.  Shared rows are
    /// counted once per *referencing cache* (the per-agent context figure),
    /// so this can exceed `blocks_live * block_tokens` under heavy sharing.
    pub rows_live: u64,
    /// Blocks with a materialised device-resident copy.
    pub dev_blocks: usize,
    /// Bytes held by device-resident block copies.
    pub dev_bytes: u64,
    /// Cumulative host→device traffic: row write-throughs + block tables.
    /// The decode-upload bench asserts the per-step delta is O(k).
    pub h2d_bytes: u64,
    /// Device-side paged gathers served (decode steps that shipped a block
    /// table instead of the cache).
    pub dev_gathers: u64,
    /// Blocks currently registered in the content-addressed prefix
    /// registry.  Each is charged once globally (`MemKind::SharedKv`),
    /// regardless of how many cache tables reference it.
    pub shared_blocks: usize,
    /// Prefix-registry lookups that attached a block by reference.
    pub prefix_hits: u64,
    /// Prefix-registry lookups that found no (further) covering block.
    pub prefix_misses: u64,
    /// Chain hits resolved mid-prefill: a partially-prefilled cache adopted
    /// continuation blocks a concurrent identical prompt registered after
    /// this cache attached its prefix (the chunked-prefill dedup path).
    pub prefix_mid_hits: u64,
    /// Parked registry entries evicted (LRU) to satisfy rents at the cap.
    pub prefix_evictions: u64,
    /// Copy-on-write block copies (a write hit a shared block).
    pub cow_copies: u64,
    /// Blocks promised to admitted-but-not-yet-prefilled sessions
    /// ([`KvPool::reserve`]); [`KvPool::can_admit`] treats them as spent.
    pub reserved_blocks: usize,
    /// Resident payload bytes of live blocks at their actual tier size —
    /// the quantity the byte budget (`max_blocks × block_bytes`) binds on.
    pub resident_payload_bytes: u64,
    /// Live blocks currently at the warm int8 tier.
    pub quantized_blocks: usize,
    /// Bytes the warm tier currently saves vs fp32 residency
    /// (`quantized_blocks × (block_bytes − q8_block_bytes)`).
    pub quant_saved_bytes: u64,
    /// Bytes of one block at the warm int8 tier.
    pub q8_block_bytes: u64,
    /// Blocks whose payload currently sits in the cold host slab.
    pub offloaded_blocks: usize,
    /// Bytes held by the cold host slab.
    pub host_slab_bytes: u64,
    /// Resident bytes of registry-shared blocks at their tier size (the
    /// `SharedKv` charge; excludes offloaded entries, which are `HostKv`).
    pub shared_payload_bytes: u64,
    /// Cumulative bytes moved device → host slab.
    pub swap_out_bytes: u64,
    /// Cumulative bytes paged host slab → device.
    pub swap_in_bytes: u64,
    /// Cumulative slab bytes dropped with their block, never paged back in
    /// (closes `swap_out == swap_in + swap_dropped + host_slab_bytes`).
    pub swap_dropped_bytes: u64,
    /// Page-ins served: registry chain hits on offloaded entries plus
    /// session resumes.
    pub resume_page_ins: u64,
}

/// RAII admission reservation from [`KvPool::reserve`]: while alive,
/// [`KvPool::can_admit`] counts `blocks` as already rented.  Dropped once
/// the owning session's prefill has rented its real blocks.
pub struct BlockReservation<'a> {
    pool: &'a KvPool,
    blocks: usize,
}

impl BlockReservation<'_> {
    pub fn blocks(&self) -> usize {
        self.blocks
    }
}

impl Drop for BlockReservation<'_> {
    fn drop(&mut self) {
        self.pool.reserved.fetch_sub(self.blocks, Ordering::SeqCst);
    }
}

impl PoolStats {
    /// Bytes held by live blocks at their actual tier size (the
    /// resident-context figure; equals `blocks_live × block_bytes` while
    /// tiering is off).
    pub fn live_bytes(&self) -> u64 {
        self.resident_payload_bytes
    }

    /// Bytes held by the pool overall (live at tier size + retained free
    /// blocks, which are always fp32).
    pub fn resident_bytes(&self) -> u64 {
        self.resident_payload_bytes + self.blocks_free as u64 * self.block_bytes
    }

    pub fn high_water_bytes(&self) -> u64 {
        self.blocks_high_water as u64 * self.block_bytes
    }

    /// Bytes held by registry-shared blocks (charged once globally, at
    /// their resident tier size).
    pub fn shared_bytes(&self) -> u64 {
        self.shared_payload_bytes
    }

    /// Internal fragmentation: the fraction of live positions that hold no
    /// row yet (allocated-but-unfilled block tails).  Clamped at 0 — under
    /// prefix sharing `rows_live` counts shared rows once per referencing
    /// cache and can exceed the physical capacity.
    pub fn fragmentation(&self) -> f64 {
        let cap = (self.blocks_live * self.block_tokens) as f64;
        if cap <= 0.0 {
            0.0
        } else {
            (1.0 - self.rows_live as f64 / cap).max(0.0)
        }
    }
}

/// The shared block allocator.  Exactly one per [`super::Engine`] — every
/// cache the engine or the orchestrator hands out rents from it, so the
/// capacity cap, the prefix registry and the occupancy gauges cover the
/// whole system.  The paging granularity (`block_tokens`) is fixed at
/// construction; the limits (`max_blocks`, `retain_free_blocks`) are
/// runtime-adjustable via [`KvPool::set_limits`] so
/// [`crate::cortex::WarpCortex`] can apply its config knobs to an
/// already-built engine's pool.
pub struct KvPool {
    block_tokens: usize,
    max_blocks: AtomicUsize,
    retain_free_blocks: AtomicUsize,
    /// Warm-tier knob ([`KvPoolConfig::quantize_parked`]), runtime-settable
    /// via [`KvPool::set_tiering`].
    quantize_parked: AtomicBool,
    /// Cold-tier capacity ([`KvPoolConfig::host_slab_blocks`]), runtime-
    /// settable via [`KvPool::set_tiering`].
    host_slab_blocks: AtomicUsize,
    n_layers: usize,
    kv_heads: usize,
    head_dim: usize,
    /// Host slab + refcounts + prefix registry, under one mutex: refcount
    /// transitions, registry membership and the CoW decision must be
    /// atomic with respect to each other.  Host-side gathers and per-row
    /// write-throughs therefore serialize pool-wide (the decode hot path
    /// itself reads the `dev` slab, not this); if contention shows up at
    /// high agent counts, the follow-up is to resolve the CoW/refcount
    /// decision under this lock but copy rows under per-slot locks (ids
    /// are stable and writers are exclusive by the CoW invariant).
    /// Likewise `evict_lru_locked` is an O(slots) scan — fine at bench
    /// scale, an indexed structure (BTreeMap<last_used, id> of parked
    /// entries) once registries hold tens of thousands of blocks.
    ///
    /// Ranked [`LockRank::PoolState`]: acquired under the session table by
    /// the admission gate, never the other way around; poison-tolerant so
    /// one panicking agent cannot cascade into every session
    /// (`poison-cascade` in warp-audit).
    state: RankedMutex<PoolState>,
    /// Device-resident block copies.  RwLock so concurrent decode gathers
    /// (read-only, and they hold the lock for the full lane memcpy) never
    /// serialize against each other.  Row write-throughs and slot
    /// materialisation/release take the write side.  Lock order: `state`
    /// before `dev` (never both unless in that order).
    dev: RwLock<DevSlab>,
    rents: AtomicU64,
    reuses: AtomicU64,
    releases: AtomicU64,
    rows_live: AtomicU64,
    h2d_bytes: AtomicU64,
    dev_gathers: AtomicU64,
    /// Blocks promised to admitted-but-not-yet-prefilled sessions
    /// ([`KvPool::reserve`]).  Accounting only — `rent_ref` never consults
    /// it — but [`KvPool::can_admit`] subtracts it so N sessions admitted
    /// in the same instant cannot all pass the headroom check and then
    /// collectively exhaust the pool at prefill time.
    reserved: AtomicUsize,
}

impl std::fmt::Debug for KvPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("KvPool")
            .field("block_tokens", &s.block_tokens)
            .field("blocks_live", &s.blocks_live)
            .field("blocks_free", &s.blocks_free)
            .field("blocks_high_water", &s.blocks_high_water)
            .field("shared_blocks", &s.shared_blocks)
            .finish()
    }
}

impl KvPool {
    pub fn new(model: &ModelConfig, cfg: KvPoolConfig) -> Arc<KvPool> {
        assert!(cfg.block_tokens > 0, "block_tokens must be positive");
        Arc::new(KvPool {
            block_tokens: cfg.block_tokens,
            max_blocks: AtomicUsize::new(cfg.max_blocks),
            retain_free_blocks: AtomicUsize::new(cfg.retain_free_blocks),
            quantize_parked: AtomicBool::new(cfg.quantize_parked),
            host_slab_blocks: AtomicUsize::new(cfg.host_slab_blocks),
            n_layers: model.n_layers,
            kv_heads: model.n_kv_heads,
            head_dim: model.head_dim,
            state: RankedMutex::new(LockRank::PoolState, PoolState::default()),
            dev: RwLock::new(DevSlab::default()),
            rents: AtomicU64::new(0),
            reuses: AtomicU64::new(0),
            releases: AtomicU64::new(0),
            rows_live: AtomicU64::new(0),
            h2d_bytes: AtomicU64::new(0),
            dev_gathers: AtomicU64::new(0),
            reserved: AtomicUsize::new(0),
        })
    }

    pub fn config(&self) -> KvPoolConfig {
        KvPoolConfig {
            block_tokens: self.block_tokens,
            max_blocks: self.max_blocks.load(Ordering::Relaxed),
            retain_free_blocks: self.retain_free_blocks.load(Ordering::Relaxed),
            quantize_parked: self.quantize_parked.load(Ordering::Relaxed),
            host_slab_blocks: self.host_slab_blocks.load(Ordering::Relaxed),
        }
    }

    /// Adjust the runtime limits (capacity cap + reclaim policy).  The
    /// paging granularity is fixed at construction — changing it would
    /// invalidate every live block table.
    pub fn set_limits(&self, max_blocks: usize, retain_free_blocks: usize) {
        self.max_blocks.store(max_blocks, Ordering::Relaxed);
        self.retain_free_blocks
            .store(retain_free_blocks, Ordering::Relaxed);
    }

    /// Adjust the tiering knobs at runtime (the orchestrator applies its
    /// config to an already-built engine's pool, like
    /// [`KvPool::set_limits`]).  Turning quantization on demotes blocks as
    /// they next park — already-parked fp32 entries are left untouched;
    /// shrinking the slab strands no data — existing entries stay until
    /// paged in or dropped, only further offloads are refused.
    pub fn set_tiering(&self, quantize_parked: bool, host_slab_blocks: usize) {
        self.quantize_parked.store(quantize_parked, Ordering::Relaxed);
        self.host_slab_blocks
            .store(host_slab_blocks, Ordering::Relaxed);
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub(crate) fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub(crate) fn kv_heads(&self) -> usize {
        self.kv_heads
    }

    pub(crate) fn head_dim(&self) -> usize {
        self.head_dim
    }

    /// Floats per (layer, position): `KV * hd`.
    pub(crate) fn row(&self) -> usize {
        self.kv_heads * self.head_dim
    }

    /// Floats in one block buffer (K or V alone).
    pub(crate) fn block_floats(&self) -> usize {
        self.n_layers * self.block_tokens * self.row()
    }

    /// Bytes of one block, K + V.
    pub fn block_bytes(&self) -> u64 {
        (self.block_floats() * 2 * 4) as u64
    }

    /// Bytes of one block at the warm int8 tier: 1 byte per K/V element
    /// plus one f32 scale per (layer, position) row of each buffer.  With
    /// typical head geometry this is ~3.5× smaller than
    /// [`KvPool::block_bytes`] — the blocks-per-GB multiplier the tiered-kv
    /// bench asserts.
    pub fn q8_block_bytes(&self) -> u64 {
        (self.block_floats() * 2 + self.n_layers * self.block_tokens * 2 * 4) as u64
    }

    /// Resident bytes a payload costs against the device byte budget.
    fn payload_bytes(&self, p: &Payload) -> u64 {
        match p {
            Payload::F32 { .. } => self.block_bytes(),
            Payload::Q8 { .. } => self.q8_block_bytes(),
            Payload::Offloaded => 0,
        }
    }

    /// Bytes a materialised device buffer holds.
    fn dev_buf_bytes(&self, b: &DevBuf) -> u64 {
        match b {
            DevBuf::F32 { .. } => self.block_bytes(),
            DevBuf::Q8 { .. } => self.q8_block_bytes(),
        }
    }

    /// The device byte budget (`max_blocks` fp32-block-equivalents);
    /// `None` = uncapped.
    fn budget_bytes(&self) -> Option<u64> {
        let max = self.max_blocks.load(Ordering::Relaxed);
        if max == 0 {
            None
        } else {
            Some(max as u64 * self.block_bytes())
        }
    }

    /// Blocks needed to hold `rows` positions (round up; 0 rows → 0 blocks).
    /// (Spelled out instead of `div_ceil` to keep the MSRV permissive.)
    #[allow(clippy::manual_div_ceil)]
    pub fn blocks_for(&self, rows: usize) -> usize {
        (rows + self.block_tokens - 1) / self.block_tokens
    }

    /// One chain hash per **full** block of `keys`: `out[i]` commits to
    /// `keys[0..(i+1)*block_tokens]` under the domain `salt`.  Partial tail
    /// blocks are never content-addressed (they are still mutable).
    pub fn prefix_hashes(&self, salt: u64, keys: &[i32]) -> Vec<u64> {
        let bt = self.block_tokens;
        let mut out = Vec::with_capacity(keys.len() / bt);
        let mut h = PREFIX_SEED ^ salt;
        for chunk in keys.chunks_exact(bt) {
            h = chain_hash(h, chunk);
            out.push(h);
        }
        out
    }

    // ── Allocation ─────────────────────────────────────────────────────

    /// Rent one private block (refcount 1): reuse a freed block if
    /// available, otherwise allocate a fresh zeroed one.  At the
    /// `max_blocks` cap, a parked registry entry is LRU-evicted first;
    /// only then does the rent fail — the caller surfaces this as
    /// cache-growth backpressure.
    pub(crate) fn rent_ref(&self) -> Result<u32> {
        let mut st = self.state.lock();
        let id = self.rent_locked(&mut st);
        self.debug_validate(&st);
        id
    }

    /// Admission-gate view of capacity: can `blocks` fresh private blocks
    /// still be rented under the byte budget?  Mirrors
    /// `KvPool::rent_ref`'s own headroom rules: unspent budget bytes, PLUS
    /// the resident payload bytes of every parked registry entry
    /// (registered, refcount 0) — a rent under pressure offloads or
    /// LRU-evicts those, so a warm prefix registry holding residency near
    /// the cap *by design* must not read as exhaustion (it would starve
    /// side-agent admission forever), and a quantized or offloaded parked
    /// set reads as exactly the bytes reclaiming it would yield.
    /// Outstanding session reservations ([`KvPool::reserve`]) count as
    /// already-spent headroom.  Always true when uncapped.
    pub fn can_admit(&self, blocks: usize) -> bool {
        let Some(budget) = self.budget_bytes() else {
            return true;
        };
        let reserved = self.reserved.load(Ordering::SeqCst);
        let st = self.state.lock();
        self.headroom_locked(&st, budget, reserved) >= blocks as u64 * self.block_bytes()
    }

    /// Admissible bytes under `budget`: unspent budget plus the resident
    /// payload bytes reclaimable from parked registry entries (offload or
    /// eviction yields exactly their current-tier size; already-offloaded
    /// entries cost — and therefore yield — nothing).
    fn headroom_locked(&self, st: &PoolState, budget: u64, reserved: usize) -> u64 {
        let spent = st.resident_bytes + reserved as u64 * self.block_bytes();
        let reclaimable: u64 = st
            .slots
            .iter()
            .flatten()
            .filter(|b| b.refs == 0 && b.hash.is_some())
            .map(|b| self.payload_bytes(&b.payload))
            .sum();
        budget.saturating_sub(spent) + reclaimable
    }

    /// Reserve admission headroom for a session between its admission and
    /// its prefill: the returned guard makes [`KvPool::can_admit`] treat
    /// `blocks` as already rented until it drops.  Pure accounting — the
    /// session's real rents still go through `KvPool::rent_ref`; the
    /// caller drops the guard once the prefill has materialised the real
    /// blocks (holding it longer double-counts and only makes admission
    /// more conservative).
    pub fn reserve(&self, blocks: usize) -> BlockReservation<'_> {
        self.reserved.fetch_add(blocks, Ordering::SeqCst);
        BlockReservation { pool: self, blocks }
    }

    /// Atomic check-and-reserve: succeed only if `blocks` still fit under
    /// the cap *including every outstanding reservation*, bumping the
    /// reservation in the same critical section.  This is what makes N
    /// simultaneously admitted sessions safe — two sessions that both
    /// passed the admission gate race here, and exactly one wins the last
    /// headroom (the loser sheds as Busy instead of failing mid-prefill).
    /// Always succeeds on an uncapped pool.
    pub fn try_reserve(&self, blocks: usize) -> Option<BlockReservation<'_>> {
        let Some(budget) = self.budget_bytes() else {
            return Some(self.reserve(blocks));
        };
        // Hold the state lock across the headroom check AND the bump so
        // concurrent try_reserve calls serialize; the guard's unlocked
        // decrement on drop is safe (headroom only grows).
        let st = self.state.lock();
        let reserved = self.reserved.load(Ordering::SeqCst);
        if self.headroom_locked(&st, budget, reserved) < blocks as u64 * self.block_bytes() {
            return None;
        }
        self.reserved.fetch_add(blocks, Ordering::SeqCst);
        Some(BlockReservation { pool: self, blocks })
    }

    fn rent_locked(&self, st: &mut PoolState) -> Result<u32> {
        // The budget binds on resident payload bytes of LIVE blocks, so it
        // must be enforced before the free list too — parked free blocks
        // (always fp32, about to count bb again) don't grant headroom.
        self.make_room_locked(st, self.block_bytes())?;
        if let Some(id) = st.free.pop() {
            st.live += 1;
            st.high_water = st.high_water.max(st.live);
            st.resident_bytes += self.block_bytes();
            let b = st.slots[id as usize]
                .as_mut()
                .expect("free-listed block has a slot");
            debug_assert_eq!(b.refs, 0);
            debug_assert!(b.hash.is_none());
            debug_assert!(matches!(b.payload, Payload::F32 { .. }));
            b.refs = 1;
            self.rents.fetch_add(1, Ordering::Relaxed);
            self.reuses.fetch_add(1, Ordering::Relaxed);
            // The block keeps its id: its device copy (if materialised) is
            // recycled with it — stale contents past the new fill are fine,
            // every reader masks by the owning cache's `len`.
            return Ok(id);
        }
        st.live += 1;
        st.high_water = st.high_water.max(st.live);
        st.resident_bytes += self.block_bytes();
        self.rents.fetch_add(1, Ordering::Relaxed);
        let id = self.reserve_dev_id();
        let n = self.block_floats();
        if st.slots.len() <= id as usize {
            st.slots.resize_with(id as usize + 1, || None);
        }
        st.slots[id as usize] = Some(HostBlock {
            payload: Payload::F32 {
                k: vec![0.0; n].into_boxed_slice(),
                v: vec![0.0; n].into_boxed_slice(),
            },
            refs: 1,
            hash: None,
            keys: None,
            last_used: 0,
        });
        Ok(id)
    }

    /// Reclaim resident bytes until `need` more fit under the byte budget:
    /// offload the LRU parked registry entry to the host slab first
    /// (lossless — the entry stays hittable), LRU-evict parked entries to
    /// the free list once the slab is full or disabled, and only when both
    /// tiers are exhausted fail with the backpressure error schedulers act
    /// on.  No-op on an uncapped pool or when `need` already fits.
    fn make_room_locked(&self, st: &mut PoolState, need: u64) -> Result<()> {
        let Some(budget) = self.budget_bytes() else {
            return Ok(());
        };
        while st.resident_bytes + need > budget {
            if self.offload_lru_parked_locked(st) {
                continue;
            }
            if let Some(id) = self.evict_lru_locked(st) {
                // Deregistered and refcount 0: the block moves to the free
                // list (payload reset to fp32), where the rent below — or a
                // later one — picks it up.
                self.free_block_locked(st, id);
                continue;
            }
            bail!(
                "kv pool exhausted: {} resident bytes + {need} needed exceed budget {budget} \
                 (max_blocks {}, block_tokens {})",
                st.resident_bytes,
                self.max_blocks.load(Ordering::Relaxed),
                self.block_tokens
            );
        }
        Ok(())
    }

    /// Reserve a device-slab slot for a freshly allocated block.  The
    /// buffer itself is materialised lazily on the first write-through.
    fn reserve_dev_id(&self) -> u32 {
        let mut dev = self.dev.write().unwrap();
        if let Some(id) = dev.free_ids.pop() {
            debug_assert!(dev.slots[id as usize].is_none());
            id
        } else {
            dev.slots.push(None);
            (dev.slots.len() - 1) as u32
        }
    }

    /// Drop one table reference on `id`.  The physical block is freed only
    /// when this was the last reference *and* the block is not registered;
    /// a registered block parks in the registry instead (still resident,
    /// still hittable, evictable under cap pressure).
    pub(crate) fn release_ref(&self, id: u32) {
        let mut st = self.state.lock();
        self.release_ref_locked(&mut st, id);
        self.debug_validate(&st);
    }

    fn release_ref_locked(&self, st: &mut PoolState, id: u32) {
        self.releases.fetch_add(1, Ordering::Relaxed);
        let (refs, registered) = {
            let b = st.slots[id as usize]
                .as_mut()
                .expect("released block has a slot");
            debug_assert!(b.refs > 0, "block refcount underflow");
            b.refs = b.refs.saturating_sub(1);
            (b.refs, b.hash.is_some())
        };
        if refs > 0 {
            return;
        }
        if registered {
            // Parked: the block stays live and hittable.  Demote it to the
            // warm int8 tier when the knob is on — parked registry entries
            // are exactly the immutable, read-mostly state the quantized
            // tier is for (the next chain hit dequantizes transparently; a
            // write would CoW-promote anyway).
            if self.quantize_parked.load(Ordering::Relaxed) {
                self.quantize_block_locked(st, id);
            }
            return;
        }
        self.free_block_locked(st, id);
    }

    /// Move a live, unreferenced, unregistered block out of the live set:
    /// onto the free list, or back to the allocator once the retain cap is
    /// hit.  Non-fp32 payloads are reset first — free blocks are always
    /// hot-tier (an offloaded payload's slab entry is dropped, counted as
    /// `swap_dropped_bytes`; a quantized payload's stale device copy goes
    /// with it).
    fn free_block_locked(&self, st: &mut PoolState, id: u32) {
        {
            let b = st.slots[id as usize]
                .as_mut()
                .expect("freed block has a slot");
            debug_assert_eq!(b.refs, 0);
            debug_assert!(b.hash.is_none());
            if !matches!(b.payload, Payload::F32 { .. }) {
                let was = std::mem::replace(
                    &mut b.payload,
                    Payload::F32 {
                        k: vec![0.0; self.block_floats()].into_boxed_slice(),
                        v: vec![0.0; self.block_floats()].into_boxed_slice(),
                    },
                );
                match was {
                    Payload::Q8 { .. } => {
                        st.resident_bytes = st.resident_bytes.saturating_sub(self.q8_block_bytes());
                        st.quantized = st.quantized.saturating_sub(1);
                    }
                    Payload::Offloaded => {
                        // Dropped, not paged in: the payload dies with the
                        // block (the `quantized` gauge counts live
                        // residents only, so a q8 slab entry never touched
                        // it).
                        if let Some(p) = st.host_slab.remove(&id) {
                            let bytes = self.payload_bytes(&p);
                            st.host_slab_bytes -= bytes;
                            st.swap_dropped_bytes += bytes;
                            self.sync_host_guard(st);
                        }
                    }
                    Payload::F32 { .. } => unreachable!("matched non-fp32 above"),
                }
                // The replacement fp32 payload is free-listed, not live —
                // it contributes no resident bytes until re-rented; drop
                // any stale non-fp32 device copy so the tiers agree.
                let mut dev = self.dev.write().unwrap();
                if let Some(slot) = dev.slots.get_mut(id as usize) {
                    if matches!(slot, Some(DevBuf::Q8 { .. })) {
                        let buf = slot.take().expect("matched Some above");
                        dev.bytes -= self.dev_buf_bytes(&buf);
                        dev.sync_guard();
                    }
                }
            } else {
                st.resident_bytes = st.resident_bytes.saturating_sub(self.block_bytes());
            }
        }
        st.live = st.live.saturating_sub(1);
        if st.free.len() < self.retain_free_blocks.load(Ordering::Relaxed) {
            st.free.push(id);
            return;
        }
        // Reclaim to the allocator: host buffer and device copy are freed
        // and the id is recycled for future fresh blocks.
        st.slots[id as usize] = None;
        let mut dev = self.dev.write().unwrap();
        if let Some(buf) = dev.slots.get_mut(id as usize).and_then(|s| s.take()) {
            dev.bytes -= self.dev_buf_bytes(&buf);
            dev.sync_guard();
        }
        dev.free_ids.push(id);
    }

    /// LRU-evict one *resident* parked registry entry (registered,
    /// refcount 0, payload not offloaded): deregister it so the caller can
    /// free its block.  Offloaded entries are never evicted — they cost
    /// zero budget bytes, so evicting them reclaims nothing (the bounded
    /// slab is their only capacity limit).
    fn evict_lru_locked(&self, st: &mut PoolState) -> Option<u32> {
        let id = self.lru_parked_locked(st)?;
        let hash = {
            let b = st.slots[id as usize]
                .as_mut()
                .expect("eviction candidate is live");
            b.keys = None;
            b.hash.take().expect("eviction candidate is registered")
        };
        st.registry.remove(&hash);
        st.shared -= 1;
        st.shared_bytes -= self.payload_bytes(
            &st.slots[id as usize]
                .as_ref()
                .expect("eviction candidate is live")
                .payload,
        );
        st.prefix_evictions += 1;
        self.sync_shared_guard(st);
        Some(id)
    }

    /// The least-recently-used resident parked registry entry, if any.
    fn lru_parked_locked(&self, st: &PoolState) -> Option<u32> {
        let mut best: Option<(u64, u32)> = None;
        for (i, slot) in st.slots.iter().enumerate() {
            if let Some(b) = slot {
                if b.refs == 0
                    && b.hash.is_some()
                    && !b.payload.is_offloaded()
                    && best.map_or(true, |(t, _)| b.last_used < t)
                {
                    best = Some((b.last_used, i as u32));
                }
            }
        }
        best.map(|(_, id)| id)
    }

    /// Demote a registered block's payload to the warm int8 tier (no-op if
    /// it is not fp32-resident).  The materialised device copy is
    /// re-encoded with the *same* ints and scales, so host- and device-side
    /// gathers keep reconstructing identical floats.
    fn quantize_block_locked(&self, st: &mut PoolState, id: u32) {
        let row = self.row();
        let (qk, qv, sk, sv) = {
            let b = st.slots[id as usize]
                .as_ref()
                .expect("quantized block is live");
            debug_assert!(b.hash.is_some(), "only registered blocks demote");
            let Payload::F32 { k, v } = &b.payload else {
                return;
            };
            let (qk, sk) = q8_quantize(k, row);
            let (qv, sv) = q8_quantize(v, row);
            (qk, qv, sk, sv)
        };
        let saved = self.block_bytes() - self.q8_block_bytes();
        {
            let b = st.slots[id as usize]
                .as_mut()
                .expect("quantized block is live");
            b.payload = Payload::Q8 {
                k: qk.clone(),
                v: qv.clone(),
                k_scales: sk.clone(),
                v_scales: sv.clone(),
            };
        }
        st.resident_bytes -= saved;
        st.shared_bytes -= saved;
        st.quantized += 1;
        self.sync_shared_guard(st);
        let mut dev = self.dev.write().unwrap();
        if let Some(slot) = dev.slots.get_mut(id as usize) {
            if slot.is_some() {
                *slot = Some(DevBuf::Q8 {
                    k: qk,
                    v: qv,
                    k_scales: sk,
                    v_scales: sv,
                });
                dev.bytes -= saved;
                dev.sync_guard();
            }
        }
    }

    /// Spill the LRU resident parked registry entry to the host slab.
    /// Returns `false` when the slab is disabled, full, or nothing is
    /// offloadable.
    fn offload_lru_parked_locked(&self, st: &mut PoolState) -> bool {
        let cap = self.host_slab_blocks.load(Ordering::Relaxed);
        if cap == 0 || st.host_slab.len() >= cap {
            return false;
        }
        let Some(id) = self.lru_parked_locked(st) else {
            return false;
        };
        self.offload_block_locked(st, id);
        true
    }

    /// Move block `id`'s payload verbatim into the host slab and drop its
    /// device copy.  The block stays live (still addressable, still
    /// registered if it was); its budget cost drops to zero until page-in.
    fn offload_block_locked(&self, st: &mut PoolState, id: u32) {
        let (bytes, registered) = {
            let b = st.slots[id as usize]
                .as_mut()
                .expect("offloaded block is live");
            debug_assert!(!b.payload.is_offloaded(), "double offload");
            let payload = std::mem::replace(&mut b.payload, Payload::Offloaded);
            let bytes = self.payload_bytes(&payload);
            if matches!(payload, Payload::Q8 { .. }) {
                st.quantized -= 1;
            }
            let registered = b.hash.is_some();
            st.host_slab.insert(id, payload);
            (bytes, registered)
        };
        st.host_slab_bytes += bytes;
        st.swap_out_bytes += bytes;
        st.resident_bytes -= bytes;
        if registered {
            st.shared_bytes -= bytes;
            self.sync_shared_guard(st);
        }
        self.sync_host_guard(st);
        // An offloaded block is not device-addressable: drop the copy (a
        // real backend frees the PJRT buffer; page-in re-uploads).
        let mut dev = self.dev.write().unwrap();
        if let Some(buf) = dev.slots.get_mut(id as usize).and_then(|s| s.take()) {
            dev.bytes -= self.dev_buf_bytes(&buf);
            dev.sync_guard();
        }
    }

    /// Page block `id`'s payload back in from the host slab, making room
    /// under the byte budget first (offload-then-evict, same order as a
    /// rent) and re-uploading the device copy.  Fails — leaving the entry
    /// offloaded and intact — when the budget cannot fit it; registry
    /// chain walks degrade that to a miss.
    fn page_in_locked(&self, st: &mut PoolState, id: u32) -> Result<()> {
        let bytes = self.payload_bytes(
            st.host_slab
                .get(&id)
                .expect("paged-in block has a slab entry"),
        );
        self.make_room_locked(st, bytes)?;
        let payload = st
            .host_slab
            .remove(&id)
            .expect("slab entry survives make_room (it is not resident-parked)");
        st.host_slab_bytes -= bytes;
        st.swap_in_bytes += bytes;
        st.page_ins += 1;
        st.resident_bytes += bytes;
        if matches!(payload, Payload::Q8 { .. }) {
            st.quantized += 1;
        }
        let registered = {
            let b = st.slots[id as usize]
                .as_mut()
                .expect("paged-in block is live");
            debug_assert!(b.payload.is_offloaded());
            b.payload = payload;
            b.hash.is_some()
        };
        if registered {
            st.shared_bytes += bytes;
            self.sync_shared_guard(st);
        }
        self.sync_host_guard(st);
        // Re-upload: the whole payload crosses host→device again, at its
        // tier size.
        let b = st.slots[id as usize]
            .as_ref()
            .expect("paged-in block is live");
        self.dev_restore(id, &b.payload);
        Ok(())
    }

    /// Materialise a device copy of `payload` for block `id` (page-in
    /// path), charging the full tier-size upload.
    fn dev_restore(&self, id: u32, payload: &Payload) {
        let buf = match payload {
            Payload::F32 { k, v } => DevBuf::F32 {
                k: k.clone(),
                v: v.clone(),
            },
            Payload::Q8 {
                k,
                v,
                k_scales,
                v_scales,
            } => DevBuf::Q8 {
                k: k.clone(),
                v: v.clone(),
                k_scales: k_scales.clone(),
                v_scales: v_scales.clone(),
            },
            Payload::Offloaded => unreachable!("page-in restored a materialised payload"),
        };
        let bytes = self.dev_buf_bytes(&buf);
        let mut dev = self.dev.write().unwrap();
        debug_assert!(
            dev.slots[id as usize].is_none(),
            "offload dropped the device copy"
        );
        dev.slots[id as usize] = Some(buf);
        dev.bytes += bytes;
        dev.sync_guard();
        drop(dev);
        self.h2d_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    fn sync_shared_guard(&self, st: &mut PoolState) {
        let bytes = st.shared_bytes;
        if let Some(g) = st.shared_guard.as_mut() {
            g.resize(bytes);
        }
    }

    fn sync_host_guard(&self, st: &mut PoolState) {
        let bytes = st.host_slab_bytes;
        if let Some(g) = st.host_guard.as_mut() {
            g.resize(bytes);
        }
    }

    // ── The prefix registry ────────────────────────────────────────────

    /// Publish block `id` under chain `hash`, recording `keys` (this
    /// block's own `block_tokens`-long key run) for hit-time verification.
    /// Returns `false` (a no-op) when the hash is already taken or the
    /// block is already registered — first writer wins, later identical
    /// blocks stay private duplicates.  On success the block becomes
    /// shared: subsequent writes to it CoW, and its bytes move to the
    /// global `SharedKv` charge.
    pub(crate) fn register_block(&self, id: u32, hash: u64, keys: &[i32]) -> bool {
        debug_assert_eq!(keys.len(), self.block_tokens);
        let mut st = self.state.lock();
        if st.registry.contains_key(&hash) {
            return false;
        }
        let tick = st.tick;
        st.tick += 1;
        {
            let b = st.slots[id as usize]
                .as_mut()
                .expect("registered block is live");
            if b.hash.is_some() {
                return false;
            }
            b.hash = Some(hash);
            b.keys = Some(keys.to_vec().into_boxed_slice());
            b.last_used = tick;
        }
        st.registry.insert(hash, id);
        st.shared += 1;
        // A registering cache holds a reference, so the payload is
        // fp32-resident: the SharedKv charge starts at full block size and
        // shrinks if the block later demotes or offloads.
        st.shared_bytes += self.block_bytes();
        self.sync_shared_guard(&mut st);
        self.debug_validate(&st);
        true
    }

    /// Resolve the longest registered prefix of `hashes`, taking one table
    /// reference on every hit (the caller owns them).  Stops at the first
    /// miss — a chain hash commits to its whole prefix, so later entries
    /// cannot hit without the earlier ones.
    ///
    /// `keys` is the caller's full key sequence (≥ `hashes.len() * bt`
    /// entries): every hash hit is verified against the registered block's
    /// stored key run, so a 64-bit chain-hash collision — FNV is not
    /// cryptographic, and prompts are untrusted — degrades to a miss
    /// instead of silently attaching another prompt's KV blocks.
    pub(crate) fn lookup_chain(&self, hashes: &[u64], keys: &[i32]) -> Vec<u32> {
        let mut st = self.state.lock();
        let ids = self.chain_walk_locked(&mut st, hashes, keys);
        st.prefix_hits += ids.len() as u64;
        st.prefix_misses += (hashes.len() - ids.len()) as u64;
        self.debug_validate(&st);
        ids
    }

    /// [`lookup_chain`](Self::lookup_chain) for the *continuation* of a
    /// chain: `hashes` start at the caller's next unfilled block index, with
    /// `keys` offset to match.  Hits count as `prefix_mid_hits` — they
    /// rescue an in-flight chunked prefill from recomputing blocks a
    /// concurrent identical prompt just registered — and misses are not
    /// counted at all, because probing and finding nothing is the expected
    /// steady state of every per-block adoption probe.
    pub(crate) fn lookup_chain_mid(&self, hashes: &[u64], keys: &[i32]) -> Vec<u32> {
        let mut st = self.state.lock();
        let ids = self.chain_walk_locked(&mut st, hashes, keys);
        st.prefix_mid_hits += ids.len() as u64;
        self.debug_validate(&st);
        ids
    }

    /// Shared core of the chain lookups: walk `hashes` until the first
    /// registry miss or key-run mismatch, taking one table reference (and an
    /// LRU bump) per hit.  The caller owns the returned references and the
    /// hit/miss accounting.
    fn chain_walk_locked(&self, st: &mut PoolState, hashes: &[u64], keys: &[i32]) -> Vec<u32> {
        let bt = self.block_tokens;
        debug_assert!(keys.len() >= hashes.len() * bt);
        let mut ids = Vec::new();
        for (i, h) in hashes.iter().enumerate() {
            let Some(&id) = st.registry.get(h) else {
                break;
            };
            let offloaded = {
                let block = st.slots[id as usize]
                    .as_ref()
                    .expect("registered block is live");
                if block.keys.as_deref() != Some(&keys[i * bt..(i + 1) * bt]) {
                    break; // hash collision: contents NOT content-equal
                }
                block.payload.is_offloaded()
            };
            // A hit on a cold-tier entry pages it back in first; if the
            // byte budget cannot make room the hit degrades to a miss —
            // attaching an unreadable block would be worse than
            // recomputing it.
            if offloaded && self.page_in_locked(st, id).is_err() {
                break;
            }
            // Take the reference (and the LRU bump) immediately, not in a
            // deferred pass: a later hit's page-in makes room by demoting
            // refcount-0 entries, and must never re-offload a block this
            // same walk just paged in.
            let tick = st.tick;
            st.tick += 1;
            {
                let b = st.slots[id as usize]
                    .as_mut()
                    .expect("registered block is live");
                b.refs += 1;
                b.last_used = tick;
            }
            ids.push(id);
        }
        ids
    }

    // ── Writes (the single CoW gate) ───────────────────────────────────

    /// Copy rows `[src_at, src_at + run)` of a `[L, n_src, KV*hd]` source
    /// into block `id` at position offset `off`, writing the touched rows
    /// through to the device copy.  If the block is shared (registered or
    /// referenced by another table) it is copied first and one reference on
    /// the original is dropped — the returned id is the block the caller's
    /// table must now hold (== `id` when the write went in place).
    ///
    /// This is the only write path into block storage, so the CoW invariant
    /// — a shared block's contents never change — holds by construction.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn write_run(
        &self,
        id: u32,
        off: usize,
        run: usize,
        src_at: usize,
        n_src: usize,
        k_rows: &[f32],
        v_rows: &[f32],
    ) -> Result<u32> {
        let row = self.row();
        let bt = self.block_tokens;
        let n_layers = self.n_layers;
        debug_assert!(off + run <= bt);
        debug_assert!(src_at + run <= n_src);
        let mut st = self.state.lock();
        // A write into a cold-tier block (a parked session growing again
        // without an explicit resume) pages it in first — writes only ever
        // land on materialised payloads.
        if st.slots[id as usize]
            .as_ref()
            .expect("written block is live")
            .payload
            .is_offloaded()
        {
            self.page_in_locked(&mut st, id)?;
        }
        let must_cow = {
            let b = st.slots[id as usize]
                .as_ref()
                .expect("written block is live");
            b.refs > 1 || b.hash.is_some()
        };
        let target = if must_cow {
            // Rent may itself offload/evict a parked entry or fail with
            // backpressure; nothing has been mutated yet on failure.
            let tid = self.rent_locked(&mut st)?;
            // Full-block copy: rows outside the written run may still be
            // valid for the writing cache (partial overwrites after
            // truncation into a shared block).  A quantized source
            // CoW-promotes: the private copy is full-precision fp32
            // reconstructed from the stored ints and scales.
            let (ck, cv) = {
                let src = st.slots[id as usize]
                    .as_ref()
                    .expect("cow source is live");
                match &src.payload {
                    Payload::F32 { k, v } => (k.clone(), v.clone()),
                    Payload::Q8 {
                        k,
                        v,
                        k_scales,
                        v_scales,
                    } => (
                        q8_dequantize(k, k_scales, row),
                        q8_dequantize(v, v_scales, row),
                    ),
                    Payload::Offloaded => unreachable!("paged in above"),
                }
            };
            {
                let dst = st.slots[tid as usize]
                    .as_mut()
                    .expect("cow target is live");
                dst.payload = Payload::F32 { k: ck, v: cv };
            }
            self.release_ref_locked(&mut st, id);
            st.cow_copies += 1;
            tid
        } else {
            id
        };
        {
            let b = st.slots[target as usize]
                .as_mut()
                .expect("write target is live");
            let Payload::F32 { k, v } = &mut b.payload else {
                unreachable!("in-place write targets are hot-tier (q8 ⇒ registered ⇒ CoW)");
            };
            for layer in 0..n_layers {
                let dst = (layer * bt + off) * row;
                let src = (layer * n_src + src_at) * row;
                k[dst..dst + run * row].copy_from_slice(&k_rows[src..src + run * row]);
                v[dst..dst + run * row].copy_from_slice(&v_rows[src..src + run * row]);
            }
        }
        // Write-through: the touched run on the in-place path; the whole
        // block after a CoW (its untouched rows may be valid too, and the
        // target's device slot knows none of them).
        let (s_off, s_n) = if must_cow { (0, bt) } else { (off, run) };
        {
            let b = st.slots[target as usize]
                .as_ref()
                .expect("write target is live");
            let Payload::F32 { k, v } = &b.payload else {
                unreachable!("write target stays hot-tier");
            };
            self.dev_sync(target, k, v, s_off, s_n);
        }
        self.debug_validate(&st);
        Ok(target)
    }

    /// Deep-copy `src_id` into a fresh private block (cache cloning),
    /// syncing the first `valid_rows` rows to the new device slot.  A
    /// warm- or cold-tier source promotes: the clone is a private fp32
    /// block whatever tier the source occupies.
    pub(crate) fn clone_block(&self, src_id: u32, valid_rows: usize) -> Result<u32> {
        let mut st = self.state.lock();
        let dst = self.rent_locked(&mut st)?;
        let (ck, cv) = {
            let view = self.tier_view(&st, src_id);
            (
                view.k().to_vec().into_boxed_slice(),
                view.v().to_vec().into_boxed_slice(),
            )
        };
        {
            let d = st.slots[dst as usize]
                .as_mut()
                .expect("clone target is live");
            d.payload = Payload::F32 { k: ck, v: cv };
        }
        if valid_rows > 0 {
            let d = st.slots[dst as usize]
                .as_ref()
                .expect("clone target is live");
            let Payload::F32 { k, v } = &d.payload else {
                unreachable!("clone target just assigned fp32");
            };
            self.dev_sync(dst, k, v, 0, valid_rows);
        }
        self.debug_validate(&st);
        Ok(dst)
    }

    // ── Host-side reads (block-table gathers) ──────────────────────────

    /// Resolve block `id`'s K/V floats whatever tier the payload occupies:
    /// hot fp32 borrows straight from the slot, warm int8 dequantizes into
    /// an owned buffer (reads never mutate the stored payload), and cold
    /// payloads are read through the host slab.  Host gathers go through
    /// this, which is what makes mixed-tier block tables transparent to
    /// every reader.
    fn tier_view<'a>(&self, st: &'a PoolState, id: u32) -> TierView<'a> {
        let b = st.slots[id as usize]
            .as_ref()
            .expect("viewed block is live");
        let payload = match &b.payload {
            Payload::Offloaded => st
                .host_slab
                .get(&id)
                .expect("offloaded block has a slab entry"),
            p => p,
        };
        match payload {
            Payload::F32 { k, v } => TierView::Hot { k, v },
            Payload::Q8 {
                k,
                v,
                k_scales,
                v_scales,
            } => TierView::Warm {
                k: q8_dequantize(k, k_scales, self.row()),
                v: q8_dequantize(v, v_scales, self.row()),
            },
            Payload::Offloaded => unreachable!("slab entries are materialised payloads"),
        }
    }

    /// Gather the first `valid` positions addressed by `table` into
    /// caller-provided zeroed `[L, c, KV, hd]` buffers — the flat reference
    /// path (prefill loads, ablations, tests).
    pub(crate) fn host_gather_prefix_into(
        &self,
        table: &[u32],
        valid: usize,
        c: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) {
        let row = self.row();
        let bt = self.block_tokens;
        let n_layers = self.n_layers;
        let per = c * row;
        debug_assert_eq!(k_out.len(), n_layers * per);
        debug_assert_eq!(v_out.len(), n_layers * per);
        let valid = valid.min(c);
        let st = self.state.lock();
        for (bi, &id) in table.iter().enumerate() {
            let start = bi * bt;
            if start >= valid {
                break;
            }
            let run = (valid - start).min(bt);
            let view = self.tier_view(&st, id);
            for layer in 0..n_layers {
                let dst = layer * per + start * row;
                let src = layer * bt * row;
                k_out[dst..dst + run * row].copy_from_slice(&view.k()[src..src + run * row]);
                v_out[dst..dst + run * row].copy_from_slice(&view.v()[src..src + run * row]);
            }
        }
    }

    /// Gather arbitrary positions (each `< table coverage`) across all
    /// layers into `[L, n, KV, hd]` buffers — the host-side analogue of the
    /// synapse program's landmark gather.
    pub(crate) fn host_gather_rows(
        &self,
        table: &[u32],
        indices: &[usize],
    ) -> (Vec<f32>, Vec<f32>) {
        let row = self.row();
        let bt = self.block_tokens;
        let n_layers = self.n_layers;
        let n = indices.len();
        let mut k = Vec::with_capacity(n_layers * n * row);
        let mut v = Vec::with_capacity(n_layers * n * row);
        let st = self.state.lock();
        // Resolve each block's tier once up front — a warm-tier block
        // dequantizes one time, not once per gathered row.
        let views: Vec<TierView> = table.iter().map(|&id| self.tier_view(&st, id)).collect();
        for layer in 0..n_layers {
            for &pos in indices {
                let (bi, off) = (pos / bt, pos % bt);
                let view = &views[bi];
                let o = (layer * bt + off) * row;
                k.extend_from_slice(&view.k()[o..o + row]);
                v.extend_from_slice(&view.v()[o..o + row]);
            }
        }
        (k, v)
    }

    /// Rows `[start, end)` of one layer, K (`want_v == false`) or V.
    pub(crate) fn host_slice(
        &self,
        table: &[u32],
        layer: usize,
        start: usize,
        end: usize,
        want_v: bool,
    ) -> Vec<f32> {
        let row = self.row();
        let bt = self.block_tokens;
        if start >= end {
            return Vec::new();
        }
        let mut out = Vec::with_capacity((end - start) * row);
        let st = self.state.lock();
        let views: Vec<TierView> = table.iter().map(|&id| self.tier_view(&st, id)).collect();
        for pos in start..end {
            let (bi, off) = (pos / bt, pos % bt);
            let view = &views[bi];
            let o = (layer * bt + off) * row;
            out.extend_from_slice(if want_v {
                &view.v()[o..o + row]
            } else {
                &view.k()[o..o + row]
            });
        }
        out
    }

    // ── Device slab ────────────────────────────────────────────────────

    /// Write rows `[off, off+n)` of block `id` through to its
    /// device-resident copy, materialising the device buffer on first
    /// touch.  The copied bytes are the only per-row host→device traffic
    /// the system pays (contrast with the seed's full-prefix re-upload
    /// every step).
    fn dev_sync(&self, id: u32, k_host: &[f32], v_host: &[f32], off: usize, n: usize) {
        if n == 0 {
            return;
        }
        let row = self.row();
        let bt = self.block_tokens;
        debug_assert!(off + n <= bt);
        let mut dev = self.dev.write().unwrap();
        let idx = id as usize;
        if dev.slots[idx].is_none() {
            let floats = self.block_floats();
            dev.slots[idx] = Some(DevBuf::F32 {
                k: vec![0.0; floats].into_boxed_slice(),
                v: vec![0.0; floats].into_boxed_slice(),
            });
            dev.bytes += self.block_bytes();
            dev.sync_guard();
        }
        let Some(DevBuf::F32 { k, v }) = dev.slots[idx].as_mut() else {
            unreachable!("row write-throughs target hot-tier blocks, whose device copy is fp32");
        };
        // Host and device copies share the `[L, bt, row]` layout, so the
        // offsets coincide.
        for layer in 0..self.n_layers {
            let o = (layer * bt + off) * row;
            k[o..o + n * row].copy_from_slice(&k_host[o..o + n * row]);
            v[o..o + n * row].copy_from_slice(&v_host[o..o + n * row]);
        }
        drop(dev);
        self.h2d_bytes
            .fetch_add((self.n_layers * n * row * 2 * 4) as u64, Ordering::Relaxed);
    }

    /// Device-side paged gather: contiguous `[L, c, KV, hd]` K and V for
    /// the first `len` positions addressed by `table`, read from the
    /// resident block copies.  Ships only the table (counted as the step's
    /// upload cost) — never the cache contents.
    ///
    /// The gather is tier-aware: a warm int8 block's device copy carries
    /// its ints and scales, and the stub program dequantizes in-gather
    /// (bit-identical to the host-side reconstruction).
    ///
    /// Fails if a needed block has no device copy: the table addresses a
    /// different pool, rows that were never written, or an offloaded
    /// (cold-tier) block that must be paged in before decoding.
    pub fn dev_gather_prefix(
        &self,
        table: &[u32],
        len: usize,
        c: usize,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let sz = self.n_layers * c * self.row();
        let mut k = vec![0.0f32; sz];
        let mut v = vec![0.0f32; sz];
        self.dev_gather_prefix_into(table, len, c, &mut k, &mut v)?;
        Ok((k, v))
    }

    /// Allocation-free variant of [`KvPool::dev_gather_prefix`]: gathers
    /// into caller-provided zeroed `[L, c, KV, hd]` buffers (the batcher's
    /// per-lane slabs).
    pub fn dev_gather_prefix_into(
        &self,
        table: &[u32],
        len: usize,
        c: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<()> {
        let row = self.row();
        debug_assert_eq!(k_out.len(), self.n_layers * c * row);
        debug_assert_eq!(v_out.len(), self.n_layers * c * row);
        let need = self.blocks_for(len.min(c));
        if table.len() < need {
            bail!(
                "paged gather: table has {} blocks, {need} needed for len {len}",
                table.len()
            );
        }
        {
            let dev = self.dev.read().unwrap();
            let mut k_blocks: Vec<xla_stub::PagedBlock> = Vec::with_capacity(need);
            let mut v_blocks: Vec<xla_stub::PagedBlock> = Vec::with_capacity(need);
            for &id in &table[..need] {
                let slot = dev
                    .slots
                    .get(id as usize)
                    .and_then(|s| s.as_ref())
                    .ok_or_else(|| {
                        anyhow!("paged gather: block {id} has no device-resident copy")
                    })?;
                match slot {
                    DevBuf::F32 { k, v } => {
                        k_blocks.push(xla_stub::PagedBlock::F32(k));
                        v_blocks.push(xla_stub::PagedBlock::F32(v));
                    }
                    DevBuf::Q8 {
                        k,
                        v,
                        k_scales,
                        v_scales,
                    } => {
                        k_blocks.push(xla_stub::PagedBlock::Q8 {
                            q: k,
                            scales: k_scales,
                        });
                        v_blocks.push(xla_stub::PagedBlock::Q8 {
                            q: v,
                            scales: v_scales,
                        });
                    }
                }
            }
            xla_stub::paged_gather_prefix_tiered(
                &k_blocks,
                self.n_layers,
                self.block_tokens,
                row,
                len,
                c,
                k_out,
            );
            xla_stub::paged_gather_prefix_tiered(
                &v_blocks,
                self.n_layers,
                self.block_tokens,
                row,
                len,
                c,
                v_out,
            );
        }
        // Per-step upload: the i32 table + the length scalar.
        self.h2d_bytes
            .fetch_add(PagedKv::upload_bytes_for(table.len()), Ordering::Relaxed);
        self.dev_gathers.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Attach the device-memory accounting guard
    /// ([`crate::cortex::memory::MemKind::DeviceKv`]); from here on every
    /// device-buffer materialisation and release resizes it.  Replaces (and
    /// thereby releases) any previously attached guard.
    pub fn track_device(&self, mut guard: MemGuard) {
        let mut dev = self.dev.write().unwrap();
        guard.resize(dev.bytes);
        dev.guard = Some(guard);
    }

    /// Attach the shared-block accounting guard
    /// ([`crate::cortex::memory::MemKind::SharedKv`]): registry-shared
    /// blocks are charged here exactly once, at their *resident tier size*
    /// (full for fp32, ~3.5× less once demoted to int8, zero while
    /// offloaded — those bytes are `HostKv`'s), however many caches
    /// reference them.  Replaces any previously attached guard.
    pub fn track_shared(&self, mut guard: MemGuard) {
        let mut st = self.state.lock();
        guard.resize(st.shared_bytes);
        st.shared_guard = Some(guard);
    }

    /// Attach the host-slab accounting guard
    /// ([`crate::cortex::memory::MemKind::HostKv`]): offloaded payload
    /// bytes are charged here — and only here — while they sit in the cold
    /// tier.  Replaces any previously attached guard.
    pub fn track_host(&self, mut guard: MemGuard) {
        let mut st = self.state.lock();
        guard.resize(st.host_slab_bytes);
        st.host_guard = Some(guard);
    }

    // ── Session park / resume (the cold tier's public face) ────────────

    /// Spill one *private* block (refcount 1, unregistered — the caller's
    /// cache holds the only reference) to the host slab: the session-park
    /// path [`super::kv::KvCache::park_to_host`] drives.  Lossless — the
    /// payload moves verbatim, so resume decodes bit-identically.  Fails
    /// when the slab is disabled or full; no-op if already offloaded.
    pub(crate) fn offload_ref(&self, id: u32) -> Result<()> {
        let mut st = self.state.lock();
        {
            let b = st.slots[id as usize]
                .as_ref()
                .expect("offloaded block has a slot");
            if b.payload.is_offloaded() {
                return Ok(());
            }
            if b.refs != 1 || b.hash.is_some() {
                bail!(
                    "offload: block {id} is shared (refs {}, registered {}) — only private \
                     session blocks park to host",
                    b.refs,
                    b.hash.is_some()
                );
            }
        }
        let cap = self.host_slab_blocks.load(Ordering::Relaxed);
        if cap == 0 || st.host_slab.len() >= cap {
            bail!(
                "offload: host slab full ({} of {cap} blocks) — cannot park block {id}",
                st.host_slab.len()
            );
        }
        self.offload_block_locked(&mut st, id);
        self.debug_validate(&st);
        Ok(())
    }

    /// Page one block back in from the host slab (session resume); no-op
    /// if it is already resident.  Fails — leaving the entry intact — when
    /// the byte budget cannot make room.
    pub(crate) fn page_in_ref(&self, id: u32) -> Result<()> {
        let mut st = self.state.lock();
        if st.slots[id as usize]
            .as_ref()
            .expect("paged-in block has a slot")
            .payload
            .is_offloaded()
        {
            self.page_in_locked(&mut st, id)?;
        }
        self.debug_validate(&st);
        Ok(())
    }

    /// Bytes currently held by device-resident block copies.
    pub fn dev_bytes(&self) -> u64 {
        self.dev.read().unwrap().bytes
    }

    pub(crate) fn note_rows_added(&self, n: usize) {
        self.rows_live.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub(crate) fn note_rows_removed(&self, n: usize) {
        // Saturating: a miscounted release must not wrap the gauge.
        let _ = self
            .rows_live
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
                Some(cur.saturating_sub(n as u64))
            });
    }

    /// A fresh pool-backed cache able to hold up to `capacity` rows.
    pub fn new_cache(self: &Arc<Self>, capacity: usize) -> KvCache {
        KvCache::with_pool(self.clone(), capacity)
    }

    pub fn stats(&self) -> PoolStats {
        let mut s = {
            let st = self.state.lock();
            PoolStats {
                block_tokens: self.block_tokens,
                block_bytes: self.block_bytes(),
                blocks_live: st.live,
                blocks_free: st.free.len(),
                blocks_high_water: st.high_water,
                shared_blocks: st.shared,
                prefix_hits: st.prefix_hits,
                prefix_misses: st.prefix_misses,
                prefix_mid_hits: st.prefix_mid_hits,
                prefix_evictions: st.prefix_evictions,
                cow_copies: st.cow_copies,
                resident_payload_bytes: st.resident_bytes,
                quantized_blocks: st.quantized,
                quant_saved_bytes: st.quantized as u64
                    * (self.block_bytes() - self.q8_block_bytes()),
                q8_block_bytes: self.q8_block_bytes(),
                offloaded_blocks: st.host_slab.len(),
                host_slab_bytes: st.host_slab_bytes,
                shared_payload_bytes: st.shared_bytes,
                swap_out_bytes: st.swap_out_bytes,
                swap_in_bytes: st.swap_in_bytes,
                swap_dropped_bytes: st.swap_dropped_bytes,
                resume_page_ins: st.page_ins,
                ..PoolStats::default()
            }
        };
        {
            let dev = self.dev.read().unwrap();
            s.dev_blocks = dev.slots.iter().filter(|sl| sl.is_some()).count();
            s.dev_bytes = dev.bytes;
        }
        s.rents = self.rents.load(Ordering::Relaxed);
        s.reuses = self.reuses.load(Ordering::Relaxed);
        s.releases = self.releases.load(Ordering::Relaxed);
        s.rows_live = self.rows_live.load(Ordering::Relaxed);
        s.h2d_bytes = self.h2d_bytes.load(Ordering::Relaxed);
        s.dev_gathers = self.dev_gathers.load(Ordering::Relaxed);
        s.reserved_blocks = self.reserved.load(Ordering::SeqCst);
        s
    }

    // ── The invariant sanitizer ────────────────────────────────────────

    /// Verify every conservation law the pool's bookkeeping rests on,
    /// naming each violated law in the error.  Laws checked (see also
    /// [`KvPool::validate_locked`]):
    ///
    /// * `block-state` — every allocated block is exactly one of
    ///   *referenced* (refs > 0), *parked* (refs == 0, registered) or
    ///   *free-listed* (refs == 0, unregistered, on the free list);
    /// * `free-list` — free ids are unique and disjoint from live blocks;
    /// * `live-count` — the `live` gauge equals referenced + parked and
    ///   never exceeds `high_water`;
    /// * `registry` — the shared gauge, the registry map and the
    ///   hash-carrying slots agree, and every registry entry points at a
    ///   slot carrying that hash (no stale ids);
    /// * `shared-bytes` — the shared gauge and the `SharedKv` accounting
    ///   guard charge exactly the resident tier-size bytes of registered
    ///   blocks;
    /// * `tier` — tier populations partition the block set: free-listed
    ///   blocks are fp32, quantized blocks are registered, a block is
    ///   offloaded *iff* the host slab holds its payload, the `quantized`
    ///   gauge counts the warm tier exactly, and every materialised device
    ///   copy is at the same tier as its host payload;
    /// * `host-slab` — the slab byte gauge equals the sum of its payloads,
    ///   the `HostKv` guard charges exactly that, and the swap traffic
    ///   conserves: `swap_out == swap_in + swap_dropped + host_slab_bytes`;
    /// * `resident-bytes` — the budget gauge equals the sum of live
    ///   blocks' tier-size payload bytes;
    /// * `cap` — when capped, resident payload bytes never exceed the
    ///   byte budget `max_blocks × block_bytes`, and the host slab never
    ///   exceeds `host_slab_blocks` entries (both assume the knob was not
    ///   lowered below current occupancy mid-flight via
    ///   [`KvPool::set_limits`] / [`KvPool::set_tiering`]).  The stronger
    ///   `resident + reserved ≤ budget` is deliberately NOT asserted: a
    ///   session legally double-counts while its prefill rents real blocks
    ///   under a still-held [`BlockReservation`], so it fails transiently
    ///   by design;
    /// * `dev-slab` — device free ids are unique, address no occupied
    ///   host slot and no materialised buffer, and the device byte gauge
    ///   matches the per-tier sum over materialised buffers.
    ///
    /// Run at tick boundaries by the step scheduler (debug builds) and
    /// explicitly from the property suites at any depth; the per-op debug
    /// hook ([`KvPool::debug_validate`]) covers the core laws after every
    /// mutating pool op.
    pub fn check_invariants(&self) -> std::result::Result<(), String> {
        let st = self.state.lock();
        let mut errs = match self.validate_locked(&st) {
            Ok(()) => Vec::new(),
            Err(e) => vec![e],
        };
        if let Some(budget) = self.budget_bytes() {
            if st.resident_bytes > budget {
                errs.push(format!(
                    "cap: {} resident payload bytes exceed the byte budget {budget}",
                    st.resident_bytes
                ));
            }
        }
        let slab_cap = self.host_slab_blocks.load(Ordering::Relaxed);
        if st.host_slab.len() > slab_cap {
            errs.push(format!(
                "host-slab: {} entries exceed host_slab_blocks {slab_cap}",
                st.host_slab.len()
            ));
        }
        // Churn conservation: every release was preceded by its rent, and
        // `releases` is loaded first, so an excess can only mean a
        // double-free or an uncounted rent path.
        let releases = self.releases.load(Ordering::Relaxed);
        let rents = self.rents.load(Ordering::Relaxed);
        if releases > rents {
            errs.push(format!("churn: {releases} releases exceed {rents} rents"));
        }
        // `shared_payload_bytes` (the `/stats` name for the registry's
        // once-only charge) is bounded by every shared block resident at
        // fp32 — a larger figure means a stale or double-counted charge.
        let shared_payload_bytes = st.shared_bytes;
        if shared_payload_bytes > st.shared as u64 * self.block_bytes() {
            errs.push(format!(
                "shared: {shared_payload_bytes} shared payload bytes exceed {} shared blocks at fp32",
                st.shared
            ));
        }
        // Lock order: `state` before `dev` — the documented pool order.
        let dev = self.dev.read().unwrap_or_else(std::sync::PoisonError::into_inner);
        let mut dev_free = HashSet::with_capacity(dev.free_ids.len());
        for &id in &dev.free_ids {
            if !dev_free.insert(id) {
                errs.push(format!(
                    "dev-slab: id {id} double-entered in the device free list"
                ));
            }
            if st.slots.get(id as usize).map_or(false, |s| s.is_some()) {
                errs.push(format!(
                    "dev-slab: id {id} is device-free but its host slot is occupied"
                ));
            }
            if dev.slots.get(id as usize).map_or(false, |s| s.is_some()) {
                errs.push(format!(
                    "dev-slab: id {id} is device-free but still materialised"
                ));
            }
        }
        let want: u64 = dev
            .slots
            .iter()
            .flatten()
            .map(|b| self.dev_buf_bytes(b))
            .sum();
        if dev.bytes != want {
            errs.push(format!(
                "dev-slab: byte gauge {} != per-tier sum over materialised buffers ({want} bytes)",
                dev.bytes
            ));
        }
        // Tier agreement: a materialised device copy mirrors its host
        // payload's tier; offloaded blocks have none.
        for (i, slot) in dev.slots.iter().enumerate() {
            let Some(buf) = slot else { continue };
            let Some(b) = st.slots.get(i).and_then(|s| s.as_ref()) else {
                continue; // free-id checks above cover unallocated slots
            };
            let host_tier = b.payload.tier_name();
            let dev_tier = match buf {
                DevBuf::F32 { .. } => "f32",
                DevBuf::Q8 { .. } => "q8",
            };
            if host_tier != dev_tier {
                errs.push(format!(
                    "tier: block {i} device copy is {dev_tier} but its host payload is {host_tier}"
                ));
            }
        }
        if errs.is_empty() {
            Ok(())
        } else {
            Err(errs.join("; "))
        }
    }

    /// Core of the sanitizer: the laws that hold after *every* mutating
    /// pool op, checked against an already-held state guard (so the debug
    /// hook can run inside the op's own critical section).
    fn validate_locked(&self, st: &PoolState) -> std::result::Result<(), String> {
        let mut errs: Vec<String> = Vec::new();
        let mut free = HashSet::with_capacity(st.free.len());
        for &id in &st.free {
            if !free.insert(id) {
                errs.push(format!(
                    "free-list: block {id} double-entered in the free list"
                ));
            }
            match st.slots.get(id as usize).and_then(|s| s.as_ref()) {
                None => errs.push(format!("free-list: block {id} is free-listed but unallocated")),
                Some(b) => {
                    if b.refs != 0 {
                        errs.push(format!(
                            "free-list: block {id} is free-listed with refcount {}",
                            b.refs
                        ));
                    }
                    if b.hash.is_some() {
                        errs.push(format!(
                            "free-list: block {id} is free-listed while registered"
                        ));
                    }
                    if !matches!(b.payload, Payload::F32 { .. }) {
                        errs.push(format!(
                            "tier: block {id} is free-listed at the {} tier (free blocks are fp32)",
                            b.payload.tier_name()
                        ));
                    }
                }
            }
        }
        let mut referenced = 0usize;
        let mut parked = 0usize;
        let mut hashed = 0usize;
        let mut quantized = 0usize;
        let mut resident_bytes = 0u64;
        let mut shared_bytes = 0u64;
        for (i, slot) in st.slots.iter().enumerate() {
            let Some(b) = slot else { continue };
            let live = b.refs > 0 || b.hash.is_some();
            if live {
                resident_bytes += self.payload_bytes(&b.payload);
                if b.hash.is_some() {
                    shared_bytes += self.payload_bytes(&b.payload);
                }
            }
            match &b.payload {
                Payload::Q8 { .. } => {
                    quantized += 1;
                    if b.hash.is_none() {
                        errs.push(format!(
                            "tier: block {i} is int8-quantized but not registered \
                             (only immutable registry blocks demote)"
                        ));
                    }
                }
                Payload::Offloaded => {
                    if !st.host_slab.contains_key(&(i as u32)) {
                        errs.push(format!(
                            "tier: block {i} is marked offloaded but the host slab has no payload"
                        ));
                    }
                }
                Payload::F32 { .. } => {}
            }
            if let Some(hash) = b.hash {
                hashed += 1;
                match b.keys.as_deref() {
                    Some(k) if k.len() == self.block_tokens => {}
                    Some(k) => errs.push(format!(
                        "registry: block {i} (hash {hash:#x}) stores {} keys, block_tokens is {}",
                        k.len(),
                        self.block_tokens
                    )),
                    None => errs.push(format!(
                        "registry: registered block {i} (hash {hash:#x}) has no key run for hit verification"
                    )),
                }
            }
            if b.refs > 0 {
                referenced += 1;
                if free.contains(&(i as u32)) {
                    errs.push(format!(
                        "block-state: block {i} is referenced (refs {}) AND free-listed",
                        b.refs
                    ));
                }
            } else if b.hash.is_some() {
                parked += 1;
                if free.contains(&(i as u32)) {
                    errs.push(format!(
                        "block-state: block {i} is parked in the registry AND free-listed"
                    ));
                }
            } else if !free.contains(&(i as u32)) {
                errs.push(format!(
                    "block-state: block {i} is neither referenced, parked, nor free-listed \
                     (a refcount underflow leaks the block)"
                ));
            }
        }
        if st.live != referenced + parked {
            errs.push(format!(
                "live-count: blocks_live gauge {} != {referenced} referenced + {parked} parked",
                st.live
            ));
        }
        if st.high_water < st.live {
            errs.push(format!(
                "live-count: high_water {} below live {}",
                st.high_water, st.live
            ));
        }
        if st.registry.len() != hashed {
            errs.push(format!(
                "registry: {} registry entries but {hashed} slots carry a hash",
                st.registry.len()
            ));
        }
        if st.shared != st.registry.len() {
            errs.push(format!(
                "registry: shared gauge {} != registry size {}",
                st.shared,
                st.registry.len()
            ));
        }
        for (&hash, &id) in &st.registry {
            match st.slots.get(id as usize).and_then(|s| s.as_ref()) {
                None => errs.push(format!(
                    "registry: hash {hash:#x} maps to unallocated block {id} (stale registry id)"
                )),
                Some(b) if b.hash != Some(hash) => errs.push(format!(
                    "registry: hash {hash:#x} maps to block {id}, which carries {:?} (stale registry id)",
                    b.hash
                )),
                Some(_) => {}
            }
        }
        if st.quantized != quantized {
            errs.push(format!(
                "tier: quantized gauge {} != {quantized} live int8 payloads",
                st.quantized
            ));
        }
        if st.resident_bytes != resident_bytes {
            errs.push(format!(
                "resident-bytes: gauge {} != {resident_bytes} summed live payload bytes",
                st.resident_bytes
            ));
        }
        if st.shared_bytes != shared_bytes {
            errs.push(format!(
                "shared-bytes: gauge {} != {shared_bytes} summed registered payload bytes",
                st.shared_bytes
            ));
        }
        if let Some(g) = st.shared_guard.as_ref() {
            if g.bytes() != st.shared_bytes {
                errs.push(format!(
                    "shared-bytes: guard charges {} bytes, registered residents hold {}",
                    g.bytes(),
                    st.shared_bytes
                ));
            }
        }
        for &id in st.host_slab.keys() {
            match st.slots.get(id as usize).and_then(|s| s.as_ref()) {
                None => errs.push(format!(
                    "tier: host slab holds a payload for unallocated block {id}"
                )),
                Some(b) if !b.payload.is_offloaded() => errs.push(format!(
                    "tier: host slab holds a payload for block {id}, whose slot is {}-tier",
                    b.payload.tier_name()
                )),
                Some(_) => {}
            }
        }
        let slab_bytes: u64 = st.host_slab.values().map(|p| self.payload_bytes(p)).sum();
        if st.host_slab_bytes != slab_bytes {
            errs.push(format!(
                "host-slab: byte gauge {} != {slab_bytes} summed slab payload bytes",
                st.host_slab_bytes
            ));
        }
        if let Some(g) = st.host_guard.as_ref() {
            if g.bytes() != st.host_slab_bytes {
                errs.push(format!(
                    "host-slab: guard charges {} bytes, slab holds {}",
                    g.bytes(),
                    st.host_slab_bytes
                ));
            }
        }
        if st.swap_out_bytes != st.swap_in_bytes + st.swap_dropped_bytes + st.host_slab_bytes {
            errs.push(format!(
                "host-slab: swap traffic does not conserve: out {} != in {} + dropped {} + held {}",
                st.swap_out_bytes, st.swap_in_bytes, st.swap_dropped_bytes, st.host_slab_bytes
            ));
        }
        if errs.is_empty() {
            Ok(())
        } else {
            Err(errs.join("; "))
        }
    }

    /// Debug-build hook: every mutating pool op re-validates the core
    /// laws before releasing the state lock, so corruption panics at the
    /// corrupting op instead of at a later symptom.  O(slots + registry)
    /// per op; compiled out of release builds entirely (the release-mode
    /// cost model is zero — the nightly deep-proptest job exercises the
    /// laws through explicit `check_invariants` calls instead).
    #[cfg(debug_assertions)]
    fn debug_validate(&self, st: &PoolState) {
        if let Err(e) = self.validate_locked(st) {
            panic!("kv pool invariant violation: {e}");
        }
    }

    #[cfg(not(debug_assertions))]
    #[inline(always)]
    fn debug_validate(&self, _st: &PoolState) {}
}

/// Test-only corruption hooks: seed one specific bookkeeping bug each, so
/// the sanitizer's negative tests can prove `check_invariants` names the
/// violated law.  Callers must not run further mutating pool ops after
/// corrupting (the per-op debug hook would — correctly — panic).
#[cfg(test)]
impl KvPool {
    /// Zero a referenced block's refcount without freeing it: the block
    /// leaks (`block-state`) and the live gauge over-counts (`live-count`).
    fn corrupt_refcount_underflow(&self, id: u32) {
        let mut st = self.state.lock();
        st.slots[id as usize].as_mut().expect("block allocated").refs = 0;
    }

    /// Enter an already-free block a second time (`free-list`).
    fn corrupt_free_list_double_entry(&self) {
        let mut st = self.state.lock();
        let id = *st.free.first().expect("a free block to duplicate");
        st.free.push(id);
    }

    /// Point a registry hash at a block that does not carry it
    /// (`registry` stale-id detection).
    fn corrupt_stale_registry_id(&self, hash: u64, id: u32) {
        let mut st = self.state.lock();
        st.registry.insert(hash, id);
        st.shared += 1; // keep shared == registry.len(): isolate the stale id
    }

    /// Drift the live gauge off the slot population (`live-count`).
    fn corrupt_live_gauge(&self) {
        let mut st = self.state.lock();
        st.live += 1;
    }

    /// Drift the host-slab byte gauge off the stored payloads
    /// (`host-slab`); the swap counter moves with it so the conservation
    /// law stays isolated from the gauge drift.
    fn corrupt_host_slab_gauge(&self) {
        let mut st = self.state.lock();
        st.host_slab_bytes += 1;
        st.swap_out_bytes += 1;
    }

    /// Drift the quantized-tier population gauge (`tier`).
    fn corrupt_quantized_gauge(&self) {
        let mut st = self.state.lock();
        st.quantized += 1;
    }

    /// Poison the state mutex the way a real bug would: panic while
    /// holding it (the cascade regression test's setup).
    fn poison_state_for_test(&self) {
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _g = self.state.lock();
            panic!("poison the pool state lock");
        }));
        assert!(res.is_err(), "the poisoning closure must panic");
    }

    fn state_is_poisoned(&self) -> bool {
        self.state.is_poisoned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 192,
            vocab_size: 260,
            head_dim: 16,
            rope_theta: 1e4,
            param_count: 0,
        }
    }

    fn pool(block_tokens: usize, max_blocks: usize) -> Arc<KvPool> {
        KvPool::new(
            &tiny_cfg(),
            KvPoolConfig {
                block_tokens,
                max_blocks,
                retain_free_blocks: usize::MAX,
                ..KvPoolConfig::default()
            },
        )
    }

    /// A pool with both demotion tiers armed: int8 quantize-on-park plus a
    /// host slab of `slab` blocks.
    fn tiered_pool(
        block_tokens: usize,
        max_blocks: usize,
        quantize_parked: bool,
        slab: usize,
    ) -> Arc<KvPool> {
        KvPool::new(
            &tiny_cfg(),
            KvPoolConfig {
                block_tokens,
                max_blocks,
                retain_free_blocks: usize::MAX,
                quantize_parked,
                host_slab_blocks: slab,
            },
        )
    }

    /// `[L, n, KV*hd]` rows filled with a constant, sized for `pool`.
    fn rows(p: &KvPool, n: usize, fill: f32) -> Vec<f32> {
        vec![fill; p.n_layers() * n * p.row()]
    }

    /// `[L, n, KV*hd]` rows with distinct, bounded values — quantization
    /// tests need real per-row dynamic range, not a constant.
    fn varied_rows(p: &KvPool, n: usize, seed: f32) -> Vec<f32> {
        (0..p.n_layers() * n * p.row())
            .map(|i| ((i as f32 + seed) * 0.618_034).sin())
            .collect()
    }

    /// Assert `got` reconstructs `orig` within the symmetric-int8 bound:
    /// per (layer, position) row, each element is within `max|row|/254`
    /// (half the quantization step) plus float noise.
    fn assert_close_q8(orig: &[f32], got: &[f32], row: usize) {
        assert_eq!(orig.len(), got.len());
        for (r, (o, g)) in orig.chunks(row).zip(got.chunks(row)).enumerate() {
            let max = o.iter().fold(0f32, |m, &x| m.max(x.abs()));
            let bound = max / 254.0 + 1e-6;
            for (i, (&a, &b)) in o.iter().zip(g.iter()).enumerate() {
                assert!(
                    (a - b).abs() <= bound,
                    "row {r} elem {i}: {a} vs {b} exceeds the q8 bound {bound}"
                );
            }
        }
    }

    #[test]
    fn rent_release_reuse_round_trip() {
        let p = pool(4, 0);
        assert_eq!(p.block_bytes(), (2 * 4 * 32 * 2 * 4) as u64);

        let a = p.rent_ref().unwrap();
        let b = p.rent_ref().unwrap();
        assert_ne!(a, b, "slab slots must be distinct");
        let s = p.stats();
        assert_eq!(s.blocks_live, 2);
        assert_eq!(s.blocks_free, 0);
        assert_eq!(s.blocks_high_water, 2);
        assert_eq!(s.reuses, 0);

        p.release_ref(a);
        p.release_ref(b);
        let s = p.stats();
        assert_eq!(s.blocks_live, 0);
        assert_eq!(s.blocks_free, 2);

        // the next rents come from the free list, not fresh allocations
        let _c = p.rent_ref().unwrap();
        let _d = p.rent_ref().unwrap();
        let s = p.stats();
        assert_eq!(s.reuses, 2);
        assert_eq!(s.blocks_live, 2);
        assert_eq!(s.blocks_free, 0);
        assert_eq!(s.blocks_high_water, 2, "reuse must not raise the peak");
    }

    #[test]
    fn exhaustion_backpressure() {
        let p = pool(4, 2);
        let a = p.rent_ref().unwrap();
        let _b = p.rent_ref().unwrap();
        let err = p.rent_ref().unwrap_err();
        assert!(format!("{err:#}").contains("exhausted"));
        // releasing frees capacity again
        p.release_ref(a);
        assert!(p.rent_ref().is_ok());
    }

    #[test]
    fn set_limits_applies_at_runtime() {
        // The orchestrator adopts an engine's pool and applies its knobs
        // after construction — the cap must bind immediately.
        let p = pool(4, 0);
        let _a = p.rent_ref().unwrap();
        p.set_limits(1, usize::MAX);
        assert!(p.rent_ref().is_err(), "cap of 1 with 1 live must refuse");
        assert_eq!(p.config().max_blocks, 1);
        p.set_limits(0, usize::MAX);
        assert!(p.rent_ref().is_ok(), "lifting the cap unblocks growth");
    }

    #[test]
    fn cap_binds_even_when_free_blocks_are_parked() {
        // A retained free list must not grant headroom past max_blocks:
        // the cap is on LIVE blocks.
        let p = pool(4, 0);
        let ids: Vec<_> = (0..5).map(|_| p.rent_ref().unwrap()).collect();
        for id in ids {
            p.release_ref(id);
        }
        assert_eq!(p.stats().blocks_free, 5);
        p.set_limits(2, usize::MAX);
        let _a = p.rent_ref().unwrap();
        let _b = p.rent_ref().unwrap();
        let err = p.rent_ref().unwrap_err();
        assert!(
            format!("{err:#}").contains("exhausted"),
            "free-list rent bypassed the cap"
        );
    }

    #[test]
    fn reclaim_policy_caps_free_list() {
        let p = KvPool::new(
            &tiny_cfg(),
            KvPoolConfig {
                block_tokens: 4,
                max_blocks: 0,
                retain_free_blocks: 1,
                ..KvPoolConfig::default()
            },
        );
        let a = p.rent_ref().unwrap();
        let b = p.rent_ref().unwrap();
        let c = p.rent_ref().unwrap();
        p.release_ref(a);
        p.release_ref(b);
        p.release_ref(c);
        let s = p.stats();
        assert_eq!(s.blocks_free, 1, "free list capped by retain_free_blocks");
        assert_eq!(s.blocks_live, 0);
    }

    #[test]
    fn blocks_for_rounds_up() {
        let p = pool(16, 0);
        assert_eq!(p.blocks_for(0), 0);
        assert_eq!(p.blocks_for(1), 1);
        assert_eq!(p.blocks_for(16), 1);
        assert_eq!(p.blocks_for(17), 2);
    }

    #[test]
    fn fragmentation_gauge() {
        let p = pool(8, 0);
        let _b = p.rent_ref().unwrap();
        p.note_rows_added(6);
        let s = p.stats();
        assert_eq!(s.rows_live, 6);
        assert!((s.fragmentation() - 0.25).abs() < 1e-9, "{}", s.fragmentation());
        p.note_rows_removed(6);
        assert_eq!(p.stats().rows_live, 0);
    }

    #[test]
    fn can_admit_counts_parked_registry_blocks_as_headroom() {
        // Uncapped: always admissible.
        assert!(pool(4, 0).can_admit(1_000_000));

        let p = pool(4, 2);
        assert!(p.can_admit(2));
        assert!(!p.can_admit(3), "beyond the cap even when empty");
        let keys: Vec<i32> = (0..8).collect();
        let hashes = p.prefix_hashes(0, &keys);
        let a0 = p.rent_ref().unwrap();
        let a1 = p.rent_ref().unwrap();
        // Fully referenced at the cap: nothing rentable.
        assert!(!p.can_admit(1));
        // Register + drop every reference: the blocks PARK (still live,
        // still hittable) — but a rent would LRU-evict them, so the
        // admission gate must read them as headroom, not exhaustion (the
        // warm-registry starvation bug).
        p.write_run(a0, 0, 4, 0, 8, &rows(&p, 8, 1.0), &rows(&p, 8, -1.0))
            .unwrap();
        p.write_run(a1, 0, 4, 4, 8, &rows(&p, 8, 1.0), &rows(&p, 8, -1.0))
            .unwrap();
        assert!(p.register_block(a0, hashes[0], &keys[..4]));
        assert!(p.register_block(a1, hashes[1], &keys[4..8]));
        p.release_ref(a0);
        p.release_ref(a1);
        assert_eq!(p.stats().blocks_live, 2, "parked, not freed");
        assert!(p.can_admit(2), "parked registry entries are evictable headroom");
        assert!(!p.can_admit(3));
        // ...and the promise is real: both rents succeed via LRU eviction.
        assert!(p.rent_ref().is_ok());
        assert!(p.rent_ref().is_ok());
    }

    #[test]
    fn session_reservations_consume_admission_headroom() {
        let p = pool(4, 4);
        assert!(p.can_admit(4));
        let r1 = p.reserve(3);
        assert_eq!(p.stats().reserved_blocks, 3);
        assert!(p.can_admit(1), "one block of headroom left");
        assert!(
            !p.can_admit(2),
            "reserved blocks must read as spent headroom"
        );
        // A second session's reservation stacks.
        let r2 = p.reserve(1);
        assert!(!p.can_admit(1));
        // Prefill done: the guard drops and the headroom returns (the real
        // rents then show up in `blocks_live` instead).
        drop(r1);
        assert!(p.can_admit(3));
        assert!(!p.can_admit(4));
        drop(r2);
        assert!(p.can_admit(4));
        assert_eq!(p.stats().reserved_blocks, 0);
        // Uncapped pools ignore reservations entirely.
        let free = pool(4, 0);
        let _r = free.reserve(1_000_000);
        assert!(free.can_admit(1_000_000));
    }

    #[test]
    fn try_reserve_is_atomic_against_concurrent_admissions() {
        // Headroom for exactly one 3-block prefill: of two racing
        // admissions, exactly one may win it (the old check-then-reserve
        // let both pass and fail mid-prefill instead).
        let p = pool(4, 4);
        let won = p.try_reserve(3).expect("first reservation fits");
        assert!(p.try_reserve(3).is_none(), "no headroom left for a twin");
        assert!(p.try_reserve(1).is_some(), "the remainder is still grantable");
        drop(won);
        assert!(p.try_reserve(3).is_some(), "headroom returns on drop");
        // Uncapped: always granted.
        assert!(pool(4, 0).try_reserve(1_000_000).is_some());
    }

    #[test]
    fn chain_hashes_commit_to_the_whole_prefix() {
        let p = pool(4, 0);
        let keys: Vec<i32> = (0..12).collect();
        let h = p.prefix_hashes(7, &keys);
        assert_eq!(h.len(), 3, "one hash per full block");
        // same prefix → same chain
        assert_eq!(p.prefix_hashes(7, &keys), h);
        // a partial tail never hashes
        assert_eq!(p.prefix_hashes(7, &keys[..7]).len(), 1);
        // changing ANY earlier key changes every later hash
        let mut other = keys.clone();
        other[1] = 99;
        let h2 = p.prefix_hashes(7, &other);
        assert_ne!(h2[0], h[0]);
        assert_ne!(h2[1], h[1]);
        assert_ne!(h2[2], h[2]);
        // a different domain salt separates identical key chains
        assert_ne!(p.prefix_hashes(8, &keys)[0], h[0]);
        // chain extension is order-sensitive
        assert_ne!(chain_hash(1, &[2, 3]), chain_hash(1, &[3, 2]));
    }

    #[test]
    fn registry_register_lookup_and_parking() {
        let p = pool(4, 0);
        let keys: Vec<i32> = (0..8).collect();
        let hashes = p.prefix_hashes(0, &keys);
        let a0 = p.rent_ref().unwrap();
        let a1 = p.rent_ref().unwrap();
        p.write_run(a0, 0, 4, 0, 8, &rows(&p, 8, 1.0), &rows(&p, 8, -1.0))
            .unwrap();
        p.write_run(a1, 0, 4, 4, 8, &rows(&p, 8, 1.0), &rows(&p, 8, -1.0))
            .unwrap();
        assert!(p.register_block(a0, hashes[0], &keys[..4]));
        assert!(p.register_block(a1, hashes[1], &keys[4..8]));
        assert!(
            !p.register_block(a1, hashes[1], &keys[4..8]),
            "re-registering is a no-op"
        );
        assert_eq!(p.stats().shared_blocks, 2);

        // a second chain lookup hits both blocks and increfs them
        let ids = p.lookup_chain(&hashes, &keys);
        assert_eq!(ids, vec![a0, a1]);
        let s = p.stats();
        assert_eq!(s.prefix_hits, 2);
        assert_eq!(s.prefix_misses, 0);

        // dropping every reference parks the blocks instead of freeing them
        p.release_ref(a0);
        p.release_ref(a1);
        p.release_ref(ids[0]);
        p.release_ref(ids[1]);
        let s = p.stats();
        assert_eq!(s.blocks_live, 2, "registered blocks park, not free");
        assert_eq!(s.blocks_free, 0);
        // ...and they still hit
        let ids2 = p.lookup_chain(&hashes, &keys);
        assert_eq!(ids2, vec![a0, a1]);
        p.release_ref(ids2[0]);
        p.release_ref(ids2[1]);

        // an unknown chain misses without touching anything
        let other = p.prefix_hashes(1, &keys);
        assert!(p.lookup_chain(&other, &keys).is_empty());
        assert_eq!(p.stats().prefix_misses, 2);
    }

    #[test]
    fn mid_chain_lookup_counts_mid_hits_and_skips_miss_accounting() {
        let p = pool(4, 0);
        let keys: Vec<i32> = (0..12).collect();
        let hashes = p.prefix_hashes(0, &keys);
        let ids: Vec<u32> = (0..3).map(|_| p.rent_ref().unwrap()).collect();
        for (i, &id) in ids.iter().enumerate() {
            p.write_run(id, 0, 4, i * 4, 12, &rows(&p, 12, 1.0), &rows(&p, 12, -1.0))
                .unwrap();
            assert!(p.register_block(id, hashes[i], &keys[i * 4..(i + 1) * 4]));
        }
        // A prefiller already past block 0 probes the chain continuation:
        // blocks 1 and 2 hit (incref'd), counted as mid-prefill hits.
        let got = p.lookup_chain_mid(&hashes[1..], &keys[4..]);
        assert_eq!(got, vec![ids[1], ids[2]]);
        let s = p.stats();
        assert_eq!(s.prefix_mid_hits, 2);
        assert_eq!(s.prefix_hits, 0, "mid hits are a separate gauge");
        // An empty probe (nothing registered past the chain) is free: no
        // miss accounting — probing is the steady state of chunked prefill.
        let other = p.prefix_hashes(9, &keys);
        assert!(p.lookup_chain_mid(&other[2..], &keys[8..]).is_empty());
        let s = p.stats();
        assert_eq!(s.prefix_mid_hits, 2);
        assert_eq!(s.prefix_misses, 0);
        for id in got {
            p.release_ref(id);
        }
        for id in ids {
            p.release_ref(id);
        }
    }

    #[test]
    fn hash_collisions_verify_keys_and_miss() {
        // The registry must never trust the 64-bit chain hash alone: a hit
        // whose stored key run differs from the caller's keys is a
        // collision and degrades to a miss — attaching another prompt's KV
        // silently would be cross-request contamination.
        let p = pool(4, 0);
        let keys: Vec<i32> = (0..4).collect();
        let hashes = p.prefix_hashes(0, &keys);
        let id = p.rent_ref().unwrap();
        p.write_run(id, 0, 4, 0, 4, &rows(&p, 4, 1.0), &rows(&p, 4, 1.0))
            .unwrap();
        assert!(p.register_block(id, hashes[0], &keys));
        // simulate a colliding chain: same hash value, different keys
        let other_keys: Vec<i32> = (100..104).collect();
        let refs_probe = p.lookup_chain(&hashes, &other_keys);
        assert!(refs_probe.is_empty(), "collision must miss, not attach");
        assert_eq!(p.stats().prefix_misses, 1);
        // the genuine keys still hit
        let hit = p.lookup_chain(&hashes, &keys);
        assert_eq!(hit, vec![id]);
        p.release_ref(hit[0]);
        p.release_ref(id);
    }

    #[test]
    fn write_to_shared_block_copies_on_write() {
        let p = pool(4, 0);
        let keys: Vec<i32> = (0..4).collect();
        let hashes = p.prefix_hashes(0, &keys);
        let a = p.rent_ref().unwrap();
        p.write_run(a, 0, 4, 0, 4, &rows(&p, 4, 1.0), &rows(&p, 4, 2.0))
            .unwrap();
        assert!(p.register_block(a, hashes[0], &keys));

        // the registering owner's own next write must CoW too
        let a2 = p
            .write_run(a, 1, 1, 0, 1, &rows(&p, 1, 9.0), &rows(&p, 1, 9.0))
            .unwrap();
        assert_ne!(a2, a, "write to a registered block must copy");
        assert_eq!(p.stats().cow_copies, 1);

        // the registered original is untouched: a fresh chain hit still
        // reads the original contents
        let hit = p.lookup_chain(&hashes, &keys);
        assert_eq!(hit, vec![a]);
        let mut k = vec![0.0f32; p.n_layers() * 4 * p.row()];
        let mut v = vec![0.0f32; p.n_layers() * 4 * p.row()];
        p.host_gather_prefix_into(&hit, 4, 4, &mut k, &mut v);
        assert!(k.iter().all(|&x| x == 1.0), "CoW mutated the shared block");
        // while the copy carries the divergent row
        let mut k2 = vec![0.0f32; p.n_layers() * 4 * p.row()];
        let mut v2 = vec![0.0f32; p.n_layers() * 4 * p.row()];
        p.host_gather_prefix_into(&[a2], 4, 4, &mut k2, &mut v2);
        let row = p.row();
        assert!(k2[row..2 * row].iter().all(|&x| x == 9.0));
        assert!(k2[..row].iter().all(|&x| x == 1.0), "copy lost the prefix");
        p.release_ref(hit[0]);
        p.release_ref(a2);
    }

    #[test]
    fn lru_eviction_frees_parked_blocks_under_the_cap() {
        let p = pool(4, 0);
        let keys: Vec<i32> = (0..12).collect();
        let hashes = p.prefix_hashes(0, &keys);
        // register three blocks, then park them all
        let ids: Vec<u32> = (0..3).map(|_| p.rent_ref().unwrap()).collect();
        for (i, &id) in ids.iter().enumerate() {
            p.write_run(id, 0, 4, i * 4, 12, &rows(&p, 12, 1.0), &rows(&p, 12, 1.0))
                .unwrap();
            assert!(p.register_block(id, hashes[i], &keys[i * 4..(i + 1) * 4]));
        }
        for &id in &ids {
            p.release_ref(id);
        }
        assert_eq!(p.stats().blocks_live, 3);

        // touch the first chain entry so it becomes most-recently-used
        let touched = p.lookup_chain(&hashes[..1], &keys);
        p.release_ref(touched[0]);

        // cap at 3: the next rent must evict the LRU parked entry — which
        // is hashes[1] (hashes[0] was just touched, hashes[2] registered
        // later... registration order gives 0,1,2; touching 0 leaves 1 as
        // the oldest).
        p.set_limits(3, usize::MAX);
        let fresh = p.rent_ref().unwrap();
        let s = p.stats();
        assert_eq!(s.prefix_evictions, 1);
        assert_eq!(s.shared_blocks, 2);
        assert_eq!(s.blocks_live, 3, "eviction reuses in place");
        // the evicted chain link now misses; the untouched survivors hit
        let broken = p.lookup_chain(&hashes, &keys);
        assert_eq!(broken.len(), 1, "chain broken at evictee");
        p.release_ref(broken[0]);
        let hit0 = p.lookup_chain(&hashes[..1], &keys);
        assert_eq!(hit0.len(), 1);
        p.release_ref(hit0[0]);
        p.release_ref(fresh);

        // with everything parked again and no cap, rents do not evict
        p.set_limits(0, usize::MAX);
        let id = p.rent_ref().unwrap();
        assert_eq!(p.stats().prefix_evictions, 1);
        p.release_ref(id);
    }

    #[test]
    fn shared_guard_tracks_registered_bytes() {
        use crate::cortex::memory::{MemKind, MemoryTracker};
        let t = MemoryTracker::new();
        let p = pool(4, 0);
        let id = p.rent_ref().unwrap();
        p.write_run(id, 0, 4, 0, 4, &rows(&p, 4, 1.0), &rows(&p, 4, 1.0))
            .unwrap();
        let guard_keys = [1, 2, 3, 4];
        let hashes = p.prefix_hashes(0, &guard_keys);
        p.track_shared(t.alloc(MemKind::SharedKv, 0));
        assert_eq!(t.live_bytes(MemKind::SharedKv), 0);
        assert!(p.register_block(id, hashes[0], &guard_keys));
        assert_eq!(t.live_bytes(MemKind::SharedKv) as u64, p.block_bytes());
        // parking does not change the global charge
        p.release_ref(id);
        assert_eq!(t.live_bytes(MemKind::SharedKv) as u64, p.block_bytes());
        // eviction releases it
        p.set_limits(1, usize::MAX);
        let id2 = p.rent_ref().unwrap();
        assert_eq!(t.live_bytes(MemKind::SharedKv), 0);
        p.release_ref(id2);
    }

    #[test]
    fn random_rent_release_sequences_reuse_without_growth() {
        // Fragmentation-free reuse: after any interleaving of rents and
        // releases, demand that never exceeds a prior peak is served
        // entirely from the free list — the high-water mark stays put.
        check("pool reuse under churn", 50, |g| {
            let p = pool(4, 0);
            let mut held = Vec::new();
            let mut peak = 0usize;
            // phase 1: random churn
            for _ in 0..g.usize_in(10..60) {
                if g.bool() || held.is_empty() {
                    held.push(p.rent_ref().map_err(|e| e.to_string())?);
                    peak = peak.max(held.len());
                } else {
                    let i = g.usize_in(0..held.len());
                    p.release_ref(held.swap_remove(i));
                }
            }
            p.check_invariants()?;
            let hw = p.stats().blocks_high_water;
            crate::prop_assert!(hw == peak, "high-water {hw} != observed peak {peak}");
            // phase 2: drop everything, then re-rent up to the peak
            for id in held.drain(..) {
                p.release_ref(id);
            }
            let before = p.stats();
            crate::prop_assert!(
                before.blocks_free == peak,
                "free list {} != peak {peak}",
                before.blocks_free
            );
            for _ in 0..peak {
                held.push(p.rent_ref().map_err(|e| e.to_string())?);
            }
            let after = p.stats();
            crate::prop_assert!(
                after.blocks_high_water == peak,
                "re-renting to the old peak grew the pool: {} > {peak}",
                after.blocks_high_water
            );
            crate::prop_assert!(
                after.reuses - before.reuses >= peak as u64,
                "expected {} reuses, got {}",
                peak,
                after.reuses - before.reuses
            );
            for id in held.drain(..) {
                p.release_ref(id);
            }
            p.check_invariants()?;
            Ok(())
        });
    }

    // ── The invariant sanitizer ────────────────────────────────────────

    #[test]
    fn check_invariants_passes_on_real_pool_states() {
        // Empty, private churn, shared/parked, evicted — all legal states.
        let p = pool(4, 2);
        p.check_invariants().unwrap();
        let keys: Vec<i32> = (0..8).collect();
        let hashes = p.prefix_hashes(0, &keys);
        let a0 = p.rent_ref().unwrap();
        let a1 = p.rent_ref().unwrap();
        p.check_invariants().unwrap();
        p.write_run(a0, 0, 4, 0, 8, &rows(&p, 8, 1.0), &rows(&p, 8, -1.0))
            .unwrap();
        p.write_run(a1, 0, 4, 4, 8, &rows(&p, 8, 1.0), &rows(&p, 8, -1.0))
            .unwrap();
        assert!(p.register_block(a0, hashes[0], &keys[..4]));
        assert!(p.register_block(a1, hashes[1], &keys[4..8]));
        p.check_invariants().unwrap();
        p.release_ref(a0);
        p.release_ref(a1); // both park in the registry
        p.check_invariants().unwrap();
        let _evictor = p.rent_ref().unwrap(); // LRU-evicts one parked entry
        p.check_invariants().unwrap();
    }

    #[test]
    fn sanitizer_names_a_refcount_underflow() {
        let p = pool(4, 0);
        let id = p.rent_ref().unwrap();
        p.corrupt_refcount_underflow(id);
        let err = p.check_invariants().unwrap_err();
        assert!(err.contains("block-state"), "law not named: {err}");
        assert!(err.contains("live-count"), "gauge drift not named: {err}");
    }

    #[test]
    fn sanitizer_names_a_free_list_double_entry() {
        let p = pool(4, 0);
        let id = p.rent_ref().unwrap();
        p.release_ref(id);
        p.corrupt_free_list_double_entry();
        let err = p.check_invariants().unwrap_err();
        assert!(err.contains("free-list"), "law not named: {err}");
        assert!(err.contains("double-entered"), "symptom not named: {err}");
    }

    #[test]
    fn sanitizer_names_a_stale_registry_id() {
        let p = pool(4, 0);
        let id = p.rent_ref().unwrap();
        // Hash points at a live block that does not carry it.
        p.corrupt_stale_registry_id(0xdead_beef, id);
        let err = p.check_invariants().unwrap_err();
        assert!(err.contains("stale registry id"), "law not named: {err}");
    }

    #[test]
    fn sanitizer_names_live_gauge_drift() {
        let p = pool(4, 0);
        let _id = p.rent_ref().unwrap();
        p.corrupt_live_gauge();
        let err = p.check_invariants().unwrap_err();
        assert!(err.contains("live-count"), "law not named: {err}");
    }

    // ── Poison containment (the cascade regression) ────────────────────

    #[test]
    fn poisoned_state_mutex_does_not_cascade_into_other_sessions() {
        // PR 4's fault-isolation rule, now load-bearing in the pool
        // itself: one agent panicking while holding the pool state lock
        // must not take every other session down with it.
        let p = pool(4, 8);
        let a = p.rent_ref().unwrap();
        p.poison_state_for_test();
        assert!(p.state_is_poisoned());
        // Other sessions keep renting, writing and releasing…
        let b = p.rent_ref().unwrap();
        p.write_run(b, 0, 2, 0, 2, &rows(&p, 2, 1.0), &rows(&p, 2, 1.0))
            .unwrap();
        assert!(p.can_admit(1), "admission gate must survive the poison");
        p.release_ref(a);
        p.release_ref(b);
        // …and `/stats` stays serveable off the same mutex.
        let s = p.stats();
        assert_eq!(s.blocks_live, 0);
        assert_eq!(s.blocks_free, 2);
        p.check_invariants().unwrap();
    }

    #[test]
    fn device_copies_materialise_lazily_and_recycle_with_blocks() {
        let p = pool(4, 0);
        let b0 = p.rent_ref().unwrap();
        let b1 = p.rent_ref().unwrap();
        assert_ne!(b0, b1, "slab slots must be distinct");
        let s = p.stats();
        assert_eq!(s.dev_blocks, 0, "no write-through yet → no device copy");
        assert_eq!(s.dev_bytes, 0);
        assert_eq!(s.h2d_bytes, 0);

        // First write-through materialises the copy and counts the rows.
        p.write_run(b0, 0, 2, 0, 2, &rows(&p, 2, 1.0), &rows(&p, 2, 1.0))
            .unwrap();
        let s = p.stats();
        assert_eq!(s.dev_blocks, 1);
        assert_eq!(s.dev_bytes, p.block_bytes());
        // 2 rows × L(2) × row(32 floats) × K+V × 4 bytes
        assert_eq!(s.h2d_bytes, (2 * 2 * 32 * 2 * 4) as u64);

        // A free-listed block keeps its device copy (recycled, not freed).
        p.release_ref(b0);
        p.release_ref(b1);
        assert_eq!(p.stats().dev_blocks, 1);
        let a = p.rent_ref().unwrap();
        let b = p.rent_ref().unwrap();
        assert!(a == b0 || b == b0, "free-listed id must recycle");
        assert_eq!(p.stats().dev_blocks, 1);
        p.release_ref(a);
        p.release_ref(b);
    }

    #[test]
    fn allocator_return_frees_the_device_copy_and_recycles_the_id() {
        let p = KvPool::new(
            &tiny_cfg(),
            KvPoolConfig {
                block_tokens: 4,
                max_blocks: 0,
                retain_free_blocks: 0, // every release returns to allocator
                ..KvPoolConfig::default()
            },
        );
        let id = p.rent_ref().unwrap();
        p.write_run(id, 0, 1, 0, 1, &rows(&p, 1, 1.0), &rows(&p, 1, 1.0))
            .unwrap();
        assert_eq!(p.stats().dev_bytes, p.block_bytes());
        p.release_ref(id);
        let s = p.stats();
        assert_eq!(s.dev_blocks, 0, "allocator return must free the copy");
        assert_eq!(s.dev_bytes, 0);
        // the id comes back for the next fresh block
        let id2 = p.rent_ref().unwrap();
        assert_eq!(id2, id);
        p.release_ref(id2);
    }

    #[test]
    fn gather_requires_resident_copies_and_counts_table_upload() {
        let p = pool(4, 0);
        let b = p.rent_ref().unwrap();
        // no write-through yet → gather over real rows must refuse
        let err = p.dev_gather_prefix(&[b], 2, 4).unwrap_err();
        assert!(format!("{err:#}").contains("no device-resident copy"));
        // an empty view gathers fine (nothing to read) but still ships the
        // (empty) table + len scalar
        let before = p.stats().h2d_bytes;
        let (k, v) = p.dev_gather_prefix(&[], 0, 4).unwrap();
        assert_eq!(k.len(), 2 * 4 * 32);
        assert!(k.iter().chain(v.iter()).all(|&x| x == 0.0));
        let s = p.stats();
        assert_eq!(s.h2d_bytes - before, 8);
        assert_eq!(s.dev_gathers, 1);
        p.release_ref(b);
    }

    #[test]
    fn device_guard_tracks_slab_bytes() {
        use crate::cortex::memory::{MemKind, MemoryTracker};
        let t = MemoryTracker::new();
        let p = pool(4, 0);
        let b = p.rent_ref().unwrap();
        p.write_run(b, 0, 1, 0, 1, &rows(&p, 1, 1.0), &rows(&p, 1, 1.0))
            .unwrap();
        // attaching after the fact syncs to the current slab size
        p.track_device(t.alloc(MemKind::DeviceKv, 0));
        assert_eq!(t.live_bytes(MemKind::DeviceKv) as u64, p.block_bytes());
        let b2 = p.rent_ref().unwrap();
        p.write_run(b2, 1, 3, 0, 3, &rows(&p, 3, 1.0), &rows(&p, 3, 1.0))
            .unwrap();
        assert_eq!(t.live_bytes(MemKind::DeviceKv) as u64, 2 * p.block_bytes());
        // reclaim-to-allocator shrinks the charge
        p.set_limits(0, 0);
        p.release_ref(b);
        p.release_ref(b2);
        assert_eq!(t.live_bytes(MemKind::DeviceKv), 0);
    }

    // ---- tiered store: quantized (warm) + host-slab (cold) tiers --------

    #[test]
    fn parked_blocks_quantize_and_stay_readable_within_the_bound() {
        let p = tiered_pool(4, 0, true, 0);
        let keys: Vec<i32> = (0..4).collect();
        let hashes = p.prefix_hashes(0, &keys);
        let k_src = varied_rows(&p, 4, 1.0);
        let v_src = varied_rows(&p, 4, 2.0);

        let id = p.rent_ref().unwrap();
        p.write_run(id, 0, 4, 0, 4, &k_src, &v_src).unwrap();
        assert!(p.register_block(id, hashes[0], &keys));
        p.release_ref(id); // parks → demotes to int8

        let s = p.stats();
        assert_eq!(s.quantized_blocks, 1);
        assert_eq!(s.blocks_live, 1);
        assert_eq!(s.resident_payload_bytes, p.q8_block_bytes());
        assert_eq!(s.quant_saved_bytes, p.block_bytes() - p.q8_block_bytes());
        assert!(
            p.q8_block_bytes() * 3 < p.block_bytes(),
            "int8 payload must be under a third of fp32 ({} vs {})",
            p.q8_block_bytes(),
            p.block_bytes()
        );
        p.check_invariants().unwrap();

        // Host reads dequantize transparently, within the per-row bound.
        let sz = p.n_layers() * 4 * p.row();
        let (mut k, mut v) = (vec![0.0; sz], vec![0.0; sz]);
        p.host_gather_prefix_into(&[id], 4, 4, &mut k, &mut v);
        assert_close_q8(&k_src, &k, p.row());
        assert_close_q8(&v_src, &v, p.row());

        // …and the device-side tiered gather reconstructs the SAME floats:
        // both paths dequantize with `q as f32 * scale`, bit-for-bit.
        let (dk, dv) = p.dev_gather_prefix(&[id], 4, 4).unwrap();
        assert_eq!(dk, k, "host and device dequantization must agree");
        assert_eq!(dv, v);

        // A chain hit attaches the quantized block as-is — no promotion.
        let hit = p.lookup_chain(&hashes, &keys);
        assert_eq!(hit, vec![id]);
        assert_eq!(p.stats().quantized_blocks, 1);
        p.release_ref(id);
        p.check_invariants().unwrap();
    }

    #[test]
    fn offload_and_page_in_round_trip_is_bit_identical() {
        let p = tiered_pool(4, 0, false, 2);
        let k_src = varied_rows(&p, 4, 3.0);
        let v_src = varied_rows(&p, 4, 4.0);
        let id = p.rent_ref().unwrap();
        p.write_run(id, 0, 4, 0, 4, &k_src, &v_src).unwrap();
        let (bk, bv) = p.dev_gather_prefix(&[id], 4, 4).unwrap();

        p.offload_ref(id).unwrap();
        let s = p.stats();
        assert_eq!(s.offloaded_blocks, 1);
        assert_eq!(s.host_slab_bytes, p.block_bytes());
        assert_eq!(s.swap_out_bytes, p.block_bytes());
        assert_eq!(s.resident_payload_bytes, 0);
        assert_eq!(s.dev_blocks, 0, "offload drops the device copy");
        assert_eq!(s.blocks_live, 1, "offloaded blocks stay live");
        p.check_invariants().unwrap();

        // Re-offloading is a no-op, and the cold block refuses device reads
        // but still resolves host-side through the slab — verbatim.
        p.offload_ref(id).unwrap();
        assert_eq!(p.stats().swap_out_bytes, p.block_bytes());
        assert!(p.dev_gather_prefix(&[id], 4, 4).is_err());
        let sz = p.n_layers() * 4 * p.row();
        let (mut k, mut v) = (vec![0.0; sz], vec![0.0; sz]);
        p.host_gather_prefix_into(&[id], 4, 4, &mut k, &mut v);
        assert_eq!(k, k_src);
        assert_eq!(v, v_src);

        p.page_in_ref(id).unwrap();
        let s = p.stats();
        assert_eq!(s.offloaded_blocks, 0);
        assert_eq!(s.swap_in_bytes, p.block_bytes());
        assert_eq!(s.resume_page_ins, 1);
        assert_eq!(s.host_slab_bytes, 0);
        // The lossless round-trip law: decode state after resume is the
        // exact bytes that were parked.
        let (ak, av) = p.dev_gather_prefix(&[id], 4, 4).unwrap();
        assert_eq!(ak, bk);
        assert_eq!(av, bv);
        // …and paging in a resident block is a no-op.
        p.page_in_ref(id).unwrap();
        assert_eq!(p.stats().resume_page_ins, 1);
        p.check_invariants().unwrap();
        p.release_ref(id);
    }

    #[test]
    fn offload_rejects_shared_blocks_and_full_slabs() {
        let p = tiered_pool(4, 0, false, 1);
        // A registered block is shared state — it parks via the registry's
        // own demotion path, never via session offload.
        let keys: Vec<i32> = (0..4).collect();
        let hashes = p.prefix_hashes(0, &keys);
        let shared = p.rent_ref().unwrap();
        assert!(p.register_block(shared, hashes[0], &keys));
        let err = p.offload_ref(shared).unwrap_err();
        assert!(
            format!("{err:#}").contains("only private session blocks"),
            "unexpected: {err:#}"
        );

        // The slab holds one block; the second private park must bail.
        let a = p.rent_ref().unwrap();
        let b = p.rent_ref().unwrap();
        p.offload_ref(a).unwrap();
        let err = p.offload_ref(b).unwrap_err();
        assert!(format!("{err:#}").contains("host slab full"), "unexpected: {err:#}");

        // A pool with no slab configured refuses outright.
        let p0 = tiered_pool(4, 0, false, 0);
        let c = p0.rent_ref().unwrap();
        let err = p0.offload_ref(c).unwrap_err();
        assert!(format!("{err:#}").contains("host slab full"), "unexpected: {err:#}");
        p.check_invariants().unwrap();
        p0.check_invariants().unwrap();
    }

    #[test]
    fn pressure_offloads_parked_registry_entries_before_evicting() {
        let p = tiered_pool(4, 3, false, 2);
        let keys: Vec<i32> = (0..12).collect();
        let hashes = p.prefix_hashes(0, &keys);
        let k_src = varied_rows(&p, 12, 5.0);
        let v_src = varied_rows(&p, 12, 6.0);
        let ids: Vec<u32> = (0..3).map(|_| p.rent_ref().unwrap()).collect();
        for (i, &id) in ids.iter().enumerate() {
            p.write_run(id, 0, 4, i * 4, 12, &k_src, &v_src).unwrap();
            assert!(p.register_block(id, hashes[i], &keys[i * 4..(i + 1) * 4]));
        }
        for &id in &ids {
            p.release_ref(id);
        }

        // At the cap with every block parked: a rent spills the LRU entry
        // to the host slab instead of evicting it — the chain survives.
        let fresh = p.rent_ref().unwrap();
        let s = p.stats();
        assert_eq!(s.prefix_evictions, 0, "offload-first: nothing evicted");
        assert_eq!(s.offloaded_blocks, 1);
        assert_eq!(s.shared_blocks, 3, "the cold entry stays registered");
        assert_eq!(s.swap_out_bytes, p.block_bytes());
        assert_eq!(s.blocks_live, 4, "4 live blocks under a 3-block device cap");
        p.check_invariants().unwrap();

        // Free the private block, then hit the full chain: the cold entry
        // pages back in and all three blocks attach.
        p.release_ref(fresh);
        let hit = p.lookup_chain(&hashes, &keys);
        assert_eq!(hit, ids);
        let s = p.stats();
        assert_eq!(s.resume_page_ins, 1);
        assert_eq!(s.offloaded_blocks, 0);
        assert_eq!(s.swap_in_bytes, s.swap_out_bytes, "every spilled byte paged back");
        // …and the paged-in prefix reads back verbatim (fp32 tier).
        let sz = p.n_layers() * 12 * p.row();
        let (mut k, mut v) = (vec![0.0; sz], vec![0.0; sz]);
        p.host_gather_prefix_into(&hit, 12, 12, &mut k, &mut v);
        assert_eq!(k, k_src);
        assert_eq!(v, v_src);
        for &id in &hit {
            p.release_ref(id);
        }
        p.check_invariants().unwrap();
    }

    #[test]
    fn eviction_resumes_when_the_host_slab_is_full() {
        let p = tiered_pool(4, 2, false, 1);
        let keys: Vec<i32> = (0..8).collect();
        let hashes = p.prefix_hashes(0, &keys);
        for i in 0..2 {
            let id = p.rent_ref().unwrap();
            assert!(p.register_block(id, hashes[i], &keys[i * 4..(i + 1) * 4]));
            p.release_ref(id);
        }
        // First rent offloads the LRU entry into the last slab slot…
        let _r1 = p.rent_ref().unwrap();
        let s = p.stats();
        assert_eq!(s.offloaded_blocks, 1);
        assert_eq!(s.prefix_evictions, 0);
        // …the second finds the slab full and falls back to eviction.
        let _r2 = p.rent_ref().unwrap();
        let s = p.stats();
        assert_eq!(s.prefix_evictions, 1);
        assert_eq!(s.offloaded_blocks, 1);
        assert_eq!(s.shared_blocks, 1, "the offloaded entry survives, the evictee is gone");
        // Both tiers exhausted → the rent sheds with backpressure.
        let err = p.rent_ref().unwrap_err();
        assert!(format!("{err:#}").contains("exhausted"), "unexpected: {err:#}");
        p.check_invariants().unwrap();
    }

    #[test]
    fn quantized_tier_multiplies_parked_capacity_under_one_budget() {
        // Identical byte budget (2 fp32 blocks), identical workload: park 3
        // registered blocks, then rent a private one.  The quantized tier
        // holds all four; the fp32 pool has to evict twice.
        let q = tiered_pool(4, 2, true, 0);
        let f = pool(4, 2);
        let keys: Vec<i32> = (0..12).collect();
        for p in [&q, &f] {
            let hashes = p.prefix_hashes(0, &keys);
            let k_src = varied_rows(p, 12, 7.0);
            let v_src = varied_rows(p, 12, 8.0);
            for i in 0..3 {
                let id = p.rent_ref().unwrap();
                p.write_run(id, 0, 4, i * 4, 12, &k_src, &v_src).unwrap();
                assert!(p.register_block(id, hashes[i], &keys[i * 4..(i + 1) * 4]));
                p.release_ref(id);
            }
            let private = p.rent_ref().unwrap();
            p.release_ref(private);
        }
        let (qs, fs) = (q.stats(), f.stats());
        assert_eq!(qs.prefix_evictions, 0, "int8 parking keeps every chain entry");
        assert_eq!(qs.quantized_blocks, 3);
        assert_eq!(qs.shared_blocks, 3);
        assert_eq!(qs.quant_saved_bytes, 3 * (q.block_bytes() - q.q8_block_bytes()));
        assert_eq!(fs.prefix_evictions, 2, "fp32 parking sheds under the same budget");
        assert_eq!(fs.shared_blocks, 1);
        // The surviving quantized chain still fully hits.
        let hashes = q.prefix_hashes(0, &keys);
        let hit = q.lookup_chain(&hashes, &keys);
        assert_eq!(hit.len(), 3);
        for id in hit {
            q.release_ref(id);
        }
        q.check_invariants().unwrap();
        f.check_invariants().unwrap();
    }

    #[test]
    fn can_admit_counts_offloadable_headroom() {
        let p = tiered_pool(4, 2, false, 2);
        let keys: Vec<i32> = (0..8).collect();
        let hashes = p.prefix_hashes(0, &keys);
        let mut ids = Vec::new();
        for i in 0..2 {
            let id = p.rent_ref().unwrap();
            assert!(p.register_block(id, hashes[i], &keys[i * 4..(i + 1) * 4]));
            ids.push(id);
        }
        // Both blocks referenced: the device budget is pinned solid.
        assert!(!p.can_admit(1));
        // Parked, they become reclaimable (offloadable to the slab), so the
        // same byte budget admits a full turnover again — the tiered
        // admission gate from the issue.
        for id in ids {
            p.release_ref(id);
        }
        assert!(p.can_admit(2));
        assert!(!p.can_admit(3));
        p.check_invariants().unwrap();
    }

    #[test]
    fn sanitizer_names_host_slab_gauge_drift() {
        let p = tiered_pool(4, 0, false, 2);
        let id = p.rent_ref().unwrap();
        p.offload_ref(id).unwrap();
        p.corrupt_host_slab_gauge();
        let err = p.check_invariants().unwrap_err();
        assert!(err.contains("host-slab"), "law not named: {err}");
    }

    #[test]
    fn sanitizer_names_quantized_gauge_drift() {
        let p = pool(4, 0);
        let _id = p.rent_ref().unwrap();
        p.corrupt_quantized_gauge();
        let err = p.check_invariants().unwrap_err();
        assert!(err.contains("tier"), "law not named: {err}");
    }

    // ---- satellite 3: tier proptests ------------------------------------

    #[test]
    fn q8_round_trip_error_is_bounded_per_row() {
        crate::util::proptest::check("q8 round trip bound", 80, |g| {
            let row = g.usize_in(1..40);
            let rows = g.usize_in(1..8);
            let mut src = Vec::with_capacity(row * rows);
            for _ in 0..row * rows {
                // mixed magnitudes, with exact zeros (and occasionally whole
                // zero rows) to exercise the degenerate-scale guard
                let x = if g.bool() {
                    (g.usize_in(0..2000) as f32 - 1000.0) / 250.0
                } else {
                    0.0
                };
                src.push(x);
            }
            let (q, scales) = q8_quantize(&src, row);
            let back = q8_dequantize(&q, &scales, row);
            for r in 0..rows {
                let s = &src[r * row..(r + 1) * row];
                let max = s.iter().fold(0f32, |m, &x| m.max(x.abs()));
                let bound = max / 254.0 + 1e-6;
                for i in 0..row {
                    let err = (s[i] - back[r * row + i]).abs();
                    crate::prop_assert!(
                        err <= bound,
                        "row {} elem {}: {} -> {} (err {} > bound {})",
                        r,
                        i,
                        s[i],
                        back[r * row + i],
                        err,
                        bound
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cow_promotion_from_q8_matches_the_dequantized_baseline() {
        crate::util::proptest::check("q8 CoW promotion", 40, |g| {
            let p = tiered_pool(4, 0, true, 0);
            let row = p.row();
            let salt = g.usize_in(0..10_000) as u64;
            let keys: Vec<i32> = (0..4).map(|i| i + salt as i32).collect();
            let hashes = p.prefix_hashes(salt, &keys);
            let k_src = varied_rows(&p, 4, salt as f32 + 0.25);
            let v_src = varied_rows(&p, 4, salt as f32 + 0.75);

            // Shared path: register, park (demotes to int8), re-attach.
            let a = p.rent_ref().map_err(|e| e.to_string())?;
            p.write_run(a, 0, 4, 0, 4, &k_src, &v_src)
                .map_err(|e| e.to_string())?;
            crate::prop_assert!(p.register_block(a, hashes[0], &keys), "register");
            p.release_ref(a);
            crate::prop_assert!(
                p.stats().quantized_blocks == 1,
                "park must quantize (got {})",
                p.stats().quantized_blocks
            );
            let hit = p.lookup_chain(&hashes[..1], &keys);
            crate::prop_assert!(hit == vec![a], "chain must hit the parked block");

            // The dequantized baseline, read straight off the int8 payload,
            // is within the quantization bound of the original rows…
            let sz = p.n_layers() * 4 * row;
            let (mut base_k, mut base_v) = (vec![0.0; sz], vec![0.0; sz]);
            p.host_gather_prefix_into(&hit, 4, 4, &mut base_k, &mut base_v);
            for (orig, base) in [(&k_src, &base_k), (&v_src, &base_v)] {
                for (r, (o, b)) in orig.chunks(row).zip(base.chunks(row)).enumerate() {
                    let max = o.iter().fold(0f32, |m, &x| m.max(x.abs()));
                    let bound = max / 254.0 + 1e-6;
                    for i in 0..row {
                        crate::prop_assert!(
                            (o[i] - b[i]).abs() <= bound,
                            "row {} elem {} beyond q8 bound",
                            r,
                            i
                        );
                    }
                }
            }

            // A write into the shared quantized block CoW-promotes: fresh
            // rows are the new fp32 data, untouched rows are bit-identical
            // to the dequantized baseline (promotion is stable).
            let off = g.usize_in(0..4);
            let run = g.usize_in(1..(4 - off + 1));
            let nk = varied_rows(&p, run, salt as f32 + 100.0);
            let nv = varied_rows(&p, run, salt as f32 + 200.0);
            let promoted = p
                .write_run(hit[0], off, run, 0, run, &nk, &nv)
                .map_err(|e| e.to_string())?;
            crate::prop_assert!(
                promoted != hit[0],
                "a write into a shared quantized block must CoW"
            );
            let (mut got_k, mut got_v) = (vec![0.0; sz], vec![0.0; sz]);
            p.host_gather_prefix_into(&[promoted], 4, 4, &mut got_k, &mut got_v);
            for (new_rows, base, got) in
                [(&nk, &base_k, &got_k), (&nv, &base_v, &got_v)]
            {
                for layer in 0..p.n_layers() {
                    for pos in 0..4 {
                        let o = (layer * 4 + pos) * row;
                        if pos >= off && pos < off + run {
                            let s = (layer * run + (pos - off)) * row;
                            crate::prop_assert!(
                                got[o..o + row] == new_rows[s..s + row],
                                "written row (layer {}, pos {}) must be fresh fp32",
                                layer,
                                pos
                            );
                        } else {
                            crate::prop_assert!(
                                got[o..o + row] == base[o..o + row],
                                "untouched row (layer {}, pos {}) must match baseline",
                                layer,
                                pos
                            );
                        }
                    }
                }
            }
            p.release_ref(promoted);
            p.release_ref(hit[0]);
            p.check_invariants()?;
            Ok(())
        });
    }

    #[test]
    fn tier_churn_keeps_invariants_green_and_gauges_reconciled() {
        crate::util::proptest::check("tier churn", 40, |g| {
            let quantize = g.bool();
            let slab = g.usize_in(0..4);
            let cap = g.usize_in(0..7); // 0 = uncapped
            let p = tiered_pool(2, cap, quantize, slab);
            let mut held: Vec<u32> = Vec::new();
            let mut cold: Vec<u32> = Vec::new(); // private blocks parked to host
            let mut salt = 0u64;
            let steps = g.usize_in(10..60);
            for _ in 0..steps {
                match g.usize_in(0..6) {
                    0 => {
                        // admit: rent + write a full block
                        if let Ok(id) = p.rent_ref() {
                            let k = varied_rows(&p, 2, salt as f32 + 0.1);
                            let v = varied_rows(&p, 2, salt as f32 + 0.2);
                            let id = p
                                .write_run(id, 0, 2, 0, 2, &k, &v)
                                .map_err(|e| e.to_string())?;
                            held.push(id);
                        }
                    }
                    1 => {
                        // drop a session block
                        if !held.is_empty() {
                            let i = g.usize_in(0..held.len());
                            p.release_ref(held.swap_remove(i));
                        }
                    }
                    2 => {
                        // register under a fresh chain, then park it
                        if !held.is_empty() {
                            let i = g.usize_in(0..held.len());
                            let id = held.swap_remove(i);
                            salt += 1;
                            let keys = [salt as i32, -(salt as i32)];
                            let h = p.prefix_hashes(salt, &keys);
                            if p.register_block(id, h[0], &keys) {
                                p.release_ref(id);
                            } else {
                                held.push(id);
                            }
                        }
                    }
                    3 => {
                        // park a private block to the host slab
                        if !held.is_empty() {
                            let i = g.usize_in(0..held.len());
                            if p.offload_ref(held[i]).is_ok() {
                                cold.push(held.swap_remove(i));
                            }
                        }
                    }
                    4 => {
                        // resume a cold block
                        if !cold.is_empty() {
                            let i = g.usize_in(0..cold.len());
                            if p.page_in_ref(cold[i]).is_ok() {
                                held.push(cold.swap_remove(i));
                            }
                        }
                    }
                    _ => {
                        // decode-style single-row write into a held block
                        if !held.is_empty() {
                            let i = g.usize_in(0..held.len());
                            let k = varied_rows(&p, 1, salt as f32 + 0.3);
                            if let Ok(nid) = p.write_run(held[i], 0, 1, 0, 1, &k, &k) {
                                held[i] = nid;
                            }
                        }
                    }
                }
                p.check_invariants()?;
            }
            // Gauge reconciliation at rest: swap traffic conserves, and the
            // quantizer's savings gauge matches its population.
            let s = p.stats();
            crate::prop_assert!(
                s.swap_out_bytes
                    == s.swap_in_bytes + s.swap_dropped_bytes + s.host_slab_bytes,
                "swap conservation: out {} != in {} + dropped {} + held {}",
                s.swap_out_bytes,
                s.swap_in_bytes,
                s.swap_dropped_bytes,
                s.host_slab_bytes
            );
            crate::prop_assert!(
                s.quant_saved_bytes
                    == s.quantized_blocks as u64 * (p.block_bytes() - p.q8_block_bytes()),
                "saved-bytes gauge must reconcile with the int8 population"
            );
            for id in held.drain(..) {
                p.release_ref(id);
            }
            for id in cold.drain(..) {
                p.release_ref(id); // drops the slab entry → swap_dropped
            }
            p.check_invariants()?;
            Ok(())
        });
    }
}
