//! The shared KV block pool: demand-paged context memory for every agent.
//!
//! The seed architecture gave each agent a full-capacity flat `[L, C, KV, hd]`
//! buffer, so resident bytes scaled with *configured* capacity rather than
//! *actual* fill.  `KvPool` replaces that with virtual-memory-style paging:
//! one shared slab of fixed-size blocks (`block_tokens` positions × all
//! layers, K+V), a free-list allocator, and per-cache block tables
//! ([`super::kv::KvCache`]).  Caches rent blocks as they grow and return
//! them when truncated, cleared or dropped, so
//!
//! * an idle or short-context agent costs a handful of blocks, not `C` rows;
//! * blocks released by finished side agents are immediately reused by new
//!   ones (the Table-2 "high-water < sum of capacities" property);
//! * the pool's gauges (blocks live / free / high-water, fragmentation) are
//!   the measured side of the paper's O(N·k) context-memory claim.
//!
//! Invariant: a rented block is exclusively owned by one cache, and readers
//! only ever observe rows `< len` of a cache — recycled blocks may therefore
//! carry stale floats beyond the fill without being re-zeroed (the decode
//! programs mask attention past `cache_len`, and every host-side gather
//! copies only the valid prefix).
//!
//! # Device residency
//!
//! Since the device-resident refactor, each block also owns a **lazily
//! materialised device copy** in the pool's *device slab*, addressed by the
//! block's stable `id` and recycled with the block through the free list.
//! Every host write ([`KvCache::append_rows`], `replace_rows`, `load_full`,
//! synapse `seed_into`) writes **only the touched rows** through to the
//! device copy, so the per-decode-step host→device traffic is
//! `O(new row + block table)` instead of the seed's `O(capacity)` full-cache
//! re-upload.  Decode-time K/V then comes from
//! [`KvPool::dev_gather_prefix`] — the paged-attention gather over resident
//! blocks (reference semantics in
//! [`crate::runtime::xla_stub::paged_gather_prefix`]); only the block table
//! itself counts as upload bytes.  On this offline substrate the slab's
//! buffers are host memory standing in for PJRT device buffers with
//! identical layout and life-cycle; the `h2d_bytes` gauge measures the
//! traffic a real backend would pay, and the O(k)-per-step property is
//! asserted by `benches/decode_upload.rs`.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{anyhow, bail, Result};

use super::kv::KvCache;
use crate::cortex::memory::MemGuard;
use crate::runtime::xla_stub;
use crate::runtime::ModelConfig;

/// Pool sizing + reclaim knobs (surfaced on [`crate::cortex::CortexConfig`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvPoolConfig {
    /// Positions per block (the paging granularity).
    pub block_tokens: usize,
    /// Hard cap on simultaneously rented blocks; `0` = unbounded.  When the
    /// cap is hit, cache growth fails with a pool-exhaustion error — the
    /// backpressure signal schedulers act on.
    pub max_blocks: usize,
    /// Reclaim policy: how many released blocks the free list may retain for
    /// reuse before further releases return their memory to the allocator.
    pub retain_free_blocks: usize,
}

impl Default for KvPoolConfig {
    fn default() -> Self {
        KvPoolConfig {
            block_tokens: 16,
            max_blocks: 0,
            retain_free_blocks: usize::MAX,
        }
    }
}

/// One fixed-size block: `block_tokens` positions × all layers, K and V.
/// Each buffer is `[L, block_tokens, KV*hd]`, row-major.  `id` is the
/// block's stable slot in the pool's device slab — it survives the free
/// list (so the device copy is recycled with the block) and is only
/// returned when the block's memory goes back to the allocator.
#[derive(Debug)]
pub struct KvBlock {
    pub(crate) id: u32,
    pub(crate) k: Box<[f32]>,
    pub(crate) v: Box<[f32]>,
}

#[derive(Debug, Default)]
struct PoolState {
    free: Vec<KvBlock>,
    live: usize,
    high_water: usize,
}

/// One block's device-resident K/V copy.  Same `[L, block_tokens, KV*hd]`
/// layout as the host buffers; on a real PJRT backend these would be
/// `PjRtBuffer`s owned by the device thread.
#[derive(Debug)]
struct DevBuf {
    k: Box<[f32]>,
    v: Box<[f32]>,
}

/// The device slab: block id → resident device buffer.
#[derive(Debug, Default)]
struct DevSlab {
    /// `None` until the block's first write-through materialises the copy.
    slots: Vec<Option<DevBuf>>,
    /// Ids of fully-dropped blocks, recycled by future rents.
    free_ids: Vec<u32>,
    /// Bytes held by materialised device buffers.
    bytes: u64,
    /// Accounting hook ([`crate::cortex::memory::MemKind::DeviceKv`]):
    /// resized on every materialisation and release.
    guard: Option<MemGuard>,
}

impl DevSlab {
    fn sync_guard(&mut self) {
        let bytes = self.bytes;
        if let Some(g) = self.guard.as_mut() {
            g.resize(bytes);
        }
    }
}

/// A device-addressable view of one cache: its block table plus the valid
/// length.  This — not multi-megabyte K/V vectors — is what a paged decode
/// request ships across threads and (conceptually) to the device.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PagedKv {
    /// Device-slab ids of the blocks covering positions `[0, len)`.
    pub table: Vec<u32>,
    /// Valid rows (`cache_len` of the decode program).
    pub len: usize,
}

impl PagedKv {
    /// Host→device bytes one decode step pays for this view: the i32 block
    /// table plus the length scalar — the O(k) figure the upload bench
    /// asserts against.
    pub fn upload_bytes(&self) -> u64 {
        PagedKv::upload_bytes_for(self.table.len())
    }

    /// Single home of the per-step table-upload formula; the gather path's
    /// `h2d_bytes` charge and the bench assertions both pin to it.
    pub(crate) fn upload_bytes_for(table_len: usize) -> u64 {
        (table_len * 4 + 8) as u64
    }
}

/// Live gauges of one pool (the `/stats` and Table-2 reporting unit).
#[derive(Debug, Clone, Default)]
pub struct PoolStats {
    pub block_tokens: usize,
    /// Bytes of one block (K + V, all layers).
    pub block_bytes: u64,
    /// Blocks currently rented by caches.
    pub blocks_live: usize,
    /// Released blocks held for reuse.
    pub blocks_free: usize,
    /// Peak simultaneously-rented blocks.
    pub blocks_high_water: usize,
    /// Total rents (fresh allocations + reuses).
    pub rents: u64,
    /// Rents served from the free list instead of a fresh allocation.
    pub reuses: u64,
    pub releases: u64,
    /// Filled positions across all live caches.
    pub rows_live: u64,
    /// Blocks with a materialised device-resident copy.
    pub dev_blocks: usize,
    /// Bytes held by device-resident block copies.
    pub dev_bytes: u64,
    /// Cumulative host→device traffic: row write-throughs + block tables.
    /// The decode-upload bench asserts the per-step delta is O(k).
    pub h2d_bytes: u64,
    /// Device-side paged gathers served (decode steps that shipped a block
    /// table instead of the cache).
    pub dev_gathers: u64,
}

impl PoolStats {
    /// Bytes held by rented blocks (the resident-context figure).
    pub fn live_bytes(&self) -> u64 {
        self.blocks_live as u64 * self.block_bytes
    }

    /// Bytes held by the pool overall (rented + retained free blocks).
    pub fn resident_bytes(&self) -> u64 {
        (self.blocks_live + self.blocks_free) as u64 * self.block_bytes
    }

    pub fn high_water_bytes(&self) -> u64 {
        self.blocks_high_water as u64 * self.block_bytes
    }

    /// Internal fragmentation: the fraction of rented positions that hold no
    /// row yet (allocated-but-unfilled block tails).
    pub fn fragmentation(&self) -> f64 {
        let cap = (self.blocks_live * self.block_tokens) as f64;
        if cap <= 0.0 {
            0.0
        } else {
            (1.0 - self.rows_live as f64 / cap).max(0.0)
        }
    }
}

/// The shared block allocator.  Exactly one per [`super::Engine`] — every
/// cache the engine or the orchestrator hands out rents from it, so the
/// capacity cap and the occupancy gauges cover the whole system.  The
/// paging granularity (`block_tokens`) is fixed at construction; the
/// limits (`max_blocks`, `retain_free_blocks`) are runtime-adjustable via
/// [`KvPool::set_limits`] so [`crate::cortex::WarpCortex`] can apply its
/// config knobs to an already-built engine's pool.
pub struct KvPool {
    block_tokens: usize,
    max_blocks: AtomicUsize,
    retain_free_blocks: AtomicUsize,
    n_layers: usize,
    kv_heads: usize,
    head_dim: usize,
    state: Mutex<PoolState>,
    /// Device-resident block copies.  RwLock so concurrent decode gathers
    /// (read-only, and they hold the lock for the full lane memcpy) never
    /// serialize against each other.  Row write-throughs and slot
    /// materialisation/release take the write side, so a write-through DOES
    /// serialize against in-flight gathers (and other writes) pool-wide —
    /// acceptable because a write is one row while a gather is O(c) rows;
    /// per-slot locking (ids are stable, owners are exclusive) is the
    /// follow-up if contention shows up at high agent counts.  Lock order:
    /// `state` before `dev` (never both unless in that order).
    dev: RwLock<DevSlab>,
    rents: AtomicU64,
    reuses: AtomicU64,
    releases: AtomicU64,
    rows_live: AtomicU64,
    h2d_bytes: AtomicU64,
    dev_gathers: AtomicU64,
}

impl std::fmt::Debug for KvPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("KvPool")
            .field("block_tokens", &s.block_tokens)
            .field("blocks_live", &s.blocks_live)
            .field("blocks_free", &s.blocks_free)
            .field("blocks_high_water", &s.blocks_high_water)
            .finish()
    }
}

impl KvPool {
    pub fn new(model: &ModelConfig, cfg: KvPoolConfig) -> Arc<KvPool> {
        assert!(cfg.block_tokens > 0, "block_tokens must be positive");
        Arc::new(KvPool {
            block_tokens: cfg.block_tokens,
            max_blocks: AtomicUsize::new(cfg.max_blocks),
            retain_free_blocks: AtomicUsize::new(cfg.retain_free_blocks),
            n_layers: model.n_layers,
            kv_heads: model.n_kv_heads,
            head_dim: model.head_dim,
            state: Mutex::new(PoolState::default()),
            dev: RwLock::new(DevSlab::default()),
            rents: AtomicU64::new(0),
            reuses: AtomicU64::new(0),
            releases: AtomicU64::new(0),
            rows_live: AtomicU64::new(0),
            h2d_bytes: AtomicU64::new(0),
            dev_gathers: AtomicU64::new(0),
        })
    }

    pub fn config(&self) -> KvPoolConfig {
        KvPoolConfig {
            block_tokens: self.block_tokens,
            max_blocks: self.max_blocks.load(Ordering::Relaxed),
            retain_free_blocks: self.retain_free_blocks.load(Ordering::Relaxed),
        }
    }

    /// Adjust the runtime limits (capacity cap + reclaim policy).  The
    /// paging granularity is fixed at construction — changing it would
    /// invalidate every live block table.
    pub fn set_limits(&self, max_blocks: usize, retain_free_blocks: usize) {
        self.max_blocks.store(max_blocks, Ordering::Relaxed);
        self.retain_free_blocks
            .store(retain_free_blocks, Ordering::Relaxed);
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub(crate) fn n_layers(&self) -> usize {
        self.n_layers
    }

    pub(crate) fn kv_heads(&self) -> usize {
        self.kv_heads
    }

    pub(crate) fn head_dim(&self) -> usize {
        self.head_dim
    }

    /// Floats per (layer, position): `KV * hd`.
    pub(crate) fn row(&self) -> usize {
        self.kv_heads * self.head_dim
    }

    /// Floats in one block buffer (K or V alone).
    pub(crate) fn block_floats(&self) -> usize {
        self.n_layers * self.block_tokens * self.row()
    }

    /// Bytes of one block, K + V.
    pub fn block_bytes(&self) -> u64 {
        (self.block_floats() * 2 * 4) as u64
    }

    /// Blocks needed to hold `rows` positions (round up; 0 rows → 0 blocks).
    /// (Spelled out instead of `div_ceil` to keep the MSRV permissive.)
    #[allow(clippy::manual_div_ceil)]
    pub fn blocks_for(&self, rows: usize) -> usize {
        (rows + self.block_tokens - 1) / self.block_tokens
    }

    /// Rent one block: reuse a freed block if available, otherwise allocate
    /// a fresh zeroed one.  Fails when the pool is at `max_blocks` — the
    /// caller surfaces this as cache-growth backpressure.
    pub(crate) fn rent_block(&self) -> Result<KvBlock> {
        let mut st = self.state.lock().unwrap();
        // The cap binds on LIVE blocks, so it must be checked before the
        // free list too — parked free blocks don't grant cap headroom.
        let max_blocks = self.max_blocks.load(Ordering::Relaxed);
        if max_blocks > 0 && st.live >= max_blocks {
            bail!(
                "kv pool exhausted: {} blocks live (max {max_blocks}, block_tokens {})",
                st.live,
                self.block_tokens
            );
        }
        if let Some(b) = st.free.pop() {
            st.live += 1;
            st.high_water = st.high_water.max(st.live);
            drop(st);
            self.rents.fetch_add(1, Ordering::Relaxed);
            self.reuses.fetch_add(1, Ordering::Relaxed);
            // The block keeps its id: its device copy (if materialised) is
            // recycled with it — stale contents past the new fill are fine,
            // every reader masks by `cache_len`.
            return Ok(b);
        }
        st.live += 1;
        st.high_water = st.high_water.max(st.live);
        drop(st);
        self.rents.fetch_add(1, Ordering::Relaxed);
        let id = self.reserve_dev_id();
        let n = self.block_floats();
        Ok(KvBlock {
            id,
            k: vec![0.0; n].into_boxed_slice(),
            v: vec![0.0; n].into_boxed_slice(),
        })
    }

    /// Reserve a device-slab slot for a freshly allocated block.  The
    /// buffer itself is materialised lazily on the first write-through.
    fn reserve_dev_id(&self) -> u32 {
        let mut dev = self.dev.write().unwrap();
        if let Some(id) = dev.free_ids.pop() {
            debug_assert!(dev.slots[id as usize].is_none());
            id
        } else {
            dev.slots.push(None);
            (dev.slots.len() - 1) as u32
        }
    }

    /// Return a block.  Retained on the free list up to
    /// `retain_free_blocks`; past that the block's memory goes back to the
    /// allocator (the reclaim policy) and its device copy is freed with it.
    pub(crate) fn release_block(&self, block: KvBlock) {
        self.releases.fetch_add(1, Ordering::Relaxed);
        let mut st = self.state.lock().unwrap();
        st.live = st.live.saturating_sub(1);
        if st.free.len() < self.retain_free_blocks.load(Ordering::Relaxed) {
            st.free.push(block);
            return;
        }
        drop(st);
        let mut dev = self.dev.write().unwrap();
        if dev
            .slots
            .get_mut(block.id as usize)
            .and_then(|s| s.take())
            .is_some()
        {
            dev.bytes -= self.block_bytes();
            dev.sync_guard();
        }
        dev.free_ids.push(block.id);
    }

    /// Write rows `[off, off+n)` of `block` through to its device-resident
    /// copy, materialising the device buffer on first touch.  This is the
    /// incremental path — one row per decode step, a handful per seed — and
    /// the copied bytes are the only per-row host→device traffic the system
    /// pays (contrast with the seed's full-prefix re-upload every step).
    pub(crate) fn dev_sync_rows(&self, block: &KvBlock, off: usize, n: usize) {
        if n == 0 {
            return;
        }
        let row = self.row();
        let bt = self.block_tokens;
        debug_assert!(off + n <= bt);
        let mut dev = self.dev.write().unwrap();
        let idx = block.id as usize;
        if dev.slots[idx].is_none() {
            let floats = self.block_floats();
            dev.slots[idx] = Some(DevBuf {
                k: vec![0.0; floats].into_boxed_slice(),
                v: vec![0.0; floats].into_boxed_slice(),
            });
            dev.bytes += self.block_bytes();
            dev.sync_guard();
        }
        let buf = dev.slots[idx].as_mut().expect("slot just materialised");
        // Host and device copies share the `[L, bt, row]` layout, so the
        // offsets coincide.
        for layer in 0..self.n_layers {
            let o = (layer * bt + off) * row;
            buf.k[o..o + n * row].copy_from_slice(&block.k[o..o + n * row]);
            buf.v[o..o + n * row].copy_from_slice(&block.v[o..o + n * row]);
        }
        drop(dev);
        self.h2d_bytes
            .fetch_add((self.n_layers * n * row * 2 * 4) as u64, Ordering::Relaxed);
    }

    /// Device-side paged gather: contiguous `[L, c, KV, hd]` K and V for
    /// the first `len` positions addressed by `table`, read from the
    /// resident block copies.  Ships only the table (counted as the step's
    /// upload cost) — never the cache contents.
    ///
    /// Fails if a needed block has no device copy, which can only mean the
    /// table addresses a different pool or rows that were never written.
    pub fn dev_gather_prefix(
        &self,
        table: &[u32],
        len: usize,
        c: usize,
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let sz = self.n_layers * c * self.row();
        let mut k = vec![0.0f32; sz];
        let mut v = vec![0.0f32; sz];
        self.dev_gather_prefix_into(table, len, c, &mut k, &mut v)?;
        Ok((k, v))
    }

    /// Allocation-free variant of [`KvPool::dev_gather_prefix`]: gathers
    /// into caller-provided zeroed `[L, c, KV, hd]` buffers (the batcher's
    /// per-lane slabs).
    pub fn dev_gather_prefix_into(
        &self,
        table: &[u32],
        len: usize,
        c: usize,
        k_out: &mut [f32],
        v_out: &mut [f32],
    ) -> Result<()> {
        let row = self.row();
        debug_assert_eq!(k_out.len(), self.n_layers * c * row);
        debug_assert_eq!(v_out.len(), self.n_layers * c * row);
        let need = self.blocks_for(len.min(c));
        if table.len() < need {
            bail!(
                "paged gather: table has {} blocks, {need} needed for len {len}",
                table.len()
            );
        }
        {
            let dev = self.dev.read().unwrap();
            let mut k_blocks: Vec<&[f32]> = Vec::with_capacity(need);
            let mut v_blocks: Vec<&[f32]> = Vec::with_capacity(need);
            for &id in &table[..need] {
                let slot = dev
                    .slots
                    .get(id as usize)
                    .and_then(|s| s.as_ref())
                    .ok_or_else(|| {
                        anyhow!("paged gather: block {id} has no device-resident copy")
                    })?;
                k_blocks.push(&slot.k[..]);
                v_blocks.push(&slot.v[..]);
            }
            xla_stub::paged_gather_prefix(
                &k_blocks,
                self.n_layers,
                self.block_tokens,
                row,
                len,
                c,
                k_out,
            );
            xla_stub::paged_gather_prefix(
                &v_blocks,
                self.n_layers,
                self.block_tokens,
                row,
                len,
                c,
                v_out,
            );
        }
        // Per-step upload: the i32 table + the length scalar.
        self.h2d_bytes
            .fetch_add(PagedKv::upload_bytes_for(table.len()), Ordering::Relaxed);
        self.dev_gathers.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Attach the device-memory accounting guard
    /// ([`crate::cortex::memory::MemKind::DeviceKv`]); from here on every
    /// device-buffer materialisation and release resizes it.  Replaces (and
    /// thereby releases) any previously attached guard.
    pub fn track_device(&self, mut guard: MemGuard) {
        let mut dev = self.dev.write().unwrap();
        guard.resize(dev.bytes);
        dev.guard = Some(guard);
    }

    /// Bytes currently held by device-resident block copies.
    pub fn dev_bytes(&self) -> u64 {
        self.dev.read().unwrap().bytes
    }

    pub(crate) fn note_rows_added(&self, n: usize) {
        self.rows_live.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub(crate) fn note_rows_removed(&self, n: usize) {
        // Saturating: a miscounted release must not wrap the gauge.
        let _ = self
            .rows_live
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |cur| {
                Some(cur.saturating_sub(n as u64))
            });
    }

    /// A fresh pool-backed cache able to hold up to `capacity` rows.
    pub fn new_cache(self: &Arc<Self>, capacity: usize) -> KvCache {
        KvCache::with_pool(self.clone(), capacity)
    }

    pub fn stats(&self) -> PoolStats {
        let (blocks_live, blocks_free, blocks_high_water) = {
            let st = self.state.lock().unwrap();
            (st.live, st.free.len(), st.high_water)
        };
        let (dev_blocks, dev_bytes) = {
            let dev = self.dev.read().unwrap();
            (dev.slots.iter().filter(|s| s.is_some()).count(), dev.bytes)
        };
        PoolStats {
            block_tokens: self.block_tokens,
            block_bytes: self.block_bytes(),
            blocks_live,
            blocks_free,
            blocks_high_water,
            rents: self.rents.load(Ordering::Relaxed),
            reuses: self.reuses.load(Ordering::Relaxed),
            releases: self.releases.load(Ordering::Relaxed),
            rows_live: self.rows_live.load(Ordering::Relaxed),
            dev_blocks,
            dev_bytes,
            h2d_bytes: self.h2d_bytes.load(Ordering::Relaxed),
            dev_gathers: self.dev_gathers.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 192,
            vocab_size: 260,
            head_dim: 16,
            rope_theta: 1e4,
            param_count: 0,
        }
    }

    fn pool(block_tokens: usize, max_blocks: usize) -> Arc<KvPool> {
        KvPool::new(
            &tiny_cfg(),
            KvPoolConfig {
                block_tokens,
                max_blocks,
                retain_free_blocks: usize::MAX,
            },
        )
    }

    #[test]
    fn rent_release_reuse_round_trip() {
        let p = pool(4, 0);
        assert_eq!(p.block_bytes(), (2 * 4 * 32 * 2 * 4) as u64);

        let a = p.rent_block().unwrap();
        let b = p.rent_block().unwrap();
        let s = p.stats();
        assert_eq!(s.blocks_live, 2);
        assert_eq!(s.blocks_free, 0);
        assert_eq!(s.blocks_high_water, 2);
        assert_eq!(s.reuses, 0);

        p.release_block(a);
        p.release_block(b);
        let s = p.stats();
        assert_eq!(s.blocks_live, 0);
        assert_eq!(s.blocks_free, 2);

        // the next rents come from the free list, not fresh allocations
        let _c = p.rent_block().unwrap();
        let _d = p.rent_block().unwrap();
        let s = p.stats();
        assert_eq!(s.reuses, 2);
        assert_eq!(s.blocks_live, 2);
        assert_eq!(s.blocks_free, 0);
        assert_eq!(s.blocks_high_water, 2, "reuse must not raise the peak");
    }

    #[test]
    fn exhaustion_backpressure() {
        let p = pool(4, 2);
        let a = p.rent_block().unwrap();
        let _b = p.rent_block().unwrap();
        let err = p.rent_block().unwrap_err();
        assert!(format!("{err:#}").contains("exhausted"));
        // releasing frees capacity again
        p.release_block(a);
        assert!(p.rent_block().is_ok());
    }

    #[test]
    fn set_limits_applies_at_runtime() {
        // The orchestrator adopts an engine's pool and applies its knobs
        // after construction — the cap must bind immediately.
        let p = pool(4, 0);
        let _a = p.rent_block().unwrap();
        p.set_limits(1, usize::MAX);
        assert!(p.rent_block().is_err(), "cap of 1 with 1 live must refuse");
        assert_eq!(p.config().max_blocks, 1);
        p.set_limits(0, usize::MAX);
        assert!(p.rent_block().is_ok(), "lifting the cap unblocks growth");
    }

    #[test]
    fn cap_binds_even_when_free_blocks_are_parked() {
        // A retained free list must not grant headroom past max_blocks:
        // the cap is on LIVE blocks.
        let p = pool(4, 0);
        let blocks: Vec<_> = (0..5).map(|_| p.rent_block().unwrap()).collect();
        for b in blocks {
            p.release_block(b);
        }
        assert_eq!(p.stats().blocks_free, 5);
        p.set_limits(2, usize::MAX);
        let _a = p.rent_block().unwrap();
        let _b = p.rent_block().unwrap();
        let err = p.rent_block().unwrap_err();
        assert!(
            format!("{err:#}").contains("exhausted"),
            "free-list rent bypassed the cap"
        );
    }

    #[test]
    fn reclaim_policy_caps_free_list() {
        let p = KvPool::new(
            &tiny_cfg(),
            KvPoolConfig {
                block_tokens: 4,
                max_blocks: 0,
                retain_free_blocks: 1,
            },
        );
        let a = p.rent_block().unwrap();
        let b = p.rent_block().unwrap();
        let c = p.rent_block().unwrap();
        p.release_block(a);
        p.release_block(b);
        p.release_block(c);
        let s = p.stats();
        assert_eq!(s.blocks_free, 1, "free list capped by retain_free_blocks");
        assert_eq!(s.blocks_live, 0);
    }

    #[test]
    fn blocks_for_rounds_up() {
        let p = pool(16, 0);
        assert_eq!(p.blocks_for(0), 0);
        assert_eq!(p.blocks_for(1), 1);
        assert_eq!(p.blocks_for(16), 1);
        assert_eq!(p.blocks_for(17), 2);
    }

    #[test]
    fn fragmentation_gauge() {
        let p = pool(8, 0);
        let _b = p.rent_block().unwrap();
        p.note_rows_added(6);
        let s = p.stats();
        assert_eq!(s.rows_live, 6);
        assert!((s.fragmentation() - 0.25).abs() < 1e-9, "{}", s.fragmentation());
        p.note_rows_removed(6);
        assert_eq!(p.stats().rows_live, 0);
    }

    #[test]
    fn random_rent_release_sequences_reuse_without_growth() {
        // Fragmentation-free reuse: after any interleaving of rents and
        // releases, demand that never exceeds a prior peak is served
        // entirely from the free list — the high-water mark stays put.
        check("pool reuse under churn", 50, |g| {
            let p = pool(4, 0);
            let mut held = Vec::new();
            let mut peak = 0usize;
            // phase 1: random churn
            for _ in 0..g.usize_in(10..60) {
                if g.bool() || held.is_empty() {
                    held.push(p.rent_block().map_err(|e| e.to_string())?);
                    peak = peak.max(held.len());
                } else {
                    let i = g.usize_in(0..held.len());
                    p.release_block(held.swap_remove(i));
                }
            }
            let hw = p.stats().blocks_high_water;
            crate::prop_assert!(hw == peak, "high-water {hw} != observed peak {peak}");
            // phase 2: drop everything, then re-rent up to the peak
            for b in held.drain(..) {
                p.release_block(b);
            }
            let before = p.stats();
            crate::prop_assert!(
                before.blocks_free == peak,
                "free list {} != peak {peak}",
                before.blocks_free
            );
            for _ in 0..peak {
                held.push(p.rent_block().map_err(|e| e.to_string())?);
            }
            let after = p.stats();
            crate::prop_assert!(
                after.blocks_high_water == peak,
                "re-renting to the old peak grew the pool: {} > {peak}",
                after.blocks_high_water
            );
            crate::prop_assert!(
                after.reuses - before.reuses >= peak as u64,
                "expected {} reuses, got {}",
                peak,
                after.reuses - before.reuses
            );
            for b in held.drain(..) {
                p.release_block(b);
            }
            Ok(())
        });
    }

    #[test]
    fn device_copies_materialise_lazily_and_recycle_with_blocks() {
        let p = pool(4, 0);
        let b0 = p.rent_block().unwrap();
        let b1 = p.rent_block().unwrap();
        assert_ne!(b0.id, b1.id, "slab slots must be distinct");
        let s = p.stats();
        assert_eq!(s.dev_blocks, 0, "no write-through yet → no device copy");
        assert_eq!(s.dev_bytes, 0);
        assert_eq!(s.h2d_bytes, 0);

        // First write-through materialises the copy and counts the rows.
        p.dev_sync_rows(&b0, 0, 2);
        let s = p.stats();
        assert_eq!(s.dev_blocks, 1);
        assert_eq!(s.dev_bytes, p.block_bytes());
        // 2 rows × L(2) × row(32 floats) × K+V × 4 bytes
        assert_eq!(s.h2d_bytes, (2 * 2 * 32 * 2 * 4) as u64);

        // A free-listed block keeps its device copy (recycled, not freed).
        let id0 = b0.id;
        p.release_block(b0);
        p.release_block(b1);
        assert_eq!(p.stats().dev_blocks, 1);
        let b = p.rent_block().unwrap();
        let b2 = p.rent_block().unwrap();
        assert!(b.id == id0 || b2.id == id0, "free-listed id must recycle");
        assert_eq!(p.stats().dev_blocks, 1);
        p.release_block(b);
        p.release_block(b2);
    }

    #[test]
    fn allocator_return_frees_the_device_copy_and_recycles_the_id() {
        let p = KvPool::new(
            &tiny_cfg(),
            KvPoolConfig {
                block_tokens: 4,
                max_blocks: 0,
                retain_free_blocks: 0, // every release returns to allocator
            },
        );
        let b = p.rent_block().unwrap();
        let id = b.id;
        p.dev_sync_rows(&b, 0, 1);
        assert_eq!(p.stats().dev_bytes, p.block_bytes());
        p.release_block(b);
        let s = p.stats();
        assert_eq!(s.dev_blocks, 0, "allocator return must free the copy");
        assert_eq!(s.dev_bytes, 0);
        // the id comes back for the next fresh block
        let b = p.rent_block().unwrap();
        assert_eq!(b.id, id);
        p.release_block(b);
    }

    #[test]
    fn gather_requires_resident_copies_and_counts_table_upload() {
        let p = pool(4, 0);
        let b = p.rent_block().unwrap();
        // no write-through yet → gather over real rows must refuse
        let err = p.dev_gather_prefix(&[b.id], 2, 4).unwrap_err();
        assert!(format!("{err:#}").contains("no device-resident copy"));
        // an empty view gathers fine (nothing to read) but still ships the
        // (empty) table + len scalar
        let before = p.stats().h2d_bytes;
        let (k, v) = p.dev_gather_prefix(&[], 0, 4).unwrap();
        assert_eq!(k.len(), 2 * 4 * 32);
        assert!(k.iter().chain(v.iter()).all(|&x| x == 0.0));
        let s = p.stats();
        assert_eq!(s.h2d_bytes - before, 8);
        assert_eq!(s.dev_gathers, 1);
        p.release_block(b);
    }

    #[test]
    fn device_guard_tracks_slab_bytes() {
        use crate::cortex::memory::{MemKind, MemoryTracker};
        let t = MemoryTracker::new();
        let p = pool(4, 0);
        let b = p.rent_block().unwrap();
        p.dev_sync_rows(&b, 0, 1);
        // attaching after the fact syncs to the current slab size
        p.track_device(t.alloc(MemKind::DeviceKv, 0));
        assert_eq!(t.live_bytes(MemKind::DeviceKv) as u64, p.block_bytes());
        let b2 = p.rent_block().unwrap();
        p.dev_sync_rows(&b2, 1, 3);
        assert_eq!(t.live_bytes(MemKind::DeviceKv) as u64, 2 * p.block_bytes());
        // reclaim-to-allocator shrinks the charge
        p.set_limits(0, 0);
        p.release_block(b);
        p.release_block(b2);
        assert_eq!(t.live_bytes(MemKind::DeviceKv), 0);
    }
}
