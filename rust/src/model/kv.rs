//! Host-side KV caches as *views into the shared block pool*.
//!
//! A `KvCache` no longer owns flat `[L, C, KV, hd]` buffers: it holds a
//! block table into a [`KvPool`](super::pool::KvPool) and grows on append,
//! one fixed-size block at a time.  `capacity` bounds how far the view may
//! grow (it matches the compiled program's cache dimension), but resident
//! bytes track the *fill*, not the capacity — the Table-2 unit is now
//! `blocks × block_bytes`, kept live-synced with the cortex
//! [`MemoryTracker`](crate::cortex::memory::MemoryTracker) through an
//! attached [`MemGuard`].
//!
//! # Shared prefixes and copy-on-write
//!
//! Since the prefix-sharing refactor a table entry may reference a *shared*
//! block: [`KvCache::register_prefix`] publishes a cache's full blocks in
//! the pool's content-addressed registry, and
//! [`KvCache::attach_shared_prefix`] lets a later cache adopt the longest
//! registered prefix by reference — N agents spawned from one prompt hold
//! the same physical blocks.  All writes funnel through the pool's CoW gate
//! (`KvPool::write_run`): a write that lands in a shared block first
//! copies it into a private one and swaps the table entry, so divergence
//! after sharing is bit-identical to never having shared (proven by the
//! proptest below).  Accounting follows ownership: [`KvCache::bytes`]
//! counts only this cache's *private* blocks — registry-shared blocks are
//! charged once globally (`MemKind::SharedKv`), never once per referencing
//! cache.
//!
//! Every write additionally goes through to the block's device copy
//! **incrementally** (the touched rows, not the prefix), so decode steps
//! never re-upload the cache: they ship a [`PagedKv`] — block table +
//! length — and the device gathers K/V from its resident copies
//! ([`KvCache::device_gather`], bit-identical to the host-side
//! [`KvCache::prefix_upload`] reference).  The host gather paths remain for
//! prefill outputs, the synapse ablations and as the flat reference; both
//! zero-fill positions past `len` — numerically transparent because every
//! compiled program masks attention beyond `cache_len`.
//!
//! # Memory tiers
//!
//! Since the tiered-KV refactor a table entry also carries the block's
//! current *tier*.  Private blocks of a parked session can spill their
//! payload to the pool's cold host slab ([`KvCache::park_to_host`]) and
//! page back in on resume ([`KvCache::resume_from_host`]) — a lossless,
//! bit-identical round trip (the offload tier stores the exact fp32
//! bytes).  Registered prefix blocks instead demote *in place* to the warm
//! int8 tier when they park (pool-level, `quantize_parked`); their reads
//! dequantize transparently and a write CoW-promotes a full-precision
//! private copy.  [`KvCache::bytes`] counts only hot private blocks: warm
//! registry blocks stay on the global `SharedKv` charge at their reduced
//! size, and offloaded payloads are charged once under `HostKv` — every
//! physical byte counted exactly once, in its tier.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::pool::{KvPool, KvPoolConfig, PagedKv};
use crate::cortex::memory::MemGuard;
use crate::runtime::ModelConfig;

/// One block-table entry: the pool block id, whether this cache holds it
/// *by reference* from the prefix registry (`shared`) or owns it
/// privately, and which memory tier the payload currently sits in.
/// Shared entries are excluded from this cache's byte charge (the pool
/// charges them once globally) and are immutable — writes CoW.
#[derive(Debug, Clone, Copy)]
struct BlockRef {
    id: u32,
    shared: bool,
    /// Cold tier: [`KvCache::park_to_host`] spilled this private block's
    /// payload to the pool's host slab.  Offloaded entries are excluded
    /// from the cache's resident byte charge (the pool charges them once
    /// under `MemKind::HostKv`) and refuse device gathers until
    /// [`KvCache::resume_from_host`] — a write through the pool's CoW
    /// gate pages the block back in transparently instead.
    offloaded: bool,
}

/// A bounded, pool-backed KV cache for one agent.
pub struct KvCache {
    pool: Arc<KvPool>,
    /// Block table: block `i` holds positions `[i*bt, (i+1)*bt)`.
    blocks: Vec<BlockRef>,
    capacity: usize,
    len: usize,
    /// Accounting hook: resized to this cache's *private* resident bytes on
    /// every rent/release/CoW, so the tracker measures fill rather than
    /// reservation and never double-counts shared blocks.
    mem: Option<MemGuard>,
}

impl std::fmt::Debug for KvCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KvCache")
            .field("len", &self.len)
            .field("capacity", &self.capacity)
            .field("blocks", &self.blocks.len())
            .field("shared_blocks", &self.shared_blocks())
            .field("block_tokens", &self.pool.block_tokens())
            .finish()
    }
}

impl KvCache {
    /// Standalone cache backed by a private pool (tests and host tools).
    /// Production caches come from a shared pool via [`KvPool::new_cache`].
    pub fn new(cfg: &ModelConfig, capacity: usize) -> KvCache {
        KvPool::new(cfg, KvPoolConfig::default()).new_cache(capacity)
    }

    pub(crate) fn with_pool(pool: Arc<KvPool>, capacity: usize) -> KvCache {
        KvCache {
            pool,
            blocks: Vec::new(),
            capacity,
            len: 0,
            mem: None,
        }
    }

    pub fn pool(&self) -> &Arc<KvPool> {
        &self.pool
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn remaining(&self) -> usize {
        self.capacity - self.len
    }

    /// Blocks this cache references out of the shared prefix registry
    /// (charged once globally, not to this cache).
    pub fn shared_blocks(&self) -> usize {
        self.blocks.iter().filter(|b| b.shared).count()
    }

    /// Length of the *leading* run of registry-shared blocks — the prefix
    /// a durable checkpoint stores by hash chain instead of by bytes
    /// (`cortex::store`): resume re-attaches exactly this many blocks via
    /// `attach_shared_prefix` and replays only the private tail rows.
    pub fn leading_shared_blocks(&self) -> usize {
        self.blocks.iter().take_while(|b| b.shared).count()
    }

    /// Resident bytes attributable to this cache: *private, resident*
    /// blocks × block bytes — the Table-2 unit.  Grows with fill, not with
    /// configured capacity, and excludes registry-shared blocks (charged
    /// once under `MemKind::SharedKv` however many caches reference them)
    /// as well as host-offloaded blocks (charged once under
    /// `MemKind::HostKv` while parked — host RAM, not VRAM).
    pub fn bytes(&self) -> u64 {
        self.blocks
            .iter()
            .filter(|b| !b.shared && !b.offloaded)
            .count() as u64
            * self.pool.block_bytes()
    }

    /// Blocks this cache currently parks in the pool's cold host slab.
    pub fn offloaded_blocks(&self) -> usize {
        self.blocks.iter().filter(|b| b.offloaded).count()
    }

    /// Park this cache's private blocks to the pool's cold host slab (the
    /// session-park path: a parked agent's context stops costing device
    /// bytes entirely).  Shared registry entries are skipped — they demote
    /// through the registry's own offload-under-pressure path and must
    /// stay addressable for other readers.  On a full slab the error
    /// surfaces after parking what fit; already-parked blocks stay parked
    /// (resume pages everything back regardless).  Returns the number of
    /// blocks newly offloaded.
    pub fn park_to_host(&mut self) -> Result<usize> {
        let mut parked = 0;
        let mut first_err = None;
        for b in self.blocks.iter_mut() {
            if b.shared || b.offloaded {
                continue;
            }
            match self.pool.offload_ref(b.id) {
                Ok(()) => {
                    b.offloaded = true;
                    parked += 1;
                }
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        self.sync_mem();
        match first_err {
            Some(e) => Err(e),
            None => Ok(parked),
        }
    }

    /// Page every host-offloaded block of this cache back to the hot tier
    /// (the session-resume path).  Paging in may itself demote other
    /// parked state to make room; if the device budget is exhausted the
    /// error surfaces with the blocks resumed so far staying resident.
    /// Returns the number of blocks paged in.
    pub fn resume_from_host(&mut self) -> Result<usize> {
        let mut resumed = 0;
        let mut first_err = None;
        for b in self.blocks.iter_mut() {
            if !b.offloaded {
                continue;
            }
            match self.pool.page_in_ref(b.id) {
                Ok(()) => {
                    b.offloaded = false;
                    resumed += 1;
                }
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        self.sync_mem();
        match first_err {
            Some(e) => Err(e),
            None => Ok(resumed),
        }
    }

    /// Bytes an eager flat `[L, C, KV, hd]` allocation of this capacity
    /// would hold — the pre-pool figure, kept for baseline comparisons.
    pub fn capacity_bytes(&self) -> u64 {
        (self.pool.n_layers() * self.capacity * self.row() * 2) as u64 * 4
    }

    /// Bytes actually occupied by the `len` filled rows.
    pub fn used_bytes(&self) -> u64 {
        (self.pool.n_layers() * self.len * self.row() * 2) as u64 * 4
    }

    /// Attach the memory-accounting guard; from here on every block rent
    /// and release resizes it to the private resident-block bytes.
    pub fn track(&mut self, mem: MemGuard) {
        self.mem = Some(mem);
        self.sync_mem();
    }

    fn sync_mem(&mut self) {
        let bytes = self.bytes();
        if let Some(g) = self.mem.as_mut() {
            g.resize(bytes);
        }
    }

    fn row(&self) -> usize {
        self.pool.row()
    }

    /// The raw id table (all rented blocks, valid or not).
    fn table_ids(&self) -> Vec<u32> {
        self.blocks.iter().map(|b| b.id).collect()
    }

    /// Rent blocks until `rows` positions fit.  On pool exhaustion the
    /// already-rented blocks are kept (the cache stays consistent) and the
    /// backpressure error bubbles up.
    fn ensure_blocks(&mut self, rows: usize) -> Result<()> {
        let need = self.pool.blocks_for(rows);
        while self.blocks.len() < need {
            match self.pool.rent_ref() {
                Ok(id) => self.blocks.push(BlockRef {
                    id,
                    shared: false,
                    offloaded: false,
                }),
                Err(e) => {
                    self.sync_mem();
                    return Err(e);
                }
            }
        }
        self.sync_mem();
        Ok(())
    }

    /// (block index, position offset within the block) for a cache position.
    fn locate(&self, pos: usize) -> (usize, usize) {
        let bt = self.pool.block_tokens();
        (pos / bt, pos % bt)
    }

    /// Copy `[L, n, KV, hd]` rows into positions `[base, base+n)` through
    /// the pool's CoW write gate: a run landing in a shared block swaps a
    /// private copy into this table.  Blocks covering those positions must
    /// already be rented or attached.
    fn write_rows(&mut self, base: usize, n: usize, k_rows: &[f32], v_rows: &[f32]) -> Result<()> {
        let bt = self.pool.block_tokens();
        let mut i = 0;
        while i < n {
            let (b, off) = self.locate(base + i);
            let run = (bt - off).min(n - i);
            let entry = self.blocks[b];
            let target = self
                .pool
                .write_run(entry.id, off, run, i, n, k_rows, v_rows)?;
            if target != entry.id {
                // Copy-on-write: this cache now privately owns the copy
                // (and is charged for it); the shared original keeps its
                // registry entry and its other readers.
                self.blocks[b] = BlockRef {
                    id: target,
                    shared: false,
                    offloaded: false,
                };
                self.sync_mem();
            } else if entry.offloaded {
                // The write gate paged a cold block back in (parked
                // sessions growing without an explicit resume); mirror the
                // promotion so the byte charge moves back to this cache.
                self.blocks[b].offloaded = false;
                self.sync_mem();
            }
            i += run;
        }
        Ok(())
    }

    /// Append one position's K/V rows.  `k_new`/`v_new` are `[L, KV, hd]`
    /// (identical to `[L, 1, KV, hd]`).
    pub fn append_row(&mut self, k_new: &[f32], v_new: &[f32]) -> Result<()> {
        self.append_rows(1, k_new, v_new)
    }

    /// Append `n` positions from `[L, n, KV, hd]` buffers (synapse loads,
    /// prefill copy-in, referential injection).
    pub fn append_rows(&mut self, n: usize, k_rows: &[f32], v_rows: &[f32]) -> Result<()> {
        if self.len + n > self.capacity {
            bail!("kv cache overflow: {} + {n} > {}", self.len, self.capacity);
        }
        let expect = self.pool.n_layers() * n * self.row();
        if k_rows.len() != expect || v_rows.len() != expect {
            bail!("append_rows: expected {expect} floats, got {}", k_rows.len());
        }
        self.ensure_blocks(self.len + n)?;
        self.write_rows(self.len, n, k_rows, v_rows)?;
        self.len += n;
        self.pool.note_rows_added(n);
        Ok(())
    }

    /// Replace the cache contents with `n` rows (`[L, n, KV, hd]`), renting
    /// any additional blocks BEFORE dropping the old rows — like
    /// [`KvCache::load_full`], pool-exhaustion backpressure during growth
    /// leaves the previous contents intact.  (A CoW rent *inside* the
    /// rewrite can still fail on an exhausted pool; the cache stays
    /// consistent but partially rewritten in that case.)
    pub fn replace_rows(&mut self, n: usize, k_rows: &[f32], v_rows: &[f32]) -> Result<()> {
        if n > self.capacity {
            bail!("replace_rows: {n} rows > capacity {}", self.capacity);
        }
        let expect = self.pool.n_layers() * n * self.row();
        if k_rows.len() != expect || v_rows.len() != expect {
            bail!("replace_rows: expected {expect} floats, got {}", k_rows.len());
        }
        let need = self.pool.blocks_for(n);
        if need > self.blocks.len() {
            self.ensure_blocks(n)?;
        }
        self.pool.note_rows_removed(self.len);
        self.len = 0;
        while self.blocks.len() > need {
            let b = self.blocks.pop().expect("block table shrank unexpectedly");
            self.pool.release_ref(b.id);
        }
        self.write_rows(0, n, k_rows, v_rows)?;
        self.len = n;
        self.pool.note_rows_added(n);
        self.sync_mem();
        Ok(())
    }

    /// [`KvCache::replace_rows`] with content keys: full blocks of the new
    /// contents are shared through the pool's prefix registry.  `keys` is
    /// one i32 per row (token ids, landmark indices, …) and `salt` is the
    /// caller's domain separator — identical `(salt, keys)` chains MUST
    /// imply identical row contents, that is the content-addressing
    /// contract.  Registered hits are attached by reference (zero copy,
    /// zero host→device traffic); misses are written privately and then
    /// published for the next caller.  The partial tail block stays
    /// private.
    ///
    /// Unlike `replace_rows`, the previous contents are dropped before the
    /// rewrite (the registry path requires an empty cache), so on a
    /// mid-rewrite pool-exhaustion error the cache is left consistent but
    /// holding only the rows written so far.
    pub fn replace_rows_keyed(
        &mut self,
        n: usize,
        salt: u64,
        keys: &[i32],
        k_rows: &[f32],
        v_rows: &[f32],
    ) -> Result<()> {
        if n > self.capacity {
            bail!("replace_rows_keyed: {n} rows > capacity {}", self.capacity);
        }
        if keys.len() != n {
            bail!("replace_rows_keyed: {} keys for {n} rows", keys.len());
        }
        let expect = self.pool.n_layers() * n * self.row();
        if k_rows.len() != expect || v_rows.len() != expect {
            bail!(
                "replace_rows_keyed: expected {expect} floats, got {}",
                k_rows.len()
            );
        }
        self.clear();
        let hashes = self.pool.prefix_hashes(salt, keys);
        let covered = self.attach_shared_prefix(&hashes, keys)?;
        if covered < n {
            let k_tail = self.rows_slice(n, k_rows, covered, n);
            let v_tail = self.rows_slice(n, v_rows, covered, n);
            self.append_rows(n - covered, &k_tail, &v_tail)?;
        }
        self.register_prefix(&hashes, keys);
        Ok(())
    }

    /// Rows `[start, end)` of a `[L, n_src, KV, hd]` buffer as a contiguous
    /// `[L, end-start, KV, hd]` copy.
    fn rows_slice(&self, n_src: usize, src: &[f32], start: usize, end: usize) -> Vec<f32> {
        let row = self.row();
        let n_layers = self.pool.n_layers();
        let mut out = Vec::with_capacity(n_layers * (end - start) * row);
        for layer in 0..n_layers {
            let base = (layer * n_src + start) * row;
            out.extend_from_slice(&src[base..base + (end - start) * row]);
        }
        out
    }

    /// On an empty cache, adopt the longest registered prefix of `hashes`
    /// by reference: hit blocks join this table as shared entries (the
    /// pool increfs them), `len` jumps to the covered rows, and no bytes
    /// move — host or device.  Returns the covered row count (0 = total
    /// miss).  Hashes come from [`KvPool::prefix_hashes`] over `keys`
    /// (which must cover every hashed block — the pool verifies each hit
    /// against the registered key run, so hash collisions miss instead of
    /// attaching foreign KV).
    pub fn attach_shared_prefix(&mut self, hashes: &[u64], keys: &[i32]) -> Result<usize> {
        if self.len != 0 || !self.blocks.is_empty() {
            bail!("attach_shared_prefix requires an empty cache");
        }
        let bt = self.pool.block_tokens();
        let take = hashes.len().min(self.capacity / bt);
        let ids = self.pool.lookup_chain(&hashes[..take], keys);
        let rows = ids.len() * bt;
        for id in ids {
            self.blocks.push(BlockRef {
                id,
                shared: true,
                offloaded: false,
            });
        }
        self.len = rows;
        if rows > 0 {
            self.pool.note_rows_added(rows);
        }
        self.sync_mem();
        Ok(rows)
    }

    /// Mid-prefill registry adoption: on a cache sitting exactly at a block
    /// boundary (every rented block full), adopt any *continuation* blocks
    /// of the chain that a concurrent identical prompt registered since
    /// this cache attached (or teacher-forced past) its prefix.  Adopted
    /// blocks join the table as shared references and `len` jumps over
    /// them — a chunked prefill skips recomputing rows a twin already
    /// published.  `hashes`/`keys` are the same full chain passed to
    /// [`KvCache::attach_shared_prefix`]; the chain hash at index `i`
    /// commits to the entire prefix `keys[..(i+1)*bt]`, so continuing the
    /// walk mid-chain is as safe as starting it (hit-time key-run
    /// verification still applies).  Off a clean block boundary there is
    /// nothing adoptable and this returns 0.  Returns the adopted row
    /// count.
    pub fn extend_shared_prefix(&mut self, hashes: &[u64], keys: &[i32]) -> usize {
        let bt = self.pool.block_tokens();
        if self.len % bt != 0 || self.blocks.len() != self.len / bt {
            return 0; // partial tail block: the chain cannot continue here
        }
        let done = self.len / bt;
        let take = hashes.len().min(self.capacity / bt);
        if done >= take {
            return 0;
        }
        let ids = self
            .pool
            .lookup_chain_mid(&hashes[done..take], &keys[done * bt..take * bt]);
        let rows = ids.len() * bt;
        for id in ids {
            self.blocks.push(BlockRef {
                id,
                shared: true,
                offloaded: false,
            });
        }
        if rows > 0 {
            self.len += rows;
            self.pool.note_rows_added(rows);
            self.sync_mem();
        }
        rows
    }

    /// Publish this cache's leading full blocks in the pool's prefix
    /// registry under `hashes` (one chain hash per full block, from
    /// [`KvPool::prefix_hashes`] over `keys`, which must cover every
    /// hashed block — each block's own key run is stored for hit-time
    /// verification).  Only fully-valid private blocks are registered;
    /// entries already shared, or whose hash another block owns, are
    /// skipped.  Registered blocks flip to shared: this cache stops being
    /// charged for them (they move to the global `SharedKv` charge) and
    /// its own later writes to them copy-on-write.
    pub fn register_prefix(&mut self, hashes: &[u64], keys: &[i32]) {
        let bt = self.pool.block_tokens();
        let full = self.len / bt;
        let mut changed = false;
        for (i, (entry, &hash)) in self.blocks.iter_mut().zip(hashes.iter()).enumerate().take(full)
        {
            if entry.shared {
                continue;
            }
            if self
                .pool
                .register_block(entry.id, hash, &keys[i * bt..(i + 1) * bt])
            {
                entry.shared = true;
                changed = true;
            }
        }
        if changed {
            self.sync_mem();
        }
    }

    /// Load from prefill outputs (`[L, C, KV, hd]` full-capacity buffers)
    /// and set the row count.  Only the first `len` positions are copied
    /// into blocks — the padded tail is masked by every downstream program
    /// and would only waste resident bytes.
    pub fn load_full(&mut self, len: usize, k_full: &[f32], v_full: &[f32]) -> Result<()> {
        let row = self.row();
        let n_layers = self.pool.n_layers();
        let expect = n_layers * self.capacity * row;
        if k_full.len() != expect || v_full.len() != expect {
            bail!("load_full: expected {expect} floats, got {}", k_full.len());
        }
        if len > self.capacity {
            bail!("load_full: len {len} > capacity {}", self.capacity);
        }
        // Grow FIRST (keeping the existing blocks) so pool-exhaustion
        // backpressure leaves the previous contents intact — a caller
        // retrying after the error has not lost the agent's state.
        let need = self.pool.blocks_for(len);
        if need > self.blocks.len() {
            self.ensure_blocks(len)?;
        }
        self.pool.note_rows_removed(self.len);
        self.len = 0;
        while self.blocks.len() > need {
            let b = self.blocks.pop().expect("block table shrank unexpectedly");
            self.pool.release_ref(b.id);
        }
        let bt = self.pool.block_tokens();
        // The `[L, C, KV, hd]` source is exactly the write gate's
        // `[L, n_src, row]` layout with n_src = capacity, so each block is
        // one run at its own source offset.  Prefill is the one
        // legitimately O(len) upload; still per-run, so a short prompt
        // ships a short copy.
        for b in 0..need {
            let start = b * bt;
            let run = (len - start).min(bt);
            let entry = self.blocks[b];
            let target = self
                .pool
                .write_run(entry.id, 0, run, start, self.capacity, k_full, v_full)?;
            if target != entry.id {
                self.blocks[b] = BlockRef {
                    id: target,
                    shared: false,
                    offloaded: false,
                };
            } else if entry.offloaded {
                self.blocks[b].offloaded = false; // write gate paged it in
            }
        }
        self.len = len;
        self.pool.note_rows_added(len);
        self.sync_mem();
        Ok(())
    }

    /// Drop rows beyond `rows`, returning now-empty blocks to the pool
    /// (shared blocks just lose this table's reference — the registry and
    /// other readers keep theirs).
    pub fn truncate(&mut self, rows: usize) {
        if rows >= self.len {
            return;
        }
        self.pool.note_rows_removed(self.len - rows);
        self.len = rows;
        let keep = self.pool.blocks_for(rows);
        while self.blocks.len() > keep {
            let b = self.blocks.pop().expect("block table shrank unexpectedly");
            self.pool.release_ref(b.id);
        }
        self.sync_mem();
    }

    /// Reset to empty.  All blocks go back to the shared pool (the reclaim
    /// path that makes finished agents nearly free).
    pub fn clear(&mut self) {
        self.truncate(0);
    }

    pub fn shape(&self) -> Vec<usize> {
        vec![
            self.pool.n_layers(),
            self.capacity,
            self.pool.kv_heads(),
            self.pool.head_dim(),
        ]
    }

    /// Contiguous `[L, c, KV, hd]` upload buffers for a capacity-`c` decode
    /// tier — the *host-side* block-translation gather.  Since the
    /// device-resident refactor this is the flat reference path (tests,
    /// ablations); the decode hot path uses [`KvCache::device_gather`],
    /// which reads the resident device copies and ships only the block
    /// table.  Requires `len() <= c <= capacity()`.
    pub fn prefix_upload(&self, c: usize) -> (Vec<f32>, Vec<f32>) {
        debug_assert!(self.len <= c && c <= self.capacity);
        let sz = self.pool.n_layers() * c * self.row();
        let mut k = vec![0.0f32; sz];
        let mut v = vec![0.0f32; sz];
        self.pool
            .host_gather_prefix_into(&self.table_ids(), self.len, c, &mut k, &mut v);
        (k, v)
    }

    /// Device block table covering the valid prefix (`len` rows).
    pub fn block_table(&self) -> Vec<u32> {
        let need = self.pool.blocks_for(self.len);
        self.blocks[..need].iter().map(|b| b.id).collect()
    }

    /// Device-addressable view of this cache: block ids + valid length —
    /// the O(k) decode-request payload that replaced the full-capacity
    /// K/V vectors in the batcher channel.
    ///
    /// The view stays valid for as long as the cache is neither mutated
    /// nor dropped; callers that hand it to another thread (the batcher)
    /// must block until the step completes, which the request/reply
    /// protocol guarantees.  Shared blocks in the table are safe to read
    /// concurrently: they are immutable by the CoW invariant, and the
    /// reader's reference keeps them from being evicted or reclaimed.
    pub fn paged(&self) -> PagedKv {
        PagedKv {
            table: self.block_table(),
            len: self.len,
        }
    }

    /// Capacity-`c` decode upload via the device-resident path: resolves
    /// this cache's block table against the pool's device copies
    /// (paged-attention gather).  Bit-identical to
    /// [`KvCache::prefix_upload`] — proven by the flat-vs-paged proptest —
    /// but the per-step host→device cost is the table, not the cache.
    pub fn device_gather(&self, c: usize) -> Result<(Vec<f32>, Vec<f32>)> {
        debug_assert!(self.len <= c && c <= self.capacity);
        self.pool.dev_gather_prefix(&self.block_table(), self.len, c)
    }

    /// Gather arbitrary rows (by position, each `< len`) across all layers
    /// into `[L, n, KV, hd]` buffers — the host-side analogue of the synapse
    /// program's landmark gather, used by the selection-policy ablation.
    pub fn gather_rows(&self, indices: &[usize]) -> (Vec<f32>, Vec<f32>) {
        self.pool.host_gather_rows(&self.table_ids(), indices)
    }

    /// K rows for position range `[start, end)` of a given layer (`end`
    /// clamped to `len`).  Owned: the range may span multiple blocks.
    pub fn k_slice(&self, layer: usize, start: usize, end: usize) -> Vec<f32> {
        self.pool
            .host_slice(&self.table_ids(), layer, start, end.min(self.len), false)
    }

    pub fn v_slice(&self, layer: usize, start: usize, end: usize) -> Vec<f32> {
        self.pool
            .host_slice(&self.table_ids(), layer, start, end.min(self.len), true)
    }
}

impl KvCache {
    /// Deep copy renting fresh blocks from the same pool, surfacing pool
    /// exhaustion as the same backpressure error every growth path returns.
    /// The copy is untracked (no memory guard) — the prism attaches guards
    /// only to registered agents — and fully private: shared table entries
    /// of the source are materialised as owned copies.
    pub fn try_clone(&self) -> Result<KvCache> {
        let mut c = KvCache::with_pool(self.pool.clone(), self.capacity);
        let bt = self.pool.block_tokens();
        for (b, entry) in self.blocks.iter().enumerate() {
            let start = b * bt;
            let valid = if start < self.len {
                (self.len - start).min(bt)
            } else {
                0
            };
            let id = self.pool.clone_block(entry.id, valid)?;
            // The clone always materialises hot: `clone_block` reads the
            // source through its tier view (dequantized / slab-resolved).
            c.blocks.push(BlockRef {
                id,
                shared: false,
                offloaded: false,
            });
        }
        c.len = self.len;
        self.pool.note_rows_added(self.len);
        Ok(c)
    }
}

impl Clone for KvCache {
    /// [`KvCache::try_clone`], panicking on pool exhaustion (`Clone`
    /// cannot surface a `Result`).  Callers running near a configured
    /// `max_blocks` cap should prefer `try_clone`.
    fn clone(&self) -> KvCache {
        self.try_clone()
            .expect("kv pool exhausted while cloning a cache")
    }
}

impl Drop for KvCache {
    fn drop(&mut self) {
        self.pool.note_rows_removed(self.len);
        for b in self.blocks.drain(..) {
            self.pool.release_ref(b.id);
        }
        // `self.mem` drops after this body, releasing the tracked resident
        // bytes (which still equal the private blocks' bytes at this point).
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 192,
            vocab_size: 260,
            head_dim: 16,
            rope_theta: 1e4,
            param_count: 0,
        }
    }

    const ROW: usize = 32; // KV * hd for tiny_cfg

    /// Reference implementation: the seed's flat `[L, C, KV, hd]` layout.
    /// The pooled cache must produce bit-identical gathers against it.
    struct FlatRef {
        k: Vec<f32>,
        v: Vec<f32>,
        n_layers: usize,
        capacity: usize,
        len: usize,
    }

    impl FlatRef {
        fn new(cfg: &ModelConfig, capacity: usize) -> FlatRef {
            FlatRef {
                k: vec![0.0; cfg.n_layers * capacity * ROW],
                v: vec![0.0; cfg.n_layers * capacity * ROW],
                n_layers: cfg.n_layers,
                capacity,
                len: 0,
            }
        }

        fn offset(&self, layer: usize, pos: usize) -> usize {
            (layer * self.capacity + pos) * ROW
        }

        fn append_rows(&mut self, n: usize, k_rows: &[f32], v_rows: &[f32]) {
            for layer in 0..self.n_layers {
                let dst = self.offset(layer, self.len);
                let src = layer * n * ROW;
                self.k[dst..dst + n * ROW].copy_from_slice(&k_rows[src..src + n * ROW]);
                self.v[dst..dst + n * ROW].copy_from_slice(&v_rows[src..src + n * ROW]);
            }
            self.len += n;
        }

        fn prefix_upload(&self, c: usize) -> (Vec<f32>, Vec<f32>) {
            let per = c * ROW;
            let mut k = Vec::with_capacity(self.n_layers * per);
            let mut v = Vec::with_capacity(self.n_layers * per);
            for layer in 0..self.n_layers {
                let off = self.offset(layer, 0);
                k.extend_from_slice(&self.k[off..off + per]);
                v.extend_from_slice(&self.v[off..off + per]);
            }
            (k, v)
        }

        fn gather_rows(&self, indices: &[usize]) -> (Vec<f32>, Vec<f32>) {
            let mut k = Vec::new();
            let mut v = Vec::new();
            for layer in 0..self.n_layers {
                for &pos in indices {
                    let off = self.offset(layer, pos);
                    k.extend_from_slice(&self.k[off..off + ROW]);
                    v.extend_from_slice(&self.v[off..off + ROW]);
                }
            }
            (k, v)
        }
    }

    fn crop_eq(a: &[f32], b: &[f32], what: &str) -> Result<(), String> {
        if a.len() != b.len() {
            return Err(format!("{what}: length {} vs {}", a.len(), b.len()));
        }
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            if x.to_bits() != y.to_bits() {
                return Err(format!("{what}[{i}]: {x} != {y} (not bit-identical)"));
            }
        }
        Ok(())
    }

    #[test]
    fn append_and_slice() {
        let cfg = tiny_cfg();
        let mut kv = KvCache::new(&cfg, 8);
        assert_eq!(kv.len(), 0);
        assert_eq!(kv.bytes(), 0, "empty cache holds no blocks");
        assert_eq!(kv.capacity_bytes(), (2 * 8 * 32 * 2 * 4) as u64);

        let row = 2 * 32; // L * KV*hd
        let k: Vec<f32> = (0..row).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..row).map(|i| -(i as f32)).collect();
        kv.append_row(&k, &v).unwrap();
        kv.append_row(&v, &k).unwrap();
        assert_eq!(kv.len(), 2);
        // layer 1 of the first appended row came from source offset 32.
        assert_eq!(kv.k_slice(1, 0, 1), &k[32..64]);
        assert_eq!(kv.k_slice(1, 1, 2), &v[32..64]);
        // resident bytes: one 16-position block
        assert_eq!(kv.bytes(), kv.pool().block_bytes());
    }

    #[test]
    fn capacity_enforced() {
        let cfg = tiny_cfg();
        let mut kv = KvCache::new(&cfg, 2);
        let row = 2 * 32;
        let k = vec![0.0; row];
        kv.append_row(&k, &k).unwrap();
        kv.append_row(&k, &k).unwrap();
        assert!(kv.append_row(&k, &k).is_err());
        assert_eq!(kv.remaining(), 0);
        kv.clear();
        assert_eq!(kv.remaining(), 2);
        assert_eq!(kv.bytes(), 0, "clear returns blocks to the pool");
    }

    #[test]
    fn append_rows_bulk() {
        let cfg = tiny_cfg();
        let mut kv = KvCache::new(&cfg, 8);
        let n = 3;
        let rows: Vec<f32> = (0..2 * n * 32).map(|i| i as f32).collect();
        kv.append_rows(n, &rows, &rows).unwrap();
        assert_eq!(kv.len(), 3);
        // layer 0 rows are the first n*32 floats
        assert_eq!(kv.k_slice(0, 0, 3), &rows[..96]);
        // layer 1 rows follow
        assert_eq!(kv.k_slice(1, 0, 3), &rows[96..192]);
        assert!(kv
            .append_rows(6, &vec![0.0; 2 * 6 * 32], &vec![0.0; 2 * 6 * 32])
            .is_err());
    }

    #[test]
    fn shape_mismatch_errors() {
        let cfg = tiny_cfg();
        let mut kv = KvCache::new(&cfg, 4);
        assert!(kv.append_row(&[0.0; 3], &[0.0; 3]).is_err());
        assert!(kv.load_full(1, &[0.0; 3], &[0.0; 3]).is_err());
    }

    #[test]
    fn block_translation_matches_flat_layout_bit_identical() {
        // Drive identical random operation sequences through the pooled
        // cache and the seed's flat layout; every gather path must agree
        // bit-for-bit on the valid region.
        let cfg = tiny_cfg();
        check("pooled == flat", 40, |g| {
            let capacity = g.usize_in(4..40);
            let pool = KvPool::new(
                &cfg,
                KvPoolConfig {
                    block_tokens: g.usize_in(1..9),
                    ..KvPoolConfig::default()
                },
            );
            let mut pooled = pool.new_cache(capacity);
            let mut flat = FlatRef::new(&cfg, capacity);
            while pooled.len() < capacity {
                let n = g.usize_in(1..(capacity - pooled.len() + 1));
                let k = g.vec_f32((2 * n * ROW)..(2 * n * ROW + 1), -4.0, 4.0);
                let v = g.vec_f32((2 * n * ROW)..(2 * n * ROW + 1), -4.0, 4.0);
                pooled.append_rows(n, &k, &v).map_err(|e| e.to_string())?;
                flat.append_rows(n, &k, &v);
                if g.bool() {
                    break;
                }
            }
            let len = pooled.len();
            crate::prop_assert!(len == flat.len, "length drift: {len} vs {}", flat.len);

            // prefix_upload at a random tier >= len
            let c = g.usize_in(len.max(1)..(capacity + 1));
            let (pk, pv) = pooled.prefix_upload(c);
            let (fk, fv) = flat.prefix_upload(c);
            // the flat reference carries zeros beyond len too (fresh buffers),
            // so the comparison covers the full tier
            crop_eq(&pk, &fk, "prefix k")?;
            crop_eq(&pv, &fv, "prefix v")?;

            // the device-resident paged gather must agree bit-for-bit with
            // both the host gather and the flat reference — this is the
            // "matching semantics" contract of the stub's paged gather
            let (dk, dv) = pooled.device_gather(c).map_err(|e| e.to_string())?;
            crop_eq(&dk, &fk, "device k")?;
            crop_eq(&dv, &fv, "device v")?;

            // gather_rows over random valid positions
            let idx = g.vec_usize(0..8, 0..len.max(1));
            let idx: Vec<usize> = idx.into_iter().filter(|&i| i < len).collect();
            let (pk, pv) = pooled.gather_rows(&idx);
            let (fk, fv) = flat.gather_rows(&idx);
            crop_eq(&pk, &fk, "gather k")?;
            crop_eq(&pv, &fv, "gather v")?;

            // per-layer range slices
            for layer in 0..cfg.n_layers {
                let got = pooled.k_slice(layer, 0, len);
                let want = &flat.k[flat.offset(layer, 0)..flat.offset(layer, 0) + len * ROW];
                crop_eq(&got, want, "k_slice")?;
            }
            pool.check_invariants()?;
            Ok(())
        });
    }

    #[test]
    fn load_full_copies_only_the_fill() {
        let cfg = tiny_cfg();
        let capacity = 8;
        let pool = KvPool::new(
            &cfg,
            KvPoolConfig {
                block_tokens: 4,
                ..KvPoolConfig::default()
            },
        );
        let mut kv = pool.new_cache(capacity);
        let full: Vec<f32> = (0..2 * capacity * ROW).map(|i| i as f32).collect();
        kv.load_full(5, &full, &full).unwrap();
        assert_eq!(kv.len(), 5);
        assert_eq!(kv.bytes(), 2 * pool.block_bytes(), "5 rows → 2 blocks of 4");
        // valid region matches the flat source
        let (k_up, _) = kv.prefix_upload(capacity);
        for layer in 0..2 {
            let src = &full[layer * capacity * ROW..layer * capacity * ROW + 5 * ROW];
            let dst = &k_up[layer * capacity * ROW..layer * capacity * ROW + 5 * ROW];
            assert_eq!(src, dst);
        }
        // past the fill the upload is zero (masked on device)
        assert!(k_up[5 * ROW..capacity * ROW].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn truncate_releases_blocks() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(
            &cfg,
            KvPoolConfig {
                block_tokens: 2,
                ..KvPoolConfig::default()
            },
        );
        let mut kv = pool.new_cache(10);
        let row = 2 * 32;
        for _ in 0..7 {
            kv.append_row(&vec![1.0; row], &vec![1.0; row]).unwrap();
        }
        assert_eq!(kv.bytes(), 4 * pool.block_bytes()); // ceil(7/2)
        kv.truncate(3);
        assert_eq!(kv.len(), 3);
        assert_eq!(kv.bytes(), 2 * pool.block_bytes());
        let s = pool.stats();
        assert_eq!(s.blocks_live, 2);
        assert_eq!(s.blocks_free, 2);
        // growth after truncation reuses the freed blocks
        for _ in 0..4 {
            kv.append_row(&vec![2.0; row], &vec![2.0; row]).unwrap();
        }
        assert_eq!(pool.stats().blocks_high_water, 4, "no net growth");
    }

    #[test]
    fn pool_exhaustion_surfaces_as_append_error() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(
            &cfg,
            KvPoolConfig {
                block_tokens: 2,
                max_blocks: 2,
                retain_free_blocks: usize::MAX,
                ..KvPoolConfig::default()
            },
        );
        let mut kv = pool.new_cache(64);
        let row = 2 * 32;
        for _ in 0..4 {
            kv.append_row(&vec![0.0; row], &vec![0.0; row]).unwrap();
        }
        let err = kv.append_row(&vec![0.0; row], &vec![0.0; row]).unwrap_err();
        assert!(format!("{err:#}").contains("exhausted"));
        assert_eq!(kv.len(), 4, "failed append must not corrupt the cache");
        // freeing another cache's worth of blocks unblocks growth
        kv.truncate(2);
        assert!(kv.append_row(&vec![0.0; row], &vec![0.0; row]).is_ok());
    }

    #[test]
    fn replace_rows_preserves_state_on_exhaustion() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(
            &cfg,
            KvPoolConfig {
                block_tokens: 2,
                max_blocks: 2,
                retain_free_blocks: usize::MAX,
                ..KvPoolConfig::default()
            },
        );
        let mut kv = pool.new_cache(64);
        // fill 3 rows → 2 blocks (the cap)
        let rows3: Vec<f32> = (0..2 * 3 * 32).map(|i| i as f32).collect();
        kv.append_rows(3, &rows3, &rows3).unwrap();
        // replacing with 5 rows needs a 3rd block → backpressure, and the
        // previous contents must survive the error
        let rows5 = vec![1.0; 2 * 5 * 32];
        assert!(kv.replace_rows(5, &rows5, &rows5).is_err());
        assert_eq!(kv.len(), 3);
        assert_eq!(kv.k_slice(0, 0, 3), &rows3[..96]);
        // replacing within the same block budget succeeds in place
        let rows4: Vec<f32> = (0..2 * 4 * 32).map(|i| -(i as f32)).collect();
        kv.replace_rows(4, &rows4, &rows4).unwrap();
        assert_eq!(kv.len(), 4);
        assert_eq!(kv.k_slice(0, 0, 4), &rows4[..128]);
    }

    #[test]
    fn per_step_upload_is_new_row_plus_table_not_capacity() {
        // The decode hot-path contract: one step's host→device traffic is
        // the freshly produced row (write-through) plus the block table
        // (gather), independent of the configured capacity.
        let cfg = tiny_cfg();
        let pool = KvPool::new(
            &cfg,
            KvPoolConfig {
                block_tokens: 16,
                ..KvPoolConfig::default()
            },
        );
        let capacity = 256;
        let mut kv = pool.new_cache(capacity);
        let row = 2 * 32; // L * KV*hd floats per position
        for _ in 0..40 {
            kv.append_row(&vec![1.0; row], &vec![1.0; row]).unwrap();
        }
        let row_bytes = (row * 2 * 4) as u64; // K+V, f32
        for _ in 0..10 {
            let before = pool.stats().h2d_bytes;
            let (k_up, _v_up) = kv.device_gather(capacity).unwrap();
            assert_eq!(k_up.len(), 2 * capacity * 32);
            kv.append_row(&vec![2.0; row], &vec![2.0; row]).unwrap();
            let delta = pool.stats().h2d_bytes - before;
            let expect = kv.paged().upload_bytes() + row_bytes;
            // table measured after the append may be one entry longer than
            // at gather time (block-boundary steps) — bound both sides
            assert!(
                delta <= expect && delta >= row_bytes + 8,
                "per-step upload {delta} outside [{}, {expect}]",
                row_bytes + 8
            );
            // and it is nowhere near the flat full-capacity re-upload
            assert!(delta * 50 < capacity as u64 * row_bytes);
        }
    }

    #[test]
    fn device_copies_survive_seed_truncate_clear_churn() {
        // Rent/write-through/release churn: after any mix of seeding
        // (replace_rows), truncation and clearing, the device gather stays
        // bit-identical to the host gather and slab slots are recycled
        // rather than leaked.
        let cfg = tiny_cfg();
        check("device churn == host", 30, |g| {
            let pool = KvPool::new(
                &cfg,
                KvPoolConfig {
                    block_tokens: g.usize_in(1..7),
                    ..KvPoolConfig::default()
                },
            );
            let capacity = g.usize_in(6..32);
            let mut kv = pool.new_cache(capacity);
            for _ in 0..g.usize_in(5..25) {
                match g.usize_in(0..4) {
                    0 => {
                        let n = g.usize_in(1..(kv.remaining().max(1) + 1));
                        if n <= kv.remaining() {
                            let k = g.vec_f32((2 * n * ROW)..(2 * n * ROW + 1), -2.0, 2.0);
                            let v = g.vec_f32((2 * n * ROW)..(2 * n * ROW + 1), -2.0, 2.0);
                            kv.append_rows(n, &k, &v).map_err(|e| e.to_string())?;
                        }
                    }
                    1 => {
                        let n = g.usize_in(1..(capacity + 1));
                        let k = g.vec_f32((2 * n * ROW)..(2 * n * ROW + 1), -2.0, 2.0);
                        let v = g.vec_f32((2 * n * ROW)..(2 * n * ROW + 1), -2.0, 2.0);
                        kv.replace_rows(n, &k, &v).map_err(|e| e.to_string())?;
                    }
                    2 => kv.truncate(g.usize_in(0..(kv.len().max(1) + 1))),
                    _ => kv.clear(),
                }
                let (hk, hv) = kv.prefix_upload(capacity);
                let (dk, dv) = kv.device_gather(capacity).map_err(|e| e.to_string())?;
                crate::prop_assert!(
                    hk.iter().zip(&dk).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "device k diverged from host at len {}",
                    kv.len()
                );
                crate::prop_assert!(
                    hv.iter().zip(&dv).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "device v diverged from host at len {}",
                    kv.len()
                );
            }
            let s = pool.stats();
            crate::prop_assert!(
                s.dev_blocks <= s.blocks_high_water,
                "slab leaked: {} device copies > {} high-water blocks",
                s.dev_blocks,
                s.blocks_high_water
            );
            pool.check_invariants()?;
            Ok(())
        });
    }

    #[test]
    fn clone_is_deep_and_reuses_the_pool() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(&cfg, KvPoolConfig::default());
        let mut a = pool.new_cache(8);
        let row = 2 * 32;
        a.append_row(&vec![3.0; row], &vec![4.0; row]).unwrap();
        let b = a.clone();
        assert_eq!(b.len(), 1);
        assert_eq!(a.k_slice(0, 0, 1), b.k_slice(0, 0, 1));
        assert_eq!(pool.stats().blocks_live, 2);
        drop(b);
        assert_eq!(pool.stats().blocks_live, 1);
        assert_eq!(pool.stats().blocks_free, 1);
    }

    // ── Prefix sharing + copy-on-write ─────────────────────────────────

    /// Deterministic `[L, n, KV, hd]` rows derived from `keys` — the
    /// content-addressing contract (same keys ⇒ same rows) made literal.
    fn rows_for_keys(cfg: &ModelConfig, keys: &[i32]) -> (Vec<f32>, Vec<f32>) {
        let n = keys.len();
        let mut k = Vec::with_capacity(cfg.n_layers * n * ROW);
        let mut v = Vec::with_capacity(cfg.n_layers * n * ROW);
        for layer in 0..cfg.n_layers {
            for (pos, &key) in keys.iter().enumerate() {
                for j in 0..ROW {
                    let x = (layer * 1000 + pos * 37 + j) as f32 * 0.01 + key as f32;
                    k.push(x);
                    v.push(-x);
                }
            }
        }
        (k, v)
    }

    #[test]
    fn second_agent_attaches_the_registered_prefix_for_free() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(
            &cfg,
            KvPoolConfig {
                block_tokens: 4,
                ..KvPoolConfig::default()
            },
        );
        let keys: Vec<i32> = (0..10).collect();
        let (k_rows, v_rows) = rows_for_keys(&cfg, &keys);

        // cold: agent A writes and registers the prompt
        let mut a = pool.new_cache(32);
        a.replace_rows_keyed(10, 1, &keys, &k_rows, &v_rows).unwrap();
        assert_eq!(a.len(), 10);
        assert_eq!(a.shared_blocks(), 2, "2 full blocks of 4 published");
        // the registering cache is charged only for its private tail block
        assert_eq!(a.bytes(), pool.block_bytes());
        let s = pool.stats();
        assert_eq!(s.shared_blocks, 2);
        assert_eq!(s.blocks_live, 3);
        let h2d_cold = s.h2d_bytes;

        // warm: agent B seeds the same keys — full blocks attach by
        // reference, only the 2-row tail is written
        let mut b = pool.new_cache(32);
        b.replace_rows_keyed(10, 1, &keys, &k_rows, &v_rows).unwrap();
        assert_eq!(b.len(), 10);
        assert_eq!(b.shared_blocks(), 2);
        assert_eq!(b.bytes(), pool.block_bytes(), "B pays one tail block");
        let s = pool.stats();
        assert_eq!(s.prefix_hits, 2);
        assert_eq!(s.blocks_live, 4, "one prompt, two agents, O(1) extra");
        // the shared rows cost zero additional h2d traffic; only the
        // 2-row tail was written through
        let tail_bytes = (cfg.n_layers * 2 * ROW * 2 * 4) as u64;
        assert_eq!(s.h2d_bytes - h2d_cold, tail_bytes);

        // both caches read identical content, host and device side
        let (ak, av) = a.prefix_upload(32);
        let (bk, bv) = b.prefix_upload(32);
        crop_eq(&ak, &bk, "shared k").unwrap();
        crop_eq(&av, &bv, "shared v").unwrap();
        let (dk, _) = b.device_gather(32).unwrap();
        crop_eq(&dk, &bk, "device k").unwrap();
    }

    #[test]
    fn extend_shared_prefix_adopts_blocks_registered_mid_prefill() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(
            &cfg,
            KvPoolConfig {
                block_tokens: 4,
                ..KvPoolConfig::default()
            },
        );
        let keys: Vec<i32> = (0..12).collect();
        let (k_rows, v_rows) = rows_for_keys(&cfg, &keys);
        let hashes = pool.prefix_hashes(1, &keys);

        // B starts a chunked prefill of the same prompt and has privately
        // filled block 0 when A (the "concurrent twin") finishes and
        // registers the full chain.
        let mut b = pool.new_cache(32);
        let (k0, v0) = rows_for_keys(&cfg, &keys[..4]);
        b.append_rows(4, &k0, &v0).unwrap();

        let mut a = pool.new_cache(32);
        a.replace_rows_keyed(12, 1, &keys, &k_rows, &v_rows).unwrap();

        // Off a block boundary: nothing adoptable.
        let mut c = pool.new_cache(32);
        let (k1, v1) = rows_for_keys(&cfg, &keys[..3]);
        c.append_rows(3, &k1, &v1).unwrap();
        assert_eq!(c.extend_shared_prefix(&hashes, &keys), 0);

        // B, at its boundary, adopts blocks 1 and 2 by reference and jumps
        // its fill over them — the mid-prefill registry hit.
        let adopted = b.extend_shared_prefix(&hashes, &keys);
        assert_eq!(adopted, 8);
        assert_eq!(b.len(), 12);
        assert_eq!(b.shared_blocks(), 2);
        assert_eq!(pool.stats().prefix_mid_hits, 2);

        // Content is bit-identical to the cache that computed every row.
        let (ak, av) = a.prefix_upload(32);
        let (bk, bv) = b.prefix_upload(32);
        crop_eq(&ak, &bk, "mid-adopted k").unwrap();
        crop_eq(&av, &bv, "mid-adopted v").unwrap();

        // A second probe at the same boundary finds nothing new.
        assert_eq!(b.extend_shared_prefix(&hashes, &keys), 0);
    }

    #[test]
    fn cow_divergence_is_isolated_and_bit_identical_to_unshared() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(
            &cfg,
            KvPoolConfig {
                block_tokens: 4,
                ..KvPoolConfig::default()
            },
        );
        let keys: Vec<i32> = (0..8).collect();
        let (k_rows, v_rows) = rows_for_keys(&cfg, &keys);
        let mut a = pool.new_cache(32);
        a.replace_rows_keyed(8, 1, &keys, &k_rows, &v_rows).unwrap();
        let mut b = pool.new_cache(32);
        b.replace_rows_keyed(8, 1, &keys, &k_rows, &v_rows).unwrap();
        let (a_before, _) = a.prefix_upload(32);

        // B truncates into the shared prefix and appends divergent rows —
        // the write lands in a shared block and must copy, not mutate
        b.truncate(6);
        let div: Vec<f32> = (0..2 * ROW).map(|i| 1000.0 + i as f32).collect();
        b.append_row(&div, &div).unwrap();
        assert!(pool.stats().cow_copies >= 1, "shared write must CoW");
        assert_eq!(b.shared_blocks(), 1, "the CoW'd entry went private");

        // A sees exactly what it saw before B diverged
        let (a_after, _) = a.prefix_upload(32);
        crop_eq(&a_before, &a_after, "A after B's divergence").unwrap();

        // and B matches an unshared cache driven through the same ops
        let mut u = pool.new_cache(32);
        u.replace_rows(8, &k_rows, &v_rows).unwrap();
        u.truncate(6);
        u.append_row(&div, &div).unwrap();
        let (bk, bv) = b.prefix_upload(32);
        let (uk, uv) = u.prefix_upload(32);
        crop_eq(&bk, &uk, "diverged k vs unshared").unwrap();
        crop_eq(&bv, &uv, "diverged v vs unshared").unwrap();
        // device side agrees too
        let (dbk, dbv) = b.device_gather(32).unwrap();
        crop_eq(&dbk, &bk, "diverged device k").unwrap();
        crop_eq(&dbv, &bv, "diverged device v").unwrap();

        // a third agent still gets the pristine prefix
        let mut c = pool.new_cache(32);
        c.replace_rows_keyed(8, 1, &keys, &k_rows, &v_rows).unwrap();
        let (ck, _) = c.prefix_upload(32);
        crop_eq(&ck, &a_after, "fresh attach after divergence").unwrap();
    }

    #[test]
    fn shared_prefix_survives_every_owner_dropping() {
        let cfg = tiny_cfg();
        let pool = KvPool::new(
            &cfg,
            KvPoolConfig {
                block_tokens: 4,
                ..KvPoolConfig::default()
            },
        );
        let keys: Vec<i32> = (0..8).collect();
        let (k_rows, v_rows) = rows_for_keys(&cfg, &keys);
        {
            let mut a = pool.new_cache(32);
            a.replace_rows_keyed(8, 1, &keys, &k_rows, &v_rows).unwrap();
        }
        // the registering cache is gone; its full blocks park in the
        // registry and a new agent still attaches them
        assert_eq!(pool.stats().blocks_live, 2, "registered blocks parked");
        let mut b = pool.new_cache(32);
        b.replace_rows_keyed(8, 1, &keys, &k_rows, &v_rows).unwrap();
        assert_eq!(pool.stats().prefix_hits, 2);
        let (bk, _) = b.prefix_upload(32);
        let (want, _) = rows_for_keys(&cfg, &keys);
        // layer 0 of the gather equals layer 0 of the canonical rows
        crop_eq(&bk[..8 * ROW], &want[..8 * ROW], "parked reattach").unwrap();
    }

    #[test]
    fn shared_churn_matches_unshared_baseline_bit_identical() {
        // The CoW/refcount proptest: interleave spawn/append/truncate/
        // clear/release across caches sharing one registered prefix, each
        // mirrored by an unshared twin in a separate pool.  Every gather
        // must stay bit-identical twin-to-twin (so a referenced block was
        // never freed or mutated), and the shared pool must hold fewer
        // live blocks than the unshared one whenever several caches share.
        let cfg = tiny_cfg();
        check("shared churn == unshared", 30, |g| {
            let bt = g.usize_in(1..7);
            let mk_pool = || {
                KvPool::new(
                    &cfg,
                    KvPoolConfig {
                        block_tokens: bt,
                        ..KvPoolConfig::default()
                    },
                )
            };
            let pool_s = mk_pool(); // shared (keyed) caches
            let pool_u = mk_pool(); // unshared twins
            let capacity = g.usize_in(8..32);
            let seed_n = g.usize_in(1..(capacity + 1));
            let keys: Vec<i32> = (0..seed_n as i32).map(|i| i * 3 + 1).collect();
            let (seed_k, seed_v) = rows_for_keys(&cfg, &keys);

            let mut pairs: Vec<(KvCache, KvCache)> = Vec::new();
            for _ in 0..g.usize_in(4..20) {
                let op = g.usize_in(0..6);
                if pairs.is_empty() || op == 0 {
                    // spawn: keyed seed vs plain replace
                    let mut s = pool_s.new_cache(capacity);
                    s.replace_rows_keyed(seed_n, 9, &keys, &seed_k, &seed_v)
                        .map_err(|e| e.to_string())?;
                    let mut u = pool_u.new_cache(capacity);
                    u.replace_rows(seed_n, &seed_k, &seed_v)
                        .map_err(|e| e.to_string())?;
                    pairs.push((s, u));
                } else if op == 5 {
                    // release a pair entirely
                    let i = g.usize_in(0..pairs.len());
                    pairs.swap_remove(i);
                } else {
                    let i = g.usize_in(0..pairs.len());
                    let (s, u) = &mut pairs[i];
                    match op {
                        1 => {
                            // append divergent rows to both twins
                            let room = s.remaining();
                            if room > 0 {
                                let n = g.usize_in(1..(room.min(4) + 1));
                                let k = g.vec_f32((2 * n * ROW)..(2 * n * ROW + 1), -2.0, 2.0);
                                let v = g.vec_f32((2 * n * ROW)..(2 * n * ROW + 1), -2.0, 2.0);
                                s.append_rows(n, &k, &v).map_err(|e| e.to_string())?;
                                u.append_rows(n, &k, &v).map_err(|e| e.to_string())?;
                            }
                        }
                        2 => {
                            let to = g.usize_in(0..(s.len().max(1) + 1));
                            s.truncate(to);
                            u.truncate(to);
                        }
                        3 => {
                            s.clear();
                            u.clear();
                        }
                        _ => {
                            // re-seed in place (the side-agent reuse path)
                            s.replace_rows_keyed(seed_n, 9, &keys, &seed_k, &seed_v)
                                .map_err(|e| e.to_string())?;
                            u.replace_rows(seed_n, &seed_k, &seed_v)
                                .map_err(|e| e.to_string())?;
                        }
                    }
                }
                // every live pair stays bit-identical, host and device
                for (s, u) in &pairs {
                    crate::prop_assert!(
                        s.len() == u.len(),
                        "len drift {} vs {}",
                        s.len(),
                        u.len()
                    );
                    let (sk, sv) = s.prefix_upload(capacity);
                    let (uk, uv) = u.prefix_upload(capacity);
                    crop_eq(&sk, &uk, "twin k")?;
                    crop_eq(&sv, &uv, "twin v")?;
                    let (dk, dv) = s.device_gather(capacity).map_err(|e| e.to_string())?;
                    crop_eq(&dk, &sk, "twin device k")?;
                    crop_eq(&dv, &sv, "twin device v")?;
                }
            }
            // sharing must not cost more blocks than not sharing, and with
            // several sharers it must cost strictly fewer for the seeded
            // prefix (each twin pays the full seed, sharers pay the tail)
            let ss = pool_s.stats();
            let us = pool_u.stats();
            crate::prop_assert!(
                ss.blocks_live <= us.blocks_live + seed_n / bt + 1,
                "sharing used more blocks: {} vs {}",
                ss.blocks_live,
                us.blocks_live
            );
            if pairs.len() >= 3 && seed_n / bt >= 2 {
                // strict dedup is only guaranteed while every sharer still
                // holds the full shared prefix (CoW legitimately privatises
                // blocks after divergence)
                let all_seeded = pairs
                    .iter()
                    .all(|(s, _)| s.len() == seed_n && s.shared_blocks() == seed_n / bt);
                if all_seeded {
                    crate::prop_assert!(
                        ss.blocks_live < us.blocks_live,
                        "no dedup despite {} sharers: {} vs {}",
                        pairs.len(),
                        ss.blocks_live,
                        us.blocks_live
                    );
                }
            }
            // a referenced block is never freed: every pair drop must leave
            // the pools consistent (parked registrations may remain live)
            drop(pairs);
            let ss = pool_s.stats();
            crate::prop_assert!(
                ss.blocks_live == ss.shared_blocks,
                "only parked registry entries may stay live: {} vs {}",
                ss.blocks_live,
                ss.shared_blocks
            );
            crate::prop_assert!(
                pool_u.stats().blocks_live == 0,
                "unshared pool leaked blocks"
            );
            pool_s.check_invariants()?;
            pool_u.check_invariants()?;
            Ok(())
        });
    }

    // ── Memory tiers: park to host / resume ────────────────────────────

    fn tiered_pool(slab: usize) -> Arc<KvPool> {
        KvPool::new(
            &tiny_cfg(),
            KvPoolConfig {
                block_tokens: 4,
                host_slab_blocks: slab,
                ..KvPoolConfig::default()
            },
        )
    }

    #[test]
    fn park_to_host_and_resume_round_trip_bit_identical() {
        let pool = tiered_pool(8);
        let mut kv = pool.new_cache(8);
        let rows6: Vec<f32> = (0..2 * 6 * ROW).map(|i| (i as f32 * 0.7).sin()).collect();
        kv.append_rows(6, &rows6, &rows6).unwrap();
        assert_eq!(kv.bytes(), 2 * pool.block_bytes());
        let (bk, bv) = kv.device_gather(8).unwrap();

        assert_eq!(kv.park_to_host().unwrap(), 2);
        assert_eq!(kv.offloaded_blocks(), 2);
        assert_eq!(kv.bytes(), 0, "parked context costs no device bytes");
        let s = pool.stats();
        assert_eq!(s.offloaded_blocks, 2);
        assert_eq!(s.host_slab_bytes, 2 * pool.block_bytes());
        // cold blocks refuse device gathers but host reads resolve through
        // the slab, verbatim
        assert!(kv.device_gather(8).is_err());
        let (hk, hv) = kv.prefix_upload(8);
        crop_eq(&hk, &bk, "parked host k").unwrap();
        crop_eq(&hv, &bv, "parked host v").unwrap();
        // a second park is a no-op
        assert_eq!(kv.park_to_host().unwrap(), 0);

        assert_eq!(kv.resume_from_host().unwrap(), 2);
        assert_eq!(kv.offloaded_blocks(), 0);
        assert_eq!(kv.bytes(), 2 * pool.block_bytes());
        // the resume round trip is lossless: decode state is bit-identical
        let (ak, av) = kv.device_gather(8).unwrap();
        crop_eq(&ak, &bk, "resumed k").unwrap();
        crop_eq(&av, &bv, "resumed v").unwrap();
        let s = pool.stats();
        assert_eq!(s.swap_in_bytes, s.swap_out_bytes);
        assert_eq!(s.resume_page_ins, 2);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn writes_into_a_parked_cache_page_in_transparently() {
        let pool = tiered_pool(8);
        let mut kv = pool.new_cache(16);
        let rows6 = vec![1.5; 2 * 6 * ROW];
        kv.append_rows(6, &rows6, &rows6).unwrap();
        assert_eq!(kv.park_to_host().unwrap(), 2);
        // the append lands in block 1 (rows 4..6 + the new row): the write
        // gate pages exactly that block back in; block 0 stays cold
        let row = vec![2.5; 2 * ROW];
        kv.append_row(&row, &row).unwrap();
        assert_eq!(kv.offloaded_blocks(), 1);
        assert_eq!(kv.bytes(), pool.block_bytes());
        assert_eq!(pool.stats().offloaded_blocks, 1);
        // resume brings back the rest
        assert_eq!(kv.resume_from_host().unwrap(), 1);
        assert_eq!(kv.bytes(), 2 * pool.block_bytes());
        pool.check_invariants().unwrap();
    }

    #[test]
    fn park_skips_shared_registry_entries() {
        let pool = tiered_pool(8);
        let keys: Vec<i32> = (0..10).collect();
        let (k_rows, v_rows) = rows_for_keys(&tiny_cfg(), &keys);
        let mut kv = pool.new_cache(32);
        kv.replace_rows_keyed(10, 1, &keys, &k_rows, &v_rows).unwrap();
        assert_eq!(kv.shared_blocks(), 2);
        // only the private tail block parks; the registry entries stay
        // addressable for other readers
        assert_eq!(kv.park_to_host().unwrap(), 1);
        assert_eq!(kv.shared_blocks(), 2);
        assert_eq!(pool.stats().offloaded_blocks, 1);
        assert_eq!(kv.resume_from_host().unwrap(), 1);
        pool.check_invariants().unwrap();
    }

    #[test]
    fn park_surfaces_slab_exhaustion_and_keeps_the_cache_consistent() {
        let pool = tiered_pool(1);
        let mut kv = pool.new_cache(8);
        let rows8 = vec![0.25; 2 * 8 * ROW];
        kv.append_rows(8, &rows8, &rows8).unwrap();
        // two private blocks, a one-block slab: the first parks, the
        // second bails — and the error leaves the table consistent
        let err = kv.park_to_host().unwrap_err();
        assert!(format!("{err:#}").contains("host slab full"));
        assert_eq!(kv.offloaded_blocks(), 1);
        assert_eq!(kv.bytes(), pool.block_bytes());
        // resume undoes the partial park
        assert_eq!(kv.resume_from_host().unwrap(), 1);
        assert_eq!(kv.offloaded_blocks(), 0);
        pool.check_invariants().unwrap();
    }
}
