//! Host-side KV cache buffers.
//!
//! Each agent owns one `KvCache` pair of flat row-major buffers shaped
//! `[L, C, KV, hd]` (matching the AOT program ABI).  The coordinator appends
//! rows as decoding proceeds and uploads the buffers with each decode op.
//! Every byte held here is accounted by `cortex::memory` — these buffers ARE
//! the per-agent context cost of Table 2.

use anyhow::{bail, Result};

use crate::runtime::{HostTensor, ModelConfig};

/// A fixed-capacity KV cache for one agent.
#[derive(Debug, Clone)]
pub struct KvCache {
    /// `[L, C, KV, hd]` keys, row-major.
    k: Vec<f32>,
    /// `[L, C, KV, hd]` values.
    v: Vec<f32>,
    n_layers: usize,
    capacity: usize,
    kv_heads: usize,
    row: usize, // KV * hd floats per (layer, position)
    len: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig, capacity: usize) -> KvCache {
        let row = cfg.n_kv_heads * cfg.head_dim;
        let total = cfg.n_layers * capacity * row;
        KvCache {
            k: vec![0.0; total],
            v: vec![0.0; total],
            n_layers: cfg.n_layers,
            capacity,
            kv_heads: cfg.n_kv_heads,
            row,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn remaining(&self) -> usize {
        self.capacity - self.len
    }

    /// Bytes held by this cache (both K and V buffers) — the Table-2 unit.
    pub fn bytes(&self) -> u64 {
        (self.k.len() + self.v.len()) as u64 * 4
    }

    /// Bytes actually in use (`len` rows).
    pub fn used_bytes(&self) -> u64 {
        (self.n_layers * self.len * self.row * 2) as u64 * 4
    }

    fn offset(&self, layer: usize, pos: usize) -> usize {
        (layer * self.capacity + pos) * self.row
    }

    /// Append one position's K/V rows.  `k_new`/`v_new` are `[L, KV, hd]`.
    pub fn append_row(&mut self, k_new: &[f32], v_new: &[f32]) -> Result<()> {
        if self.len >= self.capacity {
            bail!("kv cache full ({} rows)", self.capacity);
        }
        if k_new.len() != self.n_layers * self.row || v_new.len() != k_new.len() {
            bail!(
                "append_row: expected {} floats, got {}",
                self.n_layers * self.row,
                k_new.len()
            );
        }
        for layer in 0..self.n_layers {
            let dst = self.offset(layer, self.len);
            let src = layer * self.row;
            self.k[dst..dst + self.row].copy_from_slice(&k_new[src..src + self.row]);
            self.v[dst..dst + self.row].copy_from_slice(&v_new[src..src + self.row]);
        }
        self.len += 1;
        Ok(())
    }

    /// Append `n` positions from `[L, n, KV, hd]` buffers (synapse loads,
    /// prefill copy-in, referential injection).
    pub fn append_rows(&mut self, n: usize, k_rows: &[f32], v_rows: &[f32]) -> Result<()> {
        if self.len + n > self.capacity {
            bail!(
                "kv cache overflow: {} + {n} > {}",
                self.len,
                self.capacity
            );
        }
        let expect = self.n_layers * n * self.row;
        if k_rows.len() != expect || v_rows.len() != expect {
            bail!("append_rows: expected {expect} floats, got {}", k_rows.len());
        }
        for layer in 0..self.n_layers {
            let dst = self.offset(layer, self.len);
            let src = layer * n * self.row;
            let count = n * self.row;
            self.k[dst..dst + count].copy_from_slice(&k_rows[src..src + count]);
            self.v[dst..dst + count].copy_from_slice(&v_rows[src..src + count]);
        }
        self.len += n;
        Ok(())
    }

    /// Overwrite the whole buffer from prefill outputs (`[L, C, KV, hd]`)
    /// and set the row count.
    pub fn load_full(&mut self, len: usize, k_full: &[f32], v_full: &[f32]) -> Result<()> {
        if k_full.len() != self.k.len() || v_full.len() != self.v.len() {
            bail!(
                "load_full: expected {} floats, got {}",
                self.k.len(),
                k_full.len()
            );
        }
        if len > self.capacity {
            bail!("load_full: len {len} > capacity {}", self.capacity);
        }
        self.k.copy_from_slice(k_full);
        self.v.copy_from_slice(v_full);
        self.len = len;
        Ok(())
    }

    /// Reset to empty (buffers retained — no reallocation on the hot path).
    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// Tensor views for a decode upload.
    pub fn k_tensor(&self) -> HostTensor {
        HostTensor::f32(
            self.k.clone(),
            vec![self.n_layers, self.capacity, self.row_kv(), self.head_dim()],
        )
    }

    pub fn v_tensor(&self) -> HostTensor {
        HostTensor::f32(
            self.v.clone(),
            vec![self.n_layers, self.capacity, self.row_kv(), self.head_dim()],
        )
    }

    /// Raw access for batching (the batcher packs several caches into one
    /// `[B, L, C, KV, hd]` upload without intermediate tensors).
    pub fn k_raw(&self) -> &[f32] {
        &self.k
    }

    pub fn v_raw(&self) -> &[f32] {
        &self.v
    }

    pub fn shape(&self) -> Vec<usize> {
        vec![self.n_layers, self.capacity, self.row_kv(), self.head_dim()]
    }

    // The row split (KV heads vs head_dim) is only needed to shape uploads;
    // store the product and derive the split lazily from construction.
    fn row_kv(&self) -> usize {
        self.kv_heads
    }

    fn head_dim(&self) -> usize {
        self.row / self.kv_heads
    }
}

// NOTE: `kv_heads` retained separately for shaping uploads.
// (declared after methods for readability)
impl KvCache {
    /// Copy the first `c` positions of each layer into fresh `[L, c, KV, hd]`
    /// buffers — the upload for a capacity-`c` decode tier (§Perf opt A).
    /// Requires `len() <= c <= capacity()`.
    pub fn prefix_upload(&self, c: usize) -> (Vec<f32>, Vec<f32>) {
        debug_assert!(self.len <= c && c <= self.capacity);
        let per = c * self.row;
        let mut k = Vec::with_capacity(self.n_layers * per);
        let mut v = Vec::with_capacity(self.n_layers * per);
        for layer in 0..self.n_layers {
            let off = self.offset(layer, 0);
            k.extend_from_slice(&self.k[off..off + per]);
            v.extend_from_slice(&self.v[off..off + per]);
        }
        (k, v)
    }

    /// Gather arbitrary rows (by position) across all layers into
    /// `[L, n, KV, hd]` buffers — the host-side analogue of the synapse
    /// program's landmark gather, used by the selection-policy ablation.
    pub fn gather_rows(&self, indices: &[usize]) -> (Vec<f32>, Vec<f32>) {
        let n = indices.len();
        let mut k = Vec::with_capacity(self.n_layers * n * self.row);
        let mut v = Vec::with_capacity(self.n_layers * n * self.row);
        for layer in 0..self.n_layers {
            for &pos in indices {
                let off = self.offset(layer, pos);
                k.extend_from_slice(&self.k[off..off + self.row]);
                v.extend_from_slice(&self.v[off..off + self.row]);
            }
        }
        (k, v)
    }

    /// K rows for position range `[start, end)` of a given layer.
    pub fn k_slice(&self, layer: usize, start: usize, end: usize) -> &[f32] {
        let a = self.offset(layer, start);
        let b = self.offset(layer, end.min(self.len));
        &self.k[a..b]
    }

    pub fn v_slice(&self, layer: usize, start: usize, end: usize) -> &[f32] {
        let a = self.offset(layer, start);
        let b = self.offset(layer, end.min(self.len));
        &self.v[a..b]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 192,
            vocab_size: 260,
            head_dim: 16,
            rope_theta: 1e4,
            param_count: 0,
        }
    }

    #[test]
    fn append_and_slice() {
        let cfg = tiny_cfg();
        let mut kv = KvCache::new(&cfg, 8);
        assert_eq!(kv.len(), 0);
        assert_eq!(kv.bytes(), (2 * 8 * 32 * 2 * 4) as u64);

        let row = 2 * 32; // L * KV*hd
        let k: Vec<f32> = (0..row).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..row).map(|i| -(i as f32)).collect();
        kv.append_row(&k, &v).unwrap();
        kv.append_row(&v, &k).unwrap();
        assert_eq!(kv.len(), 2);
        // layer 1, position 0 starts at offset (1*8+0)*32 in flat buffer;
        // source layer 1 starts at 32.
        assert_eq!(kv.k_slice(1, 0, 1), &k[32..64]);
        assert_eq!(kv.k_slice(1, 1, 2), &v[32..64]);
    }

    #[test]
    fn capacity_enforced() {
        let cfg = tiny_cfg();
        let mut kv = KvCache::new(&cfg, 2);
        let row = 2 * 32;
        let k = vec![0.0; row];
        kv.append_row(&k, &k).unwrap();
        kv.append_row(&k, &k).unwrap();
        assert!(kv.append_row(&k, &k).is_err());
        assert_eq!(kv.remaining(), 0);
        kv.clear();
        assert_eq!(kv.remaining(), 2);
    }

    #[test]
    fn append_rows_bulk() {
        let cfg = tiny_cfg();
        let mut kv = KvCache::new(&cfg, 8);
        let n = 3;
        let rows: Vec<f32> = (0..2 * n * 32).map(|i| i as f32).collect();
        kv.append_rows(n, &rows, &rows).unwrap();
        assert_eq!(kv.len(), 3);
        // layer 0 rows are the first n*32 floats
        assert_eq!(kv.k_slice(0, 0, 3), &rows[..96]);
        // layer 1 rows follow
        assert_eq!(kv.k_slice(1, 0, 3), &rows[96..192]);
        assert!(kv.append_rows(6, &vec![0.0; 2 * 6 * 32], &vec![0.0; 2 * 6 * 32]).is_err());
    }

    #[test]
    fn shape_mismatch_errors() {
        let cfg = tiny_cfg();
        let mut kv = KvCache::new(&cfg, 4);
        assert!(kv.append_row(&[0.0; 3], &[0.0; 3]).is_err());
        assert!(kv.load_full(1, &[0.0; 3], &[0.0; 3]).is_err());
    }
}
